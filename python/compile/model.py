"""L2: array-level analog model of the ADRA FeFET substrate.

Each public function here is an AOT entry point: ``aot.py`` lowers it once
to HLO text under ``artifacts/`` and the Rust runtime executes it over PJRT
on the request path.  All functions are shape-static (N_COLS columns,
N_SWEEP sweep points), return tuples, and call the L1 Pallas kernels — so
the kernels lower into the same HLO module.

Entry points
------------
``dc_isl``          DC senseline operating point (Fig. 1(c) / 3(c) tables,
                    current-based sensing, Monte-Carlo variation).
``transient_cim``   RBL discharge trajectory (voltage-based sensing,
                    schemes 1 and 2) + charge/energy integrals.
``iv_sweep``        quasi-static I_D-V_G hysteresis of one device
                    (Fig. 2(c) calibration curve).
``write_transient`` polarization dynamics under a write pulse train
                    (V_SET / V_RESET), per column.
``read_disturb``    polarization drift under a sustained read bias —
                    the ablation for the V_GREAD < V_C design rule.
"""

import jax
import jax.numpy as jnp

from .params import PARAMS as P, N_COLS, N_SWEEP
from .kernels import (
    fefet_current_kernel,
    miller_step_kernel,
    rbl_step_kernel,
    senseline_kernel,
)


def _cols(x):
    return jnp.broadcast_to(jnp.asarray(x, jnp.float32), (N_COLS,))


def dc_isl(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2):
    """DC senseline currents for a dual-row activation.

    Args (all float32): ``pol_a``/``pol_b`` — stored polarization planes
    ``(N_COLS,)``; ``dvt_a``/``dvt_b`` — per-cell V_T variation offsets
    ``(N_COLS,)``; ``vg1``/``vg2`` — scalar WL assertion voltages.
    Passing ``vg1 == vg2`` reproduces the symmetric prior-work scheme
    (baseline, Fig. 1); ``vg1 < vg2`` is ADRA (Fig. 3).

    Returns ``(i_sl, i_a, i_b)`` each ``(N_COLS,)`` in amperes, at the
    full-rail operating point V_DS = V_READ.
    """
    isl, ia, ib = senseline_kernel(
        pol_a, pol_b, _cols(vg1), _cols(vg2), _cols(P.v_read),
        dvt_a, dvt_b, n=N_COLS,
    )
    return isl, ia, ib


def transient_cim(pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, v0, c_rbl):
    """RBL discharge transient for voltage-based sensing.

    The read bitline starts at ``v0`` (= V_READ for scheme 1/2 after
    precharge) and discharges through both selected cells for
    ``P.n_steps`` steps of ``P.t_step``.

    Args: polarization/variation planes as in :func:`dc_isl`; ``vg1``,
    ``vg2`` scalar WL voltages; ``v0`` scalar initial RBL voltage;
    ``c_rbl`` scalar total RBL capacitance (farads — array-size dependent,
    supplied by the Rust side).

    Returns ``(v_trace, v_final, q_drawn, e_diss)``:
      * ``v_trace``  — ``(n_steps, N_COLS)`` RBL voltage trajectory,
      * ``v_final``  — ``(N_COLS,)`` voltage at the sense instant,
      * ``q_drawn``  — ``(N_COLS,)`` integral of I_SL dt (coulombs),
      * ``e_diss``   — ``(N_COLS,)`` integral of I_SL * V_RBL dt (joules).
    """
    c_cols = _cols(c_rbl)
    dt_cols = _cols(P.t_step)
    vg1_cols, vg2_cols = _cols(vg1), _cols(vg2)

    def step(carry, _):
        v, q, e = carry
        v_next, i_sl = rbl_step_kernel(
            v, pol_a, pol_b, vg1_cols, vg2_cols, c_cols, dt_cols,
            dvt_a, dvt_b, n=N_COLS,
        )
        q = q + i_sl * P.t_step
        e = e + i_sl * v * P.t_step
        return (v_next, q, e), v_next

    zeros = jnp.zeros((N_COLS,), jnp.float32)
    init = (_cols(v0), zeros, zeros)
    (v_final, q_drawn, e_diss), v_trace = jax.lax.scan(
        step, init, None, length=P.n_steps
    )
    return v_trace, v_final, q_drawn, e_diss


def iv_sweep(vg_trace):
    """Quasi-static I_D-V_G hysteresis sweep of a single FeFET (Fig. 2(c)).

    ``vg_trace`` — ``(N_SWEEP,)`` gate-voltage waveform (the Rust side
    passes a triangular +-V sweep).  Each point applies the gate bias for
    ``P.t_step * 50`` (long enough for the lagged Miller dynamics to act)
    then samples I_D at a small V_DS = 50 mV, as in the measurement that
    calibrated the original compact model.

    Returns ``(i_d, pol)`` each ``(N_SWEEP,)``.
    """
    dwell = P.t_step * 50.0

    def step(pol, vg):
        vg1 = jnp.broadcast_to(vg, (1,)).astype(jnp.float32)
        pol_next = miller_step_kernel(pol, vg1, jnp.full((1,), dwell), n=1)
        i_d = fefet_current_kernel(
            vg1, jnp.full((1,), 0.05, jnp.float32), pol_next,
            jnp.zeros((1,)), n=1,
        )
        return pol_next, (i_d[0], pol_next[0])

    pol0 = jnp.full((1,), -P.p_store * P.ps, jnp.float32)
    _, (i_d, pol) = jax.lax.scan(step, pol0, vg_trace)
    return i_d, pol


def write_transient(pol0, vg_pulse):
    """Polarization dynamics of a column under a shared write waveform.

    ``pol0`` — ``(N_COLS,)`` initial polarizations; ``vg_pulse`` —
    ``(N_SWEEP,)`` gate waveform applied to the whole row (e.g. a V_SET
    or V_RESET pulse with rise/fall).  Returns ``(pol_final, pol_trace)``
    with ``pol_trace`` of shape ``(N_SWEEP, N_COLS)``.  Each waveform point
    dwells for ``t_step * 50`` (same quasi-static cadence as
    :func:`iv_sweep`), so a half-N_SWEEP pulse is ~256 ns >> tau_fe.
    """
    dt = jnp.full((N_COLS,), P.t_step * 50.0, jnp.float32)

    def step(pol, vg):
        pol_next = miller_step_kernel(pol, _cols(vg), dt, n=N_COLS)
        return pol_next, pol_next

    pol_final, pol_trace = jax.lax.scan(step, pol0, vg_pulse)
    return pol_final, pol_trace


def read_disturb(pol0):
    """Polarization drift under a sustained read bias (V_GREAD2, worst case).

    Applies the stronger read wordline voltage for N_SWEEP dwell steps and
    reports the polarization trajectory — quantifies the read-disturb
    margin implied by the V_GREAD < V_C design rule (paper §II.B).

    Returns ``(pol_final, pol_trace)``.
    """
    dt = jnp.full((N_COLS,), P.t_step * 50.0, jnp.float32)
    vg = _cols(P.v_gread2)

    def step(pol, _):
        pol_next = miller_step_kernel(pol, vg, dt, n=N_COLS)
        return pol_next, pol_next

    pol_final, pol_trace = jax.lax.scan(step, pol0, None, length=N_SWEEP)
    return pol_final, pol_trace
