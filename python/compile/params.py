"""Device and bias parameters for the ADRA FeFET substrate.

These constants are the single source of truth on the Python (build-time)
side and are mirrored *exactly* by ``rust/src/config/defaults.rs``.  The
integration test ``rust/tests/hlo_cross_validation.rs`` executes the AOT
artifacts and checks the Rust behavioral model against them to 1e-5, which
is what keeps the two copies honest.

Values correspond to the paper's Fig. 2(b) simulation setup: an
experimentally-calibrated Hf0.5Zr0.5O2 (HZO) FeFET on a 45 nm PTM FET, and
the Section IV bias conditions (V_READ = 1 V, V_GREAD1 = 0.83 V,
V_GREAD2 = 1 V, V_SET = 3.7 V, V_RESET = -5 V).  Where the paper text does
not give a number (e.g. per-cell bitline capacitance) we use
technology-typical values and record the choice in DESIGN.md section 2.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class FeFETParams:
    # ---- 45 nm FET (alpha-power law + smooth subthreshold) ----
    vdd: float = 1.0          # V, supply
    phi_t: float = 0.0259     # V, thermal voltage at 300 K
    n_ss: float = 1.5         # subthreshold slope factor
    alpha_sat: float = 1.3    # alpha-power exponent (velocity saturation)
    k_fet: float = 6.0e-5     # A / V^alpha, per-cell drive strength
    v_dsat: float = 0.3       # V, triode->saturation knee

    # ---- HZO ferroelectric layer (Miller / Preisach-lite) ----
    t_fe: float = 8e-9        # m, ferroelectric thickness
    ps: float = 0.25          # C/m^2  (25 uC/cm^2), saturation polarization
    pr: float = 0.20          # C/m^2  (20 uC/cm^2), remanent polarization
    ec: float = 1.2e8         # V/m    (1.2 MV/cm), coercive field
    eps_fe: float = 30.0      # background relative permittivity
    tau_fe: float = 5e-9      # s, polarization response lag (R_FE = tau/C_FE)
    kappa_fe: float = 0.5     # gate divider: V_FE = kappa_fe * V_G

    # ---- FeFET threshold map ----
    vt0: float = 0.65         # V, mid polarization threshold
    dvt_mw: float = 0.8       # V, memory window (VT swing for P = -Ps..+Ps)
    p_store: float = 0.8      # stored state = +-p_store * Ps after write relax

    # ---- Section IV bias conditions ----
    v_read: float = 1.0       # V, RBL read voltage
    v_gread1: float = 0.83    # V, WL1 (word A) assertion — the *asymmetric* bias
    v_gread2: float = 1.0     # V, WL2 (word B) assertion
    v_set: float = 3.7        # V, write +P (LRS)
    v_reset: float = -5.0     # V, write -P (HRS)

    # ---- Array electricals (per cell) ----
    c_rbl_cell: float = 0.2e-15   # F, RBL capacitance contributed per row
    c_wl_cell: float = 0.15e-15   # F, WL capacitance contributed per column
    t_step: float = 0.02e-9       # s, transient integration step
    n_steps: int = 128            # transient steps (t_sense = 2.56 ns window)

    @property
    def sigma_e(self) -> float:
        """Miller domain-spread parameter, eq. (2) of the paper."""
        import math

        return self.ec / math.log((self.ps + self.pr) / (self.ps - self.pr))


PARAMS = FeFETParams()

# Static column count for the AOT artifacts.  HLO is shape-static; the Rust
# runtime pads narrower operations up to this width (rust/src/runtime/).
N_COLS = 1024
# Static time-trace length for the I-V hysteresis sweep artifact.
N_SWEEP = 512
