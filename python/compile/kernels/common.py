"""Shared Pallas plumbing: block-size selection and 1-D elementwise grids.

The ADRA analog evaluations are all column-parallel over a row pair (up to
1024 columns), so every kernel uses the same 1-D HBM->VMEM schedule: the
column axis is split into VMEM-resident blocks and the grid walks the
blocks.  On a real TPU each block maps onto VPU lanes; ``interpret=True``
reproduces the numerics on CPU.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default column-block width.  256 f32 columns x ~8 operand planes is
#: 8 KiB of VMEM per step — far under the ~16 MiB VMEM budget, chosen so the
#: grid still exposes parallelism at the 1024-column artifact width (see
#: EXPERIMENTS.md §Perf L1 for the block sweep).
DEFAULT_BLOCK = 256


def pick_block(n: int, requested: int | None = None) -> int:
    """Largest power-of-two block <= DEFAULT_BLOCK (or `requested`) dividing n."""
    cap = requested or DEFAULT_BLOCK
    b = 1
    while b * 2 <= cap and n % (b * 2) == 0:
        b *= 2
    return b if n % b == 0 else n


def elementwise_call(kernel_body, n_out: int, n: int, block_size: int | None,
                     *arrays):
    """Run ``kernel_body`` over 1-D arrays with a block/grid schedule.

    ``kernel_body(*in_refs, *out_refs)`` sees VMEM blocks of shape
    ``(block,)``.  All inputs must already be shape ``(n,)`` float32.
    Returns the ``n_out`` outputs (a single array if ``n_out == 1``).
    """
    block = pick_block(n, block_size)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_shape = tuple(
        jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(n_out)
    )
    out = pl.pallas_call(
        kernel_body,
        grid=grid,
        in_specs=[spec] * len(arrays),
        out_specs=tuple(spec for _ in range(n_out)),
        out_shape=out_shape,
        interpret=True,
    )(*arrays)
    return out[0] if n_out == 1 else out


def as_cols(x, n: int):
    """Broadcast a scalar or (n,) array to a float32 (n,) column vector."""
    return jnp.broadcast_to(jnp.asarray(x, jnp.float32), (n,))
