"""Pallas kernel: fused RBL-discharge timestep for voltage-based sensing.

One step of C_RBL * dV/dt = -I_SL(V).  The kernel keeps V_RBL, both
polarization planes, and the energy accumulator in the same VMEM block, so
an N-step ``lax.scan`` over this kernel streams no operand more than once
per step.  The per-step senseline current is also emitted so the caller can
integrate the RBL energy component alongside the trajectory.
"""

import jax.numpy as jnp

from ..params import PARAMS as P
from .common import as_cols, elementwise_call


def _cell_current(vg, vds, pol, dvt):
    vt = P.vt0 + dvt - (0.5 * P.dvt_mw / P.ps) * pol
    u = P.n_ss * P.phi_t
    x = (vg - vt) / u
    sp = jnp.where(x > 0.0, x + jnp.log1p(jnp.exp(-x)), jnp.log1p(jnp.exp(x)))
    vov = u * sp
    sat = jnp.tanh(jnp.maximum(vds, 0.0) * (1.0 / P.v_dsat))
    return P.k_fet * jnp.exp(P.alpha_sat * jnp.log(vov)) * sat


def _body(v_ref, pol_a_ref, pol_b_ref, dvt_a_ref, dvt_b_ref, vg1_ref,
          vg2_ref, c_ref, dt_ref, vout_ref, isl_ref):
    """Explicit-Euler step: V <- max(V - I_SL(V) * dt / C, 0)."""
    v = v_ref[...]
    i_a = _cell_current(vg1_ref[...], v, pol_a_ref[...], dvt_a_ref[...])
    i_b = _cell_current(vg2_ref[...], v, pol_b_ref[...], dvt_b_ref[...])
    i_sl = i_a + i_b
    isl_ref[...] = i_sl
    vout_ref[...] = jnp.maximum(v - i_sl * dt_ref[...] / c_ref[...], 0.0)


def rbl_step_kernel(v_rbl, pol_a, pol_b, vg1, vg2, c_rbl, dt,
                    dvt_a=0.0, dvt_b=0.0, *, n=None, block_size=None):
    """One discharge step; returns ``(v_next, i_sl)`` per column."""
    if n is None:
        n = jnp.shape(jnp.asarray(v_rbl))[0]
    args = [as_cols(a, n)
            for a in (v_rbl, pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, c_rbl, dt)]
    return elementwise_call(_body, 2, n, block_size, *args)
