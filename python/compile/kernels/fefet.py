"""Pallas kernel: vectorized 1T-FeFET bitcell read current.

The device math here is intentionally written *independently* of the oracle
in :mod:`ref` (different but equivalent formulations, e.g. a hand-split
stable softplus instead of ``logaddexp``) so the kernel-vs-ref pytest is a
real cross-check and not a tautology.
"""

import jax.numpy as jnp

from ..params import PARAMS as P
from .common import as_cols, elementwise_call


def _stable_softplus(x):
    # split form of log(1 + e^x): avoids overflow for large +x and
    # underflow for large -x; equivalent to jnp.logaddexp(x, 0).
    return jnp.where(x > 0.0, x + jnp.log1p(jnp.exp(-x)), jnp.log1p(jnp.exp(x)))


def _body(vg_ref, vds_ref, pol_ref, dvt_ref, i_ref):
    """I_D of a FeFET: alpha-power FET with polarization-shifted V_T."""
    vg = vg_ref[...]
    vds = vds_ref[...]
    pol = pol_ref[...]
    dvt = dvt_ref[...]

    # polarization -> threshold: +P (LRS) lowers V_T by half the memory window
    vt = P.vt0 + dvt - (0.5 * P.dvt_mw / P.ps) * pol

    # smooth overdrive with subthreshold blending
    u = P.n_ss * P.phi_t
    vov = u * _stable_softplus((vg - vt) / u)

    # alpha-power saturation, smooth triode knee in V_DS
    sat = jnp.tanh(jnp.maximum(vds, 0.0) * (1.0 / P.v_dsat))
    i_ref[...] = P.k_fet * jnp.exp(P.alpha_sat * jnp.log(vov)) * sat


def fefet_current_kernel(v_g, v_ds, pol, dvt=0.0, *, n=None, block_size=None):
    """Bitcell read currents for ``n`` columns (A).

    All arguments broadcast to ``(n,)`` float32.  ``n`` defaults to the
    length of the first array argument.
    """
    if n is None:
        n = max(jnp.shape(jnp.asarray(a))[0] if jnp.ndim(jnp.asarray(a)) else 1
                for a in (v_g, v_ds, pol, dvt))
    args = [as_cols(a, n) for a in (v_g, v_ds, pol, dvt)]
    return elementwise_call(_body, 1, n, block_size, *args)
