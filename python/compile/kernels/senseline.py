"""Pallas kernel: ADRA dual-row senseline current (the paper's Fig. 3(a)).

One fused kernel evaluates both selected bitcells per column and their sum
I_SL, so the HBM->VMEM traffic per column block is a single pass over the
two polarization planes (instead of two separate device-model sweeps).
This fusion is the L1 hot-path optimization recorded in EXPERIMENTS.md.
"""

import jax.numpy as jnp

from ..params import PARAMS as P
from .common import as_cols, elementwise_call


def _cell_current(vg, vds, pol, dvt):
    vt = P.vt0 + dvt - (0.5 * P.dvt_mw / P.ps) * pol
    u = P.n_ss * P.phi_t
    x = (vg - vt) / u
    sp = jnp.where(x > 0.0, x + jnp.log1p(jnp.exp(-x)), jnp.log1p(jnp.exp(x)))
    vov = u * sp
    sat = jnp.tanh(jnp.maximum(vds, 0.0) * (1.0 / P.v_dsat))
    return P.k_fet * jnp.exp(P.alpha_sat * jnp.log(vov)) * sat


def _body(pol_a_ref, pol_b_ref, dvt_a_ref, dvt_b_ref, vg1_ref, vg2_ref,
          vds_ref, isl_ref, ia_ref, ib_ref):
    """I_SL = I(A at V_GREAD1) + I(B at V_GREAD2), per column."""
    vds = vds_ref[...]
    i_a = _cell_current(vg1_ref[...], vds, pol_a_ref[...], dvt_a_ref[...])
    i_b = _cell_current(vg2_ref[...], vds, pol_b_ref[...], dvt_b_ref[...])
    ia_ref[...] = i_a
    ib_ref[...] = i_b
    isl_ref[...] = i_a + i_b


def senseline_kernel(pol_a, pol_b, vg1, vg2, v_ds, dvt_a=0.0, dvt_b=0.0,
                     *, n=None, block_size=None):
    """Per-column (I_SL, I_A, I_B) for an asymmetric dual-row activation.

    ``vg1``/``vg2`` are the WL1/WL2 assertion voltages (V_GREAD1 < V_GREAD2
    in ADRA; equal voltages reproduce the symmetric prior-work scheme of
    Fig. 1 and its many-to-one mapping).
    """
    if n is None:
        n = jnp.shape(jnp.asarray(pol_a))[0]
    args = [as_cols(a, n)
            for a in (pol_a, pol_b, dvt_a, dvt_b, vg1, vg2, v_ds)]
    return elementwise_call(_body, 3, n, block_size, *args)
