"""Pure-jnp reference oracle for every Pallas kernel.

Everything here is straight-line jax.numpy with no pallas involvement; the
pytest suite asserts each kernel in this package matches its reference to
float32 allclose over randomized shapes and inputs (hypothesis sweeps).

The same equations are mirrored in ``rust/src/device/`` — three independent
implementations (ref-jnp, pallas, rust) pinned together by tests.
"""

import jax.numpy as jnp

from ..params import PARAMS as P


# ---------------------------------------------------------------------------
# 45 nm FET: alpha-power law with smooth subthreshold blending.
# ---------------------------------------------------------------------------

def softplus(x):
    """Numerically-stable softplus: log(1 + exp(x))."""
    return jnp.logaddexp(x, 0.0)


def overdrive(v_gs, v_t):
    """Smooth effective overdrive voltage.

    Above threshold this approaches (v_gs - v_t); below threshold it decays
    exponentially with the subthreshold slope n_ss * phi_t, giving a single
    smooth expression valid in both regions.
    """
    u = P.n_ss * P.phi_t
    return u * softplus((v_gs - v_t) / u)


def fet_current(v_gs, v_ds, v_t):
    """Drain current of the 45 nm access FET (A).

    I_D = K * Vov^alpha * tanh(V_DS / V_dsat): alpha-power saturation with a
    smooth triode knee.  All arguments broadcast.
    """
    vov = overdrive(v_gs, v_t)
    return P.k_fet * jnp.power(vov, P.alpha_sat) * jnp.tanh(
        jnp.maximum(v_ds, 0.0) / P.v_dsat
    )


# ---------------------------------------------------------------------------
# FeFET: polarization -> threshold map and senseline composition.
# ---------------------------------------------------------------------------

def vt_of_pol(pol, dvt=0.0):
    """Threshold voltage of a FeFET storing polarization ``pol`` (C/m^2).

    +P (LRS, logic '1') lowers V_T; -P (HRS, logic '0') raises it.  ``dvt``
    is an optional per-cell V_T offset used for Monte-Carlo variation.
    """
    return P.vt0 - 0.5 * P.dvt_mw * (pol / P.ps) + dvt


def fefet_current(v_g, v_ds, pol, dvt=0.0):
    """Read current of a 1T FeFET bitcell (A)."""
    return fet_current(v_g, v_ds, vt_of_pol(pol, dvt))


def senseline_current(pol_a, pol_b, vg1, vg2, v_ds, dvt_a=0.0, dvt_b=0.0):
    """ADRA dual-row senseline current.

    Word A sits on the row asserted to ``vg1`` (= V_GREAD1, the *lower*
    asymmetric bias) and word B on the row asserted to ``vg2`` (= V_GREAD2).
    I_SL is the sum of the two bitcell currents — Fig. 3(a)/(c).
    """
    i_a = fefet_current(vg1, v_ds, pol_a, dvt_a)
    i_b = fefet_current(vg2, v_ds, pol_b, dvt_b)
    return i_a + i_b


# ---------------------------------------------------------------------------
# Miller / Preisach-lite polarization dynamics (paper eqs. (1)-(2)).
# ---------------------------------------------------------------------------

def sigma_e():
    """Domain spread sigma = Ec / ln((Ps+Pr)/(Ps-Pr)) — eq. (2)."""
    return P.ec / jnp.log((P.ps + P.pr) / (P.ps - P.pr))


def miller_target(e_fe):
    """Branch saturation polarization curves P+-(E) — eq. (1).

    Returns (ascending, descending) branch targets.  The ascending branch
    (E > 0 drive) is Ps*tanh((E-Ec)/(2*sigma)); descending is the mirror.
    """
    s2 = 2.0 * sigma_e()
    up = P.ps * jnp.tanh((e_fe - P.ec) / s2)
    dn = P.ps * jnp.tanh((e_fe + P.ec) / s2)
    return up, dn


def miller_step(pol, v_g, dt):
    """One explicit-Euler step of the lagged Miller dynamics.

    dP/dt = (P_branch(E) - P) / tau, rectified so that positive drive can
    only raise P (ascending branch) and negative drive only lower it
    (descending branch); at E = 0 polarization is retained.  This is the
    standard monotone-branch Verilog-A realization of Miller's model and
    gives retention + hysteresis without tracking dE/dt history.
    """
    e_fe = P.kappa_fe * v_g / P.t_fe
    up, dn = miller_target(e_fe)
    drive_up = jnp.maximum(up - pol, 0.0) * (e_fe > 0.0)
    drive_dn = jnp.minimum(dn - pol, 0.0) * (e_fe < 0.0)
    dp = (drive_up + drive_dn) * (dt / P.tau_fe)
    return jnp.clip(pol + dp, -P.ps, P.ps)


# ---------------------------------------------------------------------------
# RBL discharge transient (voltage-based sensing).
# ---------------------------------------------------------------------------

def rbl_step(v_rbl, pol_a, pol_b, vg1, vg2, c_rbl, dt, dvt_a=0.0, dvt_b=0.0):
    """One explicit-Euler step of the RBL discharge ODE.

    C_RBL * dV/dt = -I_SL(V): both selected cells discharge the (pre-charged)
    read bitline; the cell currents themselves depend on the instantaneous
    RBL voltage through V_DS.  Returns (v_next, i_sl) so callers can
    integrate energy alongside the trajectory.
    """
    i_sl = senseline_current(pol_a, pol_b, vg1, vg2, v_rbl, dvt_a, dvt_b)
    v_next = jnp.maximum(v_rbl - i_sl * dt / c_rbl, 0.0)
    return v_next, i_sl
