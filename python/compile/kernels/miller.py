"""Pallas kernel: monotone-branch Miller polarization update (eqs. (1)-(2)).

Used by the write-transient and I-V hysteresis artifacts.  The branch
rectification (ascending drive can only raise P, descending only lower it)
is what yields retention at E = 0 and the hysteresis loop of Fig. 2(c).
"""

import math

import jax.numpy as jnp

from ..params import PARAMS as P
from .common import as_cols, elementwise_call

# eq. (2): domain-spread parameter; a compile-time constant.
_SIGMA = P.ec / math.log((P.ps + P.pr) / (P.ps - P.pr))


def _body(pol_ref, vg_ref, dt_ref, pout_ref):
    pol = pol_ref[...]
    e_fe = (P.kappa_fe / P.t_fe) * vg_ref[...]

    inv_s2 = 1.0 / (2.0 * _SIGMA)
    target_up = P.ps * jnp.tanh((e_fe - P.ec) * inv_s2)
    target_dn = P.ps * jnp.tanh((e_fe + P.ec) * inv_s2)

    rate = dt_ref[...] * (1.0 / P.tau_fe)
    dp_up = jnp.maximum(target_up - pol, 0.0) * (e_fe > 0.0)
    dp_dn = jnp.minimum(target_dn - pol, 0.0) * (e_fe < 0.0)
    pout_ref[...] = jnp.clip(pol + (dp_up + dp_dn) * rate, -P.ps, P.ps)


def miller_step_kernel(pol, v_g, dt, *, n=None, block_size=None):
    """One lagged-Miller polarization step; returns the new P plane."""
    if n is None:
        n = jnp.shape(jnp.asarray(pol))[0]
    args = [as_cols(a, n) for a in (pol, v_g, dt)]
    return elementwise_call(_body, 1, n, block_size, *args)
