"""L1: Pallas kernels for the ADRA analog hot-spot.

Each kernel has a pure-jnp oracle in :mod:`ref` and a hypothesis-driven
pytest comparing the two.  All kernels run with ``interpret=True`` — the CPU
PJRT plugin cannot execute Mosaic custom-calls (see DESIGN.md §3).
"""

from .fefet import fefet_current_kernel
from .senseline import senseline_kernel
from .transient import rbl_step_kernel
from .miller import miller_step_kernel

__all__ = [
    "fefet_current_kernel",
    "senseline_kernel",
    "rbl_step_kernel",
    "miller_step_kernel",
]
