"""AOT compiler: lower every L2 entry point to HLO *text* artifacts.

HLO text — not ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla_extension 0.5.1 backing the Rust ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile
``artifacts`` target).  Emits one ``<name>.hlo.txt`` per entry point plus a
``manifest.txt`` that the Rust runtime parses to locate and sanity-check
the artifacts.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .params import N_COLS, N_SWEEP

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


#: entry point -> (callable, example args).  The shapes here are the
#: runtime ABI; rust/src/runtime/artifact.rs carries the same table.
ENTRY_POINTS = {
    "dc_isl": (
        model.dc_isl,
        (_spec(N_COLS), _spec(N_COLS), _spec(N_COLS), _spec(N_COLS),
         _spec(), _spec()),
    ),
    "transient_cim": (
        model.transient_cim,
        (_spec(N_COLS), _spec(N_COLS), _spec(N_COLS), _spec(N_COLS),
         _spec(), _spec(), _spec(), _spec()),
    ),
    "iv_sweep": (model.iv_sweep, (_spec(N_SWEEP),)),
    "write_transient": (model.write_transient, (_spec(N_COLS), _spec(N_SWEEP))),
    "read_disturb": (model.read_disturb, (_spec(N_COLS),)),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str) -> str:
    fn, specs = ENTRY_POINTS[name]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of entry points")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only or list(ENTRY_POINTS)
    manifest_lines = []
    for name in names:
        fn, specs = ENTRY_POINTS[name]
        text = lower_entry(name)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        sig_in = ",".join("x".join(map(str, s.shape)) or "scalar" for s in specs)
        manifest_lines.append(f"{name}\t{fname}\tin={sig_in}")
        print(f"  {name}: {len(text)} chars -> {fname}")

    # manifest last: it is the Makefile's freshness stamp, so it must only
    # exist once every artifact above has been written successfully.
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(f"# ADRA AOT artifacts; N_COLS={N_COLS} N_SWEEP={N_SWEEP}\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(names)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
