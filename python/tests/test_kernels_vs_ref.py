"""Kernel-vs-oracle correctness: every Pallas kernel against the pure-jnp
reference in ``compile.kernels.ref``, across hypothesis-driven sweeps of
shapes, biases, and stored states.  This is the CORE L1 correctness signal.
"""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import (
    fefet_current_kernel,
    miller_step_kernel,
    rbl_step_kernel,
    senseline_kernel,
)
from compile.kernels import ref
from compile.kernels.common import pick_block
from compile.params import PARAMS as P

# Column counts exercising block==n, block<n, odd sizes, power-of-two.
SIZES = st.sampled_from([1, 2, 7, 16, 100, 128, 256, 300, 1024])

finite = dict(allow_nan=False, allow_infinity=False)
vg_st = st.floats(-1.0, 6.0, **finite)
vds_st = st.floats(0.0, 1.2, **finite)
pol_st = st.floats(-float(P.ps), float(P.ps), **finite)
dvt_st = st.floats(-0.1, 0.1, **finite)


def _arr(rng, n, lo, hi):
    return jnp.asarray(rng.uniform(lo, hi, n), jnp.float32)


@settings(max_examples=40, deadline=None)
@given(n=SIZES, vg=vg_st, vds=vds_st, pol=pol_st, dvt=dvt_st)
def test_fefet_current_matches_ref_scalar_broadcast(n, vg, vds, pol, dvt):
    got = fefet_current_kernel(
        jnp.full((n,), vg, jnp.float32), vds, pol, dvt, n=n
    )
    want = ref.fefet_current(vg, vds, pol, dvt)
    np.testing.assert_allclose(got, jnp.full((n,), want), rtol=1e-5, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=st.integers(0, 2**31 - 1))
def test_fefet_current_matches_ref_random_planes(n, seed):
    rng = np.random.default_rng(seed)
    vg = _arr(rng, n, 0.0, 1.2)
    vds = _arr(rng, n, 0.0, 1.0)
    pol = _arr(rng, n, -P.ps, P.ps)
    dvt = _arr(rng, n, -0.05, 0.05)
    got = fefet_current_kernel(vg, vds, pol, dvt, n=n)
    want = ref.fefet_current(vg, vds, pol, dvt)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=st.integers(0, 2**31 - 1),
       vg1=st.floats(0.5, 1.0, **finite), vg2=st.floats(0.5, 1.2, **finite))
def test_senseline_matches_ref(n, seed, vg1, vg2):
    rng = np.random.default_rng(seed)
    pol_a = _arr(rng, n, -P.ps, P.ps)
    pol_b = _arr(rng, n, -P.ps, P.ps)
    dvt_a = _arr(rng, n, -0.05, 0.05)
    dvt_b = _arr(rng, n, -0.05, 0.05)
    isl, ia, ib = senseline_kernel(
        pol_a, pol_b, jnp.full((n,), vg1, jnp.float32),
        jnp.full((n,), vg2, jnp.float32), jnp.full((n,), P.v_read, jnp.float32),
        dvt_a, dvt_b, n=n,
    )
    want = ref.senseline_current(pol_a, pol_b, vg1, vg2, P.v_read, dvt_a, dvt_b)
    np.testing.assert_allclose(isl, want, rtol=1e-5, atol=1e-12)
    np.testing.assert_allclose(
        ia, ref.fefet_current(vg1, P.v_read, pol_a, dvt_a), rtol=1e-5, atol=1e-12)
    np.testing.assert_allclose(
        ib, ref.fefet_current(vg2, P.v_read, pol_b, dvt_b), rtol=1e-5, atol=1e-12)
    np.testing.assert_allclose(isl, ia + ib, rtol=1e-6, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=st.integers(0, 2**31 - 1),
       v0=st.floats(0.1, 1.0, **finite))
def test_rbl_step_matches_ref(n, seed, v0):
    rng = np.random.default_rng(seed)
    pol_a = _arr(rng, n, -P.ps, P.ps)
    pol_b = _arr(rng, n, -P.ps, P.ps)
    v = jnp.full((n,), v0, jnp.float32)
    c = 1024 * P.c_rbl_cell
    got_v, got_i = rbl_step_kernel(
        v, pol_a, pol_b,
        jnp.full((n,), P.v_gread1, jnp.float32),
        jnp.full((n,), P.v_gread2, jnp.float32),
        jnp.full((n,), c, jnp.float32), jnp.full((n,), P.t_step, jnp.float32),
        n=n,
    )
    want_v, want_i = ref.rbl_step(
        v, pol_a, pol_b, P.v_gread1, P.v_gread2, c, P.t_step
    )
    np.testing.assert_allclose(got_v, want_v, rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=st.integers(0, 2**31 - 1),
       vg=st.floats(-6.0, 6.0, **finite),
       dt_mult=st.floats(0.1, 100.0, **finite))
def test_miller_step_matches_ref(n, seed, vg, dt_mult):
    rng = np.random.default_rng(seed)
    pol = _arr(rng, n, -P.ps, P.ps)
    # compare at the f32 ABI: the branch gate (e_fe > 0) is discontinuous,
    # so a subnormal f64 vg that underflows in f32 would legitimately
    # diverge between a f64 oracle and the f32 kernel.
    vg = float(np.float32(vg))
    dt = float(np.float32(P.t_step * dt_mult))
    got = miller_step_kernel(
        pol, jnp.full((n,), vg, jnp.float32), jnp.full((n,), dt, jnp.float32),
        n=n,
    )
    want = ref.miller_step(pol, vg, dt)
    # atol covers catastrophic cancellation when P crosses ~0 toward the
    # branch target (values of order 1e-5 with ~1 ulp f32 error)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("n,req,expect_divides", [
    (1024, None, True), (1024, 128, True), (100, None, True),
    (7, None, True), (300, None, True), (1, None, True),
])
def test_pick_block_divides(n, req, expect_divides):
    b = pick_block(n, req)
    assert n % b == 0
    assert 1 <= b <= max(n, 1)


def test_pick_block_prefers_large_power_of_two():
    assert pick_block(1024) == 256
    assert pick_block(512) == 256
    assert pick_block(256) == 256
    assert pick_block(128) == 128
    assert pick_block(96) == 32
