"""AOT lowering tests: every entry point lowers to parseable HLO text with
the shape signature the Rust runtime (rust/src/runtime/artifact.rs) expects.
"""

import re

import pytest

from compile import aot
from compile.params import N_COLS, N_SWEEP


@pytest.fixture(scope="module")
def hlo_texts():
    # Lower everything once; module-scoped because lowering is not free.
    return {name: aot.lower_entry(name) for name in aot.ENTRY_POINTS}


def test_all_entry_points_lower(hlo_texts):
    assert set(hlo_texts) == {
        "dc_isl", "transient_cim", "iv_sweep", "write_transient",
        "read_disturb",
    }
    for name, text in hlo_texts.items():
        assert "HloModule" in text, name
        assert "ENTRY" in text, name


def test_no_custom_calls(hlo_texts):
    """interpret=True must have eliminated Mosaic custom-calls; otherwise
    the CPU PJRT client cannot execute the artifact."""
    for name, text in hlo_texts.items():
        assert "custom-call" not in text, name


def _entry_block(text):
    """Lines of the ENTRY computation (the HLO text parser format puts
    parameters and ROOT inside an `ENTRY name { ... }` block)."""
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    block = []
    for l in lines[start + 1:]:
        if l.strip() == "}":
            break
        block.append(l)
    return block


def test_entry_signatures(hlo_texts):
    """Parameter arity in the ENTRY block matches the manifest ABI."""
    expected_params = {
        "dc_isl": 6,
        "transient_cim": 8,
        "iv_sweep": 1,
        "write_transient": 2,
        "read_disturb": 1,
    }
    for name, n_params in expected_params.items():
        block = _entry_block(hlo_texts[name])
        n_found = sum(1 for l in block if re.search(r"= f32\[[0-9]*\]\S* parameter\(", l))
        assert n_found == n_params, (name, n_found)


def test_root_is_tuple(hlo_texts):
    """Lowered with return_tuple=True — the Rust side unwraps a tuple."""
    for name, text in hlo_texts.items():
        root = next(l for l in _entry_block(text) if "ROOT" in l)
        assert "tuple(" in root or re.search(r"\) tuple", root) or "(f32" in root, (name, root)


def test_static_shapes_match_params(hlo_texts):
    assert f"f32[{N_COLS}]" in hlo_texts["dc_isl"]
    assert f"f32[{N_SWEEP}]" in hlo_texts["iv_sweep"]
