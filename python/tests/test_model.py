"""L2 entry-point tests: shapes, trajectories, and the voltage-sensing
margins the Rust side depends on (the artifact ABI contract)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.params import PARAMS as P, N_COLS, N_SWEEP

POL_LRS = P.p_store * P.ps
POL_HRS = -P.p_store * P.ps
Z = jnp.zeros((N_COLS,), jnp.float32)


def plane(bit):
    return jnp.full((N_COLS,), POL_LRS if bit else POL_HRS, jnp.float32)


@pytest.fixture(scope="module")
def transients():
    c_rbl = 1024 * P.c_rbl_cell
    out = {}
    for a in (0, 1):
        for b in (0, 1):
            out[(a, b)] = model.transient_cim(
                plane(a), plane(b), Z, Z,
                P.v_gread1, P.v_gread2, P.v_read, c_rbl,
            )
    return out


def test_dc_isl_shapes_and_consistency():
    isl, ia, ib = model.dc_isl(plane(1), plane(0), Z, Z,
                               P.v_gread1, P.v_gread2)
    assert isl.shape == ia.shape == ib.shape == (N_COLS,)
    np.testing.assert_allclose(isl, ia + ib, rtol=1e-6)


def test_transient_shapes(transients):
    v_trace, v_final, q, e = transients[(1, 1)]
    assert v_trace.shape == (P.n_steps, N_COLS)
    assert v_final.shape == q.shape == e.shape == (N_COLS,)


def test_transient_voltage_monotone_nonincreasing(transients):
    for key, (v_trace, *_rest) in transients.items():
        v = np.asarray(v_trace[:, 0])
        assert np.all(np.diff(v) <= 1e-9), key


def test_transient_four_levels_ordered(transients):
    """Discharge depth ordering mirrors the I_SL ordering: deeper discharge
    for larger senseline current — v11 < v01 < v10 < v00."""
    vf = {k: float(v[1][0]) for k, v in transients.items()}
    assert vf[(1, 1)] < vf[(0, 1)] < vf[(1, 0)] < vf[(0, 0)]


def test_transient_voltage_margins_exceed_50mv(transients):
    """Section IV: > 50 mV sense margin for voltage-based sensing."""
    vf = sorted(float(v[1][0]) for v in transients.values())
    margins = np.diff(vf)
    assert margins.min() > 0.050, f"margins (V): {margins}"


def test_transient_energy_and_charge_positive(transients):
    for key, (_vt, _vf, q, e) in transients.items():
        assert float(q[0]) >= 0.0
        assert float(e[0]) >= 0.0
        # dissipated energy can't exceed q * V_READ
        assert float(e[0]) <= float(q[0]) * P.v_read * (1 + 1e-6)


def test_transient_charge_conservation(transients):
    """Charge drawn from the RBL equals C * dV (explicit Euler identity)."""
    c_rbl = 1024 * P.c_rbl_cell
    for key, (_vt, v_final, q, _e) in transients.items():
        dv = P.v_read - float(v_final[0])
        np.testing.assert_allclose(float(q[0]), c_rbl * dv, rtol=1e-3,
                                   err_msg=str(key))


def test_iv_sweep_hysteresis():
    vg = jnp.concatenate([
        jnp.linspace(-5, 5, N_SWEEP // 2),
        jnp.linspace(5, -5, N_SWEEP - N_SWEEP // 2),
    ]).astype(jnp.float32)
    i_d, pol = model.iv_sweep(vg)
    assert i_d.shape == pol.shape == (N_SWEEP,)
    # polarization reaches both remanent states
    assert float(pol.max()) > 0.5 * P.pr
    assert float(pol.min()) < -0.5 * P.pr
    assert np.all(np.asarray(i_d) >= 0.0)


def test_write_transient_sets_and_resets():
    t = jnp.arange(N_SWEEP, dtype=jnp.float32)
    set_pulse = jnp.where(t < N_SWEEP / 2, P.v_set, 0.0)
    pol0 = jnp.full((N_COLS,), POL_HRS, jnp.float32)
    pol_set, trace = model.write_transient(pol0, set_pulse)
    assert trace.shape == (N_SWEEP, N_COLS)
    assert float(pol_set[0]) > 0.5 * P.pr

    reset_pulse = jnp.where(t < N_SWEEP / 2, P.v_reset, 0.0)
    pol_reset, _ = model.write_transient(pol_set, reset_pulse)
    assert float(pol_reset[0]) < -0.5 * P.pr


def test_read_disturb_bounded():
    """Sustained read keeps a stored '1' healthy (V_GREAD < V_C rule) and
    never drives a stored '0' past the B-reference decision point."""
    pol_final, trace = model.read_disturb(
        jnp.full((N_COLS,), POL_LRS, jnp.float32))
    assert trace.shape == (N_SWEEP, N_COLS)
    assert float(pol_final[0]) > 0.5 * P.ps

    pol_final0, _ = model.read_disturb(
        jnp.full((N_COLS,), POL_HRS, jnp.float32))
    # HRS may creep toward the ascending branch target but must stay
    # clearly negative (still reads as '0').
    assert float(pol_final0[0]) < -0.1 * P.ps
