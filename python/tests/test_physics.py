"""Physics invariants of the device model — the properties the paper's
argument rests on, independent of any particular numerical value:

* the ADRA one-to-one mapping (four distinct, ordered I_SL levels),
* the baseline many-to-one mapping ((0,1) == (1,0) when biases are equal),
* sense margins above the paper's Section IV targets,
* monotonicity / retention / hysteresis of the FeFET model.
"""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import ref
from compile.params import PARAMS as P

finite = dict(allow_nan=False, allow_infinity=False)

POL_LRS = P.p_store * P.ps   # logic '1'
POL_HRS = -P.p_store * P.ps  # logic '0'


def isl(a_bit, b_bit, vg1=P.v_gread1, vg2=P.v_gread2):
    pa = POL_LRS if a_bit else POL_HRS
    pb = POL_LRS if b_bit else POL_HRS
    return float(ref.senseline_current(pa, pb, vg1, vg2, P.v_read))


# ---------------------------------------------------------------------------
# The paper's core claim: asymmetric biasing -> one-to-one mapping.
# ---------------------------------------------------------------------------

def test_adra_four_distinct_ordered_levels():
    i00, i01, i10, i11 = isl(0, 0), isl(0, 1), isl(1, 0), isl(1, 1)
    # B sits on the stronger wordline (V_GREAD2), so (0,1) > (1,0).
    assert i00 < i10 < i01 < i11


def test_adra_sense_margin_exceeds_1ua():
    """Section IV: > 1 uA margin for current-based sensing."""
    levels = sorted([isl(0, 0), isl(0, 1), isl(1, 0), isl(1, 1)])
    margins = np.diff(levels)
    assert margins.min() > 1e-6, f"margins (A): {margins}"


def test_baseline_symmetric_is_many_to_one():
    """With V_GREAD1 == V_GREAD2 (prior work, Fig. 1), (0,1) and (1,0)
    collapse to the same senseline current — subtraction is impossible."""
    vg = P.v_gread2
    i01 = isl(0, 1, vg, vg)
    i10 = isl(1, 0, vg, vg)
    np.testing.assert_allclose(i01, i10, rtol=1e-6)
    assert isl(0, 0, vg, vg) < i01 < isl(1, 1, vg, vg)


def test_adra_reference_placement_recovers_b():
    """I_REF-B between (I_LRS1+I_HRS2) and (I_HRS1+I_LRS2) outputs bit B."""
    i_ref_b = 0.5 * (isl(1, 0) + isl(0, 1))
    for a in (0, 1):
        for b in (0, 1):
            assert (isl(a, b) > i_ref_b) == bool(b), (a, b)


def test_adra_reference_placement_recovers_or_and():
    i_ref_or = 0.5 * (isl(0, 0) + isl(1, 0))
    i_ref_and = 0.5 * (isl(0, 1) + isl(1, 1))
    for a in (0, 1):
        for b in (0, 1):
            assert (isl(a, b) > i_ref_or) == bool(a or b)
            assert (isl(a, b) > i_ref_and) == bool(a and b)


def test_oai_gate_recovers_a():
    """A = NOT[(B + NOR(A,B)) * NAND(A,B)] — the paper's OAI recovery."""
    for a in (0, 1):
        for b in (0, 1):
            nand = 1 - (a & b)
            nor = 1 - (a | b)
            got = 1 - ((b | nor) & nand)
            assert got == a, (a, b)


# ---------------------------------------------------------------------------
# Device-model sanity: monotonicity, retention, hysteresis.
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(vg=st.floats(0.0, 1.1, **finite), dv=st.floats(0.01, 0.4, **finite),
       pol=st.floats(-float(P.ps), float(P.ps), **finite))
def test_current_monotone_in_vg(vg, dv, pol):
    lo = float(ref.fefet_current(vg, P.v_read, pol))
    hi = float(ref.fefet_current(vg + dv, P.v_read, pol))
    assert hi > lo


@settings(max_examples=30, deadline=None)
@given(vg=st.floats(0.5, 1.1, **finite),
       p1=st.floats(-float(P.ps), float(P.ps), **finite),
       dp=st.floats(0.01, 0.2, **finite))
def test_current_monotone_in_polarization(vg, p1, dp):
    p2 = min(p1 + dp, float(P.ps))
    lo = float(ref.fefet_current(vg, P.v_read, p1))
    hi = float(ref.fefet_current(vg, P.v_read, p2))
    assert hi >= lo


@settings(max_examples=30, deadline=None)
@given(vds=st.floats(0.05, 1.0, **finite), dv=st.floats(0.01, 0.2, **finite))
def test_current_monotone_in_vds(vds, dv):
    lo = float(ref.fefet_current(P.v_gread2, vds, POL_LRS))
    hi = float(ref.fefet_current(P.v_gread2, vds + dv, POL_LRS))
    assert hi >= lo


def test_lrs_hrs_distinguishability():
    """Single-cell read window: LRS/HRS current ratio >> 1 at V_GREAD2."""
    i_lrs = float(ref.fefet_current(P.v_gread2, P.v_read, POL_LRS))
    i_hrs = float(ref.fefet_current(P.v_gread2, P.v_read, POL_HRS))
    assert i_lrs / i_hrs > 10.0


@settings(max_examples=30, deadline=None)
@given(pol=st.floats(-float(P.ps), float(P.ps), **finite),
       steps=st.integers(1, 50))
def test_retention_at_zero_field(pol, steps):
    p = jnp.float32(pol)
    for _ in range(steps):
        p = ref.miller_step(p, 0.0, P.t_step * 1000)
    np.testing.assert_allclose(float(p), pol, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(vg=st.floats(3.5, 6.0, **finite))
def test_set_pulse_switches_to_positive_p(vg):
    p = jnp.float32(-P.p_store * P.ps)
    for _ in range(200):
        p = ref.miller_step(p, vg, P.t_step * 50)
    assert float(p) > 0.5 * P.pr


@settings(max_examples=20, deadline=None)
@given(vg=st.floats(-6.0, -4.0, **finite))
def test_reset_pulse_switches_to_negative_p(vg):
    p = jnp.float32(P.p_store * P.ps)
    for _ in range(200):
        p = ref.miller_step(p, vg, P.t_step * 50)
    assert float(p) < -0.5 * P.pr


def test_polarization_always_bounded():
    p = jnp.float32(0.0)
    for vg in [6.0, -6.0, 6.0, -6.0]:
        for _ in range(100):
            p = ref.miller_step(p, vg, P.t_step * 100)
            assert -P.ps <= float(p) <= P.ps


def test_read_bias_does_not_switch_lrs():
    """V_GREAD < V_C design rule: read never flips a stored '1'."""
    p = jnp.float32(POL_LRS)
    for _ in range(500):
        p = ref.miller_step(p, P.v_gread2, P.t_step * 50)
    assert float(p) > 0.5 * P.ps


def test_hysteresis_loop_has_area():
    """Up-sweep and down-sweep polarizations differ (Fig. 2(c) loop)."""
    n = 100
    up = np.linspace(-5, 5, n)
    p = jnp.float32(-P.p_store * P.ps)
    p_up = []
    for vg in up:
        p = ref.miller_step(p, float(vg), P.t_step * 50)
        p_up.append(float(p))
    p_dn = []
    for vg in up[::-1]:
        p = ref.miller_step(p, float(vg), P.t_step * 50)
        p_dn.append(float(p))
    p_dn = p_dn[::-1]
    area = np.trapezoid(np.array(p_up) - np.array(p_dn), up)
    assert abs(area) > 0.01 * P.ps  # a real loop, not a line
