"""Block-schedule invariance and numerical edge cases.

The BlockSpec schedule (block size / grid) must never change results —
only performance.  These tests pin that, plus the stability of the
device-model math at extreme inputs (where naive softplus/log formulations
overflow) and the symmetric-bias special case the baseline engine relies
on.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    fefet_current_kernel,
    miller_step_kernel,
    rbl_step_kernel,
    senseline_kernel,
)
from compile.kernels import ref
from compile.params import PARAMS as P


def rand(n, lo, hi, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, n), jnp.float32)


@pytest.mark.parametrize("n,blocks", [
    (1024, [32, 64, 128, 256, 1024]),
    (256, [16, 256]),
    (96, [32, 96]),
])
def test_fefet_kernel_block_invariance(n, blocks):
    vg = rand(n, 0.0, 1.2, 1)
    vds = rand(n, 0.0, 1.0, 2)
    pol = rand(n, -P.ps, P.ps, 3)
    dvt = rand(n, -0.05, 0.05, 4)
    results = [
        np.asarray(fefet_current_kernel(vg, vds, pol, dvt, n=n, block_size=b))
        for b in blocks
    ]
    # schedule changes may re-associate fusions: identical to ~1 ulp
    for r in results[1:]:
        np.testing.assert_allclose(results[0], r, rtol=2e-6, atol=1e-30)


@pytest.mark.parametrize("n", [64, 1024])
def test_senseline_kernel_block_invariance(n):
    pol_a = rand(n, -P.ps, P.ps, 5)
    pol_b = rand(n, -P.ps, P.ps, 6)
    vg1 = jnp.full((n,), P.v_gread1, jnp.float32)
    vg2 = jnp.full((n,), P.v_gread2, jnp.float32)
    vds = jnp.full((n,), P.v_read, jnp.float32)
    outs = [
        senseline_kernel(pol_a, pol_b, vg1, vg2, vds, n=n, block_size=b)
        for b in [16, n]
    ]
    for got, want in zip(outs[0], outs[1]):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-6, atol=1e-30)


def test_symmetric_bias_collapses_in_kernel():
    """The baseline (Fig. 1) many-to-one mapping, from the Pallas kernel:
    swapping the operands must not change I_SL when vg1 == vg2."""
    n = 128
    pol_a = rand(n, -P.ps, P.ps, 7)
    pol_b = rand(n, -P.ps, P.ps, 8)
    vg = jnp.full((n,), P.v_gread2, jnp.float32)
    vds = jnp.full((n,), P.v_read, jnp.float32)
    isl_ab, _, _ = senseline_kernel(pol_a, pol_b, vg, vg, vds, n=n)
    isl_ba, _, _ = senseline_kernel(pol_b, pol_a, vg, vg, vds, n=n)
    np.testing.assert_allclose(isl_ab, isl_ba, rtol=1e-6)


def test_asymmetric_bias_separates_in_kernel():
    """...and the ADRA asymmetric bias must separate the swap."""
    n = 4
    lrs = jnp.full((n,), P.p_store * P.ps, jnp.float32)
    hrs = jnp.full((n,), -P.p_store * P.ps, jnp.float32)
    vg1 = jnp.full((n,), P.v_gread1, jnp.float32)
    vg2 = jnp.full((n,), P.v_gread2, jnp.float32)
    vds = jnp.full((n,), P.v_read, jnp.float32)
    i10, _, _ = senseline_kernel(lrs, hrs, vg1, vg2, vds, n=n)
    i01, _, _ = senseline_kernel(hrs, lrs, vg1, vg2, vds, n=n)
    assert float(jnp.abs(i01[0] - i10[0])) > 1e-6


def test_extreme_gate_voltages_are_finite():
    """Deep subthreshold and strong inversion must not produce NaN/Inf
    (the stable-softplus split is what guarantees this)."""
    n = 8
    for vg in [-20.0, -5.0, 0.0, 5.0, 20.0]:
        out = fefet_current_kernel(
            jnp.full((n,), vg, jnp.float32), 1.0, 0.0, 0.0, n=n
        )
        assert np.all(np.isfinite(np.asarray(out))), f"vg={vg}"
        assert np.all(np.asarray(out) >= 0.0)


def test_zero_capacitance_guarded_by_caller():
    """rbl_step with a tiny C must clamp at 0 V, not go negative."""
    n = 4
    v, _ = rbl_step_kernel(
        jnp.full((n,), 0.01, jnp.float32),
        jnp.full((n,), P.p_store * P.ps, jnp.float32),
        jnp.full((n,), P.p_store * P.ps, jnp.float32),
        jnp.full((n,), P.v_gread1, jnp.float32),
        jnp.full((n,), P.v_gread2, jnp.float32),
        jnp.full((n,), 1e-18, jnp.float32),
        jnp.full((n,), P.t_step, jnp.float32),
        n=n,
    )
    assert np.all(np.asarray(v) >= 0.0)


def test_miller_extreme_fields_clip():
    n = 4
    for vg in [50.0, -50.0]:
        out = miller_step_kernel(
            jnp.zeros((n,), jnp.float32),
            jnp.full((n,), vg, jnp.float32),
            jnp.full((n,), 1.0, jnp.float32),  # huge dt
            n=n,
        )
        arr = np.asarray(out)
        assert np.all(np.abs(arr) <= P.ps + 1e-7)
        assert np.all(np.isfinite(arr))


def test_ref_and_kernel_agree_at_the_operating_point():
    """Spot-check the exact Section IV bias point (the numbers the rest
    of the stack is calibrated around)."""
    lrs = P.p_store * P.ps
    i_lrs2 = float(ref.fefet_current(P.v_gread2, P.v_read, lrs))
    i_lrs1 = float(ref.fefet_current(P.v_gread1, P.v_read, lrs))
    got2 = float(fefet_current_kernel(
        jnp.full((1,), P.v_gread2, jnp.float32), P.v_read, lrs, 0.0, n=1)[0])
    got1 = float(fefet_current_kernel(
        jnp.full((1,), P.v_gread1, jnp.float32), P.v_read, lrs, 0.0, n=1)[0])
    np.testing.assert_allclose(got2, i_lrs2, rtol=1e-5)
    np.testing.assert_allclose(got1, i_lrs1, rtol=1e-5)
    # the asymmetry itself: lower wordline voltage -> lower LRS current
    assert got1 < got2
