//! End-to-end calibration-loop demo: a deliberately mis-calibrated cost
//! model is corrected by measured op costs, flips routing to the
//! measured optimum, persists its learned snapshot, and then seeds a
//! live serve queue from that snapshot after a simulated restart.
//!
//! Under scheme 1 (voltage, precharged read bit-line) ADRA dual ops
//! really cost ~1.21x the baseline's energy (Fig. 6), so the honest
//! Energy-objective routing sends dual ops to the baseline executor.
//! The demo starts from a table that underprices ADRA dual energy 2x —
//! the planner wrongly routes dual -> ADRA until the calibration loop
//! walks the correction factor up and commits the flip.
//!
//! Artifacts (CI's `calibration-smoke` job consumes all three):
//!   target/calibration.json           the learned snapshot
//!   target/calibration_scrape1.prom   scrape after the first serve wave
//!   target/calibration_scrape2.prom   scrape after the second wave
//!
//!     cargo run --release --example calibration

use adra::config::{SensingScheme, SimConfig};
use adra::planner::{
    place_calibrated, planned_coordinator, CalibratedCostModel, CalibrationStore, Executor,
    Objective, OpClass, PlanCostModel, StepOutput,
};
use adra::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue};
use adra::workload::heavy_tenant_scenario;
use adra::workload::programs::analytics_scenario;

const N_RECORDS: usize = 160;
const SHARDS: usize = 2;
const MAX_ROUNDS: usize = 20;
const SNAPSHOT: &str = "target/calibration.json";

/// Write one Prometheus scrape of the global registry and sanity-check
/// the families the calibration pipeline must expose.
fn write_scrape(path: &str, families: &[&str]) -> String {
    let text = adra::observe::expose_text(adra::observe::global());
    for family in families {
        assert!(text.contains(family), "scrape is missing family {family}:\n{text}");
    }
    std::fs::create_dir_all("target").expect("create target/");
    std::fs::write(path, &text).expect("write scrape");
    text
}

fn main() {
    let mut cfg = SimConfig::square(256, SensingScheme::VoltagePrecharged);
    cfg.word_bits = 32;

    // --- part 1: the convergence loop on the raw planner/coordinator ---
    let honest = PlanCostModel::new(&cfg, Objective::Energy);
    let lying_adra = honest.adra().scaled_class(OpClass::Dual, 0.5, 1.0);
    let lying =
        PlanCostModel::with_tables(Objective::Energy, lying_adra, honest.baseline().clone());
    println!("=== calibration loop on a 2x-underpriced ADRA dual table ===");
    println!(
        "honest routing: dual -> {}   mis-calibrated routing: dual -> {}\n",
        honest.choose_class(OpClass::Dual).executor.name(),
        lying.choose_class(OpClass::Dual).executor.name()
    );
    assert_eq!(honest.choose_class(OpClass::Dual).executor, Executor::Baseline);
    assert_eq!(lying.choose_class(OpClass::Dual).executor, Executor::Adra);

    // EDP workers natively route dual -> ADRA, so the bad plan is what
    // actually runs on the array until the loop pins it away
    let coord = planned_coordinator(&cfg, SHARDS, Objective::Edp);
    let mut cal = CalibratedCostModel::new(lying, SHARDS);
    let s = analytics_scenario(&cfg, N_RECORDS, 4242);

    let mut flip_round = None;
    for round in 1..=MAX_ROUNDS {
        let pl = place_calibrated(&s.program, &cfg, SHARDS, &cal).expect("place");
        let rep = pl.execute(&coord).expect("execute");
        assert_eq!(
            rep.outputs[s.filter_step],
            StepOutput::Matches(s.expected_matches.clone()),
            "answers are routing-invariant (round {round})"
        );
        if cal.absorb(&rep.samples) {
            cal.sync_routing(&coord);
            flip_round.get_or_insert(round);
        }
        let f = cal.store().factor(0, OpClass::Dual, Executor::Adra);
        println!(
            "round {round:>2}: adra dual factor x{:.3}  error EWMA {:.4}  routing {}{}",
            f.energy,
            cal.store().class_error(OpClass::Dual).unwrap_or(0.0),
            cal.choose_class(0, OpClass::Dual).name(),
            if flip_round == Some(round) { "  <-- flip committed" } else { "" }
        );
    }
    let flip = flip_round.expect("sustained honest measurements must flip routing");
    assert!(flip >= 3, "no flip before the sustain hysteresis: {flip}");
    for shard in 0..SHARDS {
        assert_eq!(cal.choose_class(shard, OpClass::Dual), Executor::Baseline);
    }
    let err = cal.store().class_error(OpClass::Dual).expect("dual error tracked");
    assert!(err < 0.1, "error EWMA converged: {err}");

    // the pin reached the workers: the plan now predicts the measured
    // cost exactly
    let pl = place_calibrated(&s.program, &cfg, SHARDS, &cal).expect("place");
    let rep = pl.execute(&coord).expect("execute");
    assert!(rep.prediction.within(1e-6), "{}", rep.prediction.report("calibrated"));
    println!(
        "\nflip committed at round {flip}; post-flip {}",
        rep.prediction.report("calibrated")
    );
    cal.publish(adra::observe::global());

    // --- part 2: persistence across a simulated restart ---
    std::fs::create_dir_all("target").expect("create target/");
    cal.store().save(std::path::Path::new(SNAPSHOT)).expect("save snapshot");
    let loaded = CalibrationStore::load(std::path::Path::new(SNAPSHOT));
    assert!(!loaded.is_empty(), "snapshot round-trips");
    assert_eq!(loaded.committed(0, OpClass::Dual), Some(Executor::Baseline));
    println!("snapshot -> {SNAPSHOT} ({} bytes)\n", cal.store().to_json().len());

    // --- part 3: the snapshot seeds a live serve queue ("restart") ---
    println!("=== serve queue seeded from the snapshot ===");
    let shared: adra::planner::SharedCalibration = std::sync::Arc::default();
    let queue = ServeQueue::start(ServeConfig {
        cfg: cfg.clone(),
        shards: SHARDS,
        objective: Objective::Energy,
        n_records: N_RECORDS,
        max_round: 8,
        cache_capacity: 4096,
        admission: AdmissionPolicy::Fair,
        batch: BatchPolicy::Static,
        sample_every: 1,
        calibrate_every: 1,
        // the shared handle starts empty, so the queue falls back to the
        // snapshot on disk — the restart path — and then mirrors its
        // evolving store back into the handle after every absorb
        calibration_path: Some(SNAPSHOT.into()),
        calibration: Some(shared.clone()),
        store_dir: None,
        checkpoint_every: 32,
        route_retries: 2,
        retry_backoff_ms: 1,
        wear_spare_rows: 0,
        wear_migrate_threshold: 1024,
    });

    for (wave, seed) in [(1u32, 91u64), (2, 92)] {
        let scenario = heavy_tenant_scenario(&cfg, N_RECORDS, seed, 3, 2);
        let tickets: Vec<_> = scenario
            .submissions
            .iter()
            .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
            .collect();
        for (i, ticket) in tickets.into_iter().enumerate() {
            let rep = ticket.wait().expect("serve");
            assert_eq!(
                rep.outputs[scenario.filter_step],
                StepOutput::Matches(scenario.expected_matches[i].clone()),
                "served output diverged from host ground truth (wave {wave}, submission {i})"
            );
        }
        let scrape = write_scrape(
            &format!("target/calibration_scrape{wave}.prom"),
            &[
                "adra_serve_programs",
                "adra_serve_tenant_energy",
                "adra_planner_calibration",
                "adra_planner_calibration_distortion",
                "adra_planner_prediction_error",
                "adra_run_ops",
                "adra_health_status",
            ],
        );
        println!(
            "wave {wave} served -> target/calibration_scrape{wave}.prom ({} lines)",
            scrape.lines().count()
        );
    }

    // the queue loaded the snapshot, kept absorbing honest samples, and
    // mirrored its store into the shared handle without un-flipping
    let mirrored = shared.lock().expect("calibration lock").clone();
    assert!(!mirrored.is_empty(), "queue mirrors its store into the shared handle");
    for shard in 0..SHARDS {
        assert_eq!(
            mirrored.committed(shard, OpClass::Dual),
            Some(Executor::Baseline),
            "honest serving must not un-flip the committed routing"
        );
    }
    assert!(
        mirrored.max_distortion() < 4.0,
        "factors stay inside the clamp band: {}",
        mirrored.max_distortion()
    );
    let reloaded = CalibrationStore::load(std::path::Path::new(SNAPSHOT));
    assert!(!reloaded.is_empty(), "the queue keeps the on-disk snapshot fresh");
    assert_eq!(reloaded.committed(0, OpClass::Dual), Some(Executor::Baseline));
    let m = queue.metrics();
    println!(
        "\nserved {} programs / {} rounds; mirrored store: {} ",
        m.programs,
        m.rounds,
        mirrored.report().lines().last().unwrap_or("").trim()
    );

    println!("\nCALIBRATION VALIDATION PASSED");
}
