//! Multi-tenant serving through the L4 serve layer: N client threads
//! replay dashboard-style `workload`-shaped scenarios against one shared
//! table, and the `ServeQueue` coalesces their programs into shared
//! per-shard batches, fuses dual ops ACROSS tenants onto shared
//! activations, dedupes redundant loads/broadcasts, and answers repeated
//! queries from the versioned result cache.
//!
//! The run demonstrates, against naive per-program execution:
//!   (a) cross-program fused activations > 0,
//!   (b) cache hit rate > 0 on repeated scenarios,
//!   (c) lower total modeled energy AND activation count,
//! with every served output bit-identical to the naive path.
//!
//! The run doubles as the observability smoke: kernel trace events are
//! enabled, two Prometheus scrapes of the global registry are written to
//! `target/metrics_scrape{1,2}.prom` (CI's `metrics-smoke` step feeds
//! them to `scripts/check_metrics.py`), and the flight recorder's tail
//! lands in `target/serve_trace.jsonl`.
//!
//!     cargo run --release --example serving

use std::sync::{Arc, Barrier};
use std::time::Instant;

use adra::config::{SensingScheme, SimConfig};
use adra::energy::OpCost;
use adra::logic::CompareResult;
use adra::planner::{
    place, planned_coordinator, Objective, PlanCostModel, Predicate, Program, StepOutput,
};
use adra::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue, ServeReport};
use adra::util::rng::Rng;
use adra::util::table::{fmt_si, Table};
use adra::workload::heavy_tenant_scenario;

const N_RECORDS: usize = 512;
const SHARDS: usize = 4;
const TENANTS: usize = 6;
const REPEATS: usize = 3;

/// Dashboard query: `SELECT * WHERE value < threshold` + full compare
/// pass (the analytics-scenario shape with a parameterized threshold).
fn filter_program(values: &[u64], threshold: u64) -> Program {
    let mut p = Program::new(values.len());
    let t = p.scratch();
    let all = p.all();
    p.load(0, values.to_vec());
    p.broadcast(t, threshold);
    p.filter(all, t, Predicate::Lt);
    p.compare(all, t);
    p
}

/// Derived-metric query: per-record signed difference vs a reference
/// (the diff-scenario shape).
fn diff_program(values: &[u64], reference: u64) -> Program {
    let mut p = Program::new(values.len());
    let r = p.scratch();
    let all = p.all();
    p.load(0, values.to_vec());
    p.broadcast(r, reference);
    p.sub(all, r);
    p
}

fn expected_filter(values: &[u64], threshold: u64) -> Vec<StepOutput> {
    let matches: Vec<usize> = values
        .iter()
        .enumerate()
        .filter(|(_, &v)| v < threshold)
        .map(|(i, _)| i)
        .collect();
    let orderings: Vec<(usize, CompareResult)> = values
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let o = match v.cmp(&threshold) {
                std::cmp::Ordering::Less => CompareResult::Less,
                std::cmp::Ordering::Equal => CompareResult::Equal,
                std::cmp::Ordering::Greater => CompareResult::Greater,
            };
            (i, o)
        })
        .collect();
    vec![
        StepOutput::None,
        StepOutput::None,
        StepOutput::Matches(matches),
        StepOutput::Orderings(orderings),
    ]
}

fn expected_diff(values: &[u64], reference: u64) -> Vec<StepOutput> {
    let diffs: Vec<(usize, i128)> = values
        .iter()
        .enumerate()
        .map(|(i, &v)| (i, v as i128 - reference as i128))
        .collect();
    vec![StepOutput::None, StepOutput::None, StepOutput::Diffs(diffs)]
}

/// Run one concurrent wave: every tenant submits `repeats` copies of its
/// variant program from its own thread (barrier-released together).
fn run_wave(
    queue: &Arc<ServeQueue>,
    fp: &Program,
    dp: &Program,
    repeats: usize,
) -> Vec<(usize, Vec<ServeReport>)> {
    let barrier = Arc::new(Barrier::new(TENANTS));
    let handles: Vec<_> = (0..TENANTS)
        .map(|t| {
            let q = queue.clone();
            let b = barrier.clone();
            let program = if t % 2 == 0 { fp.clone() } else { dp.clone() };
            std::thread::spawn(move || {
                b.wait();
                let reports: Vec<ServeReport> = (0..repeats)
                    .map(|_| q.submit(t, program.clone()).expect("admit").wait().expect("serve"))
                    .collect();
                (t, reports)
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("tenant thread")).collect()
}

/// Write one Prometheus scrape of the global registry and sanity-check
/// the families the acceptance criteria name.
fn write_scrape(path: &str) -> String {
    let text = adra::observe::expose_text(adra::observe::global());
    for family in [
        "adra_serve_programs",
        "adra_serve_rounds",
        "adra_run_ops",
        "adra_array_det_fraction",
        "adra_planner_prediction_error",
        "adra_serve_round_wall_ns",
        "adra_observe_overhead_ns",
        "adra_health_status",
    ] {
        assert!(text.contains(family), "scrape is missing family {family}:\n{text}");
    }
    assert!(text.contains("_bucket{"), "scrape has no histogram samples:\n{text}");
    std::fs::create_dir_all("target").expect("create target/");
    std::fs::write(path, &text).expect("write scrape");
    text
}

fn main() {
    // record per-activation kernel events for the trace export (off by
    // default; the serve rounds here are far below ring capacity churn
    // that would matter)
    adra::observe::recorder().set_kernel_events(true);

    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 32;
    cfg.max_batch = 256;
    let mut rng = Rng::new(2026);
    let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(1 << 20)).collect();
    let threshold: u64 = 1 << 19;

    println!("=== multi-tenant serving layer ===");
    println!(
        "{TENANTS} tenants x {REPEATS} replays, {N_RECORDS} records of {} bits, \
         {SHARDS}x {}x{} FeFET shards, scheme: {}, fidelity tier: {}\n",
        cfg.word_bits, cfg.rows, cfg.cols, cfg.scheme.name(), cfg.tier.name()
    );

    // --- naive reference: per-program execution (no fusion, dedup, cache)
    let model = PlanCostModel::new(&cfg, Objective::Edp);
    let naive_coord = planned_coordinator(&cfg, SHARDS, Objective::Edp);
    let naive_of = |p: &Program| {
        let pl = place(p, &cfg, SHARDS, &model).expect("place");
        let dual: usize = pl
            .shards
            .iter()
            .flat_map(|sp| sp.lowered.ops.iter())
            .filter(|r| r.op.is_dual())
            .count();
        let rep = pl.execute(&naive_coord).expect("naive execution");
        (rep.outputs, rep.measured, dual)
    };
    let fp = filter_program(&values, threshold);
    let dp = diff_program(&values, threshold);
    let (nf_out, nf_cost, nf_dual) = naive_of(&fp);
    let (nd_out, nd_cost, nd_dual) = naive_of(&dp);
    assert_eq!(nf_out, expected_filter(&values, threshold), "naive == host truth");
    assert_eq!(nd_out, expected_diff(&values, threshold), "naive == host truth");

    // --- serve the same workload through the queue ---
    let queue = Arc::new(ServeQueue::start(ServeConfig {
        cfg: cfg.clone(),
        shards: SHARDS,
        objective: Objective::Edp,
        n_records: N_RECORDS,
        max_round: 32,
        cache_capacity: 4096,
        admission: AdmissionPolicy::Fair,
        batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
        sample_every: 1,
        calibrate_every: 1,
        calibration_path: None,
        calibration: None,
        store_dir: None,
        checkpoint_every: 32,
        route_retries: 2,
        retry_backoff_ms: 1,
        wear_spare_rows: 0,
        wear_migrate_threshold: 1024,
    }));
    let t0 = Instant::now();
    let wave = run_wave(&queue, &fp, &dp, REPEATS);
    let serve_wall = t0.elapsed().as_secs_f64();

    let mut serve_cost = OpCost::default();
    let mut naive_cost = OpCost::default();
    let mut naive_activations = 0usize;
    let mut programs_served = 0usize;
    let mut verify = |t: usize, reports: &[ServeReport]| {
        let (want, ncost, ndual) = if t % 2 == 0 {
            (&nf_out, nf_cost, nf_dual)
        } else {
            (&nd_out, nd_cost, nd_dual)
        };
        for rep in reports {
            assert_eq!(&rep.outputs, want, "tenant {t} diverged from the naive path");
            serve_cost = serve_cost.then(&rep.measured);
            naive_cost = naive_cost.then(&ncost);
            naive_activations += ndual;
            programs_served += 1;
        }
    };
    for (t, reports) in &wave {
        verify(*t, reports);
    }

    // cross-program fusion needs >= 2 uncached programs in one round;
    // under pathological scheduling every round could have ended up
    // singleton, so replay cold waves (fresh thresholds, nothing cached)
    // until the counter moves.  One wave virtually always suffices.
    let mut extra_waves = 0;
    while queue.metrics().cross_program_fused_ops == 0 && extra_waves < 16 {
        extra_waves += 1;
        let th = threshold + 1000 * extra_waves as u64;
        let fp2 = filter_program(&values, th);
        let dp2 = diff_program(&values, th);
        let wave2 = run_wave(&queue, &fp2, &dp2, 1);
        let ef = expected_filter(&values, th);
        let ed = expected_diff(&values, th);
        for (t, reports) in &wave2 {
            let want = if t % 2 == 0 { &ef } else { &ed };
            for rep in reports {
                assert_eq!(&rep.outputs, want, "tenant {t} diverged (wave {extra_waves})");
                serve_cost = serve_cost.then(&rep.measured);
                // per-kind naive cost is threshold-independent (same op mix)
                let (ncost, ndual) =
                    if t % 2 == 0 { (nf_cost, nf_dual) } else { (nd_cost, nd_dual) };
                naive_cost = naive_cost.then(&ncost);
                naive_activations += ndual;
                programs_served += 1;
            }
        }
    }

    let m = queue.metrics();
    println!("all {programs_served} served programs bit-identical to the naive path\n");
    println!("{}", m.report("serve-layer"));
    for line in m.tenant_report() {
        println!("  {line}");
    }

    let mut t = Table::new(&["metric", "naive per-program", "served (coalesced)", "saving"])
        .with_title("serve vs naive, same workload");
    t.row(&[
        "modeled energy".into(),
        fmt_si(naive_cost.energy.total(), "J"),
        fmt_si(serve_cost.energy.total(), "J"),
        format!(
            "{:.1}%",
            (1.0 - serve_cost.energy.total() / naive_cost.energy.total()) * 100.0
        ),
    ]);
    t.row(&[
        "activations".into(),
        format!("{naive_activations}"),
        format!("{}", m.activations),
        format!("{:.1}%", (1.0 - m.activations as f64 / naive_activations as f64) * 100.0),
    ]);
    t.row(&[
        "writes".into(),
        format!("{}", (N_RECORDS + SHARDS * cfg.words_per_row()) * programs_served),
        format!(
            "{}",
            (N_RECORDS + SHARDS * cfg.words_per_row()) * programs_served
                - m.skipped_writes as usize
        ),
        format!("{} deduped", m.skipped_writes),
    ]);
    t.print();
    println!("\nserve wall time (main wave): {serve_wall:.3} s, {} rounds", m.rounds);
    println!(
        "activations served per tier ({} configured): digital {} / analog {} \
         ({} xval mismatches)",
        cfg.tier.name(),
        m.array_digital_activations,
        m.array_dual_activations - m.array_digital_activations,
        m.array_xval_mismatches
    );
    assert!(
        m.array_digital_activations > 0,
        "serve rounds must ride the packed digital path end-to-end"
    );
    assert_eq!(m.array_xval_mismatches, 0);

    // --- the acceptance criteria, asserted ---
    assert!(
        m.cross_program_fused_ops > 0,
        "(a) cross-program fusion must occur: {}",
        m.report("serve")
    );
    assert!(m.cache_hit_rate() > 0.0, "(b) repeats must hit the cache");
    assert!(
        serve_cost.energy.total() < naive_cost.energy.total(),
        "(c) energy: serve {:e} vs naive {:e}",
        serve_cost.energy.total(),
        naive_cost.energy.total()
    );
    assert!(
        (m.activations as usize) < naive_activations,
        "(c) activations: serve {} vs naive {naive_activations}",
        m.activations
    );

    // first observability scrape: the main wave's counters are published
    let scrape1 = write_scrape("target/metrics_scrape1.prom");
    println!(
        "\nmetrics scrape 1 -> target/metrics_scrape1.prom ({} lines)",
        scrape1.lines().count()
    );

    // === part 2: the adaptive control plane under a heavy tenant ===
    println!("\n=== control plane: heavy-tenant flood, FIFO vs weighted fair ===");
    let scenario = heavy_tenant_scenario(&cfg, N_RECORDS, 2027, 16, 4);
    println!(
        "tenant 0 floods {} programs, tenants 1..=4 submit one each (all queued first-come)\n",
        16
    );

    let run_mode = |admission: AdmissionPolicy, batch: BatchPolicy| {
        let q = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: SHARDS,
            objective: Objective::Edp,
            n_records: N_RECORDS,
            max_round: 8,
            cache_capacity: 4096,
            admission,
            batch,
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
        });
        // the adversarial pattern: the whole flood is queued before any
        // light tenant's program, exactly as a burst arrives in practice
        let tickets: Vec<_> = scenario
            .submissions
            .iter()
            .map(|(t, p)| q.submit(*t, p.clone()).expect("admit"))
            .collect();
        let reports: Vec<ServeReport> =
            tickets.into_iter().map(|t| t.wait().expect("serve")).collect();
        for (rep, want) in reports.iter().zip(&scenario.expected_matches) {
            assert_eq!(
                rep.outputs[scenario.filter_step],
                StepOutput::Matches(want.clone()),
                "served output diverged from host ground truth"
            );
        }
        (reports, q.metrics())
    };

    let (fifo_reports, fifo_m) =
        run_mode(AdmissionPolicy::Fifo, BatchPolicy::Static);
    let (fair_reports, fair_m) =
        run_mode(AdmissionPolicy::Fair, BatchPolicy::Adaptive { target_p95: 2e-3 });

    let light_last = |reports: &[ServeReport]| {
        reports[16..].iter().map(|r| r.round).max().unwrap()
    };
    let heavy_last = |reports: &[ServeReport]| {
        reports[..16].iter().map(|r| r.round).max().unwrap()
    };
    // starvation-freedom, asserted: with WFQ the light tenants are served
    // while the flood still has backlog — never after it drains
    assert!(
        light_last(&fair_reports) <= heavy_last(&fair_reports),
        "fair admission must not park light tenants behind the flood: light {} heavy {}",
        light_last(&fair_reports),
        heavy_last(&fair_reports)
    );

    let mut t = Table::new(&["metric", "FIFO + static", "fair + adaptive"])
        .with_title("control plane under the flood");
    t.row(&[
        "non-heavy p95 wall".into(),
        format!("{:.1} us", fifo_m.p95_ns_excluding(0) / 1e3),
        format!("{:.1} us", fair_m.p95_ns_excluding(0) / 1e3),
    ]);
    t.row(&[
        "light tenants' last round".into(),
        format!("{}", light_last(&fifo_reports)),
        format!("{}", light_last(&fair_reports)),
    ]);
    t.row(&[
        "quota hits / deferrals".into(),
        format!("{} / {}", fifo_m.quota_hits, fifo_m.deferred_programs),
        format!("{} / {}", fair_m.quota_hits, fair_m.deferred_programs),
    ]);
    t.row(&[
        "controller max_round (+/-/=)".into(),
        format!(
            "{} ({}/{}/{})",
            fifo_m.current_max_round,
            fifo_m.controller_grows,
            fifo_m.controller_shrinks,
            fifo_m.controller_holds
        ),
        format!(
            "{} ({}/{}/{})",
            fair_m.current_max_round,
            fair_m.controller_grows,
            fair_m.controller_shrinks,
            fair_m.controller_holds
        ),
    ]);
    t.row(&[
        "cache evictions / swept".into(),
        format!("{} / {}", fifo_m.cache_evictions, fifo_m.cache_swept),
        format!("{} / {}", fair_m.cache_evictions, fair_m.cache_swept),
    ]);
    t.print();

    // negative-result caching: a dashboard polling an empty WHERE clause
    let nq = ServeQueue::start(ServeConfig::new(cfg.clone(), SHARDS, N_RECORDS));
    let mut empty = Program::new(N_RECORDS);
    let es = empty.scratch();
    let eall = empty.all();
    empty.load(0, scenario.values.clone());
    empty.broadcast(es, 0);
    empty.filter(eall, es, Predicate::Lt); // v < 0: never matches
    let e1 = nq.submit(0, empty.clone()).expect("admit").wait().expect("serve");
    assert_eq!(e1.outputs[2], StepOutput::Matches(Vec::new()));
    let e2 = nq.submit(0, empty).expect("admit").wait().expect("serve");
    assert_eq!(e2.cached_steps, 1, "repeat empty filter served from the negative cache");
    assert_eq!(e2.measured.energy.total(), 0.0);
    let nm = nq.metrics();
    assert!(nm.negative_hits >= 1);
    println!(
        "\nnegative cache: repeated empty filter served for free ({} negative hits)",
        nm.negative_hits
    );

    // second scrape after the flood + negative-cache runs: counters must
    // have kept moving (check_metrics.py verifies monotonicity)
    let scrape2 = write_scrape("target/metrics_scrape2.prom");
    println!(
        "metrics scrape 2 -> target/metrics_scrape2.prom ({} lines)",
        scrape2.lines().count()
    );

    // flight-recorder tail: serve spans + kernel activation events
    let rec = adra::observe::recorder();
    let trace = rec.to_jsonl();
    assert!(
        trace.contains("\"kind\":\"span\"") || rec.dropped() > 0,
        "trace must hold serve spans"
    );
    assert!(
        trace.contains("\"kind\":\"kernel\""),
        "kernel events were enabled; the tail must hold activation events"
    );
    std::fs::write("target/serve_trace.jsonl", &trace).expect("write trace");
    println!(
        "trace tail -> target/serve_trace.jsonl ({} events, {} dropped by the ring)",
        trace.lines().count(),
        rec.dropped()
    );

    println!("\nSERVING VALIDATION PASSED");
}
