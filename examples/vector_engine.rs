//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! An image-diff pipeline (the signal-processing scenario from the
//! paper's introduction) runs through:
//!   L3  the threaded coordinator (router -> batcher -> shard workers),
//!   L3  the ADRA engine (sensing + Fig. 3(d) compute modules),
//!   L1/L2  the AOT-compiled JAX/Pallas analog model executed over PJRT
//!          on shard 0 (ground-truth senseline physics) with the Rust
//!          behavioral mirror on the other shards,
//! and every in-memory result is validated against the software ground
//! truth.  Energy / latency / EDP vs the near-memory baseline are
//! reported at the end.  Recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example vector_engine

use adra::cim::{AdraEngine, BaselineEngine, CimValue, Engine};
use adra::config::{SensingScheme, SimConfig};
use adra::coordinator::Coordinator;
use adra::energy::{Improvement, OpCost};
use adra::runtime::{AnalogRuntime, ArtifactManifest, PjrtBackend};
use adra::util::table::{fmt_pct, fmt_si};
use adra::workload::image_diff_trace;

fn main() {
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 16;
    let shards = 4usize;
    let n_pixels_per_shard = 512usize;

    println!("=== ADRA end-to-end: in-memory image diff ===");
    println!(
        "array 256x256, 16-bit words, {shards} shards, {} pixels total\n",
        shards * n_pixels_per_shard
    );

    // L1/L2: PJRT runtime over the AOT artifacts for shard 0
    let pjrt_available = ArtifactManifest::load_default().is_ok();
    if !pjrt_available {
        println!("NOTE: artifacts/ missing — run `make artifacts`; all shards use the behavioral mirror\n");
    }
    let cfg2 = cfg.clone();
    let coord = Coordinator::new(&cfg, shards, move |shard| -> Box<dyn Engine> {
        if shard == 0 && pjrt_available {
            let rt = AnalogRuntime::from_default_artifacts()
                .expect("PJRT runtime init");
            println!("shard 0: analog backend = JAX/Pallas AOT over PJRT ({})", rt.platform());
            Box::new(AdraEngine::with_backend(&cfg2, Box::new(PjrtBackend::new(rt))))
        } else {
            Box::new(AdraEngine::new(&cfg2))
        }
    });

    // generate per-shard traces and drive them through the coordinator
    let t0 = std::time::Instant::now();
    let mut total_ops = 0usize;
    let mut mismatches = 0usize;
    let mut adra_cost = OpCost::default();
    for shard in 0..shards {
        let (setup, diffs, expected) =
            image_diff_trace(&cfg, n_pixels_per_shard, 1000 + shard as u64);
        for op in &setup {
            coord.call(shard, *op).expect("setup write");
        }
        let results = coord.call_batch(shard, &diffs).expect("diff batch");
        for (res, want) in results.iter().zip(&expected) {
            let res = res.as_ref().expect("diff op");
            adra_cost = adra_cost.then(&res.cost);
            total_ops += 1;
            if res.value != CimValue::Diff(*want) {
                mismatches += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // the same workload on the near-memory baseline (single engine is
    // fine — we only need modeled energy/latency + correctness)
    let mut base = BaselineEngine::new(&cfg);
    let mut base_cost = OpCost::default();
    let (setup, diffs, expected) = image_diff_trace(&cfg, n_pixels_per_shard, 1000);
    for op in &setup {
        base.execute(op).expect("baseline setup");
    }
    for (op, want) in diffs.iter().zip(&expected) {
        let r = base.execute(op).expect("baseline diff");
        assert_eq!(r.value, CimValue::Diff(*want), "baseline mismatch");
        base_cost = base_cost.then(&r.cost);
    }
    // scale the single-shard baseline cost to the full workload
    let base_cost = OpCost {
        energy: base_cost.energy.scale(shards as f64),
        latency: base_cost.latency * shards as f64,
    };

    println!("\n--- results ---");
    println!(
        "{total_ops} in-memory subtractions, {mismatches} mismatches vs software ground truth"
    );
    assert_eq!(mismatches, 0, "END-TO-END VALIDATION FAILED");
    let m = coord.metrics();
    println!("{}", m.report("coordinator"));
    println!("harness wall time {wall:.3} s ({:.1} kop/s through the full stack)",
             total_ops as f64 / wall / 1e3);

    let imp = Improvement::of(&adra_cost, &base_cost);
    println!("\nADRA vs near-memory baseline on this workload (modeled):");
    println!("  energy  {} vs {}  -> decrease {}",
             fmt_si(adra_cost.energy.total(), "J"),
             fmt_si(base_cost.energy.total(), "J"),
             fmt_pct(imp.energy_decrease));
    println!("  latency {} vs {}  -> speedup {:.2}x",
             fmt_si(adra_cost.latency, "s"),
             fmt_si(base_cost.latency, "s"),
             imp.speedup);
    println!("  EDP decrease {}", fmt_pct(imp.edp_decrease));
    println!("\nEND-TO-END VALIDATION PASSED");
}
