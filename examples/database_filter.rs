//! Database analytics scenario: `SELECT * WHERE value < threshold` as
//! in-memory comparisons (the paper's §III.B comparison application).
//!
//! Stores a table of records in the FeFET array, broadcasts the query
//! threshold into one row, and filters with single-access ADRA compares.
//! The baseline runs the same query with two-read near-memory compares.
//!
//!     cargo run --release --example database_filter

use adra::cim::aggregate::AggregateEngine;
use adra::cim::{AdraEngine, BaselineEngine, CimOp, CimValue, Engine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::energy::{Improvement, OpCost};
use adra::logic::CompareResult;
use adra::util::table::{fmt_pct, fmt_si, Table};
use adra::workload::database_filter_trace;

fn main() {
    let mut cfg = SimConfig::square(512, SensingScheme::VoltageDischarged);
    cfg.word_bits = 32;
    let n_records = 2048;

    println!("=== in-memory database filter ===");
    println!(
        "{} records of {} bits, 512x512 FeFET array, scheme: {}\n",
        n_records,
        cfg.word_bits,
        cfg.scheme.name()
    );

    let trace = database_filter_trace(&cfg, n_records, 2026);
    println!(
        "query: SELECT * WHERE value < {} ({} ground-truth matches)",
        trace.threshold,
        trace.expected_matches.len()
    );

    // --- ADRA engine ---
    let mut adra = AdraEngine::new(&cfg);
    for op in &trace.setup {
        adra.execute(op).unwrap();
    }
    let mut adra_cost = OpCost::default();
    let mut matches = Vec::new();
    for (i, op) in trace.query.iter().enumerate() {
        let r = adra.execute(op).unwrap();
        adra_cost = adra_cost.then(&r.cost);
        if r.value == CimValue::Ordering(CompareResult::Less) {
            matches.push(i);
        }
    }
    assert_eq!(matches, trace.expected_matches, "ADRA filter diverged from ground truth");
    let accesses = adra.array().stats().dual_activations;
    println!("ADRA: {} matches, {} array accesses ({} per compare)",
             matches.len(), accesses, accesses as f64 / n_records as f64);

    // --- baseline engine ---
    let mut base = BaselineEngine::new(&cfg);
    for op in &trace.setup {
        base.execute(op).unwrap();
    }
    let mut base_cost = OpCost::default();
    let mut base_matches = Vec::new();
    for (i, op) in trace.query.iter().enumerate() {
        let r = base.execute(op).unwrap();
        base_cost = base_cost.then(&r.cost);
        if r.value == CimValue::Ordering(CompareResult::Less) {
            base_matches.push(i);
        }
    }
    assert_eq!(base_matches, trace.expected_matches);
    let reads = base.array().stats().reads;
    println!("baseline: {} matches, {} reads ({} per compare)",
             base_matches.len(), reads, reads as f64 / n_records as f64);

    // --- comparison ---
    let imp = Improvement::of(&adra_cost, &base_cost);
    let mut t = Table::new(&["metric", "ADRA", "baseline", "improvement"])
        .with_title("query cost (modeled device energy/latency)");
    t.row(&[
        "energy".into(),
        fmt_si(adra_cost.energy.total(), "J"),
        fmt_si(base_cost.energy.total(), "J"),
        fmt_pct(imp.energy_decrease),
    ]);
    t.row(&[
        "latency".into(),
        fmt_si(adra_cost.latency, "s"),
        fmt_si(base_cost.latency, "s"),
        format!("{:.2}x", imp.speedup),
    ]);
    t.row(&[
        "EDP".into(),
        format!("{:.3e}", adra_cost.edp()),
        format!("{:.3e}", base_cost.edp()),
        fmt_pct(imp.edp_decrease),
    ]);
    t.print();

    // --- aggregate queries on top of the same table ---
    println!("\n--- aggregate queries (cim::aggregate) ---");
    let lo_row = trace.threshold_row; // reuse: lo = threshold
    let hi_row = trace.threshold_row + 1;
    let hi_val = trace.threshold + (trace.threshold / 2);
    for w in 0..adra.cfg().words_per_row() {
        adra.execute(&CimOp::Write { addr: WordAddr { row: hi_row, word: w }, value: hi_val })
            .unwrap();
    }
    let mut agg = AggregateEngine::new(&mut adra);
    let range = agg.range_filter(&trace.records, lo_row, hi_row).unwrap();
    let want: Vec<usize> = trace
        .values
        .iter()
        .enumerate()
        .filter(|(_, &v)| v >= trace.threshold && v < hi_val)
        .map(|(i, _)| i)
        .collect();
    assert_eq!(range.result, want, "range filter diverged");
    println!(
        "range [k, 1.5k): {} matches, {} activations, {}",
        range.result.len(),
        range.activations,
        fmt_si(range.cost.energy.total(), "J")
    );
    let min = agg.min_scan(&trace.records[..256]).unwrap();
    let want_min = (0..256).min_by_key(|&i| trace.values[i]).unwrap();
    assert_eq!(trace.values[min.result], trace.values[want_min]);
    println!(
        "min scan over 256 records: value {} ({} activations)",
        trace.values[min.result], min.activations
    );
    println!("\nFILTER VALIDATION PASSED");
}
