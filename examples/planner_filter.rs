//! Database analytics through the QUERY PLANNER: the `database_filter`
//! scenario (`SELECT * WHERE value < k`, paper §III.B) rewritten as an IR
//! program that the planner prices, routes, shards, and executes — no
//! hand-built `CimOp` streams.
//!
//! The pipeline: `workload::analytics_scenario` builds the program,
//! `planner::place` splits it across a 4-shard coordinator and lowers
//! each slice through the calibrated cost tables, and
//! `Placement::execute` runs everything in parallel on cost-routed
//! `PlannedEngine` workers, then reports predicted vs measured cost.
//!
//!     cargo run --release --example planner_filter

use adra::config::{SensingScheme, SimConfig};
use adra::planner::{place, planned_coordinator, Objective, OpClass, PlanCostModel, Reduction, StepOutput};
use adra::util::table::{fmt_pct, fmt_si, Table};
use adra::workload::analytics_scenario;

fn main() {
    let mut cfg = SimConfig::square(512, SensingScheme::VoltageDischarged);
    cfg.word_bits = 32;
    cfg.max_batch = 256;
    let n_records = 2048;
    let shards = 4;
    let objective = Objective::Edp;

    println!("=== cost-model-driven query planner ===");
    println!(
        "{n_records} records of {} bits, {shards}x {}x{} FeFET shards, scheme: {}, objective: {}\n",
        cfg.word_bits,
        cfg.rows,
        cfg.cols,
        cfg.scheme.name(),
        objective.name()
    );

    // --- the program: filter + compare + aggregate, as IR ---
    let scenario = analytics_scenario(&cfg, n_records, 2026);
    println!(
        "program: SELECT * WHERE value < {} ({} ground-truth matches), \
         full compare pass, MIN aggregate",
        scenario.threshold,
        scenario.expected_matches.len()
    );

    // --- the cost model: price both executors, show the routing ---
    let model = PlanCostModel::new(&cfg, objective);
    let mut t = Table::new(&["op class", "ADRA", "baseline", "routed to"])
        .with_title("per-op price tables (modeled energy)");
    for (label, class) in [
        ("read", OpClass::Read),
        ("write", OpClass::Write),
        ("commutative CiM", OpClass::Commutative),
        ("dual (sub/cmp/read2)", OpClass::Dual),
    ] {
        t.row(&[
            label.into(),
            fmt_si(model.adra().price_class(class).cost.energy.total(), "J"),
            fmt_si(model.baseline().price_class(class).cost.energy.total(), "J"),
            model.choose_class(class).executor.name().into(),
        ]);
    }
    t.print();

    // --- place across the worker pool ---
    let placement = place(&scenario.program, &cfg, shards, &model).expect("placement");
    let (adra_ops, baseline_ops) = placement
        .shards
        .iter()
        .fold((0, 0), |(a, b), s| {
            let (sa, sb) = s.lowered.executor_counts();
            (a + sa, b + sb)
        });
    println!(
        "\nplacement: {} shards, {} lowered ops ({adra_ops} -> ADRA, {baseline_ops} -> baseline), \
         {} predicted array accesses",
        placement.shards.len(),
        placement.shards.iter().map(|s| s.lowered.ops.len()).sum::<usize>(),
        placement.predicted_accesses
    );
    println!(
        "predicted: {} serial, makespan {} across {} shards",
        fmt_si(placement.predicted.latency, "s"),
        fmt_si(placement.predicted_makespan, "s"),
        placement.shards.len()
    );
    let (fused, activations) = placement.shards[0].lowered.fused_prediction(&model);
    println!(
        "shard 0 fusion forecast: {} activations for {} dual ops, {} vs {} unfused",
        activations,
        placement.shards[0]
            .lowered
            .ops
            .iter()
            .filter(|r| r.op.is_dual())
            .count(),
        fmt_si(fused.energy.total(), "J"),
        fmt_si(placement.shards[0].lowered.predicted.energy.total(), "J"),
    );

    // --- execute on the cost-routed coordinator ---
    let coord = planned_coordinator(&cfg, shards, objective);
    let t0 = std::time::Instant::now();
    let report = placement.execute(&coord).expect("execution");
    let wall = t0.elapsed().as_secs_f64();

    // --- validate every output against ground truth ---
    match &report.outputs[scenario.filter_step] {
        StepOutput::Matches(m) => {
            assert_eq!(m, &scenario.expected_matches, "filter diverged from ground truth");
            println!("\nfilter: {} matches (ground truth confirmed)", m.len());
        }
        other => panic!("expected matches, got {other:?}"),
    }
    match &report.outputs[scenario.compare_step] {
        StepOutput::Orderings(o) => {
            assert_eq!(o.len(), n_records);
            println!("compare: {} orderings returned", o.len());
        }
        other => panic!("expected orderings, got {other:?}"),
    }
    match &report.outputs[scenario.aggregate_step] {
        StepOutput::Reduced(Reduction::Min { index, value }) => {
            assert_eq!(*index, scenario.expected_min_index, "min aggregate diverged");
            println!("aggregate: MIN = {value} at record {index} (via plain reads)");
        }
        other => panic!("expected min reduction, got {other:?}"),
    }

    // --- predicted vs measured ---
    println!("\n{}", report.prediction.report("planner"));
    assert!(
        report.prediction.within(0.2),
        "prediction outside the 20% budget: {}",
        report.prediction.report("planner")
    );
    let mut c = Table::new(&["metric", "predicted", "measured", "error"])
        .with_title("planner prediction vs coordinator measurement");
    c.row(&[
        "energy".into(),
        fmt_si(report.prediction.predicted.energy.total(), "J"),
        fmt_si(report.prediction.measured.energy.total(), "J"),
        fmt_pct(report.prediction.energy_error()),
    ]);
    c.row(&[
        "latency (serial)".into(),
        fmt_si(report.prediction.predicted.latency, "s"),
        fmt_si(report.prediction.measured.latency, "s"),
        fmt_pct(report.prediction.latency_error()),
    ]);
    c.print();
    println!(
        "\n{} ops executed on {} shards in {wall:.3}s wall ({})",
        report.ops_executed,
        placement.shards.len(),
        report.coordinator_metrics.report("coordinator"),
    );
    println!("\nPLANNER VALIDATION PASSED");
}
