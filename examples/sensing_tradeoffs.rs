//! Sensing-scheme trade-off explorer (the paper's Fig. 5 analysis as a
//! tool): sweep CiM frequency and parallelism, print which voltage
//! sensing scheme wins where, and report the crossovers.
//!
//!     cargo run --release --example sensing_tradeoffs

use adra::config::{SensingScheme, SimConfig};
use adra::energy::EnergyModel;
use adra::figures::fig5_tradeoffs::{crossover_frequency, crossover_parallelism};
use adra::util::table::{fmt_si, Table};

fn main() {
    println!("voltage-sensing scheme selection for ADRA CiM\n");
    println!("scheme 1: RBL precharged during hold (fast, leaks, half-select cost)");
    println!("scheme 2: RBL discharged during hold (charge per op, no leak)\n");

    for size in [256usize, 512, 1024] {
        let f_x = crossover_frequency(size);
        let p_x = crossover_parallelism(size);
        println!(
            "{size}x{size}: scheme 2 wins below {} or parallelism < {:.0}%",
            fmt_si(f_x, "Hz"),
            p_x * 100.0
        );
    }

    let size = 1024;
    let m = EnergyModel::new(&SimConfig::square(size, SensingScheme::VoltagePrecharged));
    let mut t = Table::new(&["frequency", "scheme 1", "scheme 2", "winner"])
        .with_title(format!("energy per CiM word-op vs frequency ({size}x{size})"));
    for f in [1e6, 2e6, 5e6, 7.53e6, 10e6, 50e6, 100e6] {
        let e1 = m.cim_energy_at_frequency(SensingScheme::VoltagePrecharged, f);
        let e2 = m.cim_energy_at_frequency(SensingScheme::VoltageDischarged, f);
        t.row(&[
            fmt_si(f, "Hz"),
            fmt_si(e1, "J"),
            fmt_si(e2, "J"),
            if e1 < e2 { "scheme 1" } else { "scheme 2" }.to_string(),
        ]);
    }
    t.print();

    let mut t2 = Table::new(&["parallelism", "scheme 1", "scheme 2", "winner"])
        .with_title(format!("energy per row activation vs parallelism ({size}x{size})"));
    for i in [1usize, 4, 8, 13, 14, 20, 32] {
        let p = i as f64 / 32.0;
        let e1 = m.row_activation_energy(SensingScheme::VoltagePrecharged, p);
        let e2 = m.row_activation_energy(SensingScheme::VoltageDischarged, p);
        t2.row(&[
            format!("{}/32 words", i),
            fmt_si(e1, "J"),
            fmt_si(e2, "J"),
            if e1 < e2 { "scheme 1" } else { "scheme 2" }.to_string(),
        ]);
    }
    t2.print();

    println!("\npaper reference points: 7.53 MHz frequency crossover, ~42% parallelism crossover");
}
