//! Quickstart: write two words, run ADRA's single-access CiM ops, and
//! compare against the two-read near-memory baseline.
//!
//!     cargo run --release --example quickstart

use adra::cim::{AdraEngine, BaselineEngine, BoolFn, CimOp, CimValue, Engine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::energy::Improvement;
use adra::util::table::{fmt_pct, fmt_si};

fn main() {
    // a 256x256 1T-FeFET array, 32-bit words, current-based sensing
    let cfg = SimConfig::square(256, SensingScheme::Current);
    let mut adra = AdraEngine::new(&cfg);
    let mut base = BaselineEngine::new(&cfg);

    let (a, b) = (1_000_000u64, 123_456u64);
    for e in [&mut adra as &mut dyn Engine, &mut base as &mut dyn Engine] {
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b }).unwrap();
    }

    println!("stored A = {a}, B = {b} in rows 0/1 of a 256x256 FeFET array\n");

    // --- the paper's headline op: single-access in-memory subtraction ---
    let sub = adra.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
    println!("ADRA  A - B = {:?}   (ONE memory access)", sub.value.diff().unwrap());
    let bsub = base.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
    println!("base  A - B = {:?}   (TWO reads + near-memory compute)", bsub.value.diff().unwrap());

    let imp = Improvement::of(&sub.cost, &bsub.cost);
    println!(
        "      energy {} vs {}  (decrease {})",
        fmt_si(sub.cost.energy.total(), "J"),
        fmt_si(bsub.cost.energy.total(), "J"),
        fmt_pct(imp.energy_decrease)
    );
    println!(
        "      latency {} vs {}  (speedup {:.2}x), EDP decrease {}\n",
        fmt_si(sub.cost.latency, "s"),
        fmt_si(bsub.cost.latency, "s"),
        imp.speedup,
        fmt_pct(imp.edp_decrease)
    );

    // --- 2-bit read + every Boolean function from the same access type ---
    let pair = adra.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
    if let CimValue::Pair(ra, rb) = pair.value {
        println!("ADRA read2: A = {ra}, B = {rb} recovered from a single access");
    }
    for f in [BoolFn::And, BoolFn::Or, BoolFn::Xor, BoolFn::AndNot] {
        let r = adra.execute(&CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 }).unwrap();
        println!("  {f:?}(A,B) = {:#x}", r.value.word().unwrap());
    }

    // --- comparison ---
    let cmp = adra.execute(&CimOp::Compare { row_a: 0, row_b: 1, word: 0 }).unwrap();
    println!("\nADRA compare(A,B) = {:?} (sign of the in-memory A-B)", cmp.value);

    // --- and the reason the baseline can't do this in one access ---
    match base.try_single_access_sub(0, 1, 0) {
        Err(e) => println!("\nbaseline single-access subtraction: {e}"),
        Ok(v) => println!("\nbaseline single-access subtraction (lucky data): {v}"),
    }
}
