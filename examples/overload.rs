//! End-to-end overload-survival demo (DESIGN.md §15): the serve queue
//! is pushed past capacity and through injected faults, and proves the
//! four survival mechanisms one by one —
//!
//!   1. deadlines + cancellation: an expired program is swept BEFORE
//!      placement and never drives the array; a tenant-wide cancel
//!      dooms a queued backlog in one sweep,
//!   2. load shedding: a burst past the per-tenant backlog bound
//!      answers `Rejected(Overloaded)` immediately instead of queueing
//!      to time out — and every answered program stays bit-identical,
//!   3. circuit breaking: a dead shard opens its breaker after the
//!      retry budget is spent, placements fail fast with
//!      `Rejected(ShardDown)`, and a half-open respawn-and-replay probe
//!      heals the shard,
//!   4. brownout: sustained SLO burn steps the degrade ladder up
//!      (pinned routing -> tighter cache -> reduced sampling -> shed);
//!      clearing the overload walks it back to normal.
//!
//! Artifacts (CI's `overload-smoke` job consumes all three):
//!   target/overload_scrape1.prom   scrape at peak overload
//!   target/overload_scrape2.prom   scrape after recovery
//!   target/overload_trace.jsonl    flight-recorder tail incl. alerts
//!
//!     cargo run --release --example overload

use std::time::Duration;

use adra::config::{SensingScheme, SimConfig};
use adra::faults::{self, FaultSpec};
use adra::planner::StepOutput;
use adra::serve::{
    BatchPolicy, RejectReason, ServeConfig, ServeError, ServeQueue, SubmitOptions,
};
use adra::workload::heavy_tenant_scenario;
use adra::workload::programs::analytics_scenario;

const N_RECORDS: usize = 48;
const SHARDS: usize = 2;

fn base_cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::Current);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

fn serve_cfg(cfg: &SimConfig, shards: usize) -> ServeConfig {
    let mut sc = ServeConfig::new(cfg.clone(), shards, N_RECORDS);
    sc.max_round = 4;
    sc.cache_capacity = 512;
    sc.batch = BatchPolicy::Static;
    sc.sample_every = 0;
    sc.calibrate_every = 0;
    sc
}

/// Write one Prometheus scrape of the global registry and sanity-check
/// the families the overload pipeline must expose.
fn write_scrape(path: &str, families: &[&str]) -> String {
    let text = adra::observe::expose_text(adra::observe::global());
    for family in families {
        assert!(text.contains(family), "scrape is missing family {family}:\n{text}");
    }
    std::fs::create_dir_all("target").expect("create target/");
    std::fs::write(path, &text).expect("write scrape");
    text
}

fn main() {
    let cfg = base_cfg();

    // ---- act 1: deadlines + cancellation --------------------------------
    println!("=== act 1: deadlines + tenant cancellation ===");
    let queue = ServeQueue::start(serve_cfg(&cfg, SHARDS));
    let s = analytics_scenario(&cfg, N_RECORDS, 11);
    let (ticket, _h) = queue
        .submit_with(0, s.program.clone(), SubmitOptions { deadline: Some(Duration::ZERO) })
        .expect("admit");
    assert!(matches!(ticket.wait(), Err(ServeError::DeadlineExceeded)));
    let m = queue.metrics();
    assert_eq!((m.deadline_expired, m.rounds), (1, 0), "expired program never ran: {m:?}");
    println!("zero-deadline program swept before placement (0 rounds executed)");

    // under multi-ms spiked rounds a tenant-wide cancel lands while the
    // backlog is still deep, and the sweep dooms what remains queued
    faults::install(FaultSpec::parse("seed=5 spike=8 spike-ns=2000000").expect("spec"));
    let sc = heavy_tenant_scenario(&cfg, N_RECORDS, 404, 12, 3);
    let tickets: Vec<_> = sc
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();
    let swept = queue.cancel_tenant(sc.heavy_tenant).expect("queue alive");
    let mut cancelled = 0usize;
    for (i, ((tenant, _), ticket)) in sc.submissions.iter().zip(tickets).enumerate() {
        match ticket.wait() {
            Ok(rep) => assert_eq!(
                rep.outputs[sc.filter_step],
                StepOutput::Matches(sc.expected_matches[i].clone()),
                "survivors answer bit-identically"
            ),
            Err(ServeError::Cancelled) => {
                assert_eq!(*tenant, sc.heavy_tenant);
                cancelled += 1;
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    faults::clear();
    assert_eq!(cancelled, swept);
    assert!(swept >= 1, "the sweep must land before a spiked backlog drains");
    println!("cancel_tenant swept {swept}/12 heavy programs; every survivor exact\n");
    drop(queue);

    // ---- act 2: load shedding -------------------------------------------
    println!("=== act 2: bounded backlog load shedding ===");
    let mut sc2 = serve_cfg(&cfg, SHARDS);
    sc2.max_tenant_backlog = 2;
    let queue = ServeQueue::start(sc2);
    faults::install(FaultSpec::parse("seed=8 spike=8 spike-ns=2000000").expect("spec"));
    let s2 = heavy_tenant_scenario(&cfg, N_RECORDS, 2024, 20, 0);
    let tickets: Vec<_> = s2
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();
    let (mut ok, mut shed) = (0usize, 0usize);
    for (i, ticket) in tickets.into_iter().enumerate() {
        match ticket.wait() {
            Ok(rep) => {
                assert_eq!(
                    rep.outputs[s2.filter_step],
                    StepOutput::Matches(s2.expected_matches[i].clone())
                );
                ok += 1;
            }
            Err(ServeError::Rejected(RejectReason::Overloaded)) => shed += 1,
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    faults::clear();
    assert_eq!(ok + shed, 20);
    assert!(ok >= 1 && shed >= 1, "a 20-deep burst against a 2-deep bound splits");
    println!("burst of 20 against backlog bound 2: {ok} served exactly, {shed} shed\n");
    drop(queue);

    // ---- act 3: circuit breaker -----------------------------------------
    println!("=== act 3: per-shard circuit breaker ===");
    let mut sc3 = serve_cfg(&cfg, 1);
    sc3.route_retries = 0;
    sc3.breaker_threshold = 1;
    sc3.breaker_probe_after = 2;
    let queue = ServeQueue::start(sc3);
    faults::install(FaultSpec::parse("seed=2 death=1 death-max=1").expect("spec"));
    let s3 = analytics_scenario(&cfg, N_RECORDS, 31);

    let r1 = queue.submit(0, s3.program.clone()).expect("admit").wait();
    assert!(matches!(r1, Err(ServeError::Route(_))), "{r1:?}");
    assert_eq!(queue.lifecycle().expect("alive").breaker, vec!["open"]);
    println!("injected worker death exhausted the retry loop: breaker OPEN");

    let r2 = queue.submit(0, s3.program.clone()).expect("admit").wait();
    assert!(matches!(r2, Err(ServeError::Rejected(RejectReason::ShardDown))), "{r2:?}");
    println!("while open, placements fail fast: Rejected(ShardDown)");

    let rep = queue.submit(0, s3.program.clone()).expect("admit").wait().expect("healed");
    assert_eq!(rep.outputs[s3.filter_step], StepOutput::Matches(s3.expected_matches.clone()));
    let lc = queue.lifecycle().expect("alive");
    assert_eq!(lc.breaker, vec!["closed"]);
    assert_eq!((lc.breaker_opens, lc.breaker_closes), (1, 1));
    faults::clear();
    println!("half-open respawn-and-replay probe healed the shard; answer exact\n");
    drop(queue);

    // ---- act 4: brownout ladder -----------------------------------------
    println!("=== act 4: brownout ladder under SLO burn ===");
    let mut sc4 = serve_cfg(&cfg, SHARDS);
    sc4.brownout = true;
    sc4.sample_every = 1;
    let queue = ServeQueue::start(sc4);

    faults::install(FaultSpec::parse("seed=6 spike=8 spike-ns=3000000").expect("spec"));
    let mut stepped = false;
    'flood: for wave in 0..40u64 {
        let s = heavy_tenant_scenario(&cfg, N_RECORDS, 9000 + wave, 4, 0);
        let tickets: Vec<_> = s
            .submissions
            .iter()
            .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            match t.wait() {
                Ok(rep) => assert_eq!(
                    rep.outputs[s.filter_step],
                    StepOutput::Matches(s.expected_matches[i].clone()),
                    "browned-out service still answers exactly"
                ),
                Err(ServeError::Rejected(RejectReason::Overloaded)) => {}
                other => panic!("wave {wave}: unexpected outcome {other:?}"),
            }
        }
        let lc = queue.lifecycle().expect("alive");
        if lc.degrade_level >= 1 {
            println!("wave {wave}: ladder stepped up to {} (level {})", lc.degrade, lc.degrade_level);
            stepped = true;
            break 'flood;
        }
    }
    assert!(stepped, "sustained 3ms rounds against a 2ms SLO must step the ladder");
    faults::clear();

    // scrape 1: peak overload — every survival family is live
    let scrape1 = write_scrape(
        "target/overload_scrape1.prom",
        &[
            "adra_serve_shed",
            "adra_serve_deadline_expired",
            "adra_serve_cancelled",
            "adra_serve_breaker_rejected",
            "adra_serve_breaker_opens",
            "adra_serve_breaker_closes",
            "adra_serve_breaker_state",
            "adra_serve_degrade_level",
            "adra_serve_degrade_step_ups",
        ],
    );
    println!(
        "scrape 1 (peak overload) -> target/overload_scrape1.prom ({} lines)",
        scrape1.lines().count()
    );

    // recovery: chaos cleared, light traffic; the slow burn window
    // drains and every Ok health evaluation walks the ladder back down
    let mut recovered = false;
    for wave in 0..400u64 {
        let s = analytics_scenario(&cfg, N_RECORDS, 20_000 + wave);
        match queue.submit(0, s.program.clone()).expect("admit").wait() {
            Ok(rep) => assert_eq!(
                rep.outputs[s.filter_step],
                StepOutput::Matches(s.expected_matches.clone())
            ),
            Err(ServeError::Rejected(RejectReason::Overloaded)) => {}
            other => panic!("recovery wave {wave}: unexpected outcome {other:?}"),
        }
        if queue.lifecycle().expect("alive").degrade_level == 0 {
            println!("recovery wave {wave}: ladder walked back to normal");
            recovered = true;
            break;
        }
    }
    assert!(recovered, "clearing the burn must walk the ladder back");
    let m = queue.metrics();
    assert!(m.degrade_step_ups >= 1 && m.degrade_step_downs >= 1, "{m:?}");
    println!("brownout trajectory: {} step-ups, {} walk-backs\n", m.degrade_step_ups, m.degrade_step_downs);

    let scrape2 = write_scrape(
        "target/overload_scrape2.prom",
        &[
            "adra_serve_shed",
            "adra_serve_deadline_expired",
            "adra_serve_cancelled",
            "adra_serve_breaker_state",
            "adra_serve_degrade_level",
            "adra_serve_degrade_step_downs",
        ],
    );
    println!(
        "scrape 2 (post-recovery) -> target/overload_scrape2.prom ({} lines)",
        scrape2.lines().count()
    );

    // ---- the alert-trace artifact ---------------------------------------
    let trace = adra::observe::recorder().to_jsonl();
    for needle in ["\"kind\":\"alert\"", "serve_cancel", "serve_deadline", "serve_shed", "shard_breaker", "brownout"] {
        assert!(trace.contains(needle), "trace must hold {needle}:\n{trace}");
    }
    std::fs::write("target/overload_trace.jsonl", &trace).expect("write trace");
    println!(
        "trace tail -> target/overload_trace.jsonl ({} events, {} alerts)",
        trace.lines().count(),
        trace.matches("\"kind\":\"alert\"").count()
    );

    println!("\nOVERLOAD VALIDATION PASSED");
}
