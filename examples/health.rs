//! End-to-end health-engine demo: a heavy-tenant flood provably trips
//! the p95 SLO-burn and quota-starvation rules.
//!
//! The serve scheduler samples the global registry into the global
//! `SeriesStore` every round (`sample_every = 1`) and evaluates the
//! standard rule set; this example additionally runs a LOCAL
//! `HealthEngine` with deliberately tight thresholds (an SLO no real
//! round can meet) so the demo deterministically produces warn/critical
//! transitions, alerts in the flight recorder, and `adra.health.status`
//! movement between two scrapes.
//!
//! Artifacts (CI's `health-smoke` job consumes all three):
//!   target/health_scrape1.prom   scrape after the warmup wave
//!   target/health_scrape2.prom   scrape after the flood + wear demo
//!   target/health_trace.jsonl    flight-recorder tail incl. alert events
//!
//!     cargo run --release --example health

use adra::array::WearLeveler;
use adra::config::{SensingScheme, SimConfig};
use adra::observe::{Direction, HealthEngine, HealthRule, RuleState, Signal, Transition};
use adra::planner::StepOutput;
use adra::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue};
use adra::workload::heavy_tenant_scenario;

const N_RECORDS: usize = 256;
const SHARDS: usize = 2;
const HEAVY_BURST: usize = 16;
const LIGHT_TENANTS: usize = 4;

/// Write one Prometheus scrape of the global registry and sanity-check
/// the families the health pipeline must expose.
fn write_scrape(path: &str, families: &[&str]) -> String {
    let text = adra::observe::expose_text(adra::observe::global());
    for family in families {
        assert!(text.contains(family), "scrape is missing family {family}:\n{text}");
    }
    std::fs::create_dir_all("target").expect("create target/");
    std::fs::write(path, &text).expect("write scrape");
    text
}

/// The demo rule set: same signal shapes as the standard rules, but with
/// an SLO (200 ns round wall) and a starvation ceiling no flood-facing
/// queue can honour — so the transitions are deterministic, not a bet on
/// runner speed.
fn flood_rules() -> Vec<HealthRule> {
    vec![
        HealthRule {
            name: "flood_round_wall_slo_burn".to_string(),
            signal: Signal::SloBurn {
                name: "adra.serve.round_wall_ns".to_string(),
                labels: Vec::new(),
                slo_ns: 200.0,
                budget: 0.05,
                fast: 4,
                slow: 8,
            },
            direction: Direction::Above,
            warn: 1.0,
            critical: 4.0,
            sustain_up: 2,
            sustain_down: 4,
        },
        HealthRule {
            name: "flood_quota_starvation".to_string(),
            signal: Signal::WindowRatio {
                num: "adra.serve.deferred_programs".to_string(),
                num_labels: Vec::new(),
                den: "adra.serve.programs".to_string(),
                den_labels: Vec::new(),
                window: 8,
            },
            direction: Direction::Above,
            warn: 0.25,
            critical: 1.0,
            sustain_up: 2,
            sustain_down: 4,
        },
    ]
}

fn main() {
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 32;

    println!("=== health engine under a heavy-tenant flood ===");
    println!(
        "{HEAVY_BURST}-program flood + {LIGHT_TENANTS} light tenants, {N_RECORDS} records, \
         {SHARDS} shards, max_round 4 (tiny on purpose: every round defers)\n"
    );

    // the tiny round quota is the starvation forcing function: the flood
    // is always bigger than one round, so deferrals pile up every round
    let queue = ServeQueue::start(ServeConfig {
        cfg: cfg.clone(),
        shards: SHARDS,
        objective: adra::planner::Objective::Edp,
        n_records: N_RECORDS,
        max_round: 4,
        cache_capacity: 4096,
        admission: AdmissionPolicy::Fair,
        batch: BatchPolicy::Static,
        sample_every: 1,
        calibrate_every: 1,
        calibration_path: None,
        calibration: None,
        store_dir: None,
        checkpoint_every: 32,
        route_retries: 2,
        retry_backoff_ms: 1,
        wear_spare_rows: 0,
        wear_migrate_threshold: 1024,
    });

    // wear demo, part 1: a write-hot accumulator row on shard 0, levelled
    // and published so `adra.array.writes{source="endurance"}` exists in
    // BOTH scrapes (check_metrics.py verifies it ratchets between them)
    let mut leveler = WearLeveler::new(cfg.rows, 1_000_000, 64);
    for _ in 0..500 {
        leveler.on_write(0);
    }
    leveler.publish(adra::observe::global(), "0");

    // warmup wave: two distinct programs so serve/run/planner families
    // are all published before the first scrape
    let warm = heavy_tenant_scenario(&cfg, N_RECORDS, 2028, 2, 0);
    for (t, p) in &warm.submissions {
        queue.submit(*t, p.clone()).expect("admit").wait().expect("serve");
    }
    let scrape1 = write_scrape(
        "target/health_scrape1.prom",
        &[
            "adra_serve_programs",
            "adra_serve_round_wall_ns",
            "adra_observe_overhead_ns",
            "adra_health_status",
            "adra_run_ops",
            "adra_array_writes",
        ],
    );
    println!(
        "scrape 1 (post-warmup) -> target/health_scrape1.prom ({} lines)",
        scrape1.lines().count()
    );

    // --- the flood, with a local tight-threshold engine ticking as
    // results stream back (the monitor's view evolves round by round) ---
    let mut engine = HealthEngine::new();
    for rule in flood_rules() {
        engine.add_rule(rule);
    }
    let scenario = heavy_tenant_scenario(&cfg, N_RECORDS, 2029, HEAVY_BURST, LIGHT_TENANTS);
    let tickets: Vec<_> = scenario
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();

    let mut transitions: Vec<Transition> = Vec::new();
    for (i, ticket) in tickets.into_iter().enumerate() {
        let rep = ticket.wait().expect("serve");
        assert_eq!(
            rep.outputs[scenario.filter_step],
            StepOutput::Matches(scenario.expected_matches[i].clone()),
            "served output diverged from host ground truth (submission {i})"
        );
        for tr in engine.evaluate(
            adra::observe::series(),
            adra::observe::global(),
            adra::observe::recorder(),
        ) {
            println!(
                "  alert: {} {} -> {} (value {:.3})",
                tr.rule,
                tr.from.name(),
                tr.to.name(),
                tr.value
            );
            transitions.push(tr);
        }
    }
    let m = queue.metrics();
    println!(
        "\nflood served: {} programs / {} rounds, {} deferrals, p95 round wall {:.1} us",
        m.programs,
        m.rounds,
        m.deferred_programs,
        m.p95_ns_excluding(usize::MAX) / 1e3
    );

    println!("\n{}", engine.report());

    // --- the acceptance criteria, asserted ---
    assert!(
        !transitions.is_empty(),
        "the flood must commit at least one health transition"
    );
    for rule in ["flood_round_wall_slo_burn", "flood_quota_starvation"] {
        let state = engine.state_of(rule).expect("rule exists");
        assert!(
            state >= RuleState::Warn,
            "{rule} must be at least warn after the flood, got {}",
            state.name()
        );
        assert!(
            transitions.iter().any(|t| t.rule == rule && t.to >= RuleState::Warn),
            "{rule} must have committed a warn/critical transition"
        );
    }
    assert!(engine.overall() >= RuleState::Warn);
    assert!(engine.transition_count() as usize >= transitions.len());

    // wear demo, part 2: more writes, republished — the counter must
    // ratchet between the scrapes
    for _ in 0..500 {
        leveler.on_write(0);
    }
    leveler.publish(adra::observe::global(), "0");
    println!(
        "wear demo: {} total writes, {} remaps, imbalance {:.2}",
        leveler.tracker().total_writes(),
        leveler.remaps(),
        leveler.tracker().imbalance()
    );

    let scrape2 = write_scrape(
        "target/health_scrape2.prom",
        &[
            "adra_serve_programs",
            "adra_serve_round_wall_ns",
            "adra_observe_overhead_ns",
            "adra_health_status",
            "adra_health_transitions",
            "adra_array_writes",
        ],
    );
    println!(
        "scrape 2 (post-flood) -> target/health_scrape2.prom ({} lines)",
        scrape2.lines().count()
    );

    // alerts must round-trip through the JSONL export
    let trace = adra::observe::recorder().to_jsonl();
    assert!(
        trace.contains("\"kind\":\"alert\""),
        "flight recorder must hold the committed alerts:\n{trace}"
    );
    assert!(
        trace.contains("flood_round_wall_slo_burn") && trace.contains("flood_quota_starvation"),
        "both demo rules must appear in the exported alerts"
    );
    std::fs::write("target/health_trace.jsonl", &trace).expect("write trace");
    println!(
        "trace tail -> target/health_trace.jsonl ({} events, {} alerts)",
        trace.lines().count(),
        trace.matches("\"kind\":\"alert\"").count()
    );

    println!("\nHEALTH VALIDATION PASSED");
}
