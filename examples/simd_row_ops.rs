//! Row-parallel (SIMD) CiM and wide arithmetic: the Fig. 5(b) P = 1
//! operating mode as a user-facing API.
//!
//! One asymmetric dual-row activation computes an op over EVERY word of a
//! row pair; wide operands span multiple words with the carry chained in
//! the near-array logic.  Also shows the in-memory argmax tournament.
//!
//!     cargo run --release --example simd_row_ops

use adra::cim::{AdraEngine, CimOp, CimValue, Engine, VectorEngine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::util::rng::Rng;
use adra::util::table::fmt_si;

fn main() {
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 16;
    let words = cfg.words_per_row();
    let mut engine = AdraEngine::new(&cfg);
    let mut rng = Rng::new(77);

    // fill two rows with vectors
    let a: Vec<u64> = (0..words).map(|_| rng.below(30_000)).collect();
    let b: Vec<u64> = (0..words).map(|_| rng.below(30_000)).collect();
    for w in 0..words {
        engine.execute(&CimOp::Write { addr: WordAddr { row: 0, word: w }, value: a[w] }).unwrap();
        engine.execute(&CimOp::Write { addr: WordAddr { row: 1, word: w }, value: b[w] }).unwrap();
    }

    println!("=== SIMD row ops: {} x {}-bit lanes per activation ===", words, cfg.word_bits);
    println!(
        "fidelity tier: {} (digital fast path {})\n",
        engine.tier().name(),
        if engine.digital_active() { "ACTIVE" } else { "off" }
    );

    engine.array_mut().reset_stats();
    let mut v = VectorEngine::new(&mut engine);
    let sub = v.sub_row(0, 1).unwrap();
    let mut ok = 0;
    for w in 0..words {
        if sub.values[w] == CimValue::Diff(a[w] as i128 - b[w] as i128) {
            ok += 1;
        }
    }
    println!(
        "vector sub: {ok}/{words} lanes correct, {} array activation(s), energy {}",
        engine.array().stats().dual_activations,
        fmt_si(sub.cost.energy.total(), "J")
    );
    assert_eq!(ok, words);
    assert_eq!(engine.array().stats().dual_activations, 1);

    // wide arithmetic: 64-bit operands across 4 x 16-bit words
    let wide_a: u64 = 0x0123_4567_89AB_CDEF;
    let wide_b: u64 = 0x0011_2233_4455_6677;
    for w in 0..4 {
        engine
            .execute(&CimOp::Write {
                addr: WordAddr { row: 4, word: w },
                value: (wide_a >> (16 * w)) & 0xFFFF,
            })
            .unwrap();
        engine
            .execute(&CimOp::Write {
                addr: WordAddr { row: 5, word: w },
                value: (wide_b >> (16 * w)) & 0xFFFF,
            })
            .unwrap();
    }
    let mut v = VectorEngine::new(&mut engine);
    let (diff, cost) = v.sub_wide(4, 5, 0, 4).unwrap();
    println!(
        "\nwide sub: {wide_a:#x} - {wide_b:#x} = {diff:#x} (one activation, {})",
        fmt_si(cost.latency, "s")
    );
    assert_eq!(diff, wide_a as i128 - wide_b as i128);

    // in-memory argmax tournament over 8 rows
    let vals: Vec<u64> = (0..8).map(|_| rng.below(30_000)).collect();
    for (i, &val) in vals.iter().enumerate() {
        engine.execute(&CimOp::Write { addr: WordAddr { row: 10 + i, word: 0 }, value: val }).unwrap();
    }
    let rows: Vec<usize> = (10..18).collect();
    let mut v = VectorEngine::new(&mut engine);
    let (idx, compares, cost) = v.argmax(&rows, 0).unwrap();
    let want = vals.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
    println!(
        "\nargmax over 8 in-memory words: index {idx} (value {}), {compares} compares, {}",
        vals[idx],
        fmt_si(cost.energy.total(), "J")
    );
    assert_eq!(idx, want);

    // per-tier accounting: with the default config every dual activation
    // above rode the bit-packed digital kernel (identical decisions and
    // modeled costs; only host wall-clock differs)
    let s = engine.array().stats();
    println!(
        "\nactivations served per tier: digital {} / analog {} (of {} total, \
         {} xval checks, {} mismatches)",
        s.digital_activations,
        s.dual_activations - s.digital_activations,
        s.dual_activations,
        s.xval_checks,
        s.xval_mismatches
    );
    assert_eq!(s.digital_activations, s.dual_activations, "default tier is digital");
    assert_eq!(s.xval_mismatches, 0);

    // === part 2: the masked packed path under V_T variation ===
    // with vt_sigma > 0 the per-cell margin masks keep the packed kernel
    // hot: deterministic columns serve from the shadow plane, the
    // marginal minority runs the exact analog pipeline, merged by mask
    let mut vcfg = SimConfig::square(256, SensingScheme::Current);
    vcfg.word_bits = 16;
    vcfg.vt_sigma = 0.02; // 20 mV — the nominal FeFET variation point
    let mut veng = AdraEngine::new(&vcfg);
    println!(
        "\n=== masked row ops under variation (sigma = {} mV, mask policy {}) ===",
        vcfg.vt_sigma * 1e3,
        vcfg.mask_policy.name()
    );
    println!(
        "masked packed path: {} (classified deterministic cell fraction {:.1}%)",
        if veng.masked_active() { "ACTIVE" } else { "off" },
        veng.array().deterministic_fraction() * 100.0
    );
    assert!(veng.masked_active());

    // an Exact-tier mirror on the same seed (same variation plane) is
    // the ground truth the masked path must match bit for bit
    let mut xcfg = vcfg.clone();
    xcfg.tier = adra::config::FidelityTier::Exact;
    let mut xeng = AdraEngine::new(&xcfg);

    let va: Vec<u64> = (0..words).map(|_| rng.below(30_000)).collect();
    let vb: Vec<u64> = (0..words).map(|_| rng.below(30_000)).collect();
    for w in 0..words {
        for e in [&mut veng, &mut xeng] {
            e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: w }, value: va[w] }).unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: w }, value: vb[w] }).unwrap();
        }
    }
    veng.array_mut().reset_stats();
    let vsub = {
        let mut v = VectorEngine::new(&mut veng);
        v.sub_row(0, 1).unwrap()
    };
    let xsub = {
        let mut v = VectorEngine::new(&mut xeng);
        v.sub_row(0, 1).unwrap()
    };
    let mut vok = 0;
    for w in 0..words {
        if vsub.values[w] == xsub.values[w] {
            vok += 1;
        }
    }
    let vs = veng.array().stats();
    println!(
        "vector sub under variation: {vok}/{words} lanes identical to the exact tier, \
         {} activation(s) ({} masked), energy {}",
        vs.dual_activations,
        vs.masked_activations,
        fmt_si(vsub.cost.energy.total(), "J")
    );
    println!(
        "deterministic-column fraction served packed: {:.1}% \
         ({} det cols / {} marginal), xval checks {} (mismatches {})",
        vs.det_col_fraction() * 100.0,
        vs.det_cols,
        vs.marginal_cols,
        vs.xval_checks,
        vs.xval_mismatches
    );
    assert_eq!(vok, words, "masked lanes must match the exact tier");
    assert_eq!(vs.dual_activations, 1);
    assert_eq!(vs.masked_activations, 1);
    assert!(
        vs.det_col_fraction() >= 0.8,
        "paper-nominal variation must keep >= 80% of columns packed"
    );
    assert_eq!(vs.xval_mismatches, 0);

    println!("\nSIMD VALIDATION PASSED");
}
