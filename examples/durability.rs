//! End-to-end durability demo: a chaos-injected flood (worker deaths,
//! latency spikes, accelerated endurance drift) is served correctly and
//! journaled to a checksummed snapshot + WAL store; the process then
//! simulates a kill by dropping the queue mid-life and proves a fresh
//! queue over the same directory recovers the array bit-identically
//! before serving its first round.
//!
//! A local tight-threshold health engine ticks while the flood drains so
//! the run deterministically commits alert transitions into the flight
//! recorder — the exported trace is the CI job's alert artifact.
//!
//! Artifacts (CI's `durability-smoke` job consumes all three):
//!   target/durability_scrape1.prom   scrape after the chaos flood
//!   target/durability_scrape2.prom   scrape after the kill + recovery
//!   target/durability_trace.jsonl    flight-recorder tail incl. alerts
//!
//!     cargo run --release --example durability

use adra::config::{SensingScheme, SimConfig};
use adra::faults::{self, FaultSpec};
use adra::observe::{Direction, HealthEngine, HealthRule, RuleState, Signal, Transition};
use adra::planner::StepOutput;
use adra::serve::{BatchPolicy, ServeConfig, ServeQueue};
use adra::workload::heavy_tenant_scenario;
use adra::workload::programs::analytics_scenario;

const N_RECORDS: usize = 192;
const SHARDS: usize = 2;
const HEAVY_BURST: usize = 14;
const LIGHT_TENANTS: usize = 3;
const STORE_DIR: &str = "target/durability_store";

/// Write one Prometheus scrape of the global registry and sanity-check
/// the families the durability pipeline must expose.
fn write_scrape(path: &str, families: &[&str]) -> String {
    let text = adra::observe::expose_text(adra::observe::global());
    for family in families {
        assert!(text.contains(family), "scrape is missing family {family}:\n{text}");
    }
    std::fs::create_dir_all("target").expect("create target/");
    std::fs::write(path, &text).expect("write scrape");
    text
}

fn durable_config(cfg: &SimConfig) -> ServeConfig {
    let mut sc = ServeConfig::new(cfg.clone(), SHARDS, N_RECORDS);
    sc.max_round = 6;
    sc.batch = BatchPolicy::Adaptive { target_p95: 2e-3 };
    sc.sample_every = 1;
    sc.calibrate_every = 1;
    sc.store_dir = Some(STORE_DIR.into());
    sc.checkpoint_every = 4;
    sc.route_retries = 3;
    sc.retry_backoff_ms = 1;
    sc.wear_spare_rows = 8;
    sc.wear_migrate_threshold = 2000;
    sc
}

/// One deliberately unmeetable SLO so the chaos flood deterministically
/// commits alert transitions (same technique as the health demo).
fn tight_rules() -> Vec<HealthRule> {
    vec![HealthRule {
        name: "durability_round_wall_slo_burn".to_string(),
        signal: Signal::SloBurn {
            name: "adra.serve.round_wall_ns".to_string(),
            labels: Vec::new(),
            slo_ns: 200.0,
            budget: 0.05,
            fast: 4,
            slow: 8,
        },
        direction: Direction::Above,
        warn: 1.0,
        critical: 4.0,
        sustain_up: 2,
        sustain_down: 4,
    }]
}

fn main() {
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 32;
    let _ = std::fs::remove_dir_all(STORE_DIR);

    println!("=== chaos flood against a durable serve queue ===");
    let spec = "seed=77 death=200 death-max=2 spike=150 spike-ns=30000000 wear=50";
    faults::install(FaultSpec::parse(spec).expect("valid spec"));
    println!("fault spec installed: {spec}");
    println!(
        "{HEAVY_BURST}-program flood + {LIGHT_TENANTS} light tenants, {N_RECORDS} records, \
         {SHARDS} shards, WAL + checkpoint every 4 rounds\n"
    );

    let mut engine = HealthEngine::new();
    for rule in tight_rules() {
        engine.add_rule(rule);
    }
    let mut transitions: Vec<Transition> = Vec::new();

    let pre_kill = {
        let queue = ServeQueue::start(durable_config(&cfg));
        for wave in 0..2u64 {
            let scenario =
                heavy_tenant_scenario(&cfg, N_RECORDS, 8_800 + wave, HEAVY_BURST, LIGHT_TENANTS);
            let tickets: Vec<_> = scenario
                .submissions
                .iter()
                .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
                .collect();
            for (i, ticket) in tickets.into_iter().enumerate() {
                let rep = ticket.wait().expect("served despite injected chaos");
                assert_eq!(
                    rep.outputs[scenario.filter_step],
                    StepOutput::Matches(scenario.expected_matches[i].clone()),
                    "chaos may slow wave {wave} submission {i}, never corrupt it"
                );
                for tr in engine.evaluate(
                    adra::observe::series(),
                    adra::observe::global(),
                    adra::observe::recorder(),
                ) {
                    println!(
                        "  alert: {} {} -> {} (value {:.3})",
                        tr.rule,
                        tr.from.name(),
                        tr.to.name(),
                        tr.value
                    );
                    transitions.push(tr);
                }
            }
            println!("wave {wave} served bit-identically under chaos");
        }

        // ground truth for the recovery proof: serve a full analytics
        // program, keep its answers, kill the queue
        let s = analytics_scenario(&cfg, N_RECORDS, 4_117);
        let rep = queue.submit(0, s.program.clone()).expect("admit").wait().expect("serve");
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));

        let m = queue.metrics();
        println!("\npre-kill metrics: {}", m.report());
        assert!(
            m.worker_respawns >= 1,
            "the injected deaths must have killed (and respawned) a worker"
        );
        assert!(m.spike_shrinks >= 1, "the 30ms spikes must have shrunk the round");
        assert!(m.wear_migrations >= 1, "accelerated wear must have migrated a hot row");
        (s, rep)
        // queue dropped here: the simulated kill — no explicit snapshot,
        // recovery rides the last checkpoint + WAL tail
    };
    faults::clear();
    println!("\nqueue killed (dropped); fault injector disarmed");

    let scrape1 = write_scrape(
        "target/durability_scrape1.prom",
        &[
            "adra_serve_programs",
            "adra_serve_worker_respawns",
            "adra_serve_wear_migrations",
            "adra_serve_spike_shrinks",
            "adra_store_wal_records",
            "adra_store_snapshot_bytes",
            "adra_store_checkpoints",
            "adra_faults_injected",
            "adra_health_status",
        ],
    );
    println!("scrape 1 (post-flood) -> target/durability_scrape1.prom ({} lines)", scrape1.lines().count());

    // --- the restart: a fresh queue over the same directory must replay
    // snapshot + WAL into fresh arrays before its first round ---
    println!("\n=== restart over {STORE_DIR} ===");
    let queue = ServeQueue::start(durable_config(&cfg));
    let (s, pre_rep) = pre_kill;
    let mut query_only = s.program.clone();
    query_only.ops.remove(0); // drop the Load: recovered contents answer
    let rep = queue.submit(0, query_only).expect("admit").wait().expect("serve after restart");
    assert_eq!(
        rep.outputs[s.filter_step - 1],
        pre_rep.outputs[s.filter_step],
        "the recovered array must answer exactly like the pre-kill one"
    );
    let m = queue.metrics();
    assert_eq!(m.recoveries, 1, "startup recovery must have fired exactly once");
    println!("recovery verified: query-only replay matches the pre-kill answers");
    println!("post-restart metrics: {}", m.report());

    let scrape2 = write_scrape(
        "target/durability_scrape2.prom",
        &[
            "adra_serve_recoveries",
            "adra_store_wal_records",
            "adra_store_replay_ns",
            "adra_store_snapshot_bytes",
            "adra_health_status",
        ],
    );
    println!("scrape 2 (post-recovery) -> target/durability_scrape2.prom ({} lines)", scrape2.lines().count());

    // --- the alert-trace artifact ---
    assert!(!transitions.is_empty(), "the flood must commit at least one health transition");
    assert!(
        engine.state_of("durability_round_wall_slo_burn").expect("rule exists")
            >= RuleState::Warn,
        "the tight SLO must be burning after a 30ms-spike flood"
    );
    let trace = adra::observe::recorder().to_jsonl();
    assert!(
        trace.contains("\"kind\":\"alert\"") && trace.contains("durability_round_wall_slo_burn"),
        "flight recorder must hold the committed alerts:\n{trace}"
    );
    std::fs::write("target/durability_trace.jsonl", &trace).expect("write trace");
    println!(
        "trace tail -> target/durability_trace.jsonl ({} events, {} alerts)",
        trace.lines().count(),
        trace.matches("\"kind\":\"alert\"").count()
    );

    println!("\nDURABILITY VALIDATION PASSED");
}
