#!/usr/bin/env python3
"""Hotpath perf-trajectory gate.

Compares a freshly produced BENCH_hotpath.json against the committed
baseline and FAILS (exit 1) on a >20% regression of the digital-tier
throughput metrics.  To stay machine-independent across CI runners, the
gated metrics are the RATIO records the bench emits (digital-vs-lut
speedup, whole-row-vs-per-word speedup, masked deterministic-column
fraction), not absolute ns — absolute timings are reported for context
only.

Usage: compare_hotpath.py CURRENT.json BASELINE.json

The first committed baseline is a conservative seed (values at the
bench's own assertion floors, marked with a "seed-baseline" record);
refresh it by copying a green CI run's BENCH_hotpath.json over the
committed file.
"""

import json
import sys

# metric name -> max tolerated relative drop vs baseline
GATED = {
    "tier/speedup 64c [digital vs lut]": 0.20,
    "row/speedup 1024c [whole-row vs per-word]": 0.20,
    "row/det-fraction s20 [masked]": 0.20,
    # telemetry tick vs the exact-tier op: a cross-domain timing ratio is
    # noisier than a same-kernel speedup, so it gets a wider band
    "observe/tick ratio [exact-op vs sample+health]": 0.50,
    # disarmed chaos guard vs the digital op: the "zero happy-path
    # overhead" claim of the fault layer; sub-ns denominators are noisy,
    # so it also gets the wide band
    "faults/overhead ratio [digital-op vs disarmed-guard]": 0.50,
}


def load(path):
    with open(path) as f:
        records = json.load(f)
    values = {}
    timings = {}
    for r in records:
        if "value" in r:
            values[r["name"]] = float(r["value"])
        elif "ns_per_iter" in r:
            timings[r["name"]] = float(r["ns_per_iter"])
    return values, timings


def fmt(v, width=10, digits=3):
    """One table cell: '-' for a missing side, fixed-point otherwise."""
    if v is None:
        return f"{'-':>{width}}"
    return f"{v:>{width}.{digits}f}"


def delta_table(title, base, cur, digits=3):
    """Per-metric delta table over the UNION of both runs' metrics.

    Metrics present on only one side render with '-' and a warning
    instead of raising — new bench records (or retired ones) must be able
    to land without breaking the gate script.
    """
    union = sorted(set(base) | set(cur))
    if not union:
        return []
    warnings = []
    print(f"\n{title}:")
    print(f"  {'metric':<48} {'baseline':>10} {'current':>10} {'delta':>9}")
    for name in union:
        b, c = base.get(name), cur.get(name)
        if b is None or c is None:
            side = "baseline" if b is None else "current"
            warnings.append(f"{name}: only in one run (missing from {side})")
            delta = f"{'n/a':>9}"
        elif b != 0:
            delta = f"{(c - b) / b * 100.0:>+8.1f}%"
        else:
            delta = f"{'n/a':>9}"
        print(f"  {name:<48} {fmt(b, digits=digits)} {fmt(c, digits=digits)} {delta}")
    for w in warnings:
        print(f"  warn: {w}")
    return warnings


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    cur_vals, cur_ns = load(sys.argv[1])
    base_vals, base_ns = load(sys.argv[2])
    seeded = "seed-baseline" in base_vals

    failures = []
    print(f"{'metric':<44} {'baseline':>10} {'current':>10} {'floor':>10}")
    for name, drop in GATED.items():
        if name not in base_vals:
            print(f"{name:<44} {'-':>10} {cur_vals.get(name, float('nan')):>10.3f} (no baseline)")
            continue
        if name not in cur_vals:
            failures.append(f"{name}: missing from current run")
            continue
        floor = base_vals[name] * (1.0 - drop)
        ok = cur_vals[name] >= floor
        print(
            f"{name:<44} {base_vals[name]:>10.3f} {cur_vals[name]:>10.3f} "
            f"{floor:>10.3f} {'ok' if ok else 'REGRESSED'}"
        )
        if not ok:
            failures.append(
                f"{name}: {cur_vals[name]:.3f} < {floor:.3f} "
                f"(baseline {base_vals[name]:.3f}, tolerance {drop:.0%})"
            )

    # full per-metric delta tables (informational; one-sided metrics warn)
    delta_table("ratio / value records", base_vals, cur_vals)
    if not seeded:
        # absolute timings: context only (runners differ), never gate
        delta_table("absolute timings (ns/iter, informational)", base_ns, cur_ns, digits=1)

    if failures:
        print("\nFAIL: digital-tier throughput regressed vs the committed baseline:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nhotpath trajectory ok" + (" (seed baseline)" if seeded else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
