#!/usr/bin/env python3
"""Prometheus-exposition smoke checker for the observability layer.

Validates two consecutive scrapes of `adra::observe::expose_text` (as
written by `cargo run --release --example serving` to
target/metrics_scrape1.prom / target/metrics_scrape2.prom):

  1. parse: every sample line is `name{labels} value`, names match the
     Prometheus charset, every sample belongs to a family that declared
     # HELP and # TYPE;
  2. coverage: the scrape is non-empty and the required serve / planner /
     kernel families are all present;
  3. histogram triples: cumulative `_bucket` series are non-decreasing in
     `le`, end in `le="+Inf"`, the +Inf bucket equals `_count`, and the
     `_sum` is present, non-negative, and zero whenever `_count` is zero;
  4. monotonicity: every counter series in scrape 1 is <= its value in
     scrape 2 (counters only ratchet; series may appear between scrapes
     but must never vanish or decrease) — histogram `_count`/`_bucket`
     series are cumulative and held to the same bar.

Usage: check_metrics.py SCRAPE1 SCRAPE2 [EXTRA_FAMILY...]

Any EXTRA_FAMILY arguments are required in BOTH scrapes on top of the
baseline set — the durability CI job passes the `adra_store_*` and
robustness `adra_serve_*` families this way, so callers whose examples
do not arm the durable store are not forced to expose them.

Exit 0 on success, 1 with a list of violations otherwise.
"""

import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{.*\})?\s+(\S+)$")

REQUIRED_FAMILIES = [
    "adra_serve_programs",
    "adra_serve_rounds",
    "adra_run_ops",
    "adra_array_det_fraction",
    "adra_planner_prediction_error",
    "adra_serve_round_wall_ns",
    "adra_observe_overhead_ns",
    "adra_health_status",
    # overload-survival families: published on every round by every
    # serve queue, so every scrape-producing example exposes them
    "adra_serve_shed",
    "adra_serve_deadline_expired",
    "adra_serve_cancelled",
    "adra_serve_degrade_level",
    "adra_serve_breaker_state",
]


def parse_value(raw):
    if raw == "+Inf":
        return float("inf")
    if raw == "-Inf":
        return float("-inf")
    return float(raw)  # handles NaN spelling too


def parse(path, errors):
    """Return (families: name -> type, samples: series -> value)."""
    helps, types, samples = {}, {}, {}
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.rstrip("\n")
            if not line:
                continue
            where = f"{path}:{lineno}"
            if line.startswith("# HELP "):
                helps[line.split(" ", 3)[2]] = True
                continue
            if line.startswith("# TYPE "):
                parts = line.split(" ")
                types[parts[2]] = parts[3]
                continue
            if line.startswith("#"):
                continue
            m = SAMPLE_RE.match(line)
            if not m:
                errors.append(f"{where}: unparseable sample line: {line!r}")
                continue
            name, labels, raw = m.group(1), m.group(2) or "", m.group(3)
            if not NAME_RE.match(name):
                errors.append(f"{where}: invalid metric name {name!r}")
            family = name
            for suffix in ("_bucket", "_sum", "_count"):
                base = name[: -len(suffix)] if name.endswith(suffix) else None
                if base and types.get(base) == "histogram":
                    family = base
                    break
            if family not in types:
                errors.append(f"{where}: sample {name!r} has no # TYPE declaration")
            if family not in helps:
                errors.append(f"{where}: sample {name!r} has no # HELP declaration")
            try:
                samples[name + labels] = parse_value(raw)
            except ValueError:
                errors.append(f"{where}: bad sample value {raw!r}")
    return types, samples


def le_of(series):
    m = re.search(r'le="([^"]*)"', series)
    return m.group(1) if m else None


def strip_le(series):
    key = re.sub(r',?le="[^"]*"', "", series)
    return key.replace("{}", "")


def check_histograms(path, types, samples, errors):
    for family, kind in types.items():
        if kind != "histogram":
            continue
        # group bucket series by their non-le label key
        groups = {}
        for series, value in samples.items():
            if series.split("{")[0] == family + "_bucket":
                labels = series[len(family) + len("_bucket"):]
                groups.setdefault(strip_le(labels), []).append((series, value))
        if not groups:
            errors.append(f"{path}: histogram {family} has no _bucket samples")
        for key, buckets in groups.items():
            inf = [v for s, v in buckets if le_of(s) == "+Inf"]
            if not inf:
                errors.append(f"{path}: {family}{key or ''} missing le=\"+Inf\" bucket")
                continue
            finite = sorted(
                ((float(le_of(s)), v) for s, v in buckets if le_of(s) != "+Inf")
            )
            ordered = [v for _, v in finite] + inf
            if any(a > b for a, b in zip(ordered, ordered[1:])):
                errors.append(f"{path}: {family}{key or ''} buckets not cumulative")
            count_series = (family + "_count" + key) if key else (family + "_count")
            count = samples.get(count_series)
            if count is None:
                errors.append(f"{path}: {family}{key or ''} missing _count sample")
            elif count != inf[0]:
                errors.append(
                    f"{path}: {family}{key or ''} _count {count} != +Inf bucket {inf[0]}"
                )
            # sum/count consistency: a histogram that never observed must
            # report a zero sum, and a latency sum can never be negative
            sum_series = (family + "_sum" + key) if key else (family + "_sum")
            total = samples.get(sum_series)
            if total is None:
                errors.append(f"{path}: {family}{key or ''} missing _sum sample")
            elif total < 0:
                errors.append(f"{path}: {family}{key or ''} _sum {total} is negative")
            elif count == 0 and total != 0:
                errors.append(
                    f"{path}: {family}{key or ''} _sum {total} nonzero with _count 0"
                )


def main():
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    errors = []
    required = REQUIRED_FAMILIES + sys.argv[3:]
    types1, samples1 = parse(sys.argv[1], errors)
    types2, samples2 = parse(sys.argv[2], errors)

    for path, types, samples in ((sys.argv[1], types1, samples1), (sys.argv[2], types2, samples2)):
        if not samples:
            errors.append(f"{path}: scrape has no samples at all")
        for family in required:
            if family not in types:
                errors.append(f"{path}: required family {family} missing")
        check_histograms(path, types, samples, errors)

    # counters only ratchet: scrape1 series must persist and not decrease.
    # Histogram _count and _bucket series are cumulative too, so they are
    # held to the same bar.
    def ratchets(series):
        name = series.split("{")[0]
        if types1.get(name) == "counter":
            return True
        for suffix in ("_count", "_bucket"):
            if name.endswith(suffix) and types1.get(name[: -len(suffix)]) == "histogram":
                return True
        return False

    counters1 = {s: v for s, v in samples1.items() if ratchets(s)}
    checked = 0
    for series, v1 in counters1.items():
        v2 = samples2.get(series)
        if v2 is None:
            errors.append(f"counter series vanished between scrapes: {series}")
        elif v2 < v1:
            errors.append(f"counter went backwards: {series} {v1} -> {v2}")
        else:
            checked += 1

    if errors:
        print(f"check_metrics: FAIL ({len(errors)} violations)")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(
        f"check_metrics: ok — {len(types2)} families, {len(samples2)} samples, "
        f"{checked} counter series monotone across scrapes"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
