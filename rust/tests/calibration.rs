//! End-to-end tests for the calibration actuator (ISSUE 8 acceptance):
//! a deliberately mis-calibrated cost model, executed through the real
//! planned coordinator, must (a) drive its prediction-error EWMA below
//! the uncalibrated error within a bounded number of runs, (b) flip
//! routing to the measured-optimal executor — and push that flip down to
//! the worker engines — and (c) keep both properties across a simulated
//! daemon restart via the JSON snapshot.

use adra::config::{SensingScheme, SimConfig};
use adra::planner::{
    place_calibrated, planned_coordinator, CalibratedCostModel, CalibrationStore, Executor,
    Objective, OpClass, PlanCostModel, StepOutput,
};
use adra::workload::programs::analytics_scenario;

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::VoltagePrecharged);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

/// The honest scheme-1 energy model, plus a copy whose ADRA table
/// underprices dual-op energy 2x — the "lying" model the paper-grounded
/// scenario starts from.  Under scheme 1 ADRA dual ops really cost
/// ~1.21x the baseline's energy (Fig. 6), so the honest Energy routing
/// is dual -> Baseline; the lie flips that to dual -> ADRA.
fn models(cfg: &SimConfig) -> (PlanCostModel, PlanCostModel) {
    let honest = PlanCostModel::new(cfg, Objective::Energy);
    let lying_adra = honest.adra().scaled_class(OpClass::Dual, 0.5, 1.0);
    let lying =
        PlanCostModel::with_tables(Objective::Energy, lying_adra, honest.baseline().clone());
    (honest, lying)
}

#[test]
fn miscalibrated_model_converges_and_flips_to_measured_optimum() {
    let cfg = cfg();
    let (honest, lying) = models(&cfg);
    assert_eq!(
        honest.choose_class(OpClass::Dual).executor,
        Executor::Baseline,
        "scheme-1/energy: the measured optimum for dual ops is the baseline"
    );
    assert_eq!(
        lying.choose_class(OpClass::Dual).executor,
        Executor::Adra,
        "the mis-calibrated table wrongly routes dual -> ADRA"
    );
    // EDP workers natively route dual -> ADRA under scheme 1, so the
    // lying plan's routing is what actually runs on the array until the
    // calibration loop pins it away.
    assert_eq!(
        PlanCostModel::new(&cfg, Objective::Edp).choose_class(OpClass::Dual).executor,
        Executor::Adra
    );

    let coord = planned_coordinator(&cfg, 2, Objective::Edp);
    let mut cal = CalibratedCostModel::new(lying, 2);
    cal.sync_routing(&coord); // empty store: a no-op, must not error
    let s = analytics_scenario(&cfg, 80, 7);

    let mut uncal_err = None;
    let mut flip_round = None;
    for round in 1..=20 {
        let pl = place_calibrated(&s.program, &cfg, 2, &cal).unwrap();
        let rep = pl.execute(&coord).unwrap();
        // correctness is routing-invariant: answers never change
        assert_eq!(
            rep.outputs[s.filter_step],
            StepOutput::Matches(s.expected_matches.clone()),
            "round {round}"
        );
        if uncal_err.is_none() {
            // the raw first-run dual error IS the uncalibrated error: a
            // fixed lying model would repeat it forever
            let d = rep
                .samples
                .iter()
                .find(|x| x.op_class == OpClass::Dual)
                .expect("the scenario executes dual ops");
            uncal_err =
                Some((d.measured.energy.total() / d.predicted.energy.total() - 1.0).abs());
        }
        if cal.absorb(&rep.samples) {
            cal.sync_routing(&coord);
            flip_round.get_or_insert(round);
        }
    }

    let flip = flip_round.expect("sustained honest measurements must flip routing");
    assert!(flip >= 3, "no flip before the sustain hysteresis: round {flip}");
    for shard in 0..2 {
        assert_eq!(cal.store().committed(shard, OpClass::Dual), Some(Executor::Baseline));
        assert_eq!(cal.choose_class(shard, OpClass::Dual), Executor::Baseline);
    }
    assert!(!cal.fuse_dual_on_adra(), "fused dual datapath follows the calibrated routing");

    let uncal = uncal_err.unwrap();
    assert!(uncal > 0.5, "the lying table starts ~2x off: {uncal}");
    let calibrated = cal.store().class_error(OpClass::Dual).expect("dual error tracked");
    assert!(
        calibrated < 0.1 && calibrated < uncal,
        "calibrated error EWMA {calibrated} must fall below uncalibrated {uncal}"
    );

    // the committed pin reached the worker engines: the next run's
    // prediction matches the engine-charged cost exactly (the plan
    // prices dual at the honest pinned baseline price, and the workers
    // execute it there)
    let pl = place_calibrated(&s.program, &cfg, 2, &cal).unwrap();
    let rep = pl.execute(&coord).unwrap();
    assert!(rep.prediction.within(1e-6), "{}", rep.prediction.report("calibrated"));
}

#[test]
fn snapshot_restart_keeps_calibrated_routing_on_the_array() {
    let cfg = cfg();
    let (_honest, lying) = models(&cfg);
    let coord = planned_coordinator(&cfg, 2, Objective::Edp);
    let mut cal = CalibratedCostModel::new(lying.clone(), 2);
    let s = analytics_scenario(&cfg, 80, 7);
    for _ in 1..=20 {
        let pl = place_calibrated(&s.program, &cfg, 2, &cal).unwrap();
        let rep = pl.execute(&coord).unwrap();
        if cal.absorb(&rep.samples) {
            cal.sync_routing(&coord);
        }
    }
    assert_eq!(cal.choose_class(0, OpClass::Dual), Executor::Baseline);

    let dir = std::env::temp_dir().join(format!("adra_cal_e2e_{}", std::process::id()));
    let path = dir.join("calibration.json");
    cal.store().save(&path).unwrap();

    // "restart": fresh wrapper around the re-loaded snapshot, fresh
    // coordinator whose workers are back on analytic routing
    let restored = CalibratedCostModel::with_store(lying, 2, CalibrationStore::load(&path));
    let coord2 = planned_coordinator(&cfg, 2, Objective::Edp);
    restored.sync_routing(&coord2);
    for shard in 0..2 {
        assert_eq!(
            restored.choose_class(shard, OpClass::Dual),
            Executor::Baseline,
            "committed routing survives the restart without new samples"
        );
    }
    let pl = place_calibrated(&s.program, &cfg, 2, &restored).unwrap();
    let rep = pl.execute(&coord2).unwrap();
    assert!(
        rep.prediction.within(1e-6),
        "restored calibration predicts the measured cost: {}",
        rep.prediction.report("restored")
    );
    assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exact_tables_stay_analytic_through_the_live_loop() {
    let cfg = cfg();
    let honest = PlanCostModel::new(&cfg, Objective::Edp);
    let coord = planned_coordinator(&cfg, 2, Objective::Edp);
    let mut cal = CalibratedCostModel::new(honest.clone(), 2);
    let s = analytics_scenario(&cfg, 80, 11);
    for round in 1..=5 {
        let pl = place_calibrated(&s.program, &cfg, 2, &cal).unwrap();
        let rep = pl.execute(&coord).unwrap();
        assert!(rep.prediction.within(1e-6), "round {round}: {}", rep.prediction.report("exact"));
        assert!(!cal.absorb(&rep.samples), "exact tables must never flip routing");
    }
    assert!(cal.store().max_distortion() < 1.0 + 1e-6, "factors stay ~1.0 on exact tables");
    for shard in 0..2 {
        assert_eq!(
            cal.choose_class(shard, OpClass::Dual),
            honest.choose_class(OpClass::Dual).executor,
            "routing is bit-identical to the analytic model"
        );
    }
}
