//! Property tests for the serving control plane: weighted fair admission
//! must keep an adversarial heavy tenant from starving anyone, adaptive
//! batch sizing and LRU/negative caching must never break bit-identity
//! with sequential unfused execution, and the negative cache must both
//! serve repeated empty filters and invalidate on range-version bumps.
//!
//! The heavy-tenant scenario's programs are self-contained (each loads
//! the shared values and broadcasts its own threshold), so every
//! admission interleaving the control plane picks must reproduce each
//! program's solo outputs — that is what makes bit-identity checkable
//! while WFQ reorders across tenants.

use adra::config::{SensingScheme, SimConfig};
use adra::planner::{Objective, Predicate, Program, StepOutput};
use adra::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue};
use adra::util::quick::Quick;
use adra::util::rng::Rng;
use adra::workload::heavy_tenant_scenario;

mod common;
use common::{naive_outputs, random_program, Seed};

const N_RECORDS: usize = 48;
const SHARDS: usize = 3;

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::Current);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

/// Starvation-freedom + bit-identity under an adversarial heavy tenant
/// with ALL three policies on (WFQ admission, adaptive max_round,
/// LRU+negative cache).  The heavy burst (18 programs) outlasts the
/// round ceiling (6), so it needs several rounds; weighted fair queueing
/// must slot every light tenant in before the flood drains — each light
/// program's serving round is bounded by the heavy tenant's last round.
#[test]
fn prop_heavy_flood_cannot_starve_light_tenants() {
    let cfg = cfg();
    Quick::with_cases(3).check::<Seed, _>("no starvation under flood", |seed| {
        let s = heavy_tenant_scenario(&cfg, N_RECORDS, seed.0, 18, 3);
        let programs: Vec<&Program> = s.submissions.iter().map(|(_, p)| p).collect();
        let naive = naive_outputs(&cfg, SHARDS, &programs);

        let queue = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: SHARDS,
            objective: Objective::Edp,
            n_records: N_RECORDS,
            max_round: 6,
            cache_capacity: 512,
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 50e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
        });
        // submit the whole adversarial pattern before waiting on anything
        let tickets: Vec<_> = s
            .submissions
            .iter()
            .map(|(t, p)| queue.submit(*t, p.clone()).expect("geometry matches"))
            .collect();
        let reports: Vec<_> = tickets
            .into_iter()
            .map(|t| t.wait().expect("served"))
            .collect();

        // bit-identity: every program matches its sequential unfused run
        for ((rep, want), (tenant, _)) in reports.iter().zip(&naive).zip(&s.submissions) {
            if &rep.outputs != want {
                eprintln!("tenant {tenant} diverged from naive execution");
                return false;
            }
        }
        // ground truth double-check on the filter step
        for (rep, want) in reports.iter().zip(&s.expected_matches) {
            if rep.outputs[s.filter_step] != StepOutput::Matches(want.clone()) {
                return false;
            }
        }

        // starvation-freedom: no light program may be served after the
        // heavy tenant's backlog has fully drained
        let heavy_last = reports[..18].iter().map(|r| r.round).max().unwrap();
        let light_last = reports[18..].iter().map(|r| r.round).max().unwrap();
        if light_last > heavy_last {
            eprintln!("light tenants starved: light last round {light_last} vs heavy {heavy_last}");
            return false;
        }
        // the flood cannot fit one round, so fairness had work to do
        heavy_last >= 2
    });
}

/// A random single-tenant stream with fairness + adaptive batching + a
/// DELIBERATELY tiny cache (constant eviction pressure) stays
/// bit-identical to sequential unfused execution.  Per-tenant FIFO is
/// what WFQ must preserve; eviction may only ever cost recomputation.
#[test]
fn prop_single_tenant_stream_identical_under_eviction_pressure() {
    let cfg = cfg();
    Quick::with_cases(6).check::<Seed, _>("identity under eviction", |seed| {
        let mut rng = Rng::new(seed.0);
        let mut programs: Vec<Program> =
            (0..7).map(|_| random_program(&mut rng, N_RECORDS)).collect();
        // exact repeat + whole-table clobber + re-query: the cache paths
        programs.push(programs[0].clone());
        let mut clobber = Program::new(N_RECORDS);
        let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(128)).collect();
        let all = clobber.all();
        clobber.load(0, values);
        clobber.scan(all);
        programs.push(clobber);
        programs.push(programs[0].clone());

        let refs: Vec<&Program> = programs.iter().collect();
        let naive = naive_outputs(&cfg, SHARDS, &refs);

        let queue = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: SHARDS,
            objective: Objective::Edp,
            n_records: N_RECORDS,
            max_round: 3,
            cache_capacity: 4, // tiny: force LRU evictions mid-stream
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 1e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
        });
        let tickets: Vec<_> = programs
            .iter()
            .map(|p| queue.submit(0, p.clone()).expect("geometry matches"))
            .collect();
        let served: Vec<Vec<StepOutput>> = tickets
            .into_iter()
            .map(|t| t.wait().expect("served").outputs)
            .collect();
        served == naive
    });
}

/// Repeated empty filters are answered by the zero-weight negative
/// cache, and a content-changing load strands the negative entry.
#[test]
fn negative_cache_hits_and_is_invalidated_by_writes() {
    let cfg = cfg();
    let mut rng = Rng::new(11);
    let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(128)).collect();
    let empty_filter = |vals: &[u64]| {
        let mut p = Program::new(N_RECORDS);
        let t = p.scratch();
        let all = p.all();
        p.load(0, vals.to_vec());
        p.broadcast(t, 0);
        p.filter(all, t, Predicate::Lt); // v < 0: never matches
        p
    };

    let queue = ServeQueue::start(ServeConfig::new(cfg.clone(), SHARDS, N_RECORDS));
    let p = empty_filter(&values);
    let first = queue.submit(0, p.clone()).unwrap().wait().unwrap();
    assert_eq!(first.outputs[2], StepOutput::Matches(Vec::new()));
    assert_eq!(first.cached_steps, 0);

    // waiting for the first reply guarantees a separate round: the
    // repeat is a negative-cache hit and touches no array
    let second = queue.submit(0, p).unwrap().wait().unwrap();
    assert_eq!(second.outputs[2], StepOutput::Matches(Vec::new()));
    assert_eq!(second.cached_steps, 1, "the empty filter came from the cache");
    assert_eq!(second.measured.energy.total(), 0.0, "nothing touched the array");
    let m = queue.metrics();
    assert!(m.negative_hits >= 1, "{}", m.report("serve"));

    // new contents bump every slot version: the stale negative entry can
    // never serve again, and the recomputed filter is still empty
    let changed: Vec<u64> = values.iter().map(|v| 127 - v).collect();
    let third = queue.submit(0, empty_filter(&changed)).unwrap().wait().unwrap();
    assert_eq!(third.cached_steps, 0, "version bump must strand the negative entry");
    assert_eq!(third.outputs[2], StepOutput::Matches(Vec::new()));
}

/// The legacy knobs still exist: FIFO admission + static max_round is
/// PR 2's scheduler, and it still matches naive execution.
#[test]
fn fifo_static_policies_remain_available_and_correct() {
    let cfg = cfg();
    let mut rng = Rng::new(5);
    let programs: Vec<Program> = (0..5).map(|_| random_program(&mut rng, N_RECORDS)).collect();
    let refs: Vec<&Program> = programs.iter().collect();
    let naive = naive_outputs(&cfg, SHARDS, &refs);

    let queue = ServeQueue::start(ServeConfig {
        cfg: cfg.clone(),
        shards: SHARDS,
        objective: Objective::Edp,
        n_records: N_RECORDS,
        max_round: 4,
        cache_capacity: 256,
        admission: AdmissionPolicy::Fifo,
        batch: BatchPolicy::Static,
        sample_every: 1,
        calibrate_every: 1,
        calibration_path: None,
        calibration: None,
        store_dir: None,
        checkpoint_every: 32,
        route_retries: 2,
        retry_backoff_ms: 1,
        wear_spare_rows: 0,
        wear_migrate_threshold: 1024,
    });
    let tickets: Vec<_> = programs
        .iter()
        .map(|p| queue.submit(0, p.clone()).expect("geometry matches"))
        .collect();
    let served: Vec<Vec<StepOutput>> = tickets
        .into_iter()
        .map(|t| t.wait().expect("served").outputs)
        .collect();
    assert_eq!(served, naive);
    let m = queue.metrics();
    assert_eq!(m.quota_hits, 0, "FIFO admission has no quotas");
    assert_eq!(m.controller_grows + m.controller_shrinks, 0, "static max_round");
}
