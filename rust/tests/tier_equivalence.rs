//! Tiered activation kernel equivalence suite (the acceptance gate of
//! the bit-packed digital fast path):
//!
//!  * every Boolean function, `sub`, `compare`, `read2`, `add`, and plain
//!    reads produce bit-identical `CimResult`s — value AND reported
//!    `OpCost` — across `Digital` / `Lut` / `Exact`, on every sensing
//!    scheme;
//!  * under `vt_sigma > 0` the MASKED digital path (per-cell margin
//!    masks, DESIGN.md §10) stays bit-identical to the `Exact` tier
//!    across all op kinds and schemes, with `xval_mismatches == 0`, and
//!    serves >= 80% of columns from the packed planes at the nominal
//!    20 mV variation (the acceptance criterion);
//!  * with `MaskPolicy::Off` the digital tier auto-disables under
//!    variation (the pre-mask fallback) while values stay correct;
//!  * every column the mask calls deterministic agrees with the analog
//!    pipeline (property-tested over random seeds/sigmas);
//!  * the sampled digital-vs-analog cross-validation counter stays zero
//!    on the default configuration;
//!  * row-wide vector ops and fused batches are tier-invariant too.

use adra::cim::{AdraEngine, BoolFn, CimOp, CimValue, Engine, VectorEngine, WordAddr};
use adra::config::{FidelityTier, MaskPolicy, SensingScheme, SimConfig};
use adra::coordinator::fuse::execute_fused;
use adra::util::quick::{Arbitrary, Quick};
use adra::util::rng::Rng;
use adra::workload::{OpMix, WorkloadGen};

fn cfg(scheme: SensingScheme, tier: FidelityTier) -> SimConfig {
    let mut c = SimConfig::square(64, scheme);
    c.word_bits = 8;
    c.tier = tier;
    c
}

fn engines(scheme: SensingScheme) -> Vec<(FidelityTier, AdraEngine)> {
    FidelityTier::ALL
        .iter()
        .map(|&t| (t, AdraEngine::new(&cfg(scheme, t))))
        .collect()
}

#[test]
fn all_ops_bit_identical_across_tiers() {
    let mut rng = Rng::new(0x7137);
    for scheme in SensingScheme::ALL {
        let mut es = engines(scheme);
        assert!(es[0].1.digital_active(), "{scheme:?}: digital tier must engage");
        for _ in 0..6 {
            let (a, b) = (rng.below(256), rng.below(256));
            let mut ops: Vec<CimOp> = vec![
                CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a },
                CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b },
                CimOp::Read(WordAddr { row: 0, word: 0 }),
                CimOp::Read2 { row_a: 0, row_b: 1, word: 0 },
                CimOp::Add { row_a: 0, row_b: 1, word: 0 },
                CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
                CimOp::Compare { row_a: 0, row_b: 1, word: 0 },
            ];
            for f in BoolFn::ALL {
                ops.push(CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 });
            }
            for op in &ops {
                let reference = es[0].1.execute(op).unwrap();
                // pin the digital tier against host semantics first
                if let CimOp::Bool { f, .. } = op {
                    assert_eq!(
                        reference.value,
                        CimValue::Word(f.apply(a, b, 0xFF)),
                        "{scheme:?} {f:?} a={a:#x} b={b:#x}"
                    );
                }
                for (tier, e) in es.iter_mut().skip(1) {
                    let got = e.execute(op).unwrap();
                    assert_eq!(
                        got.value, reference.value,
                        "{scheme:?} {tier:?} {op:?} a={a:#x} b={b:#x}"
                    );
                    assert_eq!(
                        got.cost, reference.cost,
                        "reported OpCost must be tier-invariant: {scheme:?} {tier:?} {op:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn random_workload_identical_across_tiers() {
    let base = cfg(SensingScheme::Current, FidelityTier::Digital);
    let mut digital = AdraEngine::new(&base);
    let mut lut = AdraEngine::new(&cfg(SensingScheme::Current, FidelityTier::Lut));
    let mut exact = AdraEngine::new(&cfg(SensingScheme::Current, FidelityTier::Exact));
    let mut gen = WorkloadGen::new(&base, OpMix::balanced(), 9090);
    for op in gen.batch(800) {
        let d = digital.execute(&op);
        let l = lut.execute(&op);
        let x = exact.execute(&op);
        match (&d, &l, &x) {
            (Ok(rd), Ok(rl), Ok(rx)) => {
                assert_eq!(rd.value, rl.value, "digital vs lut on {op:?}");
                assert_eq!(rd.value, rx.value, "digital vs exact on {op:?}");
                assert_eq!(rd.cost, rl.cost, "cost on {op:?}");
                assert_eq!(rd.cost, rx.cost, "cost on {op:?}");
            }
            (Err(_), Err(_), Err(_)) => {}
            other => panic!("tier divergence on {op:?}: {other:?}"),
        }
    }
    let s = digital.array().stats();
    assert!(s.digital_activations > 0, "fast path must have served: {s:?}");
    assert_eq!(s.digital_activations, s.dual_activations);
    assert_eq!(s.xval_mismatches, 0);
}

#[test]
fn digital_tier_auto_disables_with_variation_when_masks_off() {
    let mut c = cfg(SensingScheme::Current, FidelityTier::Digital);
    c.rows = 256;
    c.cols = 256;
    c.vt_sigma = 0.02;
    c.mask_policy = MaskPolicy::Off; // the pre-mask (PR 4) fallback
    let mut e = AdraEngine::new(&c);
    assert!(!e.digital_active(), "vt_sigma > 0 must disable the digital tier");
    assert!(!e.masked_active(), "MaskPolicy::Off must keep the masked path off");
    let mut c_lut = c.clone();
    c_lut.tier = FidelityTier::Lut;
    let mut mirror = AdraEngine::new(&c_lut); // same seed -> same variation plane
    let mut rng = Rng::new(31);
    for _ in 0..16 {
        let (a, b) = (rng.below(256), rng.below(256));
        for eng in [&mut e, &mut mirror] {
            eng.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a })
                .unwrap();
            eng.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b })
                .unwrap();
        }
        let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
        let m = mirror.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Pair(a, b), "analog fallback must stay correct");
        assert_eq!(r.value, m.value);
    }
    let s = e.array().stats();
    assert_eq!(s.digital_activations, 0);
    assert_eq!(s.masked_activations, 0);
    assert_eq!(s.det_cols + s.marginal_cols, 0);
    assert!(s.dual_activations > 0);
}

/// The tentpole gate: with margin masks on (the default), the masked
/// digital path must be BIT-IDENTICAL to the `Exact` tier — values and
/// costs — across every op kind and sensing scheme, over a seeded
/// `vt_sigma > 0` matrix, with zero cross-validation mismatches.
#[test]
fn masked_digital_bit_identical_to_exact_under_variation() {
    for scheme in SensingScheme::ALL {
        for sigma in [0.015, 0.03] {
            let mut c = cfg(scheme, FidelityTier::Digital);
            if scheme != SensingScheme::Current {
                // voltage margins scale with the RBL stack: 64-row arrays
                // discharge to nanovolt-level level spacing where nothing
                // is deterministic; 1024 rows is the paper geometry
                c.rows = 1024;
            }
            c.vt_sigma = sigma;
            let mut masked = AdraEngine::new(&c);
            let mut c_exact = c.clone();
            c_exact.tier = FidelityTier::Exact;
            let mut exact = AdraEngine::new(&c_exact); // same seed -> same dvt
            assert!(
                masked.masked_active(),
                "{scheme:?} sigma={sigma}: masks must keep the packed path hot"
            );
            let mut rng = Rng::new(0xAD2A ^ (sigma * 1e4) as u64);
            for round in 0..8usize {
                let (a, b) = (rng.below(256), rng.below(256));
                let row = (round % 4) * 2 + 8;
                let mut ops: Vec<CimOp> = vec![
                    CimOp::Write { addr: WordAddr { row, word: 2 }, value: a },
                    CimOp::Write { addr: WordAddr { row: row + 1, word: 2 }, value: b },
                    CimOp::Read(WordAddr { row, word: 2 }),
                    CimOp::Read2 { row_a: row, row_b: row + 1, word: 2 },
                    CimOp::Add { row_a: row, row_b: row + 1, word: 2 },
                    CimOp::Sub { row_a: row, row_b: row + 1, word: 2 },
                    CimOp::Compare { row_a: row, row_b: row + 1, word: 2 },
                ];
                for f in BoolFn::ALL {
                    ops.push(CimOp::Bool { f, row_a: row, row_b: row + 1, word: 2 });
                }
                for op in &ops {
                    let got = masked.execute(op).unwrap();
                    let want = exact.execute(op).unwrap();
                    assert_eq!(
                        got.value, want.value,
                        "{scheme:?} sigma={sigma} {op:?} a={a:#x} b={b:#x}"
                    );
                    assert_eq!(got.cost, want.cost, "{scheme:?} sigma={sigma} {op:?}");
                }
            }
            let s = masked.array().stats();
            assert_eq!(s.xval_mismatches, 0, "{scheme:?} sigma={sigma}: {s:?}");
        }
    }
}

/// Acceptance criterion: at the paper-nominal 20 mV sigma on current
/// sensing, >= 80% of the columns touched by a realistic workload are
/// served from the packed planes, with zero cross-validation mismatches.
#[test]
fn masked_fraction_meets_acceptance_at_nominal_variation() {
    let mut c = SimConfig::square(256, SensingScheme::Current);
    c.word_bits = 32;
    c.vt_sigma = 0.02;
    let mut e = AdraEngine::new(&c);
    assert!(e.masked_active());
    let mut gen = WorkloadGen::new(&c, OpMix::balanced(), 4242);
    for op in gen.batch(2000) {
        let _ = e.execute(&op);
    }
    // row-wide vector ops ride the same masked planes
    {
        let mut v = VectorEngine::new(&mut e);
        v.sub_row(0, 1).unwrap();
        v.add_row(2, 3).unwrap();
    }
    let s = e.array().stats();
    assert!(s.masked_activations > 0, "{s:?}");
    assert!(
        s.det_col_fraction() >= 0.8,
        "packed path must serve >= 80% of columns: {s:?} ({:.3})",
        s.det_col_fraction()
    );
    assert_eq!(s.xval_mismatches, 0, "{s:?}");
}

/// Property: every column the mask calls deterministic decodes exactly
/// like the analog pipeline — for random seeds, sigmas, and contents.
#[derive(Clone, Debug)]
struct MaskCase {
    seed: u64,
    sigma: f64,
}

impl Arbitrary for MaskCase {
    fn generate(rng: &mut Rng) -> Self {
        MaskCase {
            seed: rng.next_u64(),
            sigma: rng.uniform(0.005, 0.04),
        }
    }
}

#[test]
fn prop_mask_deterministic_columns_agree_with_analog() {
    Quick::with_cases(12).check::<MaskCase, _>("det columns == analog", |case| {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c.vt_sigma = case.sigma;
        c.seed = case.seed;
        let mut masked = AdraEngine::new(&c);
        let mut c_exact = c.clone();
        c_exact.tier = FidelityTier::Exact;
        let mut exact = AdraEngine::new(&c_exact);
        let mut rng = Rng::new(case.seed ^ 0x99);
        for row in 0..4usize {
            for word in 0..c.words_per_row() {
                let v = rng.below(256);
                for e in [&mut masked, &mut exact] {
                    e.execute(&CimOp::Write { addr: WordAddr { row, word }, value: v })
                        .unwrap();
                }
            }
        }
        for (ra, rb) in [(0usize, 1usize), (2, 3), (0, 3)] {
            let m_outs: Vec<_> = masked.activate_cols(ra, rb, 0, 64).unwrap().to_vec();
            let x_outs: Vec<_> = exact.activate_cols(ra, rb, 0, 64).unwrap().to_vec();
            for col in 0..64 {
                let det = masked.array().mask_window(ra, col, col + 1)
                    & masked.array().mask_window(rb, col, col + 1)
                    & 1;
                if det == 1 {
                    // mask-certified: must equal the ideal digital triple
                    let a = masked.array().bit(ra, col);
                    let b = masked.array().bit(rb, col);
                    let o = m_outs[col];
                    if o.or != (a || b) || o.b != b || o.and != (a && b) {
                        return false;
                    }
                }
                // and regardless of mask, masked == exact per column
                if m_outs[col] != x_outs[col] {
                    return false;
                }
            }
        }
        true
    });
}

/// Fused batches under masked variation match the exact tier op for op.
#[test]
fn fused_batches_identical_under_masked_variation() {
    let mut ops = vec![
        CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 99 },
        CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 45 },
        CimOp::Write { addr: WordAddr { row: 0, word: 3 }, value: 17 },
        CimOp::Write { addr: WordAddr { row: 1, word: 3 }, value: 230 },
    ];
    for _ in 0..4 {
        for w in [0usize, 3] {
            ops.push(CimOp::Sub { row_a: 0, row_b: 1, word: w });
            ops.push(CimOp::Compare { row_a: 0, row_b: 1, word: w });
            ops.push(CimOp::Bool { f: BoolFn::AndNot, row_a: 0, row_b: 1, word: w });
        }
    }
    let mut c = cfg(SensingScheme::Current, FidelityTier::Digital);
    c.vt_sigma = 0.02;
    let mut masked = AdraEngine::new(&c);
    let mut c_exact = c.clone();
    c_exact.tier = FidelityTier::Exact;
    let mut exact = AdraEngine::new(&c_exact);
    let rm = execute_fused(&mut masked, &ops);
    let rx = execute_fused(&mut exact, &ops);
    for (i, (g, w)) in rm.iter().zip(&rx).enumerate() {
        match (g, w) {
            (Ok(g), Ok(w)) => {
                assert_eq!(g.value, w.value, "fused op {i}");
                assert_eq!(g.cost, w.cost, "fused op {i} cost");
            }
            (Err(_), Err(_)) => {}
            other => panic!("masked fused divergence at {i}: {other:?}"),
        }
    }
    // the two word groups share one pair batch per run but still record
    // one activation each — identical to the exact tier's accounting
    assert_eq!(
        masked.array().stats().dual_activations,
        exact.array().stats().dual_activations
    );
    assert_eq!(masked.array().stats().xval_mismatches, 0);
}

#[test]
fn cross_validation_counter_stays_zero_on_default_config() {
    // default config == default tier (digital); run enough activations
    // that the sampled cross-validation triggers repeatedly
    let mut c = SimConfig::default();
    c.rows = 128;
    c.cols = 128;
    c.word_bits = 32;
    let mut e = AdraEngine::new(&c);
    assert!(e.digital_active());
    e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 0xCAFE_F00D })
        .unwrap();
    e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 0x1234_5678 })
        .unwrap();
    let n = 4 * AdraEngine::XVAL_PERIOD;
    for i in 0..n {
        let f = BoolFn::ALL[(i % 8) as usize];
        e.execute(&CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 }).unwrap();
    }
    let s = e.array().stats();
    assert!(s.xval_checks >= 4, "sampling must have run: {s:?}");
    assert_eq!(s.xval_mismatches, 0, "digital decisions must match analog: {s:?}");
}

#[test]
fn vector_row_ops_identical_across_tiers() {
    let mut rng = Rng::new(0xBEEF);
    let mut es = engines(SensingScheme::Current);
    let words = 64 / 8;
    for w in 0..words {
        let (a, b) = (rng.below(256), rng.below(256));
        for (_, e) in es.iter_mut() {
            e.execute(&CimOp::Write { addr: WordAddr { row: 2, word: w }, value: a })
                .unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 3, word: w }, value: b })
                .unwrap();
        }
    }
    let results: Vec<_> = es
        .iter_mut()
        .map(|(tier, e)| {
            let (sub, add, wide) = {
                let mut v = VectorEngine::new(e);
                (
                    v.sub_row(2, 3).unwrap(),
                    v.add_row(2, 3).unwrap(),
                    v.sub_wide(2, 3, 0, 4).unwrap(),
                )
            };
            (*tier, sub, add, wide)
        })
        .collect();
    let (_, sub0, add0, wide0) = &results[0];
    for (tier, sub, add, wide) in &results[1..] {
        assert_eq!(sub.values, sub0.values, "{tier:?} sub_row");
        assert_eq!(sub.cost, sub0.cost, "{tier:?} sub_row cost");
        assert_eq!(add.values, add0.values, "{tier:?} add_row");
        assert_eq!(wide.0, wide0.0, "{tier:?} sub_wide");
        assert_eq!(wide.1, wide0.1, "{tier:?} sub_wide cost");
    }
    // and every tier records the same single-activation stats
    for (tier, e) in &es {
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 3, "{tier:?}: 3 row-wide ops, 3 activations");
    }
}

#[test]
fn fused_batches_identical_across_tiers() {
    let mut ops = vec![
        CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 99 },
        CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 45 },
    ];
    for _ in 0..5 {
        ops.push(CimOp::Sub { row_a: 0, row_b: 1, word: 0 });
        ops.push(CimOp::Compare { row_a: 0, row_b: 1, word: 0 });
        ops.push(CimOp::Bool { f: BoolFn::AndNot, row_a: 0, row_b: 1, word: 0 });
    }
    let mut results = Vec::new();
    for tier in FidelityTier::ALL {
        let mut e = AdraEngine::new(&cfg(SensingScheme::Current, tier));
        let rs = execute_fused(&mut e, &ops);
        assert_eq!(e.array().stats().dual_activations, 1, "{tier:?}: one fused activation");
        results.push((tier, rs));
    }
    let (_, ref0) = &results[0];
    for (tier, rs) in &results[1..] {
        for (i, (got, want)) in rs.iter().zip(ref0.iter()).enumerate() {
            match (got, want) {
                (Ok(g), Ok(w)) => {
                    assert_eq!(g.value, w.value, "{tier:?} fused op {i}");
                    assert_eq!(g.cost, w.cost, "{tier:?} fused op {i} cost");
                }
                (Err(_), Err(_)) => {}
                other => panic!("{tier:?} fused divergence at {i}: {other:?}"),
            }
        }
    }
}
