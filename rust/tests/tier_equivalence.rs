//! Tiered activation kernel equivalence suite (the acceptance gate of
//! the bit-packed digital fast path):
//!
//!  * every Boolean function, `sub`, `compare`, `read2`, `add`, and plain
//!    reads produce bit-identical `CimResult`s — value AND reported
//!    `OpCost` — across `Digital` / `Lut` / `Exact`, on every sensing
//!    scheme;
//!  * the digital tier auto-disables when `vt_sigma > 0` (decisions stop
//!    being deterministic) while values stay correct through the analog
//!    pipeline;
//!  * the sampled digital-vs-analog cross-validation counter stays zero
//!    on the default configuration;
//!  * row-wide vector ops and fused batches are tier-invariant too.

use adra::cim::{AdraEngine, BoolFn, CimOp, CimValue, Engine, VectorEngine, WordAddr};
use adra::config::{FidelityTier, SensingScheme, SimConfig};
use adra::coordinator::fuse::execute_fused;
use adra::util::rng::Rng;
use adra::workload::{OpMix, WorkloadGen};

fn cfg(scheme: SensingScheme, tier: FidelityTier) -> SimConfig {
    let mut c = SimConfig::square(64, scheme);
    c.word_bits = 8;
    c.tier = tier;
    c
}

fn engines(scheme: SensingScheme) -> Vec<(FidelityTier, AdraEngine)> {
    FidelityTier::ALL
        .iter()
        .map(|&t| (t, AdraEngine::new(&cfg(scheme, t))))
        .collect()
}

#[test]
fn all_ops_bit_identical_across_tiers() {
    let mut rng = Rng::new(0x7137);
    for scheme in SensingScheme::ALL {
        let mut es = engines(scheme);
        assert!(es[0].1.digital_active(), "{scheme:?}: digital tier must engage");
        for _ in 0..6 {
            let (a, b) = (rng.below(256), rng.below(256));
            let mut ops: Vec<CimOp> = vec![
                CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a },
                CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b },
                CimOp::Read(WordAddr { row: 0, word: 0 }),
                CimOp::Read2 { row_a: 0, row_b: 1, word: 0 },
                CimOp::Add { row_a: 0, row_b: 1, word: 0 },
                CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
                CimOp::Compare { row_a: 0, row_b: 1, word: 0 },
            ];
            for f in BoolFn::ALL {
                ops.push(CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 });
            }
            for op in &ops {
                let reference = es[0].1.execute(op).unwrap();
                // pin the digital tier against host semantics first
                if let CimOp::Bool { f, .. } = op {
                    assert_eq!(
                        reference.value,
                        CimValue::Word(f.apply(a, b, 0xFF)),
                        "{scheme:?} {f:?} a={a:#x} b={b:#x}"
                    );
                }
                for (tier, e) in es.iter_mut().skip(1) {
                    let got = e.execute(op).unwrap();
                    assert_eq!(
                        got.value, reference.value,
                        "{scheme:?} {tier:?} {op:?} a={a:#x} b={b:#x}"
                    );
                    assert_eq!(
                        got.cost, reference.cost,
                        "reported OpCost must be tier-invariant: {scheme:?} {tier:?} {op:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn random_workload_identical_across_tiers() {
    let base = cfg(SensingScheme::Current, FidelityTier::Digital);
    let mut digital = AdraEngine::new(&base);
    let mut lut = AdraEngine::new(&cfg(SensingScheme::Current, FidelityTier::Lut));
    let mut exact = AdraEngine::new(&cfg(SensingScheme::Current, FidelityTier::Exact));
    let mut gen = WorkloadGen::new(&base, OpMix::balanced(), 9090);
    for op in gen.batch(800) {
        let d = digital.execute(&op);
        let l = lut.execute(&op);
        let x = exact.execute(&op);
        match (&d, &l, &x) {
            (Ok(rd), Ok(rl), Ok(rx)) => {
                assert_eq!(rd.value, rl.value, "digital vs lut on {op:?}");
                assert_eq!(rd.value, rx.value, "digital vs exact on {op:?}");
                assert_eq!(rd.cost, rl.cost, "cost on {op:?}");
                assert_eq!(rd.cost, rx.cost, "cost on {op:?}");
            }
            (Err(_), Err(_), Err(_)) => {}
            other => panic!("tier divergence on {op:?}: {other:?}"),
        }
    }
    let s = digital.array().stats();
    assert!(s.digital_activations > 0, "fast path must have served: {s:?}");
    assert_eq!(s.digital_activations, s.dual_activations);
    assert_eq!(s.xval_mismatches, 0);
}

#[test]
fn digital_tier_auto_disables_with_variation() {
    let mut c = cfg(SensingScheme::Current, FidelityTier::Digital);
    c.rows = 256;
    c.cols = 256;
    c.vt_sigma = 0.02;
    let mut e = AdraEngine::new(&c);
    assert!(!e.digital_active(), "vt_sigma > 0 must disable the digital tier");
    let mut c_lut = c.clone();
    c_lut.tier = FidelityTier::Lut;
    let mut mirror = AdraEngine::new(&c_lut); // same seed -> same variation plane
    let mut rng = Rng::new(31);
    for _ in 0..16 {
        let (a, b) = (rng.below(256), rng.below(256));
        for eng in [&mut e, &mut mirror] {
            eng.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a })
                .unwrap();
            eng.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b })
                .unwrap();
        }
        let r = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
        let m = mirror.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Pair(a, b), "analog fallback must stay correct");
        assert_eq!(r.value, m.value);
    }
    assert_eq!(e.array().stats().digital_activations, 0);
    assert!(e.array().stats().dual_activations > 0);
}

#[test]
fn cross_validation_counter_stays_zero_on_default_config() {
    // default config == default tier (digital); run enough activations
    // that the sampled cross-validation triggers repeatedly
    let mut c = SimConfig::default();
    c.rows = 128;
    c.cols = 128;
    c.word_bits = 32;
    let mut e = AdraEngine::new(&c);
    assert!(e.digital_active());
    e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 0xCAFE_F00D })
        .unwrap();
    e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 0x1234_5678 })
        .unwrap();
    let n = 4 * AdraEngine::XVAL_PERIOD;
    for i in 0..n {
        let f = BoolFn::ALL[(i % 8) as usize];
        e.execute(&CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 }).unwrap();
    }
    let s = e.array().stats();
    assert!(s.xval_checks >= 4, "sampling must have run: {s:?}");
    assert_eq!(s.xval_mismatches, 0, "digital decisions must match analog: {s:?}");
}

#[test]
fn vector_row_ops_identical_across_tiers() {
    let mut rng = Rng::new(0xBEEF);
    let mut es = engines(SensingScheme::Current);
    let words = 64 / 8;
    for w in 0..words {
        let (a, b) = (rng.below(256), rng.below(256));
        for (_, e) in es.iter_mut() {
            e.execute(&CimOp::Write { addr: WordAddr { row: 2, word: w }, value: a })
                .unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 3, word: w }, value: b })
                .unwrap();
        }
    }
    let results: Vec<_> = es
        .iter_mut()
        .map(|(tier, e)| {
            let (sub, add, wide) = {
                let mut v = VectorEngine::new(e);
                (
                    v.sub_row(2, 3).unwrap(),
                    v.add_row(2, 3).unwrap(),
                    v.sub_wide(2, 3, 0, 4).unwrap(),
                )
            };
            (*tier, sub, add, wide)
        })
        .collect();
    let (_, sub0, add0, wide0) = &results[0];
    for (tier, sub, add, wide) in &results[1..] {
        assert_eq!(sub.values, sub0.values, "{tier:?} sub_row");
        assert_eq!(sub.cost, sub0.cost, "{tier:?} sub_row cost");
        assert_eq!(add.values, add0.values, "{tier:?} add_row");
        assert_eq!(wide.0, wide0.0, "{tier:?} sub_wide");
        assert_eq!(wide.1, wide0.1, "{tier:?} sub_wide cost");
    }
    // and every tier records the same single-activation stats
    for (tier, e) in &es {
        let s = e.array().stats();
        assert_eq!(s.dual_activations, 3, "{tier:?}: 3 row-wide ops, 3 activations");
    }
}

#[test]
fn fused_batches_identical_across_tiers() {
    let mut ops = vec![
        CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 99 },
        CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 45 },
    ];
    for _ in 0..5 {
        ops.push(CimOp::Sub { row_a: 0, row_b: 1, word: 0 });
        ops.push(CimOp::Compare { row_a: 0, row_b: 1, word: 0 });
        ops.push(CimOp::Bool { f: BoolFn::AndNot, row_a: 0, row_b: 1, word: 0 });
    }
    let mut results = Vec::new();
    for tier in FidelityTier::ALL {
        let mut e = AdraEngine::new(&cfg(SensingScheme::Current, tier));
        let rs = execute_fused(&mut e, &ops);
        assert_eq!(e.array().stats().dual_activations, 1, "{tier:?}: one fused activation");
        results.push((tier, rs));
    }
    let (_, ref0) = &results[0];
    for (tier, rs) in &results[1..] {
        for (i, (got, want)) in rs.iter().zip(ref0.iter()).enumerate() {
            match (got, want) {
                (Ok(g), Ok(w)) => {
                    assert_eq!(g.value, w.value, "{tier:?} fused op {i}");
                    assert_eq!(g.cost, w.cost, "{tier:?} fused op {i} cost");
                }
                (Err(_), Err(_)) => {}
                other => panic!("{tier:?} fused divergence at {i}: {other:?}"),
            }
        }
    }
}
