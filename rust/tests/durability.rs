//! Chaos + durability suite: the only place fault specs are INSTALLED.
//!
//! The injector (`adra::faults`) is process-global, so arming it from a
//! lib unit test would perturb unrelated tests sharing the process.
//! This binary runs as its own process and serializes every test behind
//! [`adra::faults::test_lock`], which makes installed specs safe:
//!
//! * schedule determinism/boundedness of the seeded death/spike hooks,
//! * injected WAL/snapshot corruption: detected by checksum, recovered
//!   by prefix replay and `.prev` fallback,
//! * the crash-point sweep: for EVERY byte-truncation of the WAL the
//!   store recovers exactly the durable record prefix, bit-identical to
//!   the fault-free array state at that point,
//! * worker death mid-round: coordinator respawn at the pool level, and
//!   respawn + replay + retry inside a serving flood,
//! * wear-drift acceleration driving live row migrations without
//!   changing any answer,
//! * latency spikes driving the batch controller's multiplicative
//!   decrease while the flood stays bit-identical,
//! * restart recovery and snapshot/restore cache-staleness pinning with
//!   chaos compiled in and armed.

use std::path::PathBuf;

use adra::config::{SensingScheme, SimConfig};
use adra::coordinator::Coordinator;
use adra::faults::{self, FaultSpec, WorkerFault};
use adra::planner::{Layout, Predicate, Program, ScratchRow, StepOutput};
use adra::serve::{BatchPolicy, ServeConfig, ServeQueue, TableState};
use adra::store::{DurableState, DurableStore, WalOp};
use adra::util::quick::Quick;
use adra::util::rng::Rng;
use adra::workload::heavy_tenant_scenario;
use adra::workload::programs::analytics_scenario;

mod common;
use common::Seed;

const N_RECORDS: usize = 48;
const SHARDS: usize = 3;

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::Current);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("adra_durability_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Serving config tuned for the chaos tests: deterministic static rounds
/// and no sampling/calibration noise unless a test opts back in.
fn serve_cfg(cfg: &SimConfig) -> ServeConfig {
    let mut c = ServeConfig::new(cfg.clone(), SHARDS, N_RECORDS);
    c.max_round = 6;
    c.cache_capacity = 512;
    c.batch = BatchPolicy::Static;
    c.sample_every = 0;
    c.calibrate_every = 0;
    c
}

/// Installs a spec on construction, guarantees `clear` on drop (even on
/// assertion failure), so no test leaks an armed injector.
struct Chaos;

impl Chaos {
    fn install(spec: &str) -> Self {
        faults::clear();
        faults::install(FaultSpec::parse(spec).expect("valid spec"));
        Chaos
    }
}

impl Drop for Chaos {
    fn drop(&mut self) {
        faults::clear();
    }
}

/// Encoded WAL record size: u32 length prefix + body + u64 checksum.
fn wal_record_len(op: &WalOp) -> usize {
    let body = match op {
        WalOp::Record { .. } => 1 + 8 + 8 + 8,
        WalOp::Scratch { .. } => 1 + 8 + 8,
    };
    4 + body + 8
}

/// The longest fully-durable record prefix within `cut` bytes of WAL.
fn durable_prefix(ops: &[WalOp], cut: usize) -> &[WalOp] {
    let mut at = 0usize;
    let mut k = 0usize;
    for op in ops {
        at += wal_record_len(op);
        if at > cut {
            break;
        }
        k += 1;
    }
    &ops[..k]
}

// ---- hook schedules --------------------------------------------------

#[test]
fn death_and_spike_schedules_are_deterministic_and_bounded() {
    let _g = faults::test_lock();

    // deaths fire on the every-5th-op cadence, capped at death-max
    let schedule = |spec: &str| -> Vec<(usize, WorkerFault)> {
        let _c = Chaos::install(spec);
        (1..=20).map(|n| (n, faults::on_worker_op(0))).collect()
    };
    let a = schedule("seed=7 death=5 death-max=2");
    let deaths: Vec<usize> =
        a.iter().filter(|(_, f)| *f == WorkerFault::Die).map(|(n, _)| *n).collect();
    assert_eq!(deaths, vec![5, 10], "every-5th cadence, bounded at 2: {a:?}");
    assert!(
        a.iter().all(|(n, f)| deaths.contains(n) || *f == WorkerFault::None),
        "no other fault fires: {a:?}"
    );
    // reinstalling the same spec reproduces the schedule exactly
    assert_eq!(a, schedule("seed=7 death=5 death-max=2"), "seeded schedule is deterministic");

    // spikes fire on their own cadence with the configured stall
    let _c = Chaos::install("spike=4 spike-ns=7");
    for n in 1..=12 {
        let want = if n % 4 == 0 { WorkerFault::Delay(7) } else { WorkerFault::None };
        assert_eq!(faults::on_worker_op(1), want, "op {n}");
    }
}

#[test]
fn corruption_flips_are_seed_deterministic() {
    let _g = faults::test_lock();
    let flip = || {
        let _c = Chaos::install("seed=5 corrupt-wal=1");
        let mut buf = vec![0u8; 32];
        assert!(faults::corrupt_wal(&mut buf), "every-1st record is flipped");
        buf
    };
    let a = flip();
    assert_eq!(a, flip(), "same seed, same flip position");
    assert_eq!(a.iter().filter(|&&b| b != 0).count(), 1, "exactly one byte flipped");
}

// ---- store corruption + crash points ---------------------------------

#[test]
fn injected_wal_corruption_is_detected_and_prefix_recovered() {
    let _g = faults::test_lock();
    let dir = tmpdir("wal_corrupt");
    let ops: Vec<WalOp> = (0..6)
        .map(|i| WalOp::Record { slot: i, value: 10 + i, version: i + 1 })
        .collect();
    {
        let _c = Chaos::install("seed=3 corrupt-wal=2");
        let (mut st, _) = DurableStore::open(&dir).expect("open");
        st.append(&ops).expect("append");
        // the injector flipped a byte in every 2nd record AFTER its
        // checksum was computed, so the damage is detectable
    }
    let (st, rec) = DurableStore::open(&dir).expect("reopen");
    assert_eq!(rec.wal, &ops[..1], "replay stops at the first bad record");
    assert_eq!(rec.corruptions, 1, "the bad record is counted, not silently skipped");
    assert!(rec.state.is_none() && !rec.used_fallback);
    assert_eq!(st.corruptions_detected, 1, "the handle carries the count into adra.store.*");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_snapshot_corruption_falls_back_to_prev_checkpoint() {
    let _g = faults::test_lock();
    let dir = tmpdir("snap_corrupt");
    let mut cfg = cfg();
    cfg.word_bits = 8;
    let mut good = TableState::new(&cfg, 8);
    for slot in 0..8 {
        good.record_write(slot, slot as u64 + 1);
    }
    let good_state =
        DurableState { table: good.image(), wear: Vec::new(), calibration_json: String::new() };
    let mut clobbered = TableState::new(&cfg, 8);
    for slot in 0..8 {
        clobbered.record_write(slot, 99);
    }
    let bad_state = DurableState {
        table: clobbered.image(),
        wear: Vec::new(),
        calibration_json: String::new(),
    };
    {
        let (mut st, _) = DurableStore::open(&dir).expect("open");
        st.checkpoint(&good_state).expect("good checkpoint");
        let _c = Chaos::install("seed=11 corrupt-snapshot");
        st.checkpoint(&bad_state).expect("corrupted checkpoint still writes");
    }
    let (_, rec) = DurableStore::open(&dir).expect("reopen");
    assert!(rec.used_fallback, "snapshot.bin failed its checksum; .prev was used");
    assert!(rec.corruptions >= 1);
    assert_eq!(
        rec.state.expect("fallback recovers the previous checkpoint").table,
        good_state.table,
        "recovery falls back to the last GOOD state, not the torn one"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The crash-point sweep: for EVERY byte-truncation of the WAL, recovery
/// yields exactly the longest fully-durable record prefix — never an
/// error, never a spurious corruption (a torn tail is the normal crash
/// artifact), never a partial record.
#[test]
fn wal_crash_point_sweep_recovers_exact_durable_prefix() {
    let _g = faults::test_lock();
    faults::clear();
    let dir = tmpdir("sweep_src");
    let ops = vec![
        WalOp::Scratch { idx: 0, value: 5 },
        WalOp::Record { slot: 0, value: 17, version: 1 },
        WalOp::Record { slot: 3, value: 251, version: 2 },
        WalOp::Scratch { idx: 1, value: 42 },
        WalOp::Record { slot: 0, value: 9, version: 3 },
        WalOp::Record { slot: 7, value: 128, version: 4 },
        WalOp::Scratch { idx: 0, value: 6 },
        WalOp::Record { slot: 5, value: 1, version: 5 },
    ];
    {
        let (mut st, _) = DurableStore::open(&dir).expect("open");
        st.append(&ops).expect("append");
    }
    let bytes = std::fs::read(dir.join("wal.bin")).expect("read wal");
    assert_eq!(
        bytes.len(),
        ops.iter().map(wal_record_len).sum::<usize>(),
        "framing matches the documented record layout"
    );

    let crash = tmpdir("sweep_crash");
    for cut in 0..=bytes.len() {
        let _ = std::fs::remove_dir_all(&crash);
        std::fs::create_dir_all(&crash).expect("mkdir");
        std::fs::write(crash.join("wal.bin"), &bytes[..cut]).expect("write truncated wal");
        let (_, rec) = DurableStore::open(&crash).expect("crash-point recovery never errors");
        assert_eq!(rec.wal, durable_prefix(&ops, cut), "crash at byte {cut}");
        assert_eq!(rec.corruptions, 0, "a torn tail is not corruption (byte {cut})");
        assert!(rec.state.is_none() && !rec.used_fallback);
    }
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&crash);
}

/// Any random op sequence, any random crash point: recovery is exactly
/// the durable prefix (the property behind the deterministic sweep).
#[test]
fn prop_random_wal_truncation_recovers_a_prefix() {
    let _g = faults::test_lock();
    faults::clear();
    let dir = tmpdir("prop_src");
    let crash = tmpdir("prop_crash");
    Quick::with_cases(16).check::<Seed, _>("wal prefix recovery", |seed| {
        let mut rng = Rng::new(seed.0);
        let n_ops = 5 + rng.below(20) as usize;
        let ops: Vec<WalOp> = (0..n_ops)
            .map(|i| {
                if rng.bool() {
                    WalOp::Record {
                        slot: rng.below(64),
                        value: rng.below(256),
                        version: i as u64 + 1,
                    }
                } else {
                    WalOp::Scratch { idx: rng.below(4), value: rng.below(256) }
                }
            })
            .collect();
        let _ = std::fs::remove_dir_all(&dir);
        {
            let (mut st, _) = DurableStore::open(&dir).expect("open");
            st.append(&ops).expect("append");
        }
        let bytes = std::fs::read(dir.join("wal.bin")).expect("read wal");
        let cut = rng.below(bytes.len() as u64 + 1) as usize;
        let _ = std::fs::remove_dir_all(&crash);
        std::fs::create_dir_all(&crash).expect("mkdir");
        std::fs::write(crash.join("wal.bin"), &bytes[..cut]).expect("truncate");
        let (_, rec) = DurableStore::open(&crash).expect("recover");
        rec.corruptions == 0 && rec.wal == durable_prefix(&ops, cut)
    });
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&crash);
}

/// Snapshot + WAL overlap replays idempotently AND the recovered logical
/// state rebuilds a physical array bit-identical to the pre-crash one
/// (`FefetArray::state_digest` over the replayed writes).
#[test]
fn recovered_replay_is_bit_identical_to_pre_crash_array() {
    let _g = faults::test_lock();
    faults::clear();
    let cfg = cfg();
    let n_records = 16;
    let layout = Layout::of(&cfg, n_records);
    let dir = tmpdir("bit_identity");
    let (mut st, _) = DurableStore::open(&dir).expect("open");

    // live writes journal into the WAL while mirroring onto an array,
    // with a mid-sequence checkpoint so replay must skip the covered
    // (version-stamped) WAL prefix
    let mut state = TableState::new(&cfg, n_records);
    state.enable_journal();
    let mut live = adra::array::FefetArray::new(&cfg);
    let mut apply = |state: &mut TableState, arr: &mut adra::array::FefetArray, i: usize| {
        if i % 3 == 0 {
            let v = (i as u64 * 7 + 1) & 0xFF;
            state.scratch_write(i % 2, v);
            let row = layout.scratch_row(ScratchRow(i % 2));
            for word in 0..layout.words_per_row {
                arr.write_word(row, word, v);
            }
        } else {
            let slot = (i * 5) % n_records;
            let v = (i as u64 * 13 + 3) & 0xFF;
            if !state.record_write(slot, v) {
                let a = layout.record_addr(slot);
                arr.write_word(a.row, a.word, v);
            }
        }
    };
    for i in 0..7 {
        apply(&mut state, &mut live, i);
    }
    st.append(&state.take_journal()).expect("append first half");
    st.checkpoint(&DurableState {
        table: state.image(),
        wear: Vec::new(),
        calibration_json: String::new(),
    })
    .expect("mid-sequence checkpoint");
    for i in 7..16 {
        apply(&mut state, &mut live, i);
    }
    st.append(&state.take_journal()).expect("append second half");
    drop(st); // crash after the last append

    let (_, rec) = DurableStore::open(&dir).expect("recover");
    let ds = rec.state.expect("checkpoint recovered");
    let mut recovered = TableState::from_image(&ds.table);
    for op in &rec.wal {
        recovered.apply_wal(op);
    }
    assert_eq!(recovered.image(), state.image(), "logical state is bit-identical");

    // replaying the recovered contents slot-by-slot rebuilds the exact
    // physical array the original write ORDER produced
    let mut replayed = adra::array::FefetArray::new(&cfg);
    for slot in 0..n_records {
        if let Some(v) = recovered.record_value(slot) {
            let a = layout.record_addr(slot);
            replayed.write_word(a.row, a.word, v);
        }
    }
    for idx in 0..recovered.scratch_len() {
        if let Some(v) = recovered.scratch_value(idx) {
            let row = layout.scratch_row(ScratchRow(idx));
            for word in 0..layout.words_per_row {
                replayed.write_word(row, word, v);
            }
        }
    }
    assert_eq!(
        replayed.state_digest(),
        live.state_digest(),
        "replay-by-content == original write history"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- pool-level death + respawn --------------------------------------

#[test]
fn injected_worker_death_is_respawned_at_the_pool() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut coord = Coordinator::adra(&cfg, 2);
    use adra::cim::{CimOp, WordAddr};
    let ops: Vec<CimOp> = (0..3)
        .map(|w| CimOp::Write { addr: WordAddr { row: 0, word: w }, value: 7 })
        .collect();

    let _c = Chaos::install("seed=2 death=4 death-max=1");
    assert!(coord.call_batch(0, &ops).is_ok(), "ops 1-3 precede the death point");
    assert!(
        coord.call_batch(0, &ops).is_err(),
        "op 4 kills the worker; the batch dies un-replied"
    );
    assert!(coord.call_batch(1, &ops).is_ok(), "the other shard is untouched");
    coord.respawn(0).expect("respawn installs a fresh worker");
    assert_eq!(coord.respawns(), 1);
    assert!(coord.call_batch(0, &ops).is_ok(), "death-max=1 is exhausted; shard 0 serves again");
    let got = coord
        .call(0, CimOp::Read(WordAddr { row: 0, word: 0 }))
        .expect("read after respawn");
    assert_eq!(got.value, adra::cim::CimValue::Word(7), "re-written contents are visible");
}

// ---- serving under chaos ---------------------------------------------

#[test]
fn serve_flood_survives_worker_deaths_with_identical_answers() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let s = heavy_tenant_scenario(&cfg, N_RECORDS, 123, 12, 3);
    let mut sc = serve_cfg(&cfg);
    sc.route_retries = 3;
    let queue = ServeQueue::start(sc);

    let _c = Chaos::install("seed=40 death=40 death-max=2");
    let tickets: Vec<_> = s
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();
    let reports: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().expect("every program is answered despite worker deaths"))
        .collect();
    for (i, (rep, want)) in reports.iter().zip(&s.expected_matches).enumerate() {
        assert_eq!(
            rep.outputs[s.filter_step],
            StepOutput::Matches(want.clone()),
            "submission {i} diverged from ground truth"
        );
    }
    let m = queue.metrics();
    assert!(m.worker_respawns >= 1, "at least one injected death hit a round: {m:?}");
    assert!(m.recovered_shards >= 1, "the retry loop recovered the shard: {m:?}");
    assert!(m.route_retries >= m.recovered_shards);
}

#[test]
fn wear_acceleration_migrates_hot_rows_without_changing_answers() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg);
    sc.wear_spare_rows = 4;
    sc.wear_migrate_threshold = 64;
    let queue = ServeQueue::start(sc);

    {
        // 1000x endurance drift: one serving wave is enough soak to push
        // the hottest row past the migration threshold
        let _c = Chaos::install("seed=9 wear=1000");
        for wave in 0..3u64 {
            let s = heavy_tenant_scenario(&cfg, N_RECORDS, 700 + wave, 4, 2);
            let tickets: Vec<_> = s
                .submissions
                .iter()
                .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
                .collect();
            for (i, t) in tickets.into_iter().enumerate() {
                let rep = t.wait().expect("served");
                assert_eq!(
                    rep.outputs[s.filter_step],
                    StepOutput::Matches(s.expected_matches[i].clone()),
                    "wave {wave} submission {i} diverged after migration"
                );
            }
        }
    }
    let m = queue.metrics();
    assert!(m.wear_migrations >= 1, "accelerated wear must trigger a migration: {m:?}");

    // with the accelerant cleared, steered serving stays bit-identical
    let s = heavy_tenant_scenario(&cfg, N_RECORDS, 7103, 4, 2);
    let tickets: Vec<_> = s
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let rep = t.wait().expect("served post-chaos");
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches[i].clone()));
    }
}

#[test]
fn latency_spikes_shrink_the_batch_and_the_flood_stays_identical() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg);
    sc.batch = BatchPolicy::Adaptive { target_p95: 1e-3 };
    let queue = ServeQueue::start(sc);

    {
        // a 30ms stall every 50th op dwarfs the 1ms target: the
        // controller must halve max_round (multiplicative decrease)
        let _c = Chaos::install("seed=17 spike=50 spike-ns=30000000");
        let s = heavy_tenant_scenario(&cfg, N_RECORDS, 555, 12, 3);
        let tickets: Vec<_> = s
            .submissions
            .iter()
            .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let rep = t.wait().expect("served under spikes");
            assert_eq!(
                rep.outputs[s.filter_step],
                StepOutput::Matches(s.expected_matches[i].clone()),
                "spikes may slow submission {i}, never corrupt it"
            );
        }
    }
    let m = queue.metrics();
    assert!(m.spike_shrinks >= 1, "the spike cut max_round: {m:?}");

    // recovery: with the injector disarmed the queue keeps serving
    // correctly (and the controller is free to grow the round back)
    let s = heavy_tenant_scenario(&cfg, N_RECORDS, 556, 6, 2);
    let tickets: Vec<_> = s
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let rep = t.wait().expect("served after recovery");
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches[i].clone()));
    }
}

#[test]
fn serve_restart_recovers_under_benign_chaos() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let dir = tmpdir("serve_restart_chaos");
    let s = analytics_scenario(&cfg, N_RECORDS, 31_337);

    let _c = Chaos::install("seed=23 spike=25 spike-ns=100000 wear=7");
    let first = {
        let mut sc = serve_cfg(&cfg);
        sc.store_dir = Some(dir.clone());
        sc.checkpoint_every = 0; // WAL-only: recovery must replay the log
        let q1 = ServeQueue::start(sc);
        q1.submit(0, s.program.clone()).expect("admit").wait().expect("serve")
    };
    assert_eq!(first.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));

    // restart: a fresh queue over the same directory replays the WAL
    // into fresh arrays before its first round
    let mut sc = serve_cfg(&cfg);
    sc.store_dir = Some(dir.clone());
    sc.checkpoint_every = 0;
    let q2 = ServeQueue::start(sc);
    let mut query_only = s.program.clone();
    query_only.ops.remove(0); // drop the Load; recovered contents answer
    let rep = q2.submit(0, query_only).expect("admit").wait().expect("serve after restart");
    assert_eq!(
        rep.outputs[s.filter_step - 1],
        first.outputs[s.filter_step],
        "recovered array answers exactly like the pre-crash one"
    );
    assert_eq!(q2.metrics().recoveries, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_wal_restart_serves_fresh_programs_and_counts_corruption() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let dir = tmpdir("serve_restart_corrupt");
    {
        let _c = Chaos::install("seed=29 corrupt-wal=5");
        let mut sc = serve_cfg(&cfg);
        sc.store_dir = Some(dir.clone());
        sc.checkpoint_every = 0;
        let q1 = ServeQueue::start(sc);
        let s = analytics_scenario(&cfg, N_RECORDS, 61);
        let rep = q1.submit(0, s.program.clone()).expect("admit").wait().expect("serve");
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));
    }
    // the WAL on disk now holds detectably-corrupt records; a restarted
    // queue recovers the good prefix and keeps serving self-contained
    // programs correctly
    let mut sc = serve_cfg(&cfg);
    sc.store_dir = Some(dir.clone());
    sc.checkpoint_every = 0;
    let q2 = ServeQueue::start(sc);
    let s2 = analytics_scenario(&cfg, N_RECORDS, 62);
    let rep = q2.submit(0, s2.program.clone()).expect("admit").wait().expect("serve");
    assert_eq!(rep.outputs[s2.filter_step], StepOutput::Matches(s2.expected_matches.clone()));
    let scrape = adra::observe::expose_text(adra::observe::global());
    assert!(
        scrape.contains("adra_store_corruptions_detected"),
        "detected corruption reaches the adra.store.* families:\n{scrape}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The ResultCache staleness pin (satellite 1): after a `restore` the
/// table epoch CONTINUES, so new writes version strictly above every
/// fingerprint ever handed out — a cached pre-restore result can never
/// alias a post-restore query over different contents.
#[test]
fn restore_then_rewrite_never_serves_a_stale_cached_result() {
    let _g = faults::test_lock();
    faults::clear();
    let cfg = cfg();
    let dir = tmpdir("restore_stale");
    let queue = ServeQueue::start(serve_cfg(&cfg));

    let filter_prog = |values: &[u64], thr: u64| -> Program {
        let mut p = Program::new(N_RECORDS);
        let s0 = p.scratch();
        let all = p.all();
        p.load(0, values.to_vec());
        p.broadcast(s0, thr);
        p.filter(all, s0, Predicate::Lt);
        p
    };
    let matches_of = |values: &[u64], thr: u64| -> Vec<usize> {
        values.iter().enumerate().filter(|(_, &v)| v < thr).map(|(i, _)| i).collect()
    };
    let v1: Vec<u64> = (0..N_RECORDS as u64).map(|i| (i * 3) % 100).collect();
    let v2: Vec<u64> = (0..N_RECORDS as u64).map(|i| (i * 5 + 1) % 100).collect();
    let v3: Vec<u64> = (0..N_RECORDS as u64).map(|i| (i * 11 + 2) % 100).collect();

    let r1 = queue.submit(0, filter_prog(&v1, 50)).expect("admit").wait().expect("v1");
    assert_eq!(r1.outputs[2], StepOutput::Matches(matches_of(&v1, 50)));
    queue.snapshot_to(&dir).expect("snapshot the v1 state");

    // clobber with v2 (its filter result lands in the cache), then roll
    // back to the v1 snapshot
    let r2 = queue.submit(0, filter_prog(&v2, 50)).expect("admit").wait().expect("v2");
    assert_eq!(r2.outputs[2], StepOutput::Matches(matches_of(&v2, 50)));
    queue.restore_from(&dir).expect("restore");

    // post-restore, a THIRD contents must be answered fresh: if the
    // epoch had reset, v3's fingerprints could collide with the cached
    // v2 entry and serve v2's matches
    let r3 = queue.submit(0, filter_prog(&v3, 50)).expect("admit").wait().expect("v3");
    assert_eq!(
        r3.outputs[2],
        StepOutput::Matches(matches_of(&v3, 50)),
        "post-restore rewrite must not alias the pre-restore cache entry"
    );
    assert_eq!(queue.metrics().recoveries, 1);
    let _ = std::fs::remove_dir_all(&dir);
}
