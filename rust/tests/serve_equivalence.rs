//! Property: serving-layer execution (coalesced + fused + write-deduped
//! + cached) of random multi-program batches is bit-identical to naive
//! sequential unfused execution — including cache invalidation when a
//! load overwrites a cached query's range.
//!
//! The naive reference executes every program in admission order through
//! `Placement::execute` (per-program `call_batch`, no fusion, no dedup,
//! no cache) on its own coordinator; the serve path pushes the same
//! programs through a `ServeQueue` from a single submitter thread, so
//! admission order equals program order and any round partitioning the
//! scheduler picks must preserve the outputs.

use adra::config::{SensingScheme, SimConfig};
use adra::planner::{AggKind, Predicate, Program, StepOutput};
use adra::serve::{ServeConfig, ServeQueue};
use adra::util::quick::Quick;
use adra::util::rng::Rng;

mod common;
use common::{naive_outputs, random_program, Seed};

const N_RECORDS: usize = 48;
const SHARDS: usize = 3;

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::Current);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

#[test]
fn prop_served_batches_match_sequential_unfused_execution() {
    let cfg = cfg();
    Quick::with_cases(10).check::<Seed, _>("serve == naive", |s| {
        let mut rng = Rng::new(s.0);
        let mut programs: Vec<Program> =
            (0..6).map(|_| random_program(&mut rng, N_RECORDS)).collect();
        // force the interesting paths: an exact repeat (cache hits when
        // rounds split) and a whole-table load straight after it (every
        // overlapping cached range must be invalidated, not served)
        programs.push(programs[1].clone());
        let mut clobber = Program::new(N_RECORDS);
        let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(128)).collect();
        let all = clobber.all();
        clobber.load(0, values);
        clobber.scan(all);
        programs.push(clobber);
        programs.push(programs[1].clone()); // re-query the clobbered table

        // naive reference: sequential, unfused, uncached
        let refs: Vec<&Program> = programs.iter().collect();
        let naive = naive_outputs(&cfg, SHARDS, &refs);

        // serve path: single submitter, admission order == program order
        let queue = ServeQueue::start(ServeConfig::new(cfg.clone(), SHARDS, N_RECORDS));
        let tickets: Vec<_> = programs
            .iter()
            .map(|p| queue.submit(0, p.clone()).expect("geometry matches"))
            .collect();
        let served: Vec<Vec<StepOutput>> = tickets
            .into_iter()
            .map(|t| t.wait().expect("served").outputs)
            .collect();

        naive == served
    });
}

/// Concurrent multi-tenant submission: admission order is nondeterministic
/// across tenants, so give every tenant identical table contents (loads
/// dedupe) and a private threshold — each program is self-contained, so
/// ANY admission interleaving must reproduce the naive per-tenant outputs.
#[test]
fn concurrent_identical_table_tenants_match_naive() {
    let cfg = cfg();
    // one shared load + per-tenant query programs over the same contents
    let mut rng = Rng::new(2026);
    let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(128)).collect();
    let make_tenant_program = |tenant: usize| {
        let mut p = Program::new(N_RECORDS);
        let t = p.scratch();
        let all = p.all();
        p.load(0, values.clone());
        p.broadcast(t, 20 + 10 * tenant as u64);
        p.filter(all, t, Predicate::Lt);
        p.sub(all, t);
        p.aggregate(all, AggKind::Max);
        p
    };

    let tenant_programs: Vec<Program> = (0..4).map(|t| make_tenant_program(t)).collect();
    let refs: Vec<&Program> = tenant_programs.iter().collect();
    let naive = naive_outputs(&cfg, SHARDS, &refs);

    let queue = std::sync::Arc::new(ServeQueue::start(ServeConfig::new(
        cfg.clone(),
        SHARDS,
        N_RECORDS,
    )));
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let q = queue.clone();
            let program = make_tenant_program(t);
            std::thread::spawn(move || {
                let mut outs = Vec::new();
                for _ in 0..3 {
                    outs.push(q.submit(t, program.clone()).unwrap().wait().unwrap());
                }
                outs
            })
        })
        .collect();
    for (t, h) in handles.into_iter().enumerate() {
        for rep in h.join().unwrap() {
            assert_eq!(rep.outputs, naive[t], "tenant {t} diverged");
        }
    }
    let m = queue.metrics();
    assert_eq!(m.programs, 12);
    assert!(m.skipped_writes > 0, "identical loads must dedupe: {}", m.report("serve"));
}
