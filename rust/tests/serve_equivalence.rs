//! Property: serving-layer execution (coalesced + fused + write-deduped
//! + cached) of random multi-program batches is bit-identical to naive
//! sequential unfused execution — including cache invalidation when a
//! load overwrites a cached query's range.
//!
//! The naive reference executes every program in admission order through
//! `Placement::execute` (per-program `call_batch`, no fusion, no dedup,
//! no cache) on its own coordinator; the serve path pushes the same
//! programs through a `ServeQueue` from a single submitter thread, so
//! admission order equals program order and any round partitioning the
//! scheduler picks must preserve the outputs.

use adra::cim::BoolFn;
use adra::config::{SensingScheme, SimConfig};
use adra::planner::{
    place, planned_coordinator, AggKind, Objective, PlanCostModel, Predicate, Program,
    RecordRange, StepOutput,
};
use adra::serve::{ServeConfig, ServeQueue};
use adra::util::quick::{Arbitrary, Quick};
use adra::util::rng::Rng;

const N_RECORDS: usize = 48;
const SHARDS: usize = 3;

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::Current);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

/// A random but always-valid program over the shared table: loads,
/// broadcasts, and the full query palette over random in-bounds ranges.
fn random_program(rng: &mut Rng, n_records: usize) -> Program {
    let mut p = Program::new(n_records);
    let s0 = p.scratch();
    let s1 = p.scratch();
    let n_ops = 3 + rng.below(6) as usize;
    for _ in 0..n_ops {
        let start = rng.below(n_records as u64 - 1) as usize;
        let len = 1 + rng.below((n_records - start) as u64) as usize;
        let range = RecordRange::new(start, len);
        let rhs = if rng.bool() { s0 } else { s1 };
        match rng.below(8) {
            0 => {
                let values: Vec<u64> = (0..len).map(|_| rng.below(128)).collect();
                p.load(start, values);
            }
            1 => {
                p.broadcast(rhs, rng.below(128));
            }
            2 => {
                p.compare(range, rhs);
            }
            3 => {
                let preds = [
                    Predicate::Lt,
                    Predicate::Le,
                    Predicate::Gt,
                    Predicate::Ge,
                    Predicate::Eq,
                    Predicate::Ne,
                ];
                p.filter(range, rhs, preds[rng.below(6) as usize]);
            }
            4 => {
                p.sub(range, rhs);
            }
            5 => {
                let fns = [BoolFn::And, BoolFn::Xor, BoolFn::AndNot, BoolFn::OrNot];
                p.bool_op(fns[rng.below(4) as usize], range, rhs);
            }
            6 => {
                p.scan(range);
            }
            _ => {
                let aggs = [AggKind::Min, AggKind::Max, AggKind::Sum];
                p.aggregate(range, aggs[rng.below(3) as usize]);
            }
        }
    }
    p
}

#[derive(Clone, Debug)]
struct Seed(u64);

impl Arbitrary for Seed {
    fn generate(rng: &mut Rng) -> Self {
        Seed(rng.next_u64())
    }
}

#[test]
fn prop_served_batches_match_sequential_unfused_execution() {
    let cfg = cfg();
    Quick::with_cases(10).check::<Seed, _>("serve == naive", |s| {
        let mut rng = Rng::new(s.0);
        let mut programs: Vec<Program> =
            (0..6).map(|_| random_program(&mut rng, N_RECORDS)).collect();
        // force the interesting paths: an exact repeat (cache hits when
        // rounds split) and a whole-table load straight after it (every
        // overlapping cached range must be invalidated, not served)
        programs.push(programs[1].clone());
        let mut clobber = Program::new(N_RECORDS);
        let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(128)).collect();
        let all = clobber.all();
        clobber.load(0, values);
        clobber.scan(all);
        programs.push(clobber);
        programs.push(programs[1].clone()); // re-query the clobbered table

        // naive reference: sequential, unfused, uncached
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let naive_coord = planned_coordinator(&cfg, SHARDS, Objective::Edp);
        let naive: Vec<Vec<StepOutput>> = programs
            .iter()
            .map(|p| {
                let pl = place(p, &cfg, SHARDS, &model).expect("valid by construction");
                pl.execute(&naive_coord).expect("naive execution").outputs
            })
            .collect();

        // serve path: single submitter, admission order == program order
        let queue = ServeQueue::start(ServeConfig::new(cfg.clone(), SHARDS, N_RECORDS));
        let tickets: Vec<_> = programs
            .iter()
            .map(|p| queue.submit(0, p.clone()).expect("geometry matches"))
            .collect();
        let served: Vec<Vec<StepOutput>> = tickets
            .into_iter()
            .map(|t| t.wait().expect("served").outputs)
            .collect();

        naive == served
    });
}

/// Concurrent multi-tenant submission: admission order is nondeterministic
/// across tenants, so give every tenant identical table contents (loads
/// dedupe) and a private threshold — each program is self-contained, so
/// ANY admission interleaving must reproduce the naive per-tenant outputs.
#[test]
fn concurrent_identical_table_tenants_match_naive() {
    let cfg = cfg();
    let model = PlanCostModel::new(&cfg, Objective::Edp);
    // one shared load + per-tenant query programs over the same contents
    let mut rng = Rng::new(2026);
    let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(128)).collect();
    let make_tenant_program = |tenant: usize| {
        let mut p = Program::new(N_RECORDS);
        let t = p.scratch();
        let all = p.all();
        p.load(0, values.clone());
        p.broadcast(t, 20 + 10 * tenant as u64);
        p.filter(all, t, Predicate::Lt);
        p.sub(all, t);
        p.aggregate(all, AggKind::Max);
        p
    };

    let naive_coord = planned_coordinator(&cfg, SHARDS, Objective::Edp);
    let naive: Vec<Vec<StepOutput>> = (0..4)
        .map(|t| {
            let pl = place(&make_tenant_program(t), &cfg, SHARDS, &model).unwrap();
            pl.execute(&naive_coord).unwrap().outputs
        })
        .collect();

    let queue = std::sync::Arc::new(ServeQueue::start(ServeConfig::new(
        cfg.clone(),
        SHARDS,
        N_RECORDS,
    )));
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let q = queue.clone();
            let program = make_tenant_program(t);
            std::thread::spawn(move || {
                let mut outs = Vec::new();
                for _ in 0..3 {
                    outs.push(q.submit(t, program.clone()).unwrap().wait().unwrap());
                }
                outs
            })
        })
        .collect();
    for (t, h) in handles.into_iter().enumerate() {
        for rep in h.join().unwrap() {
            assert_eq!(rep.outputs, naive[t], "tenant {t} diverged");
        }
    }
    let m = queue.metrics();
    assert_eq!(m.programs, 12);
    assert!(m.skipped_writes > 0, "identical loads must dedupe: {}", m.report("serve"));
}
