//! Engine equivalence and paper-claim integration tests:
//!  * ADRA (behavioral backend) == ADRA (PJRT artifact backend) on a
//!    mixed workload — the analog substrate is interchangeable;
//!  * ADRA == baseline on every op's VALUE (they disagree only on cost);
//!  * the access-count asymmetry that *is* the paper: ADRA subtraction
//!    takes one activation, the baseline takes two reads.

use adra::cim::{
    AdraEngine, BaselineEngine, CimOp, CimValue, Engine, WordAddr,
};
use adra::config::{SensingScheme, SimConfig};
use adra::coordinator::Coordinator;
use adra::runtime::{AnalogRuntime, ArtifactManifest, PjrtBackend};
use adra::util::quick::{Arbitrary, Quick};
use adra::util::rng::Rng;
use adra::workload::{OpMix, WorkloadGen};

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(128, SensingScheme::Current);
    c.word_bits = 16;
    c
}

#[test]
fn adra_and_baseline_agree_on_all_values() {
    let cfg = cfg();
    let mut adra = AdraEngine::new(&cfg);
    let mut base = BaselineEngine::new(&cfg);
    let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 1234);
    let ops = gen.batch(1500);
    for op in &ops {
        let a = adra.execute(op);
        let b = base.execute(op);
        match (a, b) {
            (Ok(ra), Ok(rb)) => assert_eq!(ra.value, rb.value, "op {op:?}"),
            (Err(_), Err(_)) => {}
            (a, b) => panic!("divergence on {op:?}: {a:?} vs {b:?}"),
        }
    }
}

#[test]
fn access_count_asymmetry_is_the_paper() {
    let cfg = cfg();
    let mut adra = AdraEngine::new(&cfg);
    let mut base = BaselineEngine::new(&cfg);
    for e in [&mut adra as &mut dyn Engine, &mut base as &mut dyn Engine] {
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 100 }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 58 }).unwrap();
    }
    adra.array_mut().reset_stats();
    base.array_mut().reset_stats();
    let n = 50;
    for _ in 0..n {
        adra.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        base.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
    }
    assert_eq!(adra.array().stats().dual_activations, n);
    assert_eq!(adra.array().stats().reads, 0);
    assert_eq!(base.array().stats().reads, 2 * n);
    assert_eq!(base.array().stats().dual_activations, 0);
}

#[test]
fn pjrt_backend_equals_behavioral_backend() {
    let manifest = match ArtifactManifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            return;
        }
    };
    let cfg = cfg();
    let rt = AnalogRuntime::new(manifest).expect("PJRT init");
    let mut pjrt = AdraEngine::with_backend(&cfg, Box::new(PjrtBackend::new(rt)));
    let mut behav = AdraEngine::new(&cfg);
    let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 777);
    // smaller batch: each PJRT dual op executes a real XLA computation
    let ops = gen.batch(120);
    for op in &ops {
        let a = pjrt.execute(op);
        let b = behav.execute(op);
        match (a, b) {
            (Ok(ra), Ok(rb)) => assert_eq!(
                ra.value, rb.value,
                "backend divergence on {op:?}"
            ),
            (Err(_), Err(_)) => {}
            (a, b) => panic!("backend divergence on {op:?}: {a:?} vs {b:?}"),
        }
    }
}

#[test]
fn pjrt_backend_through_coordinator_end_to_end() {
    let manifest = match ArtifactManifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            return;
        }
    };
    let cfg = cfg();
    let rt = AnalogRuntime::new(manifest).expect("PJRT init");
    let cfg2 = cfg.clone();
    let mut rt_slot = Some(rt);
    let coord = Coordinator::new(&cfg, 1, move |_| -> Box<dyn Engine> {
        let rt = rt_slot.take().expect("single shard");
        Box::new(AdraEngine::with_backend(&cfg2, Box::new(PjrtBackend::new(rt))))
    });
    // values kept inside the positive 16-bit two's-complement range
    coord
        .call(0, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 21_000 })
        .unwrap();
    coord
        .call(0, CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 4_500 })
        .unwrap();
    let r = coord.call(0, CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
    assert_eq!(r.value, CimValue::Diff(16_500));
    let m = coord.metrics();
    assert_eq!(m.ops, 3);
}

/// Property: for random word pairs, in-memory sub/compare match integer
/// semantics through the WHOLE stack (write -> activate -> sense ->
/// modules -> carry chain).
#[derive(Clone, Debug)]
struct Pair {
    a: u64,
    b: u64,
}

impl Arbitrary for Pair {
    fn generate(rng: &mut Rng) -> Self {
        Self { a: rng.below(1 << 16), b: rng.below(1 << 16) }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if self.a > 0 {
            v.push(Self { a: self.a / 2, b: self.b });
        }
        if self.b > 0 {
            v.push(Self { a: self.a, b: self.b / 2 });
        }
        v
    }
}

#[test]
fn prop_full_stack_subtraction() {
    let cfg = cfg();
    let engine = std::cell::RefCell::new(AdraEngine::new(&cfg));
    Quick::with_cases(100).check::<Pair, _>("stack sub == integer sub", |p| {
        let mut e = engine.borrow_mut();
        e.execute(&CimOp::Write { addr: WordAddr { row: 2, word: 1 }, value: p.a }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 3, word: 1 }, value: p.b }).unwrap();
        let r = e.execute(&CimOp::Sub { row_a: 2, row_b: 3, word: 1 }).unwrap();
        let sign = |v: u64| -> i128 {
            (v as i128) - if v >= 1 << 15 { 1 << 16 } else { 0 }
        };
        r.value == CimValue::Diff(sign(p.a) - sign(p.b))
    });
}
