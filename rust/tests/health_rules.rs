//! Health-pipeline property suite: the concurrency contracts the
//! telemetry layer leans on (ratcheting publishers, saturating merges)
//! plus deterministic rule trajectories over synthetic series.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use adra::metrics::LatencyHistogram;
use adra::observe::{
    Direction, FlightRecorder, HealthEngine, HealthRule, Registry, RuleState, SampleValue,
    SeriesStore, Signal,
};
use adra::util::rng::Rng;

const THREADS: usize = 8;
const ITERS: usize = 2000;

/// `set_at_least` under contention is a lock-free max: the final value
/// equals the maximum ever published, and a concurrent reader only ever
/// observes a non-decreasing sequence.
#[test]
fn gauge_ratchet_is_monotone_under_contention() {
    let reg = Registry::new();
    let gauge = reg.gauge("test.ratchet", "ratchet under contention", &[]);
    let done = Arc::new(AtomicBool::new(false));

    let reader = {
        let gauge = gauge.clone();
        let done = done.clone();
        std::thread::spawn(move || {
            let mut last = f64::NEG_INFINITY;
            while !done.load(Ordering::Acquire) {
                let v = gauge.get();
                assert!(v >= last, "ratchet went backwards: {last} -> {v}");
                last = v;
            }
        })
    };

    let mut expected_max = 0.0f64;
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let mut rng = Rng::new(42 + t as u64);
            let mut local_max = 0.0f64;
            let gauge = &gauge;
            for _ in 0..ITERS {
                local_max = local_max.max(rng.below(1 << 20) as f64);
            }
            expected_max = expected_max.max(local_max);
            s.spawn(move || {
                let mut rng = Rng::new(42 + t as u64);
                for _ in 0..ITERS {
                    gauge.set_at_least(rng.below(1 << 20) as f64);
                }
            });
        }
    });
    done.store(true, Ordering::Release);
    reader.join().expect("reader");
    assert_eq!(gauge.get(), expected_max, "final value is the global max");
}

#[test]
fn counter_ratchet_is_monotone_under_contention() {
    let reg = Registry::new();
    let counter = reg.counter("test.ratchet", "ratchet under contention", &[]);
    let mut expected_max = 0u64;
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let mut rng = Rng::new(7 + t as u64);
            for _ in 0..ITERS {
                expected_max = expected_max.max(rng.below(1 << 30));
            }
            let counter = &counter;
            s.spawn(move || {
                let mut rng = Rng::new(7 + t as u64);
                for _ in 0..ITERS {
                    counter.set_at_least(rng.below(1 << 30));
                }
            });
        }
    });
    assert_eq!(counter.get(), expected_max);
    // a stale republish afterwards must not move it
    counter.set_at_least(expected_max / 2);
    assert_eq!(counter.get(), expected_max);
}

/// Merging two separately-recorded histograms is exactly equivalent to
/// recording both streams into one.
#[test]
fn histogram_merge_matches_single_stream() {
    let mut rng = Rng::new(99);
    let samples: Vec<f64> = (0..500).map(|_| rng.below(1 << 24) as f64 * 1e-9).collect();
    let mut one = LatencyHistogram::default();
    let (mut a, mut b) = (LatencyHistogram::default(), LatencyHistogram::default());
    for (i, &s) in samples.iter().enumerate() {
        one.record(s);
        if i % 2 == 0 { a.record(s) } else { b.record(s) }
    }
    a.merge(&b);
    assert_eq!(a.count(), one.count());
    assert_eq!(a.buckets(), one.buckets());
    assert_eq!(a.max_ns(), one.max_ns());
    assert!((a.sum_ns() - one.sum_ns()).abs() < 1e-6 * one.sum_ns().max(1.0));
    for p in [50.0, 95.0, 99.0] {
        assert_eq!(a.percentile_ns(p), one.percentile_ns(p));
    }
}

/// Repeated self-merge doubles the counts; past 64 doublings every
/// count pins at `u64::MAX` instead of wrapping, and the histogram
/// stays queryable.
#[test]
fn histogram_merge_saturates_at_u64_max() {
    let mut h = LatencyHistogram::default();
    h.record(100e-9);
    for _ in 0..70 {
        let snapshot = h.clone();
        h.merge(&snapshot);
    }
    assert_eq!(h.count(), u64::MAX, "count saturates");
    assert_eq!(h.buckets().iter().copied().max(), Some(u64::MAX), "bucket saturates");
    assert!(h.percentile_ns(95.0).is_finite());
    assert!(h.mean_ns() >= 0.0);
}

/// Ingest one synthetic scrape of a round-wall histogram: `clean` new
/// samples in bucket 3 ([8, 16) ns) and `slow` in bucket 10
/// ([1024, 2048) ns), as cumulative totals.
struct HistFeed {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    t_us: u64,
}

impl HistFeed {
    fn new() -> Self {
        Self { buckets: vec![0; LatencyHistogram::NUM_BUCKETS], count: 0, sum: 0.0, t_us: 0 }
    }

    fn push(&mut self, store: &SeriesStore, clean: u64, slow: u64) {
        self.buckets[3] += clean;
        self.buckets[10] += slow;
        self.count += clean + slow;
        self.sum += clean as f64 * 12.0 + slow as f64 * 1500.0;
        self.t_us += 1_000_000;
        store.ingest(
            "adra.serve.round_wall_ns",
            &[("queue", "0")],
            self.t_us,
            SampleValue::Histogram {
                count: self.count,
                sum: self.sum,
                buckets: self.buckets.clone(),
            },
        );
    }
}

fn burn_signal() -> Signal {
    Signal::SloBurn {
        name: "adra.serve.round_wall_ns".to_string(),
        labels: Vec::new(),
        slo_ns: 512.0,
        budget: 0.25,
        fast: 2,
        slow: 8,
    }
}

/// The dual-window burn is the MIN of the fast and slow windows: a burst
/// that saturates the fast window alone cannot raise the combined burn
/// past what the slow window admits.
#[test]
fn slo_burn_requires_both_windows() {
    let store = SeriesStore::with_capacity(32);
    let mut feed = HistFeed::new();
    let signal = burn_signal();

    // 9 clean scrapes: burn is 0 on both windows
    for _ in 0..9 {
        feed.push(&store, 10, 0);
    }
    assert_eq!(signal.eval(&store, Direction::Above), Some(0.0));

    // 2 all-violating scrapes: fast window burns 1.0/0.25 = 4.0, but the
    // slow window has seen 20 slow of 80 -> 0.25/0.25 = 1.0; min wins
    feed.push(&store, 0, 10);
    feed.push(&store, 0, 10);
    let v = signal.eval(&store, Direction::Above).expect("burn");
    assert!((v - 1.0).abs() < 1e-9, "slow window must veto the burst: {v}");

    // sustained violation: the slow window catches up and the combined
    // burn reaches the fast window's 4.0
    for _ in 0..8 {
        feed.push(&store, 0, 10);
    }
    let v = signal.eval(&store, Direction::Above).expect("burn");
    assert!((v - 4.0).abs() < 1e-9, "sustained burn must read full: {v}");
}

/// End-to-end trajectory through a `HealthEngine`: the burn rule stays
/// quiet through the burst, escalates only once under sustained
/// violation, and clears with down-hysteresis once the signal recovers.
#[test]
fn burn_rule_trajectory_over_synthetic_series() {
    let store = SeriesStore::with_capacity(64);
    let reg = Registry::new();
    let rec = FlightRecorder::with_capacity(64);
    let mut engine = HealthEngine::new();
    engine.add_rule(HealthRule {
        name: "round_wall_slo_burn".to_string(),
        signal: burn_signal(),
        direction: Direction::Above,
        warn: 1.5,
        critical: 3.0,
        sustain_up: 2,
        sustain_down: 3,
    });
    let mut feed = HistFeed::new();
    let mut committed = Vec::new();
    let mut tick = |feed: &mut HistFeed,
                    engine: &mut HealthEngine,
                    committed: &mut Vec<(RuleState, RuleState)>,
                    clean: u64,
                    slow: u64| {
        feed.push(&store, clean, slow);
        for tr in engine.evaluate(&store, &reg, &rec) {
            committed.push((tr.from, tr.to));
        }
    };

    // warmup + short burst: below warn, nothing commits
    for _ in 0..9 {
        tick(&mut feed, &mut engine, &mut committed, 10, 0);
    }
    tick(&mut feed, &mut engine, &mut committed, 0, 10);
    tick(&mut feed, &mut engine, &mut committed, 0, 10);
    assert!(committed.is_empty(), "burst alone must not alert: {committed:?}");
    assert_eq!(engine.state_of("round_wall_slo_burn"), Some(RuleState::Ok));

    // sustained violation: the slow window fills up gradually, so the
    // engine commits exactly one escalation per severity level — no
    // flapping, no repeats
    for _ in 0..10 {
        tick(&mut feed, &mut engine, &mut committed, 0, 10);
    }
    assert_eq!(
        committed,
        vec![(RuleState::Ok, RuleState::Warn), (RuleState::Warn, RuleState::Critical)],
        "one committed transition per excursion level"
    );
    assert_eq!(engine.state_of("round_wall_slo_burn"), Some(RuleState::Critical));

    // recovery: clean scrapes flush the windows; down-hysteresis holds
    // for `sustain_down` evaluations, then a single clear commits
    for _ in 0..12 {
        tick(&mut feed, &mut engine, &mut committed, 10, 0);
    }
    assert_eq!(committed.len(), 3, "recovery commits once: {committed:?}");
    assert_eq!(committed[2], (RuleState::Critical, RuleState::Ok));
    assert_eq!(engine.transition_count(), 3);
    // every committed transition landed in the recorder as an alert event
    let jsonl = rec.to_jsonl();
    assert_eq!(jsonl.matches("\"kind\":\"alert\"").count(), 3, "{jsonl}");
}
