//! Cross-layer validation: execute the AOT JAX/Pallas artifacts over PJRT
//! and pin the Rust behavioral device model against them.
//!
//! This is the test that keeps the three device-model implementations
//! (jnp oracle, Pallas kernels, Rust mirror) honest.  Requires
//! `make artifacts` (the `test` Makefile target guarantees it).

use adra::config::{DeviceParams, N_COLS, N_SWEEP};
use adra::device;
use adra::runtime::{AnalogRuntime, ArtifactManifest};
use adra::util::rng::Rng;

/// Worst-case relative error budget between the f32 artifact numerics and
/// the f64 Rust mirror.
const REL_TOL: f64 = 5e-4;

fn runtime() -> Option<AnalogRuntime> {
    match ArtifactManifest::load_default() {
        Ok(m) => Some(AnalogRuntime::new(m).expect("PJRT init")),
        Err(e) => {
            // artifacts are built by `make test`; tolerate running bare
            // `cargo test` before `make artifacts` by skipping
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn rel_err(got: f64, want: f64) -> f64 {
    if want.abs() < 1e-12 {
        (got - want).abs()
    } else {
        ((got - want) / want).abs()
    }
}

#[test]
fn dc_isl_matches_behavioral_model_on_random_planes() {
    let Some(rt) = runtime() else { return };
    let p = DeviceParams::default();
    let mut rng = Rng::new(0xC0DE);
    for round in 0..4 {
        let pol_a: Vec<f32> =
            (0..N_COLS).map(|_| rng.uniform(-p.ps, p.ps) as f32).collect();
        let pol_b: Vec<f32> =
            (0..N_COLS).map(|_| rng.uniform(-p.ps, p.ps) as f32).collect();
        let dvt_a: Vec<f32> = (0..N_COLS).map(|_| rng.uniform(-0.05, 0.05) as f32).collect();
        let dvt_b: Vec<f32> = (0..N_COLS).map(|_| rng.uniform(-0.05, 0.05) as f32).collect();
        let (isl, ia, ib) = rt
            .dc_isl(&pol_a, &pol_b, &dvt_a, &dvt_b, p.v_gread1 as f32, p.v_gread2 as f32)
            .unwrap();
        let mut worst = 0.0f64;
        for c in 0..N_COLS {
            let want = device::senseline_current(
                &p,
                pol_a[c] as f64,
                pol_b[c] as f64,
                p.v_gread1,
                p.v_gread2,
                p.v_read,
                dvt_a[c] as f64,
                dvt_b[c] as f64,
            );
            worst = worst.max(rel_err(isl[c] as f64, want));
            // i_sl decomposition consistency within the artifact itself
            assert!(
                ((ia[c] + ib[c]) - isl[c]).abs() <= 1e-9 + 1e-5 * isl[c].abs(),
                "artifact self-consistency at col {c}"
            );
        }
        assert!(worst < REL_TOL, "round {round}: worst rel err {worst:.2e}");
    }
}

#[test]
fn dc_isl_reproduces_the_four_adra_levels() {
    let Some(rt) = runtime() else { return };
    let p = DeviceParams::default();
    let z = vec![0.0f32; N_COLS];
    let mut levels = Vec::new();
    for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
        let pol_a = vec![p.pol_of_bit(a) as f32; N_COLS];
        let pol_b = vec![p.pol_of_bit(b) as f32; N_COLS];
        let (isl, _, _) = rt
            .dc_isl(&pol_a, &pol_b, &z, &z, p.v_gread1 as f32, p.v_gread2 as f32)
            .unwrap();
        levels.push(isl[0] as f64);
    }
    // I00 < I10 < I01 < I11 with >1uA margins — from the ARTIFACT numerics
    assert!(levels[0] < levels[1] && levels[1] < levels[2] && levels[2] < levels[3]);
    for w in levels.windows(2) {
        assert!(w[1] - w[0] > 1e-6, "artifact margin {}", w[1] - w[0]);
    }
}

#[test]
fn transient_matches_behavioral_model() {
    let Some(rt) = runtime() else { return };
    let p = DeviceParams::default();
    let c_rbl = 1024.0 * p.c_rbl_cell;
    let z = vec![0.0f32; N_COLS];
    for (a, b) in [(false, false), (true, false), (false, true), (true, true)] {
        let pol_a = vec![p.pol_of_bit(a) as f32; N_COLS];
        let pol_b = vec![p.pol_of_bit(b) as f32; N_COLS];
        let out = rt
            .transient_cim(
                &pol_a, &pol_b, &z, &z,
                p.v_gread1 as f32, p.v_gread2 as f32,
                p.v_read as f32, c_rbl as f32,
            )
            .unwrap();
        let want = device::rbl_transient(
            &p,
            p.pol_of_bit(a),
            p.pol_of_bit(b),
            p.v_gread1,
            p.v_gread2,
            p.v_read,
            c_rbl,
            0.0,
            0.0,
        );
        let got_v = out.v_final[0] as f64;
        assert!(
            (got_v - want.v_final).abs() < 2e-3,
            "v_final ({a},{b}): artifact {got_v} vs rust {}",
            want.v_final
        );
        let got_q = out.q_drawn[0] as f64;
        assert!(rel_err(got_q, want.q_drawn) < 5e-3, "q ({a},{b})");
        let got_e = out.e_diss[0] as f64;
        assert!(rel_err(got_e, want.e_diss) < 5e-3, "e ({a},{b})");
        // trace shape: n_steps * N_COLS, monotone nonincreasing per column
        assert_eq!(out.v_trace.len(), p.n_steps * N_COLS);
        let mut last = p.v_read as f32 + 1e-6;
        for step in 0..p.n_steps {
            let v = out.v_trace[step * N_COLS];
            assert!(v <= last + 1e-6, "trace not monotone at step {step}");
            last = v;
        }
    }
}

#[test]
fn iv_sweep_artifact_shows_hysteresis() {
    let Some(rt) = runtime() else { return };
    let p = DeviceParams::default();
    let half = N_SWEEP / 2;
    let vg: Vec<f32> = (0..N_SWEEP)
        .map(|i| {
            if i < half {
                -5.0 + 10.0 * i as f32 / (half - 1) as f32
            } else {
                5.0 - 10.0 * (i - half) as f32 / (N_SWEEP - half - 1) as f32
            }
        })
        .collect();
    let (i_d, pol) = rt.iv_sweep(&vg).unwrap();
    let pol_max = pol.iter().cloned().fold(f32::MIN, f32::max);
    let pol_min = pol.iter().cloned().fold(f32::MAX, f32::min);
    assert!(pol_max as f64 > 0.5 * p.pr, "sweep never set: {pol_max}");
    assert!((pol_min as f64) < -0.5 * p.pr, "sweep never reset: {pol_min}");
    assert!(i_d.iter().all(|&x| x >= 0.0));
    // branch separation at V_G ~ +0.5 V between up and down sweeps
    let idx_up = (0.55 * half as f32) as usize; // ~ +0.5 V on the way up
    let idx_dn = N_SWEEP - 1 - (idx_up - half / 2) * 0; // symmetric point below
    let _ = idx_dn;
    let up_pol = pol[idx_up];
    let dn_pol = pol[N_SWEEP - 1 - (idx_up as isize - half as isize).unsigned_abs()];
    assert!(
        dn_pol > up_pol,
        "no hysteresis in artifact: up {up_pol} dn {dn_pol}"
    );
}

#[test]
fn write_transient_switches_polarization() {
    let Some(rt) = runtime() else { return };
    let p = DeviceParams::default();
    let pol0 = vec![p.pol_of_bit(false) as f32; N_COLS];
    let set_pulse: Vec<f32> = (0..N_SWEEP)
        .map(|i| if i < N_SWEEP / 2 { p.v_set as f32 } else { 0.0 })
        .collect();
    let pol_set = rt.write_transient(&pol0, &set_pulse).unwrap();
    assert!(
        pol_set[0] as f64 > 0.5 * p.pr,
        "SET pulse failed in artifact: {}",
        pol_set[0]
    );

    let reset_pulse: Vec<f32> = (0..N_SWEEP)
        .map(|i| if i < N_SWEEP / 2 { p.v_reset as f32 } else { 0.0 })
        .collect();
    let pol_reset = rt.write_transient(&pol_set, &reset_pulse).unwrap();
    assert!((pol_reset[0] as f64) < -0.5 * p.pr, "RESET pulse failed");
}

#[test]
fn monte_carlo_pjrt_agrees_with_behavioral() {
    let Some(rt) = runtime() else { return };
    let p = DeviceParams::default();
    let mc = adra::analysis::MonteCarlo::new(&p);
    for sigma in [0.0, 0.02, 0.10] {
        let behav = mc.run(sigma, 2048, 0xAB);
        let pjrt = mc.run_pjrt(&rt, sigma, 2048, 0xAB).unwrap();
        // same seed, same sampler -> identical variation planes modulo
        // draw order; compare aggregate BER within statistical slack
        let (b1, b2) = (behav.ber(), pjrt.ber());
        assert!(
            (b1 - b2).abs() < 0.01 + 0.5 * (b1 + b2).max(1e-9),
            "sigma {sigma}: behavioral BER {b1} vs PJRT BER {b2}"
        );
        if sigma == 0.0 {
            assert_eq!(b2, 0.0, "artifact path must be clean at sigma 0");
        }
    }
}

#[test]
fn read_disturb_within_design_budget() {
    let Some(rt) = runtime() else { return };
    let p = DeviceParams::default();
    let lrs = vec![p.pol_of_bit(true) as f32; N_COLS];
    let out = rt.read_disturb(&lrs).unwrap();
    assert!(
        out[0] as f64 > 0.5 * p.ps,
        "sustained read disturbed LRS: {}",
        out[0]
    );
}
