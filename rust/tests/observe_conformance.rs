//! Observability conformance: Prometheus text-format invariants of
//! `observe::expose_text`, concurrency properties of the registry, and
//! the end-to-end guarantee that a served workload populates the global
//! registry with the serve / kernel / planner families the acceptance
//! criteria name.

use std::collections::HashMap;
use std::sync::Arc;

use adra::config::{SensingScheme, SimConfig};
use adra::observe::{self, expose_text, FlightRecorder, Registry, Stage};
use adra::planner::Objective;
use adra::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue};
use adra::workload::analytics_scenario;

/// Split one exposition sample line into (series-with-labels, value).
fn split_sample(line: &str) -> (&str, f64) {
    let sp = line.rfind(' ').expect("sample line has a value");
    let v = line[sp + 1..].trim();
    let value = match v {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        _ => v.parse().unwrap_or_else(|e| panic!("bad value {v:?} in {line:?}: {e}")),
    };
    (&line[..sp], value)
}

/// The metric-name charset the format requires: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
fn assert_valid_metric_name(name: &str) {
    let mut chars = name.chars();
    let first = chars.next().expect("non-empty metric name");
    assert!(
        first.is_ascii_alphabetic() || first == '_' || first == ':',
        "bad leading char in metric name {name:?}"
    );
    for c in chars {
        assert!(
            c.is_ascii_alphanumeric() || c == '_' || c == ':',
            "bad char {c:?} in metric name {name:?}"
        );
    }
}

/// Structural walk of an exposition: every family has HELP then TYPE
/// then samples; names are in-charset; histogram triples are consistent.
/// Returns (family -> type) and the flat (series, value) samples.
fn validate_exposition(text: &str) -> (HashMap<String, String>, Vec<(String, f64)>) {
    let mut kinds: HashMap<String, String> = HashMap::new();
    let mut helped: HashMap<String, bool> = HashMap::new();
    let mut samples = Vec::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().expect("HELP names a family");
            assert_valid_metric_name(name);
            assert!(
                !helped.contains_key(name),
                "family {name} emitted HELP twice — families must be contiguous"
            );
            helped.insert(name.to_string(), true);
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().expect("TYPE names a family");
            let kind = it.next().expect("TYPE has a kind");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown TYPE {kind} for {name}"
            );
            assert!(helped.contains_key(name), "TYPE for {name} must follow its HELP");
            kinds.insert(name.to_string(), kind.to_string());
        } else if !line.is_empty() {
            let (series, value) = split_sample(line);
            let name = series.split('{').next().unwrap();
            assert_valid_metric_name(name);
            // every sample belongs to a declared family (histograms via
            // their _bucket/_sum/_count suffixes)
            let family = ["_bucket", "_sum", "_count"]
                .iter()
                .find_map(|s| {
                    name.strip_suffix(s).filter(|f| kinds.get(*f) == Some(&"histogram".into()))
                })
                .unwrap_or(name);
            assert!(kinds.contains_key(family), "sample {series} has no TYPE declaration");
            samples.push((series.to_string(), value));
        }
    }
    // histogram triples: cumulative buckets, le="+Inf" == _count
    for (family, kind) in &kinds {
        if kind != "histogram" {
            continue;
        }
        // group buckets by their full label set minus `le`
        let mut by_series: HashMap<String, Vec<(String, f64)>> = HashMap::new();
        for (series, value) in &samples {
            if let Some(rest) = series.strip_prefix(&format!("{family}_bucket")) {
                let le_start = rest.find("le=\"").expect("bucket sample carries le");
                let le_end = rest[le_start + 4..].find('"').unwrap() + le_start + 4;
                let le = rest[le_start + 4..le_end].to_string();
                // key: labels with the le pair removed, normalized to the
                // spelling the _sum/_count samples use
                let key = format!("{}{}", &rest[..le_start], &rest[le_end + 1..])
                    .replace(",}", "}")
                    .replace("{,", "{")
                    .replace("{}", "");
                by_series.entry(key).or_default().push((le, *value));
            }
        }
        assert!(!by_series.is_empty(), "histogram {family} emitted no buckets");
        for (key, buckets) in by_series {
            let mut prev = 0.0;
            for (le, v) in &buckets {
                assert!(
                    *v >= prev,
                    "{family} buckets must be cumulative: le={le} fell to {v} (key {key})"
                );
                prev = *v;
            }
            let (last_le, last_v) = buckets.last().unwrap();
            assert_eq!(last_le, "+Inf", "{family} must close with le=\"+Inf\"");
            let count_series = format!("{family}_count{key}");
            let count = samples
                .iter()
                .find(|(s, _)| *s == count_series)
                .map(|(_, v)| *v)
                .unwrap_or_else(|| panic!("histogram {family} is missing {count_series}"));
            assert_eq!(*last_v, count, "{family}: le=+Inf bucket must equal _count (key {key})");
        }
    }
    (kinds, samples)
}

#[test]
fn exposition_format_conforms() {
    let r = Registry::new();
    r.counter("adra.test.ops", "Ops with a \"quoted\" help\nand newline.", &[("tenant", "a\"b\\c")])
        .add(3);
    r.gauge("adra.test.ratio", "A ratio.", &[]).set(0.375);
    let h = r.histogram("adra.test.lat_ns", "Latency.", &[("tier", "digital")]);
    h.record(1.0);
    h.record(3.0);
    h.record(1e18); // lands in the open-ended last bucket
    let text = expose_text(&r);

    // label escaping: backslash and quote escaped, help newline escaped
    assert!(text.contains("tenant=\"a\\\"b\\\\c\""), "{text}");
    assert!(text.contains("# HELP adra_test_ops Ops with a \"quoted\" help\\nand newline."));
    let (kinds, samples) = validate_exposition(&text);
    assert_eq!(kinds.get("adra_test_ops").map(String::as_str), Some("counter"));
    assert_eq!(kinds.get("adra_test_ratio").map(String::as_str), Some("gauge"));
    assert_eq!(kinds.get("adra_test_lat_ns").map(String::as_str), Some("histogram"));
    // the +Inf bucket carries all 3 samples even with the huge outlier
    assert!(samples
        .iter()
        .any(|(s, v)| s.contains("adra_test_lat_ns_bucket") && s.contains("le=\"+Inf\"") && *v == 3.0));
    assert!(samples.iter().any(|(s, v)| s == "adra_test_lat_ns_count{tier=\"digital\"}" && *v == 3.0));
}

#[test]
fn exposition_handles_non_finite_and_fractional_values() {
    let r = Registry::new();
    r.gauge("adra.test.inf", "inf", &[]).set(f64::INFINITY);
    r.gauge("adra.test.ninf", "ninf", &[]).set(f64::NEG_INFINITY);
    r.gauge("adra.test.nan", "nan", &[]).set(f64::NAN);
    r.gauge("adra.test.frac", "frac", &[]).set(-2.5);
    let text = expose_text(&r);
    assert!(text.contains("adra_test_inf +Inf\n"), "{text}");
    assert!(text.contains("adra_test_ninf -Inf\n"), "{text}");
    assert!(text.contains("adra_test_nan NaN\n"), "{text}");
    assert!(text.contains("adra_test_frac -2.5\n"), "{text}");
}

/// N threads x M increments == N*M, for the counter's saturating CAS and
/// the histogram's per-bucket atomics.
#[test]
fn concurrent_increments_are_lossless() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 10_000;
    let r = Arc::new(Registry::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let r = r.clone();
            std::thread::spawn(move || {
                // every thread get-or-creates the same series handles
                let c = r.counter("adra.test.concurrent", "c", &[]);
                let h = r.histogram("adra.test.concurrent_h", "h", &[]);
                let g = r.gauge("adra.test.concurrent_g", "g", &[]);
                for i in 0..PER_THREAD {
                    c.inc();
                    h.record((t as u64 * PER_THREAD + i) as f64 % 1000.0);
                    g.add(1.0);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = (THREADS as u64) * PER_THREAD;
    assert_eq!(r.counter("adra.test.concurrent", "c", &[]).get(), total);
    let h = r.histogram("adra.test.concurrent_h", "h", &[]);
    assert_eq!(h.count(), total);
    assert_eq!(h.bucket_counts().iter().sum::<u64>(), total);
    // gauge adds go through a CAS loop: also lossless
    let g = r.gauge("adra.test.concurrent_g", "g", &[]).get();
    assert!((g - total as f64).abs() < 1e-6, "gauge {g} vs {total}");
}

#[test]
fn registry_counters_saturate_under_snapshot_publishing() {
    let r = Registry::new();
    let c = r.counter("adra.test.sat", "s", &[]);
    c.set_at_least(u64::MAX - 1);
    c.add(100); // clamps
    assert_eq!(c.get(), u64::MAX);
    c.set_at_least(7); // ratchet never regresses
    assert_eq!(c.get(), u64::MAX);
    let text = expose_text(&r);
    assert!(text.contains(&format!("adra_test_sat {}", u64::MAX)), "{text}");
}

/// Serving a workload end-to-end populates the global registry with the
/// serve, run/array (kernel tier), and planner prediction families, and
/// the flight recorder holds the round's pipeline spans.
#[test]
fn served_workload_populates_global_registry_and_recorder() {
    let mut cfg = SimConfig::square(64, SensingScheme::Current);
    cfg.word_bits = 8;
    cfg.max_batch = 16;
    let queue = ServeQueue::start(ServeConfig {
        cfg: cfg.clone(),
        shards: 2,
        objective: Objective::Edp,
        n_records: 48,
        max_round: 8,
        cache_capacity: 64,
        admission: AdmissionPolicy::Fair,
        batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
        sample_every: 1,
        calibrate_every: 1,
        calibration_path: None,
        calibration: None,
        store_dir: None,
        checkpoint_every: 32,
        route_retries: 2,
        retry_backoff_ms: 1,
        wear_spare_rows: 0,
        wear_migrate_threshold: 1024,
    });
    let qid = queue.instance().to_string();
    let s = analytics_scenario(&cfg, 48, 3);
    for _ in 0..2 {
        queue.submit(1, s.program.clone()).unwrap().wait().unwrap();
    }
    // joining the scheduler thread guarantees the final round's registry
    // publish has landed before we scrape
    drop(queue);

    let text = expose_text(observe::global());
    let qsel = format!("queue=\"{qid}\"");
    for family in [
        "adra_serve_programs",
        "adra_serve_rounds",
        "adra_serve_cache_hit_rate",
        "adra_run_ops",
        "adra_array_activations",
        "adra_array_det_fraction",
        "adra_planner_prediction_error",
        "adra_planner_prediction_error_ppm",
        "adra_serve_tenant_wall_ns",
        "adra_serve_round_wall_ns",
    ] {
        assert!(text.contains(family), "missing family {family}:\n{text}");
    }
    // this queue's own series exist under its instance label
    assert!(text.contains(&format!("adra_serve_programs{{{qsel}}} 2")), "{text}");
    assert!(
        text.contains(&format!("adra_serve_tenant_wall_ns_count{{{qsel},tenant=\"1\"}} 2")),
        "{text}"
    );
    // the planner published per-class errors incl. the dual class ADRA
    // exists for, and the tables are exact so the error gauge reads ~0
    assert!(text.contains("kind=\"energy\",op_class=\"dual\""), "{text}");
    assert!(text.contains("op_class=\"all\""), "{text}");
    let dual_err = observe::global()
        .gauge(
            "adra.planner.prediction_error",
            "Signed relative predicted-vs-measured cost error of the last run \
             ((predicted - measured) / measured).",
            &[("kind", "energy"), ("op_class", "dual")],
        )
        .get();
    assert!(dual_err.abs() < 1e-6, "exact tables must predict dual ops: {dual_err}");
    // and the whole scrape stays structurally valid
    validate_exposition(&text);

    // the scheduler recorded pipeline spans for the rounds it ran
    let events = observe::recorder().snapshot();
    let stages: Vec<&'static str> = events
        .iter()
        .filter_map(|r| match &r.event {
            observe::TraceEvent::Span { stage, .. } => Some(stage.name()),
            _ => None,
        })
        .collect();
    for want in ["admit", "schedule", "coalesce", "fuse", "execute", "cache"] {
        assert!(stages.contains(&want), "missing {want} span in {stages:?}");
    }
    let jsonl = observe::recorder().to_jsonl();
    assert!(jsonl.contains("\"stage\":\"execute\""), "{jsonl}");
}

#[test]
fn flight_recorder_ring_drops_oldest_and_counts() {
    let r = FlightRecorder::with_capacity(4);
    for i in 0..10u64 {
        r.record_span(i, None, Stage::Execute, i * 10, 1);
    }
    assert_eq!(r.len(), 4);
    assert_eq!(r.dropped(), 6);
    let snap = r.snapshot();
    assert_eq!(snap.first().unwrap().seq, 6, "oldest surviving event");
    assert_eq!(snap.last().unwrap().seq, 9, "newest event");
    // export is the tail, oldest first, one JSON object per line
    let jsonl = r.to_jsonl();
    assert_eq!(jsonl.lines().count(), 4);
    for line in jsonl.lines() {
        assert!(line.starts_with("{\"seq\":") && line.ends_with('}'), "{line}");
    }
}
