//! The paper's quantitative claims, each asserted from the system itself
//! (not from constants) — the checklist EXPERIMENTS.md reports against.
//!
//! Abstract: "simultaneous single-cycle memory read and CiM", "computation
//! of any Boolean function", "CiM of non-commutative functions", "23.2% -
//! 72.6% decrease in EDP".  Section IV: margins, 1.94x / 41.18% / 69.04%
//! (current), 7.53 MHz and ~42% crossovers (Fig. 5), scheme-1 and
//! scheme-2 bands (Figs. 6, 7).

use adra::cim::{AdraEngine, BoolFn, CimOp, CimValue, Engine, WordAddr};
use adra::config::{DeviceParams, SensingScheme, SimConfig};
use adra::device;
use adra::energy::{EnergyModel, Improvement};
use adra::figures::fig5_tradeoffs::{crossover_frequency, crossover_parallelism};
use adra::figures::fig67_voltage::fig67_sweep;
use adra::sensing::MarginReport;

#[test]
fn claim_single_access_read2_plus_and_or() {
    // "simultaneous single-cycle memory read [of both operands] and CiM
    // of primitive Boolean functions"
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 16;
    let mut e = AdraEngine::new(&cfg);
    e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 0xBEEF }).unwrap();
    e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 0x1234 }).unwrap();
    e.array_mut().reset_stats();
    let pair = e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }).unwrap();
    assert_eq!(pair.value, CimValue::Pair(0xBEEF, 0x1234));
    assert_eq!(e.array().stats().dual_activations, 1);
    assert_eq!(e.array().stats().reads, 0);
}

#[test]
fn claim_any_two_input_boolean_function() {
    // "computation of any Boolean function" — all 8 named functions,
    // including the non-commutative ones, each in a single access
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 16;
    let mut e = AdraEngine::new(&cfg);
    let (a, b) = (0xA5F0u64, 0x3C0Fu64);
    e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: a }).unwrap();
    e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: b }).unwrap();
    for f in BoolFn::ALL {
        e.array_mut().reset_stats();
        let r = e.execute(&CimOp::Bool { f, row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Word(f.apply(a, b, 0xFFFF)), "{f:?}");
        assert_eq!(e.array().stats().dual_activations, 1, "{f:?} must be 1 access");
    }
}

#[test]
fn claim_sense_margins_section_iv() {
    // "> 50mV and > 1uA for voltage and current-based sensing"
    let p = DeviceParams::default();
    let r = MarginReport::evaluate(&p, p.v_gread1, p.v_gread2, 1024.0 * p.c_rbl_cell);
    assert!(r.current_margin > 1e-6, "current margin {}", r.current_margin);
    assert!(r.voltage_margin > 0.050, "voltage margin {}", r.voltage_margin);
}

#[test]
fn claim_current_sensing_headline() {
    // "1.94x faster and uses 41.18% lesser energy ... EDP decrease of
    // 69.04%" at 1024x1024; "CiM operation expends 1.24 times the energy
    // of the standard read"; "91%" / "74%" RBL shares
    let m = EnergyModel::new(&SimConfig::square(1024, SensingScheme::Current));
    let imp = Improvement::of(&m.cim_cost(), &m.baseline_cost());
    assert!((imp.speedup - 1.94).abs() < 0.02, "{imp:?}");
    assert!((imp.energy_decrease - 0.4118).abs() < 0.005, "{imp:?}");
    assert!((imp.edp_decrease - 0.6904).abs() < 0.015, "{imp:?}");
    let ratio = m.cim_cost().energy.total() / m.read_cost().energy.total();
    assert!((ratio - 1.24).abs() < 0.01);
    assert!((m.read_cost().energy.rbl_fraction() - 0.91).abs() < 0.01);
    assert!((m.cim_cost().energy.rbl_fraction() - 0.74).abs() < 0.02);
}

#[test]
fn claim_fig5_crossovers() {
    // "at frequencies below 7.53 MHz, scheme 2 is the more energy
    // efficient approach"; "arrays with P < ~42%, scheme 2 is more
    // energy efficient"
    let f = crossover_frequency(1024);
    assert!((f - 7.53e6).abs() / 7.53e6 < 0.05, "frequency crossover {f}");
    let p = crossover_parallelism(1024);
    assert!((p - 0.42).abs() < 0.04, "parallelism crossover {p}");
}

#[test]
fn claim_scheme1_bands() {
    // "speedup ranges from 1.57x to 1.73x"; "costs 20-23% more energy";
    // "23.26% - 28.81% decrease in EDP"; "bitline charging energy for the
    // CiM operation is approximately 3 times that of the standard read"
    let m = EnergyModel::new(&SimConfig::square(1024, SensingScheme::VoltagePrecharged));
    let rbl_ratio = m.cim_cost().energy.rbl / m.read_cost().energy.rbl;
    assert!((rbl_ratio - 3.0).abs() < 1e-9);
    let rows = fig67_sweep(SensingScheme::VoltagePrecharged);
    for r in rows.iter().filter(|r| r.size >= 256) {
        let overhead = -r.improvement.energy_decrease;
        assert!((0.17..0.26).contains(&overhead), "{}: {overhead}", r.size);
        assert!((1.54..1.76).contains(&r.improvement.speedup));
        assert!((0.21..0.31).contains(&r.improvement.edp_decrease));
    }
}

#[test]
fn claim_scheme2_bands() {
    // "speedup of 94.5 - 98.3% and expends 35.5 - 45.8% lesser energy
    // ... 66.83% - 72.6% decrease in EDP"
    let rows = fig67_sweep(SensingScheme::VoltageDischarged);
    for r in rows.iter().filter(|r| r.size >= 256) {
        assert!((1.92..2.01).contains(&r.improvement.speedup), "{r:?}");
        assert!((0.33..0.48).contains(&r.improvement.energy_decrease), "{r:?}");
        assert!((0.64..0.75).contains(&r.improvement.edp_decrease), "{r:?}");
    }
}

#[test]
fn claim_abstract_edp_range() {
    // "23.2% - 72.6% decrease in energy-delay product (EDP)"
    let mut lo = f64::MAX;
    let mut hi = f64::MIN;
    for scheme in SensingScheme::ALL {
        for r in fig67_sweep(scheme).iter().filter(|r| r.size >= 256) {
            lo = lo.min(r.improvement.edp_decrease);
            hi = hi.max(r.improvement.edp_decrease);
        }
    }
    assert!((lo - 0.232).abs() < 0.02, "abstract low end: {lo}");
    assert!((hi - 0.726).abs() < 0.02, "abstract high end: {hi}");
}

#[test]
fn claim_comparator_overhead_one_gate_per_bit() {
    // "n-1 two-input AND gates are needed ... overhead of just 1 gate per
    // bit (memory column) of comparison"
    assert_eq!(adra::logic::comparator::and_tree_gate_count(32), 31);
}

#[test]
fn claim_one_to_one_vs_many_to_one_is_the_asymmetry() {
    // turning the asymmetry OFF must reintroduce the mapping problem —
    // the claim is causal, not incidental
    let p = DeviceParams::default();
    let asym = device::isl_levels(&p, p.v_gread1, p.v_gread2);
    let sym = device::isl_levels(&p, p.v_gread2, p.v_gread2);
    assert!(asym[0b01] - asym[0b10] > 1e-6, "asymmetric separates (0,1)/(1,0)");
    assert!((sym[0b01] - sym[0b10]).abs() < 1e-12, "symmetric collapses them");
}
