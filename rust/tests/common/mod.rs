//! Helpers shared by the serving test binaries (`serve_equivalence`,
//! `serve_fairness`): the random program generator, the sequential
//! unfused reference, and the quickcheck seed wrapper.  One copy, so the
//! op palette cannot drift between the two suites.
#![allow(dead_code)] // each test binary uses a subset

use adra::cim::BoolFn;
use adra::config::SimConfig;
use adra::planner::{
    place, planned_coordinator, AggKind, Objective, PlanCostModel, Predicate, Program,
    RecordRange, StepOutput,
};
use adra::util::quick::Arbitrary;
use adra::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Seed(pub u64);

impl Arbitrary for Seed {
    fn generate(rng: &mut Rng) -> Self {
        Seed(rng.next_u64())
    }
}

/// Sequential unfused reference: place + execute every program in order
/// on one fresh planned coordinator (per-program `call_batch`, no
/// fusion, no dedup, no cache) — what the serve path must bit-match.
pub fn naive_outputs(
    cfg: &SimConfig,
    shards: usize,
    programs: &[&Program],
) -> Vec<Vec<StepOutput>> {
    let model = PlanCostModel::new(cfg, Objective::Edp);
    let coord = planned_coordinator(cfg, shards, Objective::Edp);
    programs
        .iter()
        .map(|p| {
            let pl = place(p, cfg, shards, &model).expect("valid by construction");
            pl.execute(&coord).expect("naive execution").outputs
        })
        .collect()
}

/// A random but always-valid program over the shared table: loads,
/// broadcasts, and the full query palette over random in-bounds ranges.
pub fn random_program(rng: &mut Rng, n_records: usize) -> Program {
    let mut p = Program::new(n_records);
    let s0 = p.scratch();
    let s1 = p.scratch();
    let n_ops = 3 + rng.below(6) as usize;
    for _ in 0..n_ops {
        let start = rng.below(n_records as u64 - 1) as usize;
        let len = 1 + rng.below((n_records - start) as u64) as usize;
        let range = RecordRange::new(start, len);
        let rhs = if rng.bool() { s0 } else { s1 };
        match rng.below(8) {
            0 => {
                let values: Vec<u64> = (0..len).map(|_| rng.below(128)).collect();
                p.load(start, values);
            }
            1 => {
                p.broadcast(rhs, rng.below(128));
            }
            2 => {
                p.compare(range, rhs);
            }
            3 => {
                let preds = [
                    Predicate::Lt,
                    Predicate::Le,
                    Predicate::Gt,
                    Predicate::Ge,
                    Predicate::Eq,
                    Predicate::Ne,
                ];
                p.filter(range, rhs, preds[rng.below(6) as usize]);
            }
            4 => {
                p.sub(range, rhs);
            }
            5 => {
                let fns = [BoolFn::And, BoolFn::Xor, BoolFn::AndNot, BoolFn::OrNot];
                p.bool_op(fns[rng.below(4) as usize], range, rhs);
            }
            6 => {
                p.scan(range);
            }
            _ => {
                let aggs = [AggKind::Min, AggKind::Max, AggKind::Sum];
                p.aggregate(range, aggs[rng.below(3) as usize]);
            }
        }
    }
    p
}
