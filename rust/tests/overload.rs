//! Overload-survival suite: deadlines, cancellation, load shedding,
//! circuit breaking, and the health-driven brownout ladder
//! (DESIGN.md §15), soaked under the PR 9 chaos injector.
//!
//! The invariants every test leans on:
//!
//! * answered results are BIT-IDENTICAL to solo execution — survival
//!   machinery may drop work, never corrupt it,
//! * every admitted program resolves to exactly one outcome (a report,
//!   or one terminal `ServeError`),
//! * a cancelled/expired program never reaches the array: doomed
//!   programs are swept BEFORE placement + coalescing, so no round
//!   executes (or even counts) on their behalf,
//! * breaker and brownout transitions are deterministic under a seeded
//!   fault schedule and visible in the alert trace.
//!
//! Like `durability.rs`, this binary installs fault specs, so every
//! test serializes behind `faults::test_lock()`.

use std::time::Duration;

use adra::config::{SensingScheme, SimConfig};
use adra::faults::{self, FaultSpec};
use adra::planner::StepOutput;
use adra::serve::{
    BatchPolicy, RejectReason, ServeConfig, ServeError, ServeQueue, SubmitOptions,
};
use adra::util::quick::Quick;
use adra::workload::heavy_tenant_scenario;
use adra::workload::programs::analytics_scenario;

mod common;
use common::Seed;

const N_RECORDS: usize = 48;

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::Current);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

/// Deterministic serving config: static rounds, no sampling/calibration
/// noise unless a test opts back in.
fn serve_cfg(cfg: &SimConfig, shards: usize) -> ServeConfig {
    let mut c = ServeConfig::new(cfg.clone(), shards, N_RECORDS);
    c.max_round = 6;
    c.cache_capacity = 512;
    c.batch = BatchPolicy::Static;
    c.sample_every = 0;
    c.calibrate_every = 0;
    c
}

/// Installs a spec on construction, guarantees `clear` on drop (even on
/// assertion failure), so no test leaks an armed injector.
struct Chaos;

impl Chaos {
    fn install(spec: &str) -> Self {
        faults::clear();
        faults::install(FaultSpec::parse(spec).expect("valid spec"));
        Chaos
    }
}

impl Drop for Chaos {
    fn drop(&mut self) {
        faults::clear();
    }
}

// ---- deadlines -------------------------------------------------------

#[test]
fn expired_deadline_is_swept_before_any_round_touches_the_array() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let queue = ServeQueue::start(serve_cfg(&cfg, 2));

    // a zero deadline is expired the moment the sweep looks at it; the
    // sweep runs before placement + coalescing on every scheduling
    // pass, so the program can never execute
    let s = analytics_scenario(&cfg, N_RECORDS, 11);
    let (ticket, _h) = queue
        .submit_with(0, s.program.clone(), SubmitOptions { deadline: Some(Duration::ZERO) })
        .expect("admit");
    let out = ticket.wait();
    assert!(matches!(out, Err(ServeError::DeadlineExceeded)), "{out:?}");

    // activation pin: the doomed program produced NO round and NO
    // served program — the array was never driven on its behalf
    let m = queue.metrics();
    assert_eq!(m.deadline_expired, 1, "{m:?}");
    assert_eq!(m.rounds, 0, "expired program must not start a round: {m:?}");
    assert_eq!(m.programs, 0, "{m:?}");

    // the table is untouched: a live submission still answers exactly
    let rep = queue.submit(1, s.program.clone()).expect("admit").wait().expect("served");
    assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));
    let m = queue.metrics();
    assert_eq!((m.rounds, m.programs), (1, 1), "{m:?}");
}

#[test]
fn config_default_deadline_applies_when_submission_carries_none() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg, 2);
    sc.default_deadline = Some(Duration::ZERO);
    let queue = ServeQueue::start(sc);

    let s = analytics_scenario(&cfg, N_RECORDS, 12);
    // plain submit: inherits the config default (zero -> always expired)
    let out = queue.submit(0, s.program.clone()).expect("admit").wait();
    assert!(matches!(out, Err(ServeError::DeadlineExceeded)), "{out:?}");
    // an explicit generous per-submission deadline overrides the default
    let (t, _h) = queue
        .submit_with(0, s.program.clone(), SubmitOptions { deadline: Some(Duration::from_secs(60)) })
        .expect("admit");
    let rep = t.wait().expect("served within its own deadline");
    assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));
}

// ---- cancellation ----------------------------------------------------

#[test]
fn cancel_handle_dooms_a_queued_program() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let queue = ServeQueue::start(serve_cfg(&cfg, 2));
    // spikes stretch every round to multiple ms, so a cancel issued
    // nanoseconds after submission always lands before the program's
    // scheduling pass
    let _c = Chaos::install("seed=3 spike=8 spike-ns=2000000");

    let s = analytics_scenario(&cfg, N_RECORDS, 21);
    let mut cancelled = 0usize;
    for _ in 0..10 {
        let (ticket, handle) =
            queue.submit_with(0, s.program.clone(), SubmitOptions::default()).expect("admit");
        handle.cancel();
        assert!(handle.is_cancelled());
        match ticket.wait() {
            Err(ServeError::Cancelled) => cancelled += 1,
            Ok(rep) => {
                // the scheduler won the race: the answer must be exact
                assert_eq!(
                    rep.outputs[s.filter_step],
                    StepOutput::Matches(s.expected_matches.clone())
                );
            }
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    assert!(cancelled >= 1, "an immediate cancel practically always wins the race");
    assert_eq!(queue.metrics().cancelled, cancelled as u64);
}

#[test]
fn tenant_wide_cancel_sweeps_the_backlog_and_survivors_stay_identical() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg, 2);
    sc.max_round = 1; // keep the backlog deep: one program per round
    let queue = ServeQueue::start(sc);
    let _c = Chaos::install("seed=5 spike=8 spike-ns=2000000");

    let s = heavy_tenant_scenario(&cfg, N_RECORDS, 404, 12, 3);
    let tickets: Vec<_> = s
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();
    let swept = queue.cancel_tenant(s.heavy_tenant).expect("queue alive");

    let mut heavy_ok = 0usize;
    let mut heavy_cancelled = 0usize;
    for (i, ((tenant, _), ticket)) in s.submissions.iter().zip(tickets).enumerate() {
        match ticket.wait() {
            Ok(rep) => {
                assert_eq!(
                    rep.outputs[s.filter_step],
                    StepOutput::Matches(s.expected_matches[i].clone()),
                    "submission {i} diverged"
                );
                if *tenant == s.heavy_tenant {
                    heavy_ok += 1;
                }
            }
            Err(ServeError::Cancelled) => {
                assert_eq!(*tenant, s.heavy_tenant, "only the heavy tenant was cancelled");
                heavy_cancelled += 1;
            }
            other => panic!("submission {i}: unexpected outcome {other:?}"),
        }
    }
    // exactly-one-outcome conservation: every heavy program either
    // completed before the sweep or was cancelled by it, nothing both,
    // nothing lost
    assert_eq!(heavy_ok + heavy_cancelled, 12);
    assert_eq!(heavy_cancelled, swept, "the sweep count matches the cancelled tickets");
    assert!(swept >= 1, "with multi-ms rounds the sweep lands before the backlog drains");
    assert_eq!(queue.metrics().cancelled, swept as u64);
}

// ---- load shedding ---------------------------------------------------

#[test]
fn bounded_backlog_sheds_overflow_and_answers_stay_identical() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg, 2);
    sc.max_tenant_backlog = 2;
    let queue = ServeQueue::start(sc);
    // slow rounds guarantee the burst outruns the scheduler, so the
    // per-tenant bound actually engages
    let _c = Chaos::install("seed=8 spike=8 spike-ns=2000000");

    let s = heavy_tenant_scenario(&cfg, N_RECORDS, 2024, 20, 0);
    let tickets: Vec<_> = s
        .submissions
        .iter()
        .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
        .collect();

    let mut ok = 0usize;
    let mut shed = 0usize;
    for (i, ticket) in tickets.into_iter().enumerate() {
        match ticket.wait() {
            Ok(rep) => {
                assert_eq!(
                    rep.outputs[s.filter_step],
                    StepOutput::Matches(s.expected_matches[i].clone()),
                    "submission {i} diverged"
                );
                ok += 1;
            }
            Err(ServeError::Rejected(RejectReason::Overloaded)) => shed += 1,
            other => panic!("submission {i}: unexpected outcome {other:?}"),
        }
    }
    assert_eq!(ok + shed, 20, "exactly one outcome per submission");
    assert!(ok >= 1, "an empty backlog always admits");
    assert!(shed >= 1, "a 20-deep burst against a 2-deep bound must shed");
    assert_eq!(queue.metrics().shed, shed as u64);

    // shed rejections are visible in the alert trace
    let trace = adra::observe::recorder().to_jsonl();
    assert!(trace.contains("serve_shed"), "shed alerts recorded");
}

// ---- exactly-one-outcome property ------------------------------------

#[test]
fn every_submission_resolves_to_exactly_one_outcome() {
    let _g = faults::test_lock();
    faults::clear();
    let cfg = cfg();

    Quick::with_cases(5).check("exactly one outcome", |seed: &Seed| {
        let s = heavy_tenant_scenario(&cfg, N_RECORDS, seed.0, 6, 2);
        let queue = ServeQueue::start(serve_cfg(&cfg, 2));

        // every third submission carries an already-expired deadline —
        // those can NEVER produce a report (swept, or caught by the
        // last-chance check; both happen before coalescing)
        let entries: Vec<_> = s
            .submissions
            .iter()
            .enumerate()
            .map(|(i, (t, p))| {
                let opts = SubmitOptions {
                    deadline: (i % 3 == 0).then_some(Duration::ZERO),
                };
                (i, *t, queue.submit_with(*t, p.clone(), opts).expect("admit").0)
            })
            .collect();
        // and the heavy tenant gets a tenant-wide cancel mid-flight
        let _ = queue.cancel_tenant(s.heavy_tenant).expect("queue alive");

        let (mut ok, mut cancelled, mut expired) = (0usize, 0usize, 0usize);
        for (i, tenant, ticket) in entries {
            match ticket.wait() {
                Ok(rep) => {
                    if rep.outputs[s.filter_step]
                        != StepOutput::Matches(s.expected_matches[i].clone())
                    {
                        return false; // answered but wrong
                    }
                    if i % 3 == 0 {
                        return false; // expired-at-admission must never execute
                    }
                    ok += 1;
                }
                Err(ServeError::Cancelled) => {
                    if tenant != s.heavy_tenant {
                        return false; // only the heavy tenant was cancelled
                    }
                    cancelled += 1;
                }
                Err(ServeError::DeadlineExceeded) => {
                    if i % 3 != 0 {
                        return false; // nobody else carried a deadline
                    }
                    expired += 1;
                }
                Err(_) => return false, // no chaos: no other error is legal
            }
        }
        let m = queue.metrics();
        ok + cancelled + expired == 8
            && m.cancelled == cancelled as u64
            && m.deadline_expired == expired as u64
    });
}

// ---- circuit breaker -------------------------------------------------

#[test]
fn breaker_opens_fails_fast_and_heals_through_a_half_open_probe() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg, 1); // one shard: every placement needs it
    sc.route_retries = 0; // the first worker death exhausts the round
    sc.breaker_threshold = 1;
    sc.breaker_probe_after = 2;
    let queue = ServeQueue::start(sc);

    // exactly one injected death, on the first worker op
    let _c = Chaos::install("seed=2 death=1 death-max=1");
    let s = analytics_scenario(&cfg, N_RECORDS, 31);

    // round 1: the worker dies, no retries -> Route error, breaker opens
    let r1 = queue.submit(0, s.program.clone()).expect("admit").wait();
    assert!(matches!(r1, Err(ServeError::Route(_))), "round 1 fails on the dead shard: {r1:?}");
    let lc = queue.lifecycle().expect("queue alive");
    assert_eq!(lc.breaker, vec!["open"], "one exhausted retry loop trips threshold 1");
    assert_eq!(lc.breaker_opens, 1);

    // pass 2 (probe age 1 < 2): placement fails fast, nothing queues
    let r2 = queue.submit(0, s.program.clone()).expect("admit").wait();
    assert!(
        matches!(r2, Err(ServeError::Rejected(RejectReason::ShardDown))),
        "breaker fails fast while open: {r2:?}"
    );

    // pass 3 (probe age 2): half-open respawn-and-replay probe heals the
    // shard, and the round serves bit-identically — the death budget is
    // spent, replay restored the table
    let rep = queue.submit(0, s.program.clone()).expect("admit").wait().expect("healed");
    assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));
    let lc = queue.lifecycle().expect("queue alive");
    assert_eq!(lc.breaker, vec!["closed"]);
    assert_eq!((lc.breaker_opens, lc.breaker_closes), (1, 1));

    let m = queue.metrics();
    assert_eq!(m.breaker_rejected, 1, "{m:?}");
    assert_eq!((m.breaker_opens, m.breaker_closes), (1, 1), "{m:?}");

    // the full open -> half-open -> closed trajectory is in the trace
    let trace = adra::observe::recorder().to_jsonl();
    assert!(trace.contains("shard_breaker"), "breaker alerts recorded");
    assert!(trace.contains("half-open"), "probe transition recorded");
}

#[test]
fn retry_budget_caps_backoff_blocking_per_round() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg, 1);
    // generous retry count but a 1 ms sleep budget against 64 ms+ of
    // exponential backoff: the loop must give up almost immediately and
    // hand the shard to the breaker instead of stalling the round
    sc.route_retries = 8;
    sc.retry_backoff_ms = 64;
    sc.retry_budget_ms = 1;
    sc.breaker_threshold = 1;
    sc.breaker_probe_after = 1;
    let queue = ServeQueue::start(sc);

    let _c = Chaos::install("seed=4 death=1 death-max=1");
    let s = analytics_scenario(&cfg, N_RECORDS, 41);
    let started = std::time::Instant::now();
    let r1 = queue.submit(0, s.program.clone()).expect("admit").wait();
    assert!(matches!(r1, Err(ServeError::Route(_))), "{r1:?}");
    assert!(
        started.elapsed() < Duration::from_millis(64),
        "the budget forbids even the first 64 ms backoff sleep"
    );
    assert_eq!(queue.lifecycle().expect("alive").breaker, vec!["open"]);
    assert_eq!(queue.metrics().route_retries, 0, "no retry fit inside the budget");

    // the shard still heals through the probe path afterwards
    let r2 = queue.submit(0, s.program.clone()).expect("admit").wait();
    let rep = match r2 {
        Ok(rep) => rep,
        // probe age may need one more pass depending on drain batching
        Err(ServeError::Rejected(RejectReason::ShardDown)) => {
            queue.submit(0, s.program.clone()).expect("admit").wait().expect("healed")
        }
        other => panic!("unexpected outcome {other:?}"),
    };
    assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches.clone()));
}

// ---- brownout ladder -------------------------------------------------

#[test]
fn brownout_steps_up_under_slo_burn_and_walks_back_on_recovery() {
    let _g = faults::test_lock();
    let cfg = cfg();
    let mut sc = serve_cfg(&cfg, 2);
    sc.brownout = true;
    sc.sample_every = 1; // evaluate health every round
    sc.max_round = 4;
    let queue = ServeQueue::start(sc);

    // phase 1: sustained multi-ms rounds burn the 2 ms round-wall SLO;
    // once the dual-window burn commits critical, each further sample
    // climbs the ladder one rung
    {
        let _c = Chaos::install("seed=6 spike=8 spike-ns=3000000");
        let mut stepped = false;
        'flood: for wave in 0..40u64 {
            let s = heavy_tenant_scenario(&cfg, N_RECORDS, 9000 + wave, 4, 0);
            let tickets: Vec<_> = s
                .submissions
                .iter()
                .map(|(t, p)| queue.submit(*t, p.clone()).expect("admit"))
                .collect();
            for (i, t) in tickets.into_iter().enumerate() {
                match t.wait() {
                    Ok(rep) => assert_eq!(
                        rep.outputs[s.filter_step],
                        StepOutput::Matches(s.expected_matches[i].clone()),
                        "browned-out service still answers exactly"
                    ),
                    // at the shed rung over-quota admissions bounce
                    Err(ServeError::Rejected(RejectReason::Overloaded)) => {}
                    other => panic!("wave {wave}: unexpected outcome {other:?}"),
                }
            }
            if queue.lifecycle().expect("alive").degrade_level >= 1 {
                stepped = true;
                break 'flood;
            }
        }
        assert!(stepped, "sustained SLO burn must climb the ladder within 40 waves");
    }

    // phase 2: chaos cleared, light waves; the slow burn window drains,
    // the rule recovers, and every Ok evaluation steps back down
    let mut recovered = false;
    for wave in 0..400u64 {
        let s = analytics_scenario(&cfg, N_RECORDS, 20_000 + wave);
        match queue.submit(0, s.program.clone()).expect("admit").wait() {
            Ok(rep) => assert_eq!(
                rep.outputs[s.filter_step],
                StepOutput::Matches(s.expected_matches.clone())
            ),
            Err(ServeError::Rejected(RejectReason::Overloaded)) => {}
            other => panic!("recovery wave {wave}: unexpected outcome {other:?}"),
        }
        if queue.lifecycle().expect("alive").degrade_level == 0 {
            recovered = true;
            break;
        }
    }
    assert!(recovered, "the ladder walks back to normal once the burn clears");

    let m = queue.metrics();
    assert!(m.degrade_step_ups >= 1, "{m:?}");
    assert!(m.degrade_step_downs >= 1, "{m:?}");
    assert_eq!(m.degrade_level, 0, "{m:?}");

    let trace = adra::observe::recorder().to_jsonl();
    assert!(trace.contains("brownout"), "ladder transitions recorded as alerts");
}
