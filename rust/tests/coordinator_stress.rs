//! Coordinator invariants under stress (quickcheck-lite + thread storms):
//!  * every request gets exactly one response, ids intact;
//!  * batching never changes results (== serial mirror engine);
//!  * per-shard linearization: reads observe the latest write;
//!  * metrics conservation: ops + errors == requests.

use std::sync::Arc;

use adra::cim::{AdraEngine, CimOp, CimValue, Engine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::coordinator::Coordinator;
use adra::util::quick::{Arbitrary, Quick};
use adra::util::rng::Rng;
use adra::workload::{OpMix, WorkloadGen};

fn cfg() -> SimConfig {
    let mut c = SimConfig::square(64, SensingScheme::Current);
    c.word_bits = 8;
    c.max_batch = 16;
    c
}

#[test]
fn storm_requests_one_response_each() {
    let cfg = cfg();
    let coord = Arc::new(Coordinator::adra(&cfg, 4));
    let threads = 8;
    let per_thread = 500;
    let mut handles = Vec::new();
    for t in 0..threads {
        let c = coord.clone();
        let cfg2 = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut gen = WorkloadGen::new(&cfg2, OpMix::balanced(), 31 + t as u64);
            let mut got = 0;
            for i in 0..per_thread {
                let shard = (t + i) % 4;
                let op = gen.next_op();
                match c.call(shard, op) {
                    Ok(_) | Err(adra::coordinator::CallError::Engine(_)) => got += 1,
                    Err(e) => panic!("routing failed: {e}"),
                }
            }
            got
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, threads * per_thread);
    let m = coord.metrics();
    assert_eq!(m.ops + m.errors, (threads * per_thread) as u64);
}

/// A randomized single-shard script of writes and reads, validated
/// against a HashMap model (linearizability of the shard queue).
#[derive(Clone, Debug)]
enum ScriptOp {
    Write { row: usize, word: usize, value: u64 },
    Read { row: usize, word: usize },
}

#[derive(Clone, Debug)]
struct Script(Vec<ScriptOp>);

impl Arbitrary for Script {
    fn generate(rng: &mut Rng) -> Self {
        let len = 1 + rng.below(40) as usize;
        Script(
            (0..len)
                .map(|_| {
                    let row = rng.below(8) as usize;
                    let word = rng.below(4) as usize;
                    if rng.bool() {
                        ScriptOp::Write { row, word, value: rng.below(256) }
                    } else {
                        ScriptOp::Read { row, word }
                    }
                })
                .collect(),
        )
    }

    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if self.0.len() > 1 {
            v.push(Script(self.0[..self.0.len() / 2].to_vec()));
            v.push(Script(self.0[1..].to_vec()));
        }
        v
    }
}

#[test]
fn prop_reads_observe_latest_write() {
    let cfg = cfg();
    Quick::with_cases(60).check::<Script, _>("linearized shard", |script| {
        let coord = Coordinator::adra(&cfg, 1);
        let mut model: std::collections::HashMap<(usize, usize), u64> =
            std::collections::HashMap::new();
        let ops: Vec<CimOp> = script
            .0
            .iter()
            .map(|s| match *s {
                ScriptOp::Write { row, word, value } => {
                    CimOp::Write { addr: WordAddr { row, word }, value }
                }
                ScriptOp::Read { row, word } => CimOp::Read(WordAddr { row, word }),
            })
            .collect();
        let results = coord.call_batch(0, &ops).unwrap();
        for (s, r) in script.0.iter().zip(results) {
            match *s {
                ScriptOp::Write { row, word, value } => {
                    model.insert((row, word), value);
                }
                ScriptOp::Read { row, word } => {
                    let want = model.get(&(row, word)).copied().unwrap_or(0);
                    if r.unwrap().value != CimValue::Word(want) {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_batched_equals_serial_mirror() {
    let cfg = cfg();
    Quick::with_cases(25).check::<u64, _>("batch == serial", |&seed| {
        let coord = Coordinator::adra(&cfg, 1);
        let mut mirror = AdraEngine::new(&cfg);
        let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), seed);
        let ops = gen.batch(60);
        let batched = coord.call_batch(0, &ops).unwrap();
        for (op, got) in ops.iter().zip(batched) {
            let want = mirror.execute(op);
            let agree = match (&got, &want) {
                (Ok(g), Ok(w)) => g.value == w.value,
                (Err(_), Err(_)) => true,
                _ => false,
            };
            if !agree {
                return false;
            }
        }
        true
    });
}

#[test]
fn shutdown_with_inflight_work_is_clean() {
    let cfg = cfg();
    for _ in 0..10 {
        let coord = Coordinator::adra(&cfg, 2);
        let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 5);
        let mut pending = Vec::new();
        for i in 0..100 {
            pending.push(coord.submit(i % 2, gen.next_op()).unwrap());
        }
        // drop half the pendings without waiting, wait on the rest
        for (i, p) in pending.into_iter().enumerate() {
            if i % 2 == 0 {
                let _ = p.wait();
            }
        }
        drop(coord); // must join cleanly, no hang, no panic
    }
}

#[test]
fn errors_are_reported_not_fatal() {
    let cfg = cfg();
    let coord = Coordinator::adra(&cfg, 1);
    // out-of-range op
    let r = coord.call(0, CimOp::Read(WordAddr { row: 10_000, word: 0 }));
    assert!(r.is_err());
    // the worker is still alive and serving
    let ok = coord.call(0, CimOp::Read(WordAddr { row: 0, word: 0 })).unwrap();
    assert_eq!(ok.value, CimValue::Word(0));
    let m = coord.metrics();
    assert_eq!(m.errors, 1);
    assert_eq!(m.ops, 1);
}
