//! Bench: the L3 hot path, layer by layer — device-model evaluation,
//! sensing, compute-module ripple, and the whole engine op, plus the
//! tiered activation kernel (Digital vs Lut vs Exact).  This is the
//! bench the §Perf optimization loop iterates against; results also land
//! in `BENCH_hotpath.json` (name, ns/iter, iters) for the perf
//! trajectory CI uploads.

use adra::cim::{AdraEngine, BoolFn, CimOp, Engine, VectorEngine, WordAddr};
use adra::config::{DeviceParams, FidelityTier, SensingScheme, SimConfig};
use adra::coordinator::fuse::execute_fused;
use adra::device;
use adra::logic::{ripple_add_sub, sense_from_bits};
use adra::sensing::{CurrentRefs, CurrentSenseBank};
use adra::util::bench::{self, black_box, Bench, BenchStats};
use adra::util::rng::Rng;

/// Engine-level tier comparison: the same 64-col dual-row Boolean op on
/// each fidelity tier.  Returns the median ns/iter.
///
/// Note: the digital median deliberately INCLUDES the amortized cost of
/// the sampled cross-validation (one analog re-run every
/// `AdraEngine::XVAL_PERIOD` activations) — that overhead is part of the
/// tier's real served cost, so the >=10x gate below guards the effective
/// throughput, xval and all.  Shrinking XVAL_PERIOD raises this median
/// by design.
fn bench_tier(b: &Bench, all: &mut Vec<BenchStats>, tier: FidelityTier) -> f64 {
    let mut cfg = SimConfig::square(1024, SensingScheme::Current);
    cfg.word_bits = 64;
    cfg.tier = tier;
    let mut e = AdraEngine::new(&cfg);
    e.execute(&CimOp::Write {
        addr: WordAddr { row: 0, word: 0 },
        value: 0xDEAD_BEEF_0123_4567,
    })
    .unwrap();
    e.execute(&CimOp::Write {
        addr: WordAddr { row: 1, word: 0 },
        value: 0xFEDC_BA98_7654_3210,
    })
    .unwrap();
    let stats = b.run(&format!("engine/bool-or 64c [{}]", tier.name()), || {
        e.execute(&CimOp::Bool { f: BoolFn::Or, row_a: 0, row_b: 1, word: 0 }).unwrap()
    });
    let ns = stats.median_ns();
    all.push(stats);
    ns
}

fn main() {
    let p = DeviceParams::default();
    let b = Bench::default();
    let mut all: Vec<BenchStats> = Vec::new();

    // L0: one device-model evaluation (the innermost function)
    let mut vg = 0.5f64;
    all.push(b.run("device/cell_current", || {
        vg = if vg > 1.0 { 0.5 } else { vg + 1e-6 };
        device::cell_current(&p, vg, 1.0, 0.2, 0.0)
    }));

    // a full 32-column senseline evaluation
    let pol_a: Vec<f64> = (0..32).map(|i| if i % 3 == 0 { 0.2 } else { -0.2 }).collect();
    let pol_b: Vec<f64> = (0..32).map(|i| if i % 2 == 0 { 0.2 } else { -0.2 }).collect();
    all.push(b.run("device/senseline x32", || {
        let mut acc = 0.0;
        for i in 0..32 {
            acc += device::senseline_current(
                &p, pol_a[i], pol_b[i], p.v_gread1, p.v_gread2, p.v_read, 0.0, 0.0,
            );
        }
        acc
    }));

    // one RBL discharge transient (the voltage-sensing inner loop):
    // exact closed-form path vs the separable LUT fast path (§Perf)
    all.push(b.run("device/rbl_transient exact (128 steps)", || {
        device::rbl_transient(&p, 0.2, -0.2, p.v_gread1, p.v_gread2, 1.0,
                              204.8e-15, 0.0, 0.0)
    }));
    let lut = device::CellLut::new(&p);
    all.push(b.run("device/rbl_transient LUT (128 steps)", || {
        lut.rbl_transient(&p, 0.2, -0.2, p.v_gread1, p.v_gread2, 1.0,
                          204.8e-15, 0.0, 0.0)
    }));
    let mut u = -0.5f64;
    all.push(b.run("device/cell_current LUT", || {
        u = if u > 0.5 { -0.5 } else { u + 1e-6 };
        lut.cell_current(1.0 + u, 1.0, 0.2, 0.0)
    }));

    // sensing bank over 32 columns
    let bank = CurrentSenseBank::new(CurrentRefs::derive(&p, p.v_gread1, p.v_gread2));
    let isl: Vec<f64> = (0..32).map(|i| 1e-6 + i as f64 * 2e-6).collect();
    all.push(b.run("sensing/bank x32", || bank.sense_all(black_box(&isl))));

    // the ripple carry chain (33 compute modules)
    let sense = sense_from_bits(0xDEADBEEF, 0x12345678, 32);
    all.push(b.run("logic/ripple_add_sub 32b", || ripple_add_sub(black_box(&sense), true)));

    // whole-engine ops at 1024^2, current sensing (default = digital tier)
    let mut cfg = SimConfig::square(1024, SensingScheme::Current);
    cfg.word_bits = 32;
    let mut e = AdraEngine::new(&cfg);
    let mut rng = Rng::new(1);
    for row in 0..8 {
        for word in 0..4 {
            let v = rng.next_u64() & 0xFFFF_FFFF;
            e.execute(&CimOp::Write { addr: WordAddr { row, word }, value: v }).unwrap();
        }
    }
    all.push(b.run("engine/read", || {
        e.execute(&CimOp::Read(WordAddr { row: 1, word: 1 })).unwrap()
    }));
    all.push(b.run("engine/read2", || {
        e.execute(&CimOp::Read2 { row_a: 0, row_b: 1, word: 2 }).unwrap()
    }));
    all.push(b.run("engine/bool-xor", || {
        e.execute(&CimOp::Bool { f: BoolFn::Xor, row_a: 2, row_b: 3, word: 0 }).unwrap()
    }));
    all.push(b.run("engine/sub", || {
        e.execute(&CimOp::Sub { row_a: 4, row_b: 5, word: 3 }).unwrap()
    }));
    all.push(b.run("engine/compare", || {
        e.execute(&CimOp::Compare { row_a: 6, row_b: 7, word: 1 }).unwrap()
    }));

    // the tiered activation kernel, engine level: identical op + costs,
    // wall clock is the only difference
    let digital_ns = bench_tier(&b, &mut all, FidelityTier::Digital);
    let lut_ns = bench_tier(&b, &mut all, FidelityTier::Lut);
    let exact_ns = bench_tier(&b, &mut all, FidelityTier::Exact);
    println!(
        "\ntier speedup on the 64-col dual-row OR: digital {:.1}x vs lut, {:.1}x vs exact",
        lut_ns / digital_ns,
        exact_ns / digital_ns
    );
    // the acceptance gate: the packed path must stay >= 10x faster than
    // the LUT tier on the 64-col op (CI runs this bench, so a fast-path
    // regression fails the job rather than just shrinking a number)
    assert!(
        lut_ns / digital_ns >= 10.0,
        "digital tier regressed: {digital_ns:.1} ns vs lut {lut_ns:.1} ns ({:.1}x < 10x)",
        lut_ns / digital_ns
    );

    // ---- whole-row word-slice kernel vs the per-word PR 4 kernel ----
    // the new packed row path serves a whole 256/1024-column sub_row from
    // u64 word slices; the legacy kernel is what PR 4 shipped: one
    // 64-col window at a time, per-column SenseOut materialization +
    // ripple per word
    let mut row_speedup_1024 = 0.0;
    for cols in [256usize, 1024] {
        let mut cfg = SimConfig::square(cols, SensingScheme::Current);
        cfg.word_bits = 64;
        let words = cfg.words_per_row();
        let mut e = AdraEngine::new(&cfg);
        let mut rng = Rng::new(7);
        for row in 0..2 {
            for w in 0..words {
                e.execute(&CimOp::Write { addr: WordAddr { row, word: w }, value: rng.next_u64() })
                    .unwrap();
            }
        }
        let packed = b.run(&format!("row/sub {cols}c [digital]"), || {
            let mut v = VectorEngine::new(&mut e);
            v.sub_row(0, 1).unwrap()
        });
        let legacy = b.run(&format!("row/sub {cols}c [per-word legacy]"), || {
            // the PR 4 kernel: per 64-col window, materialize + ripple
            let mut acc = 0i128;
            for w in 0..words {
                let outs = e.activate_cols(0, 1, w * 64, (w + 1) * 64).unwrap();
                acc = acc.wrapping_add(ripple_add_sub(outs, true).as_signed());
            }
            acc
        });
        let speedup = legacy.median_ns() / packed.median_ns();
        println!("row/sub {cols}c: whole-row {speedup:.1}x vs per-word");
        if cols == 1024 {
            row_speedup_1024 = speedup;
        }
        all.push(packed);
        all.push(legacy);
    }
    // the whole-row acceptance gate
    assert!(
        row_speedup_1024 >= 4.0,
        "whole-row kernel regressed: {row_speedup_1024:.1}x < 4x vs the per-word kernel"
    );

    // ---- masked digital under variation (sigma = 20 mV, paper-nominal)
    // vs the analog tiers on the same whole-row op; also record the
    // deterministic-column fraction the masks deliver
    let mut det_fraction = 0.0;
    {
        let mut mk = |tier: FidelityTier, label: &str| -> BenchStats {
            let mut cfg = SimConfig::square(1024, SensingScheme::Current);
            cfg.word_bits = 64;
            cfg.vt_sigma = 0.02;
            cfg.tier = tier;
            let mut e = AdraEngine::new(&cfg);
            let mut rng = Rng::new(11);
            for row in 0..2 {
                for w in 0..cfg.words_per_row() {
                    e.execute(&CimOp::Write {
                        addr: WordAddr { row, word: w },
                        value: rng.next_u64(),
                    })
                    .unwrap();
                }
            }
            if tier == FidelityTier::Digital {
                assert!(e.masked_active(), "masked path must engage at 20 mV");
            }
            let stats = b.run(&format!("row/sub 1024c s20 [{label}]"), || {
                let mut v = VectorEngine::new(&mut e);
                v.sub_row(0, 1).unwrap()
            });
            if tier == FidelityTier::Digital {
                let s = e.array().stats();
                det_fraction = s.det_col_fraction();
                assert_eq!(s.xval_mismatches, 0, "masked xval must stay clean");
            }
            stats
        };
        let masked = mk(FidelityTier::Digital, "masked");
        let lut = mk(FidelityTier::Lut, "lut");
        let exact = mk(FidelityTier::Exact, "exact");
        println!(
            "masked row kernel at 20 mV sigma: {:.1}x vs lut, {:.1}x vs exact, \
             det-col fraction {:.3}",
            lut.median_ns() / masked.median_ns(),
            exact.median_ns() / masked.median_ns(),
            det_fraction
        );
        assert!(
            det_fraction >= 0.8,
            "masks must keep >= 80% of columns packed at 20 mV: {det_fraction:.3}"
        );
        all.push(masked);
        all.push(lut);
        all.push(exact);
    }

    // ---- fused pair-batch: 8 word groups on one row pair, one plane
    // fill per batch on the packed tiers
    for (tier, label) in [(FidelityTier::Digital, "digital"), (FidelityTier::Lut, "lut")] {
        let mut cfg = SimConfig::square(1024, SensingScheme::Current);
        cfg.word_bits = 64;
        cfg.tier = tier;
        let mut e = AdraEngine::new(&cfg);
        let mut ops = Vec::new();
        let mut rng = Rng::new(13);
        for w in 0..8 {
            e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: w }, value: rng.next_u64() })
                .unwrap();
            e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: w }, value: rng.next_u64() })
                .unwrap();
            ops.push(CimOp::Sub { row_a: 0, row_b: 1, word: w });
            ops.push(CimOp::Compare { row_a: 0, row_b: 1, word: w });
        }
        all.push(b.run(&format!("fused/pair-batch 8w [{label}]"), || {
            execute_fused(&mut e, &ops)
        }));
    }

    // ---- observability self-metering: one serve-round telemetry tick
    // (registry snapshot -> series store -> standard-rule health
    // evaluation) on a registry shaped like a live two-queue serve
    // process.  Gated as a ratio against the exact-tier engine op so the
    // gate is machine-independent: telemetry must stay cheap relative to
    // the work it observes.
    let tick_ns = {
        use adra::observe::{standard_engine, Registry, SeriesStore};
        let reg = Registry::new();
        for q in ["0", "1"] {
            let l = [("queue", q)];
            reg.counter("adra.serve.programs", "Programs admitted and answered.", &l).add(128);
            reg.counter("adra.serve.deferred_programs", "Deferred at admission close.", &l)
                .add(64);
            reg.counter("adra.serve.rounds", "Executed rounds.", &l).add(32);
            reg.gauge("adra.serve.cache_hit_rate", "Cache hit rate.", &l).set(0.4);
            let h = reg.histogram("adra.serve.round_wall_ns", "Round wall (ns).", &l);
            for i in 0..64u32 {
                h.record(1000.0 * (i + 1) as f64);
            }
        }
        reg.gauge("adra.array.det_fraction", "Deterministic column fraction.", &[]).set(0.97);
        let store = SeriesStore::with_capacity(64);
        let mut engine = standard_engine();
        let stats = b.run("observe/sample+health tick", || {
            store.sample(&reg);
            engine.evaluate(&store, &reg, adra::observe::recorder())
        });
        let ns = stats.median_ns();
        all.push(stats);
        ns
    };
    println!(
        "observe tick: {tick_ns:.0} ns/round ({:.1}x under the exact-tier 64-col op)",
        exact_ns / tick_ns
    );

    // ---- fault-injection happy path: with no spec installed the pool's
    // per-op guard is one relaxed atomic load (`faults::active`).  Gated
    // as a ratio against the digital engine op so the gate is machine-
    // independent: the disarmed guard must stay far cheaper than the
    // cheapest real op it fronts — the "zero happy-path overhead" claim
    // of the chaos layer, held by CI.
    let guard_ns = {
        adra::faults::clear();
        let stats = b.run("faults/active disarmed", || black_box(adra::faults::active()));
        let ns = stats.median_ns().max(1e-3); // clamp: sub-picosecond medians are timer noise
        all.push(stats);
        ns
    };
    println!(
        "faults guard: {guard_ns:.2} ns disarmed ({:.1}x under the digital 64-col op)",
        digital_ns / guard_ns
    );
    assert!(
        digital_ns / guard_ns >= 5.0,
        "disarmed fault guard is no longer negligible: {guard_ns:.2} ns vs digital op \
         {digital_ns:.1} ns"
    );

    bench::write_json_with_meta(
        "BENCH_hotpath.json",
        &all,
        &[
            ("row/det-fraction s20 [masked]", det_fraction),
            ("row/speedup 1024c [whole-row vs per-word]", row_speedup_1024),
            ("tier/speedup 64c [digital vs lut]", lut_ns / digital_ns),
            ("observe/tick ratio [exact-op vs sample+health]", exact_ns / tick_ns),
            ("faults/overhead ratio [digital-op vs disarmed-guard]", digital_ns / guard_ns),
        ],
    )
    .expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json ({} benchmarks)", all.len());
}
