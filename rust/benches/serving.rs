//! Bench: serving-layer throughput and modeled energy vs naive
//! per-program execution as concurrent tenants scale.
//!
//! Naive = every submission runs `Placement::execute` on a shared
//! planned coordinator, sequentially (no coalescing, fusion, dedup, or
//! caching).  Served = the same multiset of programs pushed through a
//! `ServeQueue` from one client thread per tenant.
//!
//! §Perf targets: served modeled energy well below naive at >= 4 tenants
//! (cross-tenant dedup + fusion + cache), wall throughput at worst
//! comparable at 1 tenant and improving with tenant count.

use std::sync::{Arc, Barrier};
use std::time::Instant;

use adra::config::{SensingScheme, SimConfig};
use adra::energy::OpCost;
use adra::planner::{
    place, planned_coordinator, Objective, PlanCostModel, Predicate, Program, StepOutput,
};
use adra::serve::{AdmissionPolicy, BatchPolicy, ServeConfig, ServeQueue, ServeReport};
use adra::util::rng::Rng;
use adra::workload::heavy_tenant_scenario;

const N_RECORDS: usize = 256;
const SHARDS: usize = 4;
const REPEATS: usize = 4;

fn tenant_program(values: &[u64], threshold: u64, tenant: usize) -> Program {
    let mut p = Program::new(values.len());
    let t = p.scratch();
    let all = p.all();
    p.load(0, values.to_vec());
    p.broadcast(t, threshold);
    if tenant % 2 == 0 {
        p.filter(all, t, Predicate::Lt);
        p.compare(all, t);
    } else {
        p.sub(all, t);
    }
    p
}

fn main() {
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 32;
    cfg.max_batch = 256;
    let mut rng = Rng::new(7);
    let values: Vec<u64> = (0..N_RECORDS).map(|_| rng.below(1 << 20)).collect();
    let threshold: u64 = 1 << 19;
    let model = PlanCostModel::new(&cfg, Objective::Edp);

    println!(
        "serving bench: {N_RECORDS} records, {SHARDS} shards, {REPEATS} replays/tenant\n"
    );
    println!(
        "{:>7} {:>12} {:>12} {:>8} {:>14} {:>14} {:>8} {:>7} {:>7}",
        "tenants",
        "naive wall",
        "serve wall",
        "speedup",
        "naive energy",
        "serve energy",
        "saving",
        "fused%",
        "hit%"
    );

    for &tenants in &[1usize, 2, 4, 8] {
        let programs: Vec<Program> = (0..tenants)
            .map(|t| tenant_program(&values, threshold, t))
            .collect();

        // --- naive: sequential per-program execution ---
        let naive_coord = planned_coordinator(&cfg, SHARDS, Objective::Edp);
        let placements: Vec<_> = programs
            .iter()
            .map(|p| place(p, &cfg, SHARDS, &model).expect("place"))
            .collect();
        let t0 = Instant::now();
        let mut naive_cost = OpCost::default();
        for _ in 0..REPEATS {
            for pl in &placements {
                let rep = pl.execute(&naive_coord).expect("naive");
                naive_cost = naive_cost.then(&rep.measured);
            }
        }
        let naive_wall = t0.elapsed().as_secs_f64();

        // --- served: one client thread per tenant ---
        let queue = Arc::new(ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: SHARDS,
            objective: Objective::Edp,
            n_records: N_RECORDS,
            max_round: 32,
            cache_capacity: 4096,
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
        }));
        let barrier = Arc::new(Barrier::new(tenants));
        let t1 = Instant::now();
        let handles: Vec<_> = programs
            .into_iter()
            .enumerate()
            .map(|(t, program)| {
                let q = queue.clone();
                let b = barrier.clone();
                std::thread::spawn(move || {
                    b.wait();
                    let mut cost = OpCost::default();
                    for _ in 0..REPEATS {
                        let rep = q.submit(t, program.clone()).expect("admit").wait().expect("serve");
                        cost = cost.then(&rep.measured);
                    }
                    cost
                })
            })
            .collect();
        let mut serve_cost = OpCost::default();
        for h in handles {
            serve_cost = serve_cost.then(&h.join().expect("tenant"));
        }
        let serve_wall = t1.elapsed().as_secs_f64();
        let m = queue.metrics();

        println!(
            "{:>7} {:>11.4}s {:>11.4}s {:>7.2}x {:>12.3}nJ {:>12.3}nJ {:>7.1}% {:>6.1}% {:>6.1}%",
            tenants,
            naive_wall,
            serve_wall,
            naive_wall / serve_wall,
            naive_cost.energy.total() * 1e9,
            serve_cost.energy.total() * 1e9,
            (1.0 - serve_cost.energy.total() / naive_cost.energy.total()) * 100.0,
            m.fused_share() * 100.0,
            m.cache_hit_rate() * 100.0,
        );

        assert!(
            serve_cost.energy.total() <= naive_cost.energy.total(),
            "serving must never cost more modeled energy than naive"
        );
    }

    fairness_bench(&cfg);
}

/// §Fairness: a heavy tenant floods the queue ahead of four light
/// tenants.  Weighted fair admission must improve the NON-heavy p95 wall
/// latency vs FIFO while the fused-activation savings (the EDP lever the
/// paper's 23.2%-72.6% win rides on) do not regress.
fn fairness_bench(cfg: &SimConfig) {
    const HEAVY_BURST: usize = 24;
    const LIGHTS: usize = 4;
    let scenario = heavy_tenant_scenario(cfg, N_RECORDS, 41, HEAVY_BURST, LIGHTS);

    // naive activation count for one program (every dual op pays one)
    let model = PlanCostModel::new(cfg, Objective::Edp);
    let naive_dual: usize = scenario
        .submissions
        .iter()
        .map(|(_, p)| {
            place(p, cfg, SHARDS, &model)
                .expect("place")
                .shards
                .iter()
                .flat_map(|sp| sp.lowered.ops.iter())
                .filter(|r| r.op.is_dual())
                .count()
        })
        .sum();

    let run = |admission: AdmissionPolicy, batch: BatchPolicy| {
        let q = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: SHARDS,
            objective: Objective::Edp,
            n_records: N_RECORDS,
            max_round: 8,
            cache_capacity: 4096,
            admission,
            batch,
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
        });
        // queue the whole flood ahead of the light tenants, then wait —
        // the adversarial arrival order both policies must digest
        let tickets: Vec<_> = scenario
            .submissions
            .iter()
            .map(|(t, p)| q.submit(*t, p.clone()).expect("admit"))
            .collect();
        let reports: Vec<ServeReport> =
            tickets.into_iter().map(|t| t.wait().expect("serve")).collect();
        for (rep, want) in reports.iter().zip(&scenario.expected_matches) {
            assert_eq!(
                rep.outputs[scenario.filter_step],
                StepOutput::Matches(want.clone()),
                "fairness must never change results"
            );
        }
        (reports, q.metrics())
    };

    let (_, fifo_m) = run(AdmissionPolicy::Fifo, BatchPolicy::Static);
    let (fair_reports, fair_m) =
        run(AdmissionPolicy::Fair, BatchPolicy::Adaptive { target_p95: 2e-3 });

    let fifo_p95 = fifo_m.p95_ns_excluding(scenario.heavy_tenant);
    let fair_p95 = fair_m.p95_ns_excluding(scenario.heavy_tenant);
    println!(
        "\nfairness: {HEAVY_BURST}-program flood + {LIGHTS} light tenants, \
         {N_RECORDS} records, {SHARDS} shards"
    );
    println!(
        "{:>22} {:>14} {:>14} {:>12}",
        "policy", "non-heavy p95", "heavy p95", "activations"
    );
    println!(
        "{:>22} {:>11.1} us {:>11.1} us {:>12}",
        "FIFO + static",
        fifo_p95 / 1e3,
        fifo_m.tenant_latency[&scenario.heavy_tenant].percentile_ns(95.0) / 1e3,
        fifo_m.activations,
    );
    println!(
        "{:>22} {:>11.1} us {:>11.1} us {:>12}",
        "fair + adaptive",
        fair_p95 / 1e3,
        fair_m.tenant_latency[&scenario.heavy_tenant].percentile_ns(95.0) / 1e3,
        fair_m.activations,
    );
    println!(
        "quota hits {}, deferrals {}, controller max_round {} ({}+ {}- {}=)",
        fair_m.quota_hits,
        fair_m.deferred_programs,
        fair_m.current_max_round,
        fair_m.controller_grows,
        fair_m.controller_shrinks,
        fair_m.controller_holds,
    );

    // §Perf targets, asserted: the neighbors' tail improves under WFQ...
    assert!(
        fair_p95 <= fifo_p95,
        "non-heavy p95 must improve under fair admission: fair {fair_p95} ns vs fifo {fifo_p95} ns"
    );
    // ...and the amortization levers do not regress: cross-tenant fusion
    // still collapses activations well below the naive per-program count
    assert!(
        (fair_m.activations as usize) < naive_dual,
        "fused-activation savings regressed: {} activations vs naive {naive_dual}",
        fair_m.activations
    );
    // starvation-freedom in the bench scenario too
    let heavy_last = fair_reports[..HEAVY_BURST].iter().map(|r| r.round).max().unwrap();
    let light_last = fair_reports[HEAVY_BURST..].iter().map(|r| r.round).max().unwrap();
    assert!(light_last <= heavy_last, "light tenants starved: {light_last} > {heavy_last}");
}
