//! Bench: Fig. 7 — voltage sensing, scheme 2 (discharged RBL).

use adra::cim::{AdraEngine, BaselineEngine, CimOp, Engine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::figures::fig67_voltage::fig67_sweep;
use adra::util::bench::Bench;

fn main() {
    println!("=== Fig 7: voltage sensing, scheme 2 (discharged) ===");
    println!("{:>10} {:>16} {:>10} {:>14}", "array", "energy decrease", "speedup", "EDP decrease");
    for row in fig67_sweep(SensingScheme::VoltageDischarged) {
        println!(
            "{:>7}^2 {:>15.2}% {:>9.3}x {:>13.2}%",
            row.size,
            row.improvement.energy_decrease * 100.0,
            row.improvement.speedup,
            row.improvement.edp_decrease * 100.0
        );
    }
    println!("(paper: -35.5..-45.8% energy, 1.945-1.983x, EDP -66.83..-72.6%)\n");

    let b = Bench::coarse();
    let mut cfg = SimConfig::square(1024, SensingScheme::VoltageDischarged);
    cfg.word_bits = 32;
    let mut adra = AdraEngine::new(&cfg);
    let mut base = BaselineEngine::new(&cfg);
    for e in [&mut adra as &mut dyn Engine, &mut base as &mut dyn Engine] {
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 99 }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 31 }).unwrap();
    }
    b.run("adra/compare/scheme2/1024", || {
        adra.execute(&CimOp::Compare { row_a: 0, row_b: 1, word: 0 }).unwrap()
    });
    b.run("baseline/compare/scheme2/1024", || {
        base.execute(&CimOp::Compare { row_a: 0, row_b: 1, word: 0 }).unwrap()
    });
}
