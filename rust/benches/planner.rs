//! Bench: planner overhead and scaling — how much wall time the planning
//! layers (lowering, placement) add on top of raw execution, and what the
//! shard fan-out buys end-to-end.
//!
//! §Perf targets: lowering throughput in the millions of ops/s (planning
//! must never be the bottleneck of a query), and 4-shard planned
//! execution beating the 1-shard path on wall time.

use std::time::Instant;

use adra::config::{SensingScheme, SimConfig};
use adra::planner::{lower, place, planned_coordinator, Objective, PlanCostModel};
use adra::util::bench::black_box;
use adra::workload::analytics_scenario;

fn timed<T>(label: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let dt = t0.elapsed().as_secs_f64();
    println!("bench {label:<46} {dt:>10.4} s");
    (out, dt)
}

fn main() {
    let mut cfg = SimConfig::square(512, SensingScheme::Current);
    cfg.word_bits = 32;
    cfg.max_batch = 256;
    let n_records = 4096;
    let objective = Objective::Edp;

    let scenario = analytics_scenario(&cfg, n_records, 11);
    let model = PlanCostModel::new(&cfg, objective);

    // --- planning-layer throughput ---
    let reps = 20;
    let (lowered, t_lower) = timed(&format!("lower x{reps} ({n_records} records)"), || {
        let mut last = None;
        for _ in 0..reps {
            last = Some(lower(&scenario.program, &cfg, &model).unwrap());
        }
        last.unwrap()
    });
    let lowered_ops = lowered.ops.len();
    println!(
        "      lowering throughput: {:.2} M lowered ops/s ({lowered_ops} ops per program)",
        reps as f64 * lowered_ops as f64 / t_lower / 1e6
    );

    let (placement4, t_place) = timed(&format!("place x{reps} across 4 shards"), || {
        let mut last = None;
        for _ in 0..reps {
            last = Some(place(&scenario.program, &cfg, 4, &model).unwrap());
        }
        last.unwrap()
    });
    println!(
        "      placement throughput: {:.2} M lowered ops/s",
        reps as f64 * lowered_ops as f64 / t_place / 1e6
    );

    // --- end-to-end: planned execution, 1 shard vs 4 shards ---
    let placement1 = place(&scenario.program, &cfg, 1, &model).unwrap();
    let coord1 = planned_coordinator(&cfg, 1, objective);
    let (rep1, t1) = timed("execute planned, 1 shard", || {
        black_box(placement1.execute(&coord1).unwrap())
    });
    let coord4 = planned_coordinator(&cfg, 4, objective);
    let (rep4, t4) = timed("execute planned, 4 shards", || {
        black_box(placement4.execute(&coord4).unwrap())
    });
    // the 4-shard placement replicates the broadcast scratch row on each
    // extra shard; everything else must match op for op
    let replicated = (placement4.shards.len() - 1) * cfg.words_per_row();
    assert_eq!(rep4.ops_executed, rep1.ops_executed + replicated);
    assert!(rep1.prediction.within(0.2) && rep4.prediction.within(0.2));

    println!(
        "\nplanning overhead: {:.2}% of 1-shard execution wall time",
        (t_lower + t_place) / reps as f64 / t1 * 100.0
    );
    println!("4-shard speedup over 1-shard: {:.2}x wall", t1 / t4);
    println!(
        "modeled device makespan: {:.3} us (1 shard) -> {:.3} us (4 shards)",
        placement1.predicted_makespan * 1e6,
        placement4.predicted_makespan * 1e6
    );
}
