//! Bench: Fig. 5 — scheme1/scheme2 frequency and parallelism trade-offs
//! with the crossover points, plus evaluation-throughput measurements of
//! the energy model itself (it sits on the coordinator's metrics path).

use adra::figures::fig5_tradeoffs::{
    crossover_frequency, crossover_parallelism, fig5a_sweep, fig5b_sweep,
};
use adra::config::{SensingScheme, SimConfig};
use adra::energy::EnergyModel;
use adra::util::bench::Bench;

fn main() {
    println!("=== Fig 5: voltage-sensing trade-offs ===");
    println!("fig 5(a): energy per word-op vs CiM frequency (1024^2)");
    for (f, e1, e2) in fig5a_sweep(1024) {
        println!(
            "  {:>9.2} MHz   scheme1 {:>9.3} pJ   scheme2 {:>9.3} pJ   winner: {}",
            f / 1e6,
            e1 * 1e12,
            e2 * 1e12,
            if e1 < e2 { "scheme1" } else { "scheme2" }
        );
    }
    println!(
        "  crossover {:.2} MHz (paper 7.53 MHz)\n",
        crossover_frequency(1024) / 1e6
    );

    println!("fig 5(b): energy per row activation vs parallelism (1024^2)");
    for (p, e1, e2) in fig5b_sweep(1024) {
        println!(
            "  P={:>5.3}   scheme1 {:>9.3} pJ   scheme2 {:>9.3} pJ   winner: {}",
            p,
            e1 * 1e12,
            e2 * 1e12,
            if e1 < e2 { "scheme1" } else { "scheme2" }
        );
    }
    println!(
        "  crossover P = {:.3} (paper ~0.42)\n",
        crossover_parallelism(1024)
    );

    let m = EnergyModel::new(&SimConfig::square(1024, SensingScheme::VoltagePrecharged));
    let b = Bench::default();
    let mut f = 1e6;
    b.run("energy-model/cim_energy_at_frequency", || {
        f = if f > 100e6 { 1e6 } else { f * 1.01 };
        m.cim_energy_at_frequency(SensingScheme::VoltagePrecharged, f)
    });
    let mut p = 0.03125;
    b.run("energy-model/row_activation_energy", || {
        p = if p >= 1.0 { 0.03125 } else { p + 0.01 };
        m.row_activation_energy(SensingScheme::VoltagePrecharged, p)
    });
}
