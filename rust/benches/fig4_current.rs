//! Bench: Fig. 4 — current-based sensing, ADRA vs two-read baseline.
//!
//! Regenerates the paper's series (energy decrease / speedup / EDP vs
//! array size) from the calibrated model, then measures the *simulator's*
//! wall-clock throughput executing real subtraction ops end-to-end on
//! both engines at each size.

use adra::cim::{AdraEngine, BaselineEngine, CimOp, Engine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::figures::fig4_current::fig4_sweep;
use adra::util::bench::Bench;
use adra::util::rng::Rng;

fn main() {
    println!("=== Fig 4: current-based sensing ===");
    println!("{:>10} {:>16} {:>10} {:>14}", "array", "energy decrease", "speedup", "EDP decrease");
    for row in fig4_sweep(SensingScheme::Current) {
        println!(
            "{:>7}^2 {:>15.2}% {:>9.3}x {:>13.2}%",
            row.size,
            row.improvement.energy_decrease * 100.0,
            row.improvement.speedup,
            row.improvement.edp_decrease * 100.0
        );
    }

    println!("\nsimulator throughput (behavioral analog backend):");
    let b = Bench::default();
    for size in [256usize, 1024] {
        let mut cfg = SimConfig::square(size, SensingScheme::Current);
        cfg.word_bits = 32;
        let mut rng = Rng::new(4);

        let mut adra = AdraEngine::new(&cfg);
        adra.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 77 }).unwrap();
        adra.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 33 }).unwrap();
        b.run(&format!("adra/sub/current/{size}"), || {
            let w = rng.below(4) as usize;
            adra.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: w }).unwrap()
        });

        let mut base = BaselineEngine::new(&cfg);
        base.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 77 }).unwrap();
        base.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 33 }).unwrap();
        b.run(&format!("baseline/sub/current/{size}"), || {
            let w = rng.below(4) as usize;
            base.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: w }).unwrap()
        });
    }
}
