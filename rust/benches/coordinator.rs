//! Bench: coordinator overhead — raw engine throughput vs the same ops
//! through the router/batcher/worker pipeline, and scaling across shards.
//! §Perf target: the coordinator adds <10% over raw engine throughput at
//! batch granularity.

use std::time::Instant;

use adra::cim::{AdraEngine, CimOp, Engine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::coordinator::Coordinator;
use adra::util::bench::black_box;
use adra::workload::{OpMix, WorkloadGen};

fn ops_per_sec(label: &str, n: usize, f: impl FnOnce()) -> f64 {
    let t0 = Instant::now();
    f();
    let dt = t0.elapsed().as_secs_f64();
    let rate = n as f64 / dt;
    println!("bench {label:<46} {rate:>14.0} op/s  ({n} ops in {dt:.3}s)");
    rate
}

fn main() {
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 32;
    cfg.max_batch = 64;
    let n_ops = 60_000;

    // populate + generate one shared op stream
    let mut gen = WorkloadGen::new(&cfg, OpMix::subtraction_heavy(), 7);
    let ops = gen.batch(n_ops);

    // raw engine
    let mut engine = AdraEngine::new(&cfg);
    for row in 0..cfg.rows.min(64) {
        engine
            .execute(&CimOp::Write { addr: WordAddr { row, word: 0 }, value: row as u64 })
            .unwrap();
    }
    let raw = ops_per_sec("engine/raw (no coordinator)", n_ops, || {
        for op in &ops {
            black_box(engine.execute(op).ok());
        }
    });

    // through the coordinator, 1 shard (pure overhead measurement)
    let coord1 = Coordinator::adra(&cfg, 1);
    let one = ops_per_sec("coordinator/1-shard batched", n_ops, || {
        for chunk in ops.chunks(512) {
            black_box(coord1.call_batch(0, chunk).unwrap());
        }
    });

    // through the coordinator, 4 shards (scaling)
    let coord4 = std::sync::Arc::new(Coordinator::adra(&cfg, 4));
    let four = ops_per_sec("coordinator/4-shard parallel", n_ops * 4, || {
        let mut handles = Vec::new();
        for shard in 0..4usize {
            let c = coord4.clone();
            let ops = ops.clone();
            handles.push(std::thread::spawn(move || {
                for chunk in ops.chunks(512) {
                    black_box(c.call_batch(shard, chunk).unwrap());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    let overhead = (raw - one) / raw * 100.0;
    println!("\ncoordinator overhead vs raw engine: {overhead:.1}%  (target < 10%)");
    println!("4-shard scaling: {:.2}x over 1-shard", four / one);
}
