//! Bench: Fig. 6 — voltage sensing, scheme 1 (precharged RBL).

use adra::cim::{AdraEngine, CimOp, Engine, WordAddr};
use adra::config::{SensingScheme, SimConfig};
use adra::figures::fig67_voltage::fig67_sweep;
use adra::util::bench::Bench;

fn main() {
    println!("=== Fig 6: voltage sensing, scheme 1 (precharged) ===");
    println!("{:>10} {:>16} {:>10} {:>14}", "array", "energy overhead", "speedup", "EDP decrease");
    for row in fig67_sweep(SensingScheme::VoltagePrecharged) {
        println!(
            "{:>7}^2 {:>15.2}% {:>9.3}x {:>13.2}%",
            row.size,
            -row.improvement.energy_decrease * 100.0,
            row.improvement.speedup,
            row.improvement.edp_decrease * 100.0
        );
    }
    println!("(paper: +20-23% energy, 1.57-1.73x, EDP -23.26..-28.81%)\n");

    // throughput of the full voltage-sensing simulation path (the RBL
    // discharge transient integration dominates — this is the L3 hot path
    // for voltage schemes)
    let b = Bench::coarse();
    for size in [256usize, 1024] {
        let mut cfg = SimConfig::square(size, SensingScheme::VoltagePrecharged);
        cfg.word_bits = 32;
        let mut e = AdraEngine::new(&cfg);
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 123 }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 77 }).unwrap();
        b.run(&format!("adra/sub/scheme1/{size} (transient)"), || {
            e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap()
        });
    }
}
