//! Ablation benches for the design choices DESIGN.md calls out:
//!   1. wordline asymmetry (V_GREAD1 sweep) -> margins + MC yield;
//!   2. compute-module variant (muxed vs duplicated) -> throughput when a
//!      workload wants add AND sub of the same operands;
//!   3. coordinator batching (max_batch sweep) -> ops/s;
//!   4. bulk-write scheme (two-phase vs FLASH-like) -> pulses + latency.

use std::time::Instant;

use adra::analysis::{bias_ablation, MonteCarlo};
use adra::array::{bulk_write, FefetArray, WriteScheme};
use adra::cim::{AdraEngine, CimOp, Engine, WordAddr};
use adra::config::{DeviceParams, SensingScheme, SimConfig};
use adra::coordinator::Coordinator;
use adra::logic::{AdraComputeModule, ComputeModuleVariant};
use adra::sensing::SenseOut;
use adra::util::bench::{black_box, Bench};
use adra::util::rng::Rng;
use adra::workload::{OpMix, WorkloadGen};

fn main() {
    ablation_bias();
    ablation_module_variant();
    ablation_batching();
    ablation_write_scheme();
    ablation_fusion();
}

fn ablation_fusion() {
    println!("=== ablation 5: activation fusion (coordinator::fuse) ===");
    // query pattern: each operand pair asked for sub AND compare (the
    // database-filter inner loop)
    let mut cfg = SimConfig::square(128, SensingScheme::Current);
    cfg.word_bits = 16;
    let mut ops = Vec::new();
    let mut rng2 = Rng::new(12);
    for _ in 0..64 {
        ops.push(CimOp::Write {
            addr: WordAddr { row: rng2.below(64) as usize, word: 0 },
            value: rng2.below(30_000),
        });
    }
    for i in 0..2000usize {
        let row_a = i % 64;
        let row_b = 64 + (i % 32);
        ops.push(CimOp::Sub { row_a, row_b, word: 0 });
        ops.push(CimOp::Compare { row_a, row_b, word: 0 });
    }
    let mut e1 = AdraEngine::new(&cfg);
    let t0 = Instant::now();
    let mut plain_energy = 0.0;
    for op in &ops {
        if let Ok(r) = e1.execute(op) {
            plain_energy += r.cost.energy.total();
        }
    }
    let t_plain = t0.elapsed().as_secs_f64();
    let plain_act = e1.array().stats().dual_activations;

    let mut e2 = AdraEngine::new(&cfg);
    let t0 = Instant::now();
    let fused = adra::coordinator::fuse::execute_fused(&mut e2, &ops);
    let t_fused = t0.elapsed().as_secs_f64();
    let fused_energy: f64 = fused
        .iter()
        .filter_map(|r| r.as_ref().ok())
        .map(|r| r.cost.energy.total())
        .sum();
    let fused_act = e2.array().stats().dual_activations;

    println!(
        "  unfused: {plain_act} activations, {:.2} nJ, {:.1} ms wall",
        plain_energy * 1e9,
        t_plain * 1e3
    );
    println!(
        "  fused:   {fused_act} activations, {:.2} nJ, {:.1} ms wall",
        fused_energy * 1e9,
        t_fused * 1e3
    );
    println!(
        "  -> {:.2}x fewer activations, {:.1}% modeled energy saved, {:.2}x sim speedup\n",
        plain_act as f64 / fused_act as f64,
        (1.0 - fused_energy / plain_energy) * 100.0,
        t_plain / t_fused
    );
}

fn ablation_bias() {
    println!("=== ablation 1: wordline asymmetry (V_GREAD1) ===");
    let p = DeviceParams::default();
    for b in bias_ablation(&p, 9, 0.02, 1500) {
        println!(
            "  V_GREAD1 {:.3} V | one-to-one {:5} | margin {:8.3} uA | BER {:.2e}",
            b.vg1,
            b.margins.one_to_one,
            b.margins.current_margin * 1e6,
            b.ber
        );
    }
    let mc = MonteCarlo::new(&p);
    println!(
        "  max sigma(V_T) @ BER<=1e-3: {:.1} mV\n",
        mc.max_tolerable_sigma(1e-3, 2000, 1) * 1e3
    );
}

fn ablation_module_variant() {
    println!("=== ablation 2: compute-module variant (Fig 3(d)) ===");
    let muxed = AdraComputeModule::new(ComputeModuleVariant::Muxed);
    let dup = AdraComputeModule::new(ComputeModuleVariant::Duplicated);
    println!(
        "  transistors/module: muxed {} vs duplicated {} (paper: +4T)",
        muxed.gate_counts().total_transistors(),
        dup.gate_counts().total_transistors()
    );
    // workload: need BOTH a+b and a-b per operand pair.  muxed variant
    // must evaluate twice (SELECT flip); duplicated gets both per cycle.
    let sense: Vec<SenseOut> = (0..32)
        .map(|i| {
            let a = i % 3 == 0;
            let b = i % 2 == 0;
            SenseOut { or: a || b, b, and: a && b }
        })
        .collect();
    let bench = Bench::default();
    bench.run("module/muxed add+sub (2 passes)", || {
        let mut cin_a = false;
        let mut cin_s = true;
        for s in &sense {
            let add = muxed.eval(s, cin_a, false);
            cin_a = add.carry;
            let sub = muxed.eval(s, cin_s, true);
            cin_s = sub.carry;
        }
        (cin_a, cin_s)
    });
    bench.run("module/duplicated add+sub (1 pass)", || {
        let mut cin_a = false;
        let mut cin_s = true;
        for s in &sense {
            let (add, sub) = dup.eval_both(s, cin_a, cin_s);
            cin_a = add.carry;
            cin_s = sub.carry;
        }
        (cin_a, cin_s)
    });
    println!();
}

fn ablation_batching() {
    println!("=== ablation 3: coordinator max_batch ===");
    let n_ops = 40_000;
    for max_batch in [1usize, 4, 16, 64, 256] {
        let mut cfg = SimConfig::square(128, SensingScheme::Current);
        cfg.word_bits = 16;
        cfg.max_batch = max_batch;
        let coord = Coordinator::adra(&cfg, 1);
        let mut gen = WorkloadGen::new(&cfg, OpMix::subtraction_heavy(), 3);
        let ops = gen.batch(n_ops);
        let t0 = Instant::now();
        for chunk in ops.chunks(512) {
            black_box(coord.call_batch(0, chunk).unwrap());
        }
        let rate = n_ops as f64 / t0.elapsed().as_secs_f64();
        println!("  max_batch {max_batch:>4}: {rate:>12.0} op/s");
    }
    println!();
}

fn ablation_write_scheme() {
    println!("=== ablation 4: bulk-write scheme ===");
    let mut cfg = SimConfig::square(256, SensingScheme::Current);
    cfg.word_bits = 32;
    let mut rng = Rng::new(9);
    let rows = 64;
    let words = cfg.cols / cfg.word_bits;
    let old: Vec<Vec<u64>> = (0..rows)
        .map(|_| (0..words).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect();
    let img: Vec<Vec<u64>> = (0..rows)
        .map(|_| (0..words).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect();
    for scheme in [WriteScheme::TwoPhase, WriteScheme::FlashLike] {
        let mut arr = FefetArray::new(&cfg);
        bulk_write(&mut arr, 0, &old, WriteScheme::TwoPhase);
        let t0 = Instant::now();
        let rep = bulk_write(&mut arr, 0, &img, scheme);
        println!(
            "  {scheme:?}: {} row pulses, {} cells switched, modeled {:.2} us, sim wall {:.1} ms",
            rep.row_pulses,
            rep.cells_switched,
            rep.latency * 1e6,
            t0.elapsed().as_secs_f64() * 1e3
        );
    }
    println!();

    // sanity workload: engine still answers correctly after bulk loads
    let mut e = AdraEngine::new(&cfg);
    e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 5 }).unwrap();
    e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 3 }).unwrap();
    let r = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
    assert_eq!(r.value, adra::cim::CimValue::Diff(2));
}
