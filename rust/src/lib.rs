//! # ADRA — Asymmetric Dual-Row-Activation computing-in-memory
//!
//! Full-stack reproduction of *"ADRA: Extending Digital Computing-in-Memory
//! with Asymmetric Dual-Row-Activation"* (Malhotra, Saha, Wang, Gupta —
//! Purdue, 2022).
//!
//! Architecture (see DESIGN.md):
//! * **L1/L2 (build-time Python)** — JAX + Pallas analog model of the
//!   1T-FeFET array, AOT-lowered to HLO text under `artifacts/`.
//! * **L3 (this crate)** — everything digital and architectural: the
//!   behavioral device mirror, array state, sensing periphery, gate-level
//!   compute modules, the calibrated energy/latency model, the ADRA and
//!   baseline CiM engines, and a threaded request coordinator.  The
//!   `runtime` module executes the AOT artifacts over PJRT (CPU) — Python
//!   is never on the request path.
//! * **Planner (`planner`)** — the query layer above the engines: a tiny
//!   program IR for bulk bitwise/arithmetic column programs, calibrated
//!   ADRA-vs-baseline cost tables, per-op executor routing, and
//!   shard-aware placement over the coordinator pool with
//!   predicted-vs-measured cost reporting.
//! * **Serving layer (`serve`)** — multi-tenant admission in front of the
//!   planner: cross-program batch coalescing, write dedup, fused shard
//!   execution through the pool, and a versioned result cache, with
//!   queue/fusion/cache/per-tenant observability.  Its control plane
//!   (`serve::control`) adds weighted fair queueing with per-tenant
//!   quotas, an EWMA-adaptive round size with a p95 target, and
//!   size-aware LRU + negative-result caching.
//! * **Observability (`observe`)** — the unified telemetry layer: a
//!   thread-safe metric registry (counters / gauges / log-bucketed
//!   histograms with stable dotted names and label sets), Prometheus
//!   text-format + JSON exposition, and a trace-span flight recorder
//!   over the serve pipeline and the kernel tier boundary.  The serve
//!   queue, coordinator run metrics, array stats, and planner
//!   predicted-vs-measured errors all publish into it.

pub mod analysis;
pub mod array;
pub mod cim;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod energy;
pub mod faults;
pub mod figures;
pub mod logic;
pub mod metrics;
pub mod observe;
pub mod planner;
pub mod runtime;
pub mod sensing;
pub mod serve;
pub mod store;
pub mod util;
pub mod workload;
