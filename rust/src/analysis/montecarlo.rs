//! Monte-Carlo variability analysis.
//!
//! The paper (§II.B) flags device variability as the key FeFET challenge.
//! ADRA is *more* exposed than a plain read: four I_SL levels share the
//! window that a read splits in two, so the same sigma(V_T) eats 3x the
//! margin.  This module quantifies that: sample per-cell V_T offsets,
//! push each input vector through the full sensing path, and report the
//! bit-error rate per vector, the total yield, and the maximum sigma that
//! keeps BER below a target.

use crate::config::DeviceParams;
use crate::device;
use crate::sensing::{CurrentRefs, CurrentSenseBank, SenseOut};
use crate::util::rng::Rng;

/// Result of one Monte-Carlo campaign at a fixed sigma.
#[derive(Clone, Debug)]
pub struct McReport {
    pub sigma_vt: f64,
    pub samples: usize,
    /// decode errors per input vector (A,B) indexed by (a<<1)|b.
    pub errors: [usize; 4],
    /// single-row read errors (for comparison: ADRA vs plain read).
    pub read_errors: usize,
}

impl McReport {
    /// Overall CiM bit-error rate across the four vectors.
    pub fn ber(&self) -> f64 {
        self.errors.iter().sum::<usize>() as f64 / (4 * self.samples) as f64
    }

    pub fn read_ber(&self) -> f64 {
        self.read_errors as f64 / (2 * self.samples) as f64
    }
}

/// Monte-Carlo engine over the behavioral device model.
pub struct MonteCarlo {
    params: DeviceParams,
    bank: CurrentSenseBank,
}

impl MonteCarlo {
    pub fn new(params: &DeviceParams) -> Self {
        Self {
            params: params.clone(),
            bank: CurrentSenseBank::new(CurrentRefs::derive(
                params,
                params.v_gread1,
                params.v_gread2,
            )),
        }
    }

    /// Run a campaign: `samples` random cell pairs per input vector.
    pub fn run(&self, sigma_vt: f64, samples: usize, seed: u64) -> McReport {
        self.run_with_sa_offset(sigma_vt, 0.0, samples, seed)
    }

    /// Campaign including input-referred sense-amplifier offset: each SA's
    /// reference is displaced by a normal current offset (expressed as a
    /// fraction of the worst-case level margin).  SA offset and cell V_T
    /// variation are the two dominant mismatch sources in a real macro.
    pub fn run_with_sa_offset(
        &self,
        sigma_vt: f64,
        sa_offset_frac: f64,
        samples: usize,
        seed: u64,
    ) -> McReport {
        let mut rng = Rng::new(seed);
        let mut errors = [0usize; 4];
        let mut read_errors = 0usize;
        let p = &self.params;
        // offset scale: fraction of the smallest inter-level gap
        let levels = {
            let mut l = crate::device::isl_levels(p, p.v_gread1, p.v_gread2).to_vec();
            l.sort_by(|a, b| a.partial_cmp(b).unwrap());
            l
        };
        let min_gap = levels.windows(2).map(|w| w[1] - w[0]).fold(f64::MAX, f64::min);
        let sa_sigma = sa_offset_frac * min_gap;
        for _ in 0..samples {
            let dvt_a = rng.normal() * sigma_vt;
            let dvt_b = rng.normal() * sigma_vt;
            let bank = if sa_sigma > 0.0 {
                let mut refs = self.bank.refs;
                refs.i_ref_or += rng.normal() * sa_sigma;
                refs.i_ref_b += rng.normal() * sa_sigma;
                refs.i_ref_and += rng.normal() * sa_sigma;
                CurrentSenseBank::new(refs)
            } else {
                self.bank
            };
            for a in [false, true] {
                for b in [false, true] {
                    let isl = device::senseline_current(
                        p,
                        p.pol_of_bit(a),
                        p.pol_of_bit(b),
                        p.v_gread1,
                        p.v_gread2,
                        p.v_read,
                        dvt_a,
                        dvt_b,
                    );
                    let out = bank.sense(isl);
                    if out != (SenseOut { or: a || b, b, and: a && b }) {
                        errors[((a as usize) << 1) | b as usize] += 1;
                    }
                }
            }
            // plain single-row read of each state with the same offset
            for bit in [false, true] {
                let i = device::cell_current(p, p.v_gread2, p.v_read, p.pol_of_bit(bit), dvt_a);
                if self.bank.sense_read(i) != bit {
                    read_errors += 1;
                }
            }
        }
        McReport { sigma_vt, samples, errors, read_errors }
    }

    /// Vectorized campaign through the AOT `dc_isl` artifact over PJRT:
    /// the per-cell V_T variation planes go straight into the JAX/Pallas
    /// device model, 1024 sampled columns per executable call.  This is
    /// the Monte-Carlo path a real sign-off flow would use (analog ground
    /// truth), and it must agree with the behavioral campaign.
    pub fn run_pjrt(
        &self,
        rt: &crate::runtime::AnalogRuntime,
        sigma_vt: f64,
        samples: usize,
        seed: u64,
    ) -> anyhow::Result<McReport> {
        use crate::config::N_COLS;
        let p = &self.params;
        let mut rng = Rng::new(seed);
        let mut errors = [0usize; 4];
        let mut done = 0usize;
        while done < samples {
            let n = (samples - done).min(N_COLS);
            let dvt_a: Vec<f32> =
                (0..N_COLS).map(|_| (rng.normal() * sigma_vt) as f32).collect();
            let dvt_b: Vec<f32> =
                (0..N_COLS).map(|_| (rng.normal() * sigma_vt) as f32).collect();
            for a in [false, true] {
                for b in [false, true] {
                    let pol_a = vec![p.pol_of_bit(a) as f32; N_COLS];
                    let pol_b = vec![p.pol_of_bit(b) as f32; N_COLS];
                    let (isl, _, _) = rt.dc_isl(
                        &pol_a, &pol_b, &dvt_a, &dvt_b,
                        p.v_gread1 as f32, p.v_gread2 as f32,
                    )?;
                    let want = SenseOut { or: a || b, b, and: a && b };
                    for &i in isl.iter().take(n) {
                        if self.bank.sense(i as f64) != want {
                            errors[((a as usize) << 1) | b as usize] += 1;
                        }
                    }
                }
            }
            done += n;
        }
        Ok(McReport { sigma_vt, samples: done, errors, read_errors: 0 })
    }

    /// Largest sigma (by bisection over `steps` halvings, granularity-
    /// limited) with campaign BER <= `target_ber`.
    pub fn max_tolerable_sigma(
        &self,
        target_ber: f64,
        samples: usize,
        seed: u64,
    ) -> f64 {
        let (mut lo, mut hi) = (0.0f64, 0.3f64);
        for _ in 0..12 {
            let mid = 0.5 * (lo + hi);
            let rep = self.run(mid, samples, seed);
            if rep.ber() <= target_ber {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> MonteCarlo {
        MonteCarlo::new(&DeviceParams::default())
    }

    #[test]
    fn zero_sigma_is_error_free() {
        let rep = mc().run(0.0, 500, 1);
        assert_eq!(rep.errors, [0, 0, 0, 0]);
        assert_eq!(rep.read_errors, 0);
        assert_eq!(rep.ber(), 0.0);
    }

    #[test]
    fn small_sigma_stays_clean_huge_sigma_fails() {
        let rep_small = mc().run(0.01, 500, 2);
        assert_eq!(rep_small.ber(), 0.0, "10 mV sigma must be safe");
        let rep_big = mc().run(0.25, 500, 3);
        assert!(rep_big.ber() > 0.01, "250 mV sigma must break sensing");
    }

    #[test]
    fn ber_monotone_in_sigma() {
        let m = mc();
        let b1 = m.run(0.03, 2000, 4).ber();
        let b2 = m.run(0.08, 2000, 4).ber();
        let b3 = m.run(0.15, 2000, 4).ber();
        assert!(b1 <= b2 && b2 <= b3, "{b1} {b2} {b3}");
    }

    #[test]
    fn adra_more_sensitive_than_plain_read() {
        // the 4-level window is tighter than the 2-level read window, so
        // at a sigma where CiM starts failing, plain reads should be
        // no worse
        let m = mc();
        let rep = m.run(0.08, 4000, 5);
        assert!(rep.ber() >= rep.read_ber(), "CiM {} vs read {}", rep.ber(), rep.read_ber());
    }

    #[test]
    fn sa_offset_adds_to_the_error_budget() {
        let m = mc();
        let without = m.run_with_sa_offset(0.05, 0.0, 3000, 9).ber();
        let with = m.run_with_sa_offset(0.05, 0.25, 3000, 9).ber();
        assert!(with >= without, "SA offset must not reduce BER: {with} vs {without}");
        // a quarter-gap SA sigma alone must start producing errors
        let only_sa = m.run_with_sa_offset(0.0, 0.35, 3000, 10).ber();
        assert!(only_sa > 0.0, "35%-gap SA offset must cause errors");
    }

    #[test]
    fn zero_sa_offset_is_identical_to_plain_run() {
        let m = mc();
        let a = m.run(0.04, 1500, 11);
        let b = m.run_with_sa_offset(0.04, 0.0, 1500, 11);
        assert_eq!(a.errors, b.errors);
    }

    #[test]
    fn tolerable_sigma_is_reasonable() {
        let m = mc();
        let s = m.max_tolerable_sigma(1e-3, 800, 6);
        // tens of millivolts: enough for a real HZO process corner, far
        // below the half-window
        assert!(s > 0.01, "sigma {s} too pessimistic");
        assert!(s < 0.15, "sigma {s} implausibly robust");
    }

    #[test]
    fn middle_levels_fail_first() {
        // (1,0) and (0,1) sit between two references; (0,0)/(1,1) have a
        // reference on only one side, so the middle vectors dominate the
        // error budget at moderate sigma
        let m = mc();
        let rep = m.run(0.1, 6000, 7);
        let mid = rep.errors[0b01] + rep.errors[0b10];
        let edge = rep.errors[0b00] + rep.errors[0b11];
        assert!(mid >= edge, "mid {mid} edge {edge}");
    }
}
