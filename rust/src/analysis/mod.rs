//! Analysis tools beyond the paper's headline figures: Monte-Carlo
//! variability / yield (the FeFET variability challenge of §II.B), and
//! the bias-point ablation behind the V_GREAD1 choice.

pub mod ablation;
pub mod corners;
pub mod montecarlo;

pub use ablation::{bias_ablation, BiasPoint};
pub use corners::{params_at, temperature_sweep, CornerReport};
pub use montecarlo::{McReport, MonteCarlo};
