//! Bias-point ablation: why V_GREAD1 = 0.83 V?
//!
//! Sweeps the asymmetric wordline bias and evaluates, at each point, the
//! four-level separation, the worst margin, and Monte-Carlo BER at a
//! process-typical sigma.  The paper picks the bias for >1 uA / >50 mV
//! margins; this ablation shows the full trade-off curve: too close to
//! V_GREAD2 collapses (1,0)/(0,1), too low collapses (1,0) into (0,0).

use crate::config::DeviceParams;
use crate::sensing::MarginReport;

use super::montecarlo::MonteCarlo;

/// One swept bias point.
#[derive(Clone, Debug)]
pub struct BiasPoint {
    pub vg1: f64,
    pub margins: MarginReport,
    /// Monte-Carlo BER at the probe sigma.
    pub ber: f64,
}

/// Sweep V_GREAD1 in `steps` points over (0.5 V .. V_GREAD2), probing BER
/// at `sigma_vt`.
pub fn bias_ablation(
    p: &DeviceParams,
    steps: usize,
    sigma_vt: f64,
    samples: usize,
) -> Vec<BiasPoint> {
    let c_rbl = 1024.0 * p.c_rbl_cell;
    (0..steps)
        .map(|i| {
            let vg1 = 0.5 + (p.v_gread2 - 0.5) * i as f64 / (steps - 1) as f64;
            let mut pp = p.clone();
            pp.v_gread1 = vg1;
            let mc = MonteCarlo::new(&pp);
            BiasPoint {
                vg1,
                margins: MarginReport::evaluate(&pp, vg1, pp.v_gread2, c_rbl),
                ber: mc.run(sigma_vt, samples, 0xB1A5).ber(),
            }
        })
        .collect()
}

/// The bias with the best worst-case current margin (the "optimal"
/// asymmetry for this device corner).
pub fn best_bias(points: &[BiasPoint]) -> &BiasPoint {
    points
        .iter()
        .filter(|b| b.margins.one_to_one)
        .max_by(|a, b| {
            a.margins
                .current_margin
                .partial_cmp(&b.margins.current_margin)
                .unwrap()
        })
        .expect("at least one viable bias point")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shows_both_failure_modes() {
        let p = DeviceParams::default();
        let pts = bias_ablation(&p, 12, 0.02, 300);
        // near-symmetric end must fail one-to-one
        assert!(!pts.last().unwrap().margins.one_to_one);
        // somewhere in the middle must be viable
        assert!(pts.iter().any(|b| b.margins.meets_paper_targets()));
    }

    #[test]
    fn paper_bias_is_near_optimal() {
        let p = DeviceParams::default();
        let pts = bias_ablation(&p, 24, 0.02, 200);
        let best = best_bias(&pts);
        // the paper's 0.83 V should be within 150 mV of the sweep optimum
        assert!(
            (best.vg1 - p.v_gread1).abs() < 0.15,
            "optimum {} vs paper {}",
            best.vg1,
            p.v_gread1
        );
    }

    #[test]
    fn paper_bias_point_is_robust() {
        // statically-viable but *marginal* bias points (e.g. vg1 ~ 0.5 V)
        // can still fail under variation; the paper's operating point must
        // be clean at a process-typical 20 mV sigma
        let p = DeviceParams::default();
        let mc = MonteCarlo::new(&p);
        let ber = mc.run(0.02, 3000, 0xB1A5).ber();
        assert!(ber < 1e-3, "paper bias BER {ber}");
    }

    #[test]
    fn ber_separates_comfortable_from_marginal_biases() {
        let p = DeviceParams::default();
        let pts = bias_ablation(&p, 10, 0.02, 500);
        let best = best_bias(&pts);
        // the best-margin point must have lower (or equal) BER than every
        // statically-viable-but-marginal point
        for b in pts.iter().filter(|b| b.margins.one_to_one) {
            assert!(best.ber <= b.ber + 1e-9, "best {} vs {} at {}", best.ber, b.ber, b.vg1);
        }
    }
}
