//! Temperature-corner analysis (Rust-side; the AOT artifacts stay at the
//! 300 K calibration point, so cross-validation is unaffected).
//!
//! Temperature enters the sensing problem through two first-order effects:
//! the thermal voltage phi_t = kT/q flattens the subthreshold slope (HRS
//! leakage grows fast with T), and the threshold voltage drops roughly
//! linearly (~ -1 mV/K around 300 K for a 45 nm-class stack).  Both
//! squeeze the I00 <-> I10 margin from below.  This module derives corner
//! device parameters and re-evaluates the Fig. 3 margins and Monte-Carlo
//! yield across the industrial temperature range.

use crate::config::DeviceParams;
use crate::sensing::MarginReport;

use super::montecarlo::MonteCarlo;

/// Boltzmann/charge ratio in V/K.
const K_OVER_Q: f64 = 8.617_333e-5;
/// Threshold temperature coefficient (V/K), magnitude typical of 45 nm.
const DVT_DT: f64 = -1.0e-3;
/// Reference temperature of the calibration (K).
const T_REF: f64 = 300.0;

/// Industrial temperature range endpoints + room temperature.
pub const INDUSTRIAL_TEMPS: [f64; 5] = [233.0, 273.0, 300.0, 358.0, 398.0];

/// Derive device parameters at temperature `t_kelvin`.
pub fn params_at(p: &DeviceParams, t_kelvin: f64) -> DeviceParams {
    let mut out = p.clone();
    out.phi_t = K_OVER_Q * t_kelvin;
    out.vt0 = p.vt0 + DVT_DT * (t_kelvin - T_REF);
    out
}

/// One temperature corner's evaluation.
#[derive(Clone, Debug)]
pub struct CornerReport {
    pub t_kelvin: f64,
    pub margins: MarginReport,
    /// Monte-Carlo BER at the probe sigma.
    pub ber: f64,
}

/// Evaluate margins + MC yield at each temperature.
pub fn temperature_sweep(
    p: &DeviceParams,
    temps: &[f64],
    sigma_vt: f64,
    samples: usize,
) -> Vec<CornerReport> {
    temps
        .iter()
        .map(|&t| {
            let pt = params_at(p, t);
            let mc = MonteCarlo::new(&pt);
            CornerReport {
                t_kelvin: t,
                margins: MarginReport::evaluate(
                    &pt,
                    pt.v_gread1,
                    pt.v_gread2,
                    1024.0 * pt.c_rbl_cell,
                ),
                ber: mc.run(sigma_vt, samples, 0x7E39).ber(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_point_is_identity() {
        let p = DeviceParams::default();
        let p300 = params_at(&p, T_REF);
        assert!((p300.phi_t - 0.025852).abs() < 1e-4);
        assert_eq!(p300.vt0, p.vt0);
    }

    #[test]
    fn margins_degrade_with_temperature() {
        let p = DeviceParams::default();
        let sweep = temperature_sweep(&p, &INDUSTRIAL_TEMPS, 0.0, 1);
        // the worst current margin shrinks monotonically with T: hotter
        // subthreshold leaks more, pushing I00 up toward I10
        let margins: Vec<f64> = sweep.iter().map(|c| c.margins.current_margin).collect();
        for w in margins.windows(2) {
            assert!(w[1] <= w[0] * 1.02, "margin grew with T: {margins:?}");
        }
    }

    #[test]
    fn sensing_works_across_the_industrial_range() {
        let p = DeviceParams::default();
        for c in temperature_sweep(&p, &INDUSTRIAL_TEMPS, 0.0, 200) {
            assert!(c.margins.one_to_one, "one-to-one lost at {} K", c.t_kelvin);
            assert!(
                c.margins.meets_paper_targets(),
                "margins lost at {} K: {:?}",
                c.t_kelvin,
                c.margins
            );
            assert_eq!(c.ber, 0.0, "sigma=0 decode errors at {} K", c.t_kelvin);
        }
    }

    #[test]
    fn hot_corner_is_more_variation_sensitive() {
        let p = DeviceParams::default();
        let cold = temperature_sweep(&p, &[233.0], 0.06, 3000)[0].ber;
        let hot = temperature_sweep(&p, &[398.0], 0.06, 3000)[0].ber;
        assert!(hot >= cold, "hot {hot} vs cold {cold}");
    }
}
