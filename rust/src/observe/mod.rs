//! Unified observability layer: metric registry, Prometheus-style
//! exposition, and the trace-span flight recorder (DESIGN.md §11).
//!
//! Everything the stack measures flows through here so the scrape
//! surface is one endpoint instead of N report strings:
//!
//! * [`registry`] — [`Registry`]: thread-safe families of atomic
//!   [`Counter`]s, [`Gauge`]s, and log-bucketed [`Histogram`]s (same
//!   bucket semantics as `metrics::LatencyHistogram`), keyed by stable
//!   dotted names and label sets (`queue`, `tenant`, `shard`, `tier`,
//!   `op_class`).
//! * [`expose`] — [`expose_text`] (Prometheus text format 0.0.4) and
//!   [`expose_json`] snapshots, surfaced through the REPL (`metrics`
//!   command) and `examples/serving.rs`.
//! * [`trace`] — [`FlightRecorder`]: a fixed-capacity ring buffer of
//!   serve-pipeline spans (admit -> schedule -> coalesce -> fuse ->
//!   execute -> cache), kernel-tier activation events, and health-rule
//!   alerts, exported as JSONL for postmortems.
//! * [`series`] — [`SeriesStore`]: a bounded ring of registry samples
//!   per metric series (one point per serve round), plus the pure
//!   windowed derivations (rates, EWMAs, drift slopes, histogram-delta
//!   percentiles) built on it.
//! * [`health`] — [`HealthEngine`]: declarative [`HealthRule`]s over
//!   the series store (SLO burn, drift, starvation...), with hysteresis
//!   bounding flapping; transitions alert into the recorder and publish
//!   `adra.health.status{rule}` back into the registry.
//!
//! Producers migrated onto the registry: `serve::ServeMetrics`
//! (`publish`), the coordinator's `metrics::RunMetrics` and
//! `array::ArrayStats` (`RunMetrics::publish`), the serve control plane
//! (`FairScheduler` / `BatchController` counters ride the `ServeMetrics`
//! publish), and the planner's predicted-vs-measured error, which
//! `planner::Placement::assemble` records per op class into
//! `adra.planner.prediction_error` — the persistent signal the future
//! adaptive cost model (ROADMAP open item 1) reads.
//!
//! Observation only: nothing here alters modeled hardware costs or
//! results — the serve/tier equivalence suites run bit-identical with
//! instrumentation enabled.

pub mod expose;
pub mod health;
pub mod registry;
pub mod series;
pub mod trace;

pub use expose::{expose_json, expose_text, sanitize_name};
pub use health::{
    standard_engine, standard_rules, Direction, HealthEngine, HealthRule, RuleState, Signal,
    Transition,
};
pub use registry::{Counter, FamilySnapshot, Gauge, Histogram, LabelSet, MetricKind, Registry};
pub use series::{SamplePoint, SampleValue, SeriesStore};
pub use trace::{FlightRecorder, KernelRoute, Recorded, Stage, TraceEvent};

use std::sync::{Mutex, OnceLock};

/// The process-wide default registry — what the REPL and the examples
/// scrape.  Producers default here; tests that need isolation construct
/// their own [`Registry`].
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// The process-wide flight recorder (span events on, kernel events off
/// by default — see `trace`).
pub fn recorder() -> &'static FlightRecorder {
    static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
    RECORDER.get_or_init(FlightRecorder::default)
}

/// The process-wide time-series store the serve scheduler samples into
/// each round and the health engine reads (see `series`).
pub fn series() -> &'static SeriesStore {
    static SERIES: OnceLock<SeriesStore> = OnceLock::new();
    SERIES.get_or_init(SeriesStore::default)
}

/// The process-wide health engine, preloaded with the standard ADRA
/// rule set (`health::standard_rules`).  Behind a mutex: evaluation
/// mutates hysteresis streaks and is called from the serve scheduler
/// thread and the REPL.
pub fn health() -> &'static Mutex<HealthEngine> {
    static HEALTH: OnceLock<Mutex<HealthEngine>> = OnceLock::new();
    HEALTH.get_or_init(|| Mutex::new(standard_engine()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_instances_are_stable() {
        let a = global() as *const Registry;
        let b = global() as *const Registry;
        assert_eq!(a, b);
        let c = recorder() as *const FlightRecorder;
        let d = recorder() as *const FlightRecorder;
        assert_eq!(c, d);
        let e = series() as *const SeriesStore;
        let f = series() as *const SeriesStore;
        assert_eq!(e, f);
        assert!(health().lock().unwrap().rule_count() >= 7, "standard rules preloaded");
    }
}
