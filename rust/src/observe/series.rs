//! Fixed-capacity time-series store over [`Registry`] snapshots, plus
//! the pure windowed derivations the health engine consumes.
//!
//! A [`SeriesStore`] keeps the last `capacity` sample points per metric
//! series (a ring per `(name, labels)` key).  `sample()` walks a
//! registry snapshot and appends one point per series; the serve
//! scheduler calls it once per round (`ServeConfig::sample_every`) and
//! the REPL / examples call it at whatever cadence they like.  Points
//! carry the raw cumulative values — counters, gauge readings, full
//! histogram state — so every derivation is a *pure function over a
//! window of points*, recomputable after the fact and trivially
//! unit-testable via [`SeriesStore::ingest`].
//!
//! Windows are specified in POINTS (trailing sample count), not wall
//! time: the serve loop samples per round, so "the last 8 rounds" is the
//! natural unit, and tests stay deterministic with synthetic timestamps.
//! Rates and slopes still divide by the wall-time delta between the
//! window's endpoints (`t_us`), so their units are per-second.
//!
//! Why cumulative points instead of pre-derived rates: the adaptive cost
//! model (ROADMAP item 1) and the health rules want *different* windows
//! over the *same* history; storing raw points lets each consumer pick
//! its own.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::LatencyHistogram;

use super::registry::{LabelSet, Registry, Series};

/// One sampled value: the cumulative state of a series at an instant.
#[derive(Clone, Debug, PartialEq)]
pub enum SampleValue {
    Counter(u64),
    Gauge(f64),
    /// Full cumulative histogram state; derivations subtract two points
    /// to get the distribution of *just the window*.
    Histogram { count: u64, sum: f64, buckets: Vec<u64> },
}

/// A timestamped sample.  `t_us` is microseconds since the store's
/// epoch (monotonic, process-relative).
#[derive(Clone, Debug)]
pub struct SamplePoint {
    pub t_us: u64,
    pub value: SampleValue,
}

/// Default ring depth per series: at one sample per serve round this is
/// ~512 rounds of history, far beyond the widest standard rule window.
pub const DEFAULT_CAPACITY: usize = 512;

/// The store: a bounded ring of [`SamplePoint`]s per `(name, labels)`.
pub struct SeriesStore {
    epoch: Instant,
    capacity: usize,
    inner: Mutex<BTreeMap<(String, LabelSet), VecDeque<SamplePoint>>>,
}

impl Default for SeriesStore {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }
}

impl SeriesStore {
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            epoch: Instant::now(),
            capacity: capacity.max(2),
            inner: Mutex::new(BTreeMap::new()),
        }
    }

    /// Ring depth per series.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of distinct series currently tracked.
    pub fn series_count(&self) -> usize {
        self.inner.lock().expect("series lock").len()
    }

    /// Total points held across all series.
    pub fn point_count(&self) -> usize {
        self.inner.lock().expect("series lock").values().map(|r| r.len()).sum()
    }

    fn push(&self, key: (String, LabelSet), point: SamplePoint) {
        let mut inner = self.inner.lock().expect("series lock");
        let ring = inner.entry(key).or_default();
        while ring.len() >= self.capacity {
            ring.pop_front();
        }
        ring.push_back(point);
    }

    /// Sample every series in `registry` at "now".  One point per
    /// series; cheap relative to a scrape (no string rendering).
    pub fn sample(&self, registry: &Registry) {
        let t_us = self.epoch.elapsed().as_micros() as u64;
        for fam in registry.snapshot() {
            for (labels, series) in &fam.series {
                let value = match series {
                    Series::Counter(c) => SampleValue::Counter(c.get()),
                    Series::Gauge(g) => SampleValue::Gauge(g.get()),
                    Series::Histogram(h) => SampleValue::Histogram {
                        count: h.count(),
                        sum: h.sum(),
                        buckets: h.bucket_counts(),
                    },
                };
                self.push((fam.name.clone(), labels.clone()), SamplePoint { t_us, value });
            }
        }
    }

    /// Inject a synthetic point (tests and offline replay): same ring
    /// semantics as `sample`, caller controls the clock.
    pub fn ingest(&self, name: &str, labels: &[(&str, &str)], t_us: u64, value: SampleValue) {
        let mut key: LabelSet =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        key.sort();
        self.push((name.to_string(), key), SamplePoint { t_us, value });
    }

    /// All series under `name` whose label set is a SUPERSET of
    /// `labels` (so `&[]` matches every series of the family, and
    /// `&[("op_class", "dual")]` matches regardless of other labels).
    /// Points are oldest-first.
    pub fn matching(
        &self,
        name: &str,
        labels: &[(&str, &str)],
    ) -> Vec<(LabelSet, Vec<SamplePoint>)> {
        let inner = self.inner.lock().expect("series lock");
        inner
            .iter()
            .filter(|((n, ls), _)| {
                n == name
                    && labels
                        .iter()
                        .all(|(k, v)| ls.iter().any(|(lk, lv)| lk == k && lv == v))
            })
            .map(|((_, ls), ring)| (ls.clone(), ring.iter().cloned().collect()))
            .collect()
    }

    /// Drop all history (REPL / test hygiene).
    pub fn clear(&self) {
        self.inner.lock().expect("series lock").clear();
    }
}

// ---------------------------------------------------------------------------
// Windowed derivations: pure functions over oldest-first point slices.
// `window` is a trailing POINT count; a window of `n` uses the last
// `n + 1` points (n deltas).  All return `None` when the slice cannot
// support the computation — under-populated ring, zero wall-time delta,
// wrong sample kind — so rules skip rather than misfire during warmup.
// ---------------------------------------------------------------------------

/// The trailing `n + 1` points (n intervals), or fewer if the ring is
/// still filling.
fn tail(points: &[SamplePoint], window: usize) -> &[SamplePoint] {
    let take = (window + 1).min(points.len());
    &points[points.len() - take..]
}

fn as_counter(p: &SamplePoint) -> Option<u64> {
    match p.value {
        SampleValue::Counter(v) => Some(v),
        _ => None,
    }
}

fn as_gauge(p: &SamplePoint) -> Option<f64> {
    match p.value {
        SampleValue::Gauge(v) => Some(v),
        _ => None,
    }
}

fn dt_seconds(first: &SamplePoint, last: &SamplePoint) -> Option<f64> {
    let dt = last.t_us.saturating_sub(first.t_us) as f64 * 1e-6;
    if dt > 0.0 { Some(dt) } else { None }
}

/// Increase of a cumulative counter over the window (saturating: a
/// counter reset to a smaller value reads as zero delta, not underflow).
pub fn counter_delta(points: &[SamplePoint], window: usize) -> Option<u64> {
    let w = tail(points, window);
    if w.len() < 2 {
        return None;
    }
    Some(as_counter(w.last()?)?.saturating_sub(as_counter(w.first()?)?))
}

/// Counter rate over the window, per second.
pub fn counter_rate(points: &[SamplePoint], window: usize) -> Option<f64> {
    let w = tail(points, window);
    if w.len() < 2 {
        return None;
    }
    let delta = as_counter(w.last()?)?.saturating_sub(as_counter(w.first()?)?);
    Some(delta as f64 / dt_seconds(w.first()?, w.last()?)?)
}

/// Exponentially-weighted moving average of a gauge over the window
/// (seeded at the window's first value; `alpha` is the new-sample
/// weight).  `abs` smooths `|v|` — signed errors must not cancel.
pub fn gauge_ewma(points: &[SamplePoint], window: usize, alpha: f64, abs: bool) -> Option<f64> {
    let w = tail(points, window);
    let mut vals = w.iter().filter_map(as_gauge).map(|v| if abs { v.abs() } else { v });
    let mut ewma = vals.next()?;
    for v in vals {
        ewma += alpha * (v - ewma);
    }
    Some(ewma)
}

/// Min and max of a gauge over the window.
pub fn gauge_min_max(points: &[SamplePoint], window: usize) -> Option<(f64, f64)> {
    let w = tail(points, window);
    let mut it = w.iter().filter_map(as_gauge);
    let first = it.next()?;
    let (mut lo, mut hi) = (first, first);
    for v in it {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    Some((lo, hi))
}

/// Drift detector: per-second slope of the EWMA-smoothed gauge across
/// the window — `(smoothed_end - start) / dt`.  Smoothing first means a
/// single noisy sample cannot fake a drift; a sustained trend survives
/// it.
pub fn ewma_slope(points: &[SamplePoint], window: usize, alpha: f64, abs: bool) -> Option<f64> {
    let w = tail(points, window);
    // the EWMA folds gauge points only, so the slope denominator must be
    // the gauge sub-series' span — mixed-type series (counters sampled
    // into the same window) must not dilate dt
    let gauges: Vec<&SamplePoint> = w
        .iter()
        .filter(|p| matches!(p.value, SampleValue::Gauge(_)))
        .collect();
    if gauges.len() < 2 {
        return None;
    }
    let mut vals = gauges
        .iter()
        .filter_map(|p| as_gauge(p))
        .map(|v| if abs { v.abs() } else { v });
    let start = vals.next()?;
    let mut ewma = start;
    for v in vals {
        ewma += alpha * (v - ewma);
    }
    Some((ewma - start) / dt_seconds(gauges.first()?, gauges.last()?)?)
}

/// Bucket-wise increase of a cumulative histogram over the window:
/// `(delta_count, delta_buckets)`.
fn histogram_delta(points: &[SamplePoint], window: usize) -> Option<(u64, Vec<u64>)> {
    let w = tail(points, window);
    if w.len() < 2 {
        return None;
    }
    let (first, last) = (w.first()?, w.last()?);
    match (&first.value, &last.value) {
        (
            SampleValue::Histogram { count: c0, buckets: b0, .. },
            SampleValue::Histogram { count: c1, buckets: b1, .. },
        ) => {
            let buckets: Vec<u64> = b1
                .iter()
                .zip(b0.iter())
                .map(|(n, o)| n.saturating_sub(*o))
                .collect();
            Some((c1.saturating_sub(*c0), buckets))
        }
        _ => None,
    }
}

/// p95 of the samples recorded DURING the window, from histogram bucket
/// deltas.  Resolution is the log-bucket grid: returns the upper bound
/// of the bucket holding the 95th percentile (the open-ended last bucket
/// reports its lower bound).  `None` if no samples landed in the window.
pub fn delta_p95_ns(points: &[SamplePoint], window: usize) -> Option<f64> {
    let (count, buckets) = histogram_delta(points, window)?;
    if count == 0 {
        return None;
    }
    let target = (count as f64 * 0.95).ceil() as u64;
    let mut cum = 0u64;
    for (i, b) in buckets.iter().enumerate() {
        cum = cum.saturating_add(*b);
        if cum >= target {
            let (lo, hi) = LatencyHistogram::bucket_bounds(i);
            return Some(if hi.is_finite() { hi } else { lo });
        }
    }
    None
}

/// Fraction of window samples that violated `threshold_ns`, from bucket
/// deltas.  Buckets entirely above the threshold (`lo >= threshold`)
/// count fully; a bucket that straddles the threshold is apportioned
/// linearly by the fraction of its span above the threshold (samples are
/// assumed uniform within a bucket).  The open-ended last bucket counts
/// fully whenever it overlaps the threshold — there is no finite span to
/// apportion, so it stays conservative.
pub fn violation_fraction(points: &[SamplePoint], window: usize, threshold_ns: f64) -> Option<f64> {
    let (count, buckets) = histogram_delta(points, window)?;
    if count == 0 {
        return None;
    }
    let mut violating = 0.0f64;
    for (i, b) in buckets.iter().enumerate() {
        let (lo, hi) = LatencyHistogram::bucket_bounds(i);
        if lo >= threshold_ns {
            // fully above the threshold
            violating += *b as f64;
        } else if hi > threshold_ns {
            if hi.is_finite() {
                // straddling bucket: apportion by span above threshold
                violating += *b as f64 * (hi - threshold_ns) / (hi - lo);
            } else {
                // open-ended tail overlapping the threshold: conservative
                violating += *b as f64;
            }
        }
    }
    Some((violating / count as f64).clamp(0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counter_points(vals: &[(u64, u64)]) -> Vec<SamplePoint> {
        vals.iter()
            .map(|&(t_us, v)| SamplePoint { t_us, value: SampleValue::Counter(v) })
            .collect()
    }

    fn gauge_points(vals: &[(u64, f64)]) -> Vec<SamplePoint> {
        vals.iter()
            .map(|&(t_us, v)| SamplePoint { t_us, value: SampleValue::Gauge(v) })
            .collect()
    }

    #[test]
    fn store_rings_per_series_and_matches_label_supersets() {
        let s = SeriesStore::with_capacity(3);
        for i in 0..5u64 {
            s.ingest("adra.x", &[("queue", "0")], i * 1000, SampleValue::Counter(i));
        }
        s.ingest("adra.x", &[("queue", "1")], 0, SampleValue::Counter(9));
        s.ingest("adra.y", &[], 0, SampleValue::Gauge(1.0));
        assert_eq!(s.series_count(), 3);

        let m = s.matching("adra.x", &[("queue", "0")]);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].1.len(), 3, "ring keeps the newest `capacity` points");
        assert_eq!(m[0].1[0].value, SampleValue::Counter(2), "oldest kept point");

        assert_eq!(s.matching("adra.x", &[]).len(), 2, "empty filter matches the family");
        assert!(s.matching("adra.x", &[("queue", "7")]).is_empty());
        assert!(s.matching("adra.z", &[]).is_empty());
        s.clear();
        assert_eq!(s.point_count(), 0);
    }

    #[test]
    fn sample_walks_a_registry() {
        let r = Registry::new();
        r.counter("adra.c", "c", &[("queue", "0")]).add(5);
        r.gauge("adra.g", "g", &[]).set(0.5);
        r.histogram("adra.h", "h", &[]).record(100.0);
        let s = SeriesStore::with_capacity(8);
        s.sample(&r);
        r.counter("adra.c", "c", &[("queue", "0")]).add(2);
        s.sample(&r);
        assert_eq!(s.series_count(), 3);
        let pts = &s.matching("adra.c", &[])[0].1;
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].value, SampleValue::Counter(7));
        match &s.matching("adra.h", &[])[0].1[0].value {
            SampleValue::Histogram { count, buckets, .. } => {
                assert_eq!(*count, 1);
                assert_eq!(buckets.iter().sum::<u64>(), 1);
            }
            other => panic!("expected histogram point, got {other:?}"),
        }
    }

    #[test]
    fn counter_rate_and_delta() {
        // 100 increments over 2 seconds (window endpoints), sampled every 500ms
        let pts = counter_points(&[(0, 0), (500_000, 10), (1_000_000, 50), (2_000_000, 100)]);
        assert_eq!(counter_delta(&pts, 3), Some(100));
        assert_eq!(counter_delta(&pts, 1), Some(50));
        let r = counter_rate(&pts, 3).unwrap();
        assert!((r - 50.0).abs() < 1e-9, "{r}");
        // under-populated / degenerate inputs
        assert_eq!(counter_rate(&pts[..1], 4), None);
        assert_eq!(counter_rate(&counter_points(&[(5, 1), (5, 9)]), 1), None, "zero dt");
        // reset (value went down) clamps to zero, never underflows
        assert_eq!(counter_delta(&counter_points(&[(0, 100), (1_000, 3)]), 1), Some(0));
    }

    #[test]
    fn gauge_ewma_minmax_and_slope() {
        let flat = gauge_points(&[(0, 0.8), (1_000_000, 0.8), (2_000_000, 0.8)]);
        assert!((gauge_ewma(&flat, 2, 0.5, false).unwrap() - 0.8).abs() < 1e-12);
        assert_eq!(ewma_slope(&flat, 2, 0.5, false), Some(0.0));

        let rising = gauge_points(&[(0, 0.0), (1_000_000, 1.0), (2_000_000, 2.0)]);
        // ewma: 0 -> 0.5 -> 1.25; slope = 1.25 / 2s
        let e = gauge_ewma(&rising, 2, 0.5, false).unwrap();
        assert!((e - 1.25).abs() < 1e-12, "{e}");
        let s = ewma_slope(&rising, 2, 0.5, false).unwrap();
        assert!((s - 0.625).abs() < 1e-12, "{s}");
        assert_eq!(gauge_min_max(&rising, 2), Some((0.0, 2.0)));

        // abs mode: signed errors must not cancel
        let signed = gauge_points(&[(0, -1.0), (1_000_000, 1.0)]);
        assert!((gauge_ewma(&signed, 1, 0.5, true).unwrap() - 1.0).abs() < 1e-12);
        assert_eq!(gauge_ewma(&signed, 1, 1.0, false), Some(1.0));

        // window restricts history: a long-flat series with a recent step
        let step = gauge_points(&[(0, 0.0), (1, 0.0), (2, 0.0), (1_000_000, 5.0), (2_000_000, 5.0)]);
        assert!((gauge_ewma(&step, 1, 1.0, false).unwrap() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_delta_percentile_and_violations() {
        let mk = |t_us: u64, counts: &[(usize, u64)]| {
            let mut buckets = vec![0u64; LatencyHistogram::NUM_BUCKETS];
            let mut total = 0;
            for &(i, n) in counts {
                buckets[i] += n;
                total += n;
            }
            SamplePoint {
                t_us,
                value: SampleValue::Histogram { count: total, sum: 0.0, buckets },
            }
        };
        // window adds 95 samples in bucket 4 ([16,32)) and 5 in bucket 10
        // ([1024,2048)) => p95 falls exactly at the bucket-4 boundary
        let pts = vec![mk(0, &[(2, 7)]), mk(1_000_000, &[(2, 7), (4, 95), (10, 5)])];
        assert_eq!(delta_p95_ns(&pts, 1), Some(32.0));
        // threshold 512ns: only the 5 bucket-10 samples violate
        let vf = violation_fraction(&pts, 1, 512.0).unwrap();
        assert!((vf - 0.05).abs() < 1e-12, "{vf}");
        // threshold mid-bucket: bucket 4 ([16,32)) straddles 20ns, so its
        // 95 samples are apportioned by the span above the threshold
        // (12/16 of them), plus the 5 fully-violating bucket-10 samples
        let vf = violation_fraction(&pts, 1, 20.0).unwrap();
        let expect = (95.0 * (32.0 - 20.0) / (32.0 - 16.0) + 5.0) / 100.0;
        assert!((vf - expect).abs() < 1e-12, "mid-bucket apportionment: {vf} vs {expect}");
        // threshold exactly on a bucket edge: the whole bucket violates
        let vf = violation_fraction(&pts, 1, 16.0).unwrap();
        assert!((vf - 1.0).abs() < 1e-12, "{vf}");
        // empty window
        let flat = vec![mk(0, &[(2, 7)]), mk(1_000_000, &[(2, 7)])];
        assert_eq!(delta_p95_ns(&flat, 1), None);
        assert_eq!(violation_fraction(&flat, 1, 1.0), None);
        // kind mismatch
        assert_eq!(delta_p95_ns(&counter_points(&[(0, 0), (1, 5)]), 1), None);
    }

    #[test]
    fn violation_fraction_open_ended_tail_counts_fully() {
        let mk = |t_us: u64, counts: &[(usize, u64)]| {
            let mut buckets = vec![0u64; LatencyHistogram::NUM_BUCKETS];
            let mut total = 0;
            for &(i, n) in counts {
                buckets[i] += n;
                total += n;
            }
            SamplePoint {
                t_us,
                value: SampleValue::Histogram { count: total, sum: 0.0, buckets },
            }
        };
        let last = LatencyHistogram::NUM_BUCKETS - 1;
        let (lo, hi) = LatencyHistogram::bucket_bounds(last);
        assert!(hi.is_infinite());
        let pts = vec![mk(0, &[]), mk(1_000_000, &[(last, 4), (2, 4)])];
        // threshold inside the open-ended bucket: no finite span to
        // apportion, all 4 tail samples count (conservative)
        let vf = violation_fraction(&pts, 1, lo * 2.0).unwrap();
        assert!((vf - 0.5).abs() < 1e-12, "{vf}");
    }

    #[test]
    fn ewma_slope_ignores_interleaved_counter_points() {
        // gauge samples at t=0 and t=1s rise 0 -> 1; a counter point at
        // t=9s shares the series (mixed-type window).  dt must span the
        // GAUGE samples (1s), not the whole window (9s).
        let pts = vec![
            SamplePoint { t_us: 0, value: SampleValue::Gauge(0.0) },
            SamplePoint { t_us: 1_000_000, value: SampleValue::Gauge(1.0) },
            SamplePoint { t_us: 9_000_000, value: SampleValue::Counter(7) },
        ];
        let s = ewma_slope(&pts, 2, 1.0, false).unwrap();
        assert!((s - 1.0).abs() < 1e-12, "slope must use gauge-sample dt: {s}");

        // counter-only series has no gauge pair -> None, not a panic
        let counters = counter_points(&[(0, 1), (1_000_000, 2), (2_000_000, 3)]);
        assert_eq!(ewma_slope(&counters, 2, 0.5, false), None);

        // a single gauge among counters is still insufficient
        let one = vec![
            SamplePoint { t_us: 0, value: SampleValue::Counter(1) },
            SamplePoint { t_us: 1_000_000, value: SampleValue::Gauge(0.5) },
        ];
        assert_eq!(ewma_slope(&one, 1, 0.5, false), None);
    }
}
