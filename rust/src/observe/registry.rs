//! The metric registry: named families of atomic counters, gauges, and
//! log-bucketed histograms, each series keyed by a sorted label set.
//!
//! Naming scheme (DESIGN.md §11): registry names are dotted
//! (`adra.serve.programs`); exposition sanitizes them to the Prometheus
//! character set (`adra_serve_programs`).  Label keys come from the small
//! stable vocabulary the stack routes on — `queue`, `tenant`, `shard`,
//! `tier`, `op_class`, `kind`, `source` — but the registry accepts any.
//!
//! Concurrency model: `Registry::{counter,gauge,histogram}` take a short
//! mutex to get-or-create the series and hand back an `Arc` handle;
//! producers on hot paths hold the handle and update it with plain atomic
//! ops (no lock, no allocation).  All counter arithmetic saturates at
//! `u64::MAX` — a soak run that wraps a counter must clamp, not panic in
//! debug builds (see the `u64::MAX`-vicinity tests).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::LatencyHistogram;

/// A sorted, owned label set — the series key within a family.
pub type LabelSet = Vec<(String, String)>;

/// Normalize a caller's label slice into the canonical sorted key.
fn label_set(labels: &[(&str, &str)]) -> LabelSet {
    let mut v: LabelSet = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    v.sort();
    v
}

fn f64_update(cell: &AtomicU64, f: impl Fn(f64) -> f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = f(f64::from_bits(cur)).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Monotone counter.  `add` saturates; `set_at_least` ratchets toward a
/// cumulative snapshot (publishing an absolute total is idempotent and
/// can never move the counter backwards).
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        let mut cur = self.value.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(n);
            match self
                .value
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    /// Ratchet to `v` if `v` is larger (snapshot publishing).
    pub fn set_at_least(&self, v: u64) {
        self.value.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Last-written-wins floating-point gauge.
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Self {
        Self { bits: AtomicU64::new(0f64.to_bits()) }
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, v: f64) {
        f64_update(&self.bits, |cur| cur + v);
    }

    /// Ratchet to `v` if `v` is larger — the gauge analogue of
    /// [`Counter::set_at_least`], for publishing running maxima (e.g.
    /// `max_round_occupancy`) from concurrent snapshots: the result is
    /// the max over every publisher regardless of interleaving.  NaN is
    /// ignored (`f64::max` discards it), so a poisoned sample can never
    /// wedge the ratchet.
    pub fn set_at_least(&self, v: f64) {
        f64_update(&self.bits, |cur| cur.max(v));
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Lock-free histogram with `LatencyHistogram` bucket semantics: bucket 0
/// covers [0, 2), bucket i >= 1 covers [2^i, 2^(i+1)), the last bucket is
/// open-ended (`LatencyHistogram::bucket_bounds`).  Values are unitless
/// to the bucketer; each family documents its unit in the name
/// (`..._ns`, `..._ppm`).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: (0..LatencyHistogram::NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            max_bits: AtomicU64::new(0f64.to_bits()),
        }
    }
}

impl Histogram {
    fn bucket_index(v: f64) -> usize {
        if v < 1.0 {
            0
        } else {
            (v.log2().floor() as usize).min(LatencyHistogram::NUM_BUCKETS - 1)
        }
    }

    /// Record one sample (same bucketing as `LatencyHistogram::record`
    /// applied to the raw value).
    pub fn record(&self, v: f64) {
        let idx = Self::bucket_index(v);
        // saturating: see the module doc on overflow hygiene
        self.buckets[idx].fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| {
            Some(c.saturating_add(1))
        })
        .ok();
        self.count
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| Some(c.saturating_add(1)))
            .ok();
        f64_update(&self.sum_bits, |cur| cur + v);
        f64_update(&self.max_bits, |cur| cur.max(v));
    }

    /// Record a latency sample in seconds into nanosecond buckets.
    pub fn record_seconds(&self, s: f64) {
        self.record(s * 1e9);
    }

    /// Ratchet this histogram toward a CUMULATIVE `LatencyHistogram`
    /// snapshot: per-bucket / count / sum / max all `fetch_max`.  Only
    /// valid when `snap` itself is monotone over time for this series
    /// (e.g. a coordinator's cumulative metrics) — re-publishing the same
    /// snapshot is then idempotent instead of double-counting.
    pub fn set_to_snapshot(&self, snap: &LatencyHistogram) {
        for (cell, &b) in self.buckets.iter().zip(snap.buckets()) {
            cell.fetch_max(b, Ordering::Relaxed);
        }
        self.count.fetch_max(snap.count(), Ordering::Relaxed);
        f64_update(&self.sum_bits, |cur| cur.max(snap.sum_ns()));
        f64_update(&self.max_bits, |cur| cur.max(snap.max_ns()));
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Non-cumulative per-bucket counts, index-aligned with
    /// `LatencyHistogram::bucket_bounds`.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// One series handle — what a family stores per label set.
#[derive(Clone, Debug)]
pub enum Series {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// The exposition kind of a family (every series in a family shares it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

#[derive(Debug)]
struct Family {
    help: String,
    kind: MetricKind,
    series: BTreeMap<LabelSet, Series>,
}

/// Point-in-time view of a family, for exposition.
pub struct FamilySnapshot {
    pub name: String,
    pub help: String,
    pub kind: MetricKind,
    /// (labels, live series handle) in deterministic label order.
    pub series: Vec<(LabelSet, Series)>,
}

/// Thread-safe registry of metric families.  See the module doc for the
/// naming scheme and concurrency model.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    fn series(
        &self,
        name: &str,
        help: &str,
        kind: MetricKind,
        labels: &[(&str, &str)],
    ) -> Series {
        let key = label_set(labels);
        let mut fams = self.families.lock().expect("registry lock");
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            series: BTreeMap::new(),
        });
        assert_eq!(
            fam.kind, kind,
            "metric family {name:?} registered as {} but requested as {}",
            fam.kind.name(),
            kind.name()
        );
        fam.series
            .entry(key)
            .or_insert_with(|| match kind {
                MetricKind::Counter => Series::Counter(Arc::new(Counter::default())),
                MetricKind::Gauge => Series::Gauge(Arc::new(Gauge::default())),
                MetricKind::Histogram => Series::Histogram(Arc::new(Histogram::default())),
            })
            .clone()
    }

    /// Get-or-create a counter series; the handle is lock-free to update.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.series(name, help, MetricKind::Counter, labels) {
            Series::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.series(name, help, MetricKind::Gauge, labels) {
            Series::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        match self.series(name, help, MetricKind::Histogram, labels) {
            Series::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    /// Deterministically ordered snapshot of every family (name
    /// ascending, label sets ascending) — what the expositions render.
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        let fams = self.families.lock().expect("registry lock");
        fams.iter()
            .map(|(name, fam)| FamilySnapshot {
                name: name.clone(),
                help: fam.help.clone(),
                kind: fam.kind,
                series: fam.series.iter().map(|(k, s)| (k.clone(), s.clone())).collect(),
            })
            .collect()
    }

    /// Number of registered families.
    pub fn family_count(&self) -> usize {
        self.families.lock().expect("registry lock").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram_roundtrip() {
        let r = Registry::new();
        let c = r.counter("adra.test.ops", "ops", &[("tenant", "3")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // same (name, labels) -> same series; label order is normalized
        let c2 = r.counter("adra.test.ops", "ops", &[("tenant", "3")]);
        c2.inc();
        assert_eq!(c.get(), 6);

        let g = r.gauge("adra.test.frac", "fraction", &[]);
        g.set(0.25);
        g.add(0.5);
        assert!((g.get() - 0.75).abs() < 1e-12);

        let h = r.histogram("adra.test.lat_ns", "latency", &[]);
        h.record_seconds(3e-9);
        h.record(1000.0);
        assert_eq!(h.count(), 2);
        assert!((h.sum() - 1003.0).abs() < 1e-9);
        assert_eq!(h.max(), 1000.0);
        assert_eq!(r.family_count(), 3);
    }

    #[test]
    fn gauge_ratchets_and_ignores_nan() {
        let g = Gauge::default();
        g.set_at_least(2.5);
        g.set_at_least(1.0); // can't move backwards
        assert_eq!(g.get(), 2.5);
        g.set_at_least(f64::NAN); // ignored, never wedges the cell
        g.set_at_least(3.0);
        assert_eq!(g.get(), 3.0);
    }

    #[test]
    fn label_order_is_normalized() {
        let r = Registry::new();
        let a = r.counter("m", "", &[("b", "2"), ("a", "1")]);
        let b = r.counter("m", "", &[("a", "1"), ("b", "2")]);
        a.inc();
        assert_eq!(b.get(), 1, "different label orders must resolve to one series");
    }

    #[test]
    #[should_panic(expected = "registered as counter")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("m", "", &[]);
        r.gauge("m", "", &[]);
    }

    #[test]
    fn counter_saturates_at_u64_max() {
        let c = Counter::default();
        c.set_at_least(u64::MAX - 2);
        c.add(1);
        assert_eq!(c.get(), u64::MAX - 1);
        c.add(10); // would overflow: clamps, never panics (debug builds too)
        assert_eq!(c.get(), u64::MAX);
        c.inc();
        assert_eq!(c.get(), u64::MAX);
        c.set_at_least(5); // ratchet can't move backwards
        assert_eq!(c.get(), u64::MAX);
    }

    #[test]
    fn histogram_buckets_match_latency_histogram() {
        let h = Histogram::default();
        let mut reference = LatencyHistogram::default();
        for ns in [0.25, 1.0, 2.0, 3.99, 64.0, 1e12] {
            h.record(ns);
            reference.record(ns * 1e-9);
        }
        assert_eq!(h.bucket_counts(), reference.buckets());
        assert_eq!(h.count(), reference.count());
    }

    #[test]
    fn snapshot_ratchet_is_idempotent() {
        let mut lh = LatencyHistogram::default();
        lh.record(5e-9);
        lh.record(100e-9);
        let h = Histogram::default();
        h.set_to_snapshot(&lh);
        h.set_to_snapshot(&lh); // re-publishing the same totals: no double count
        assert_eq!(h.count(), 2);
        assert_eq!(h.bucket_counts(), lh.buckets());
        lh.record(7e-9); // source advances monotonically
        h.set_to_snapshot(&lh);
        assert_eq!(h.count(), 3);
    }
}
