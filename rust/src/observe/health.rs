//! Declarative health rules over the [`SeriesStore`]: windowed signals,
//! warn/critical thresholds, hysteresis, and alert emission.
//!
//! A [`HealthRule`] names a [`Signal`] (a windowed derivation over one
//! or more matching series), a [`Direction`] (which side of the
//! threshold is bad), warn/critical levels, and sustain counts.  The
//! [`HealthEngine`] evaluates every rule against the store, applies
//! hysteresis — a target state must repeat for `sustain_up`
//! (escalation) or `sustain_down` (clearing) consecutive evaluations
//! before the rule transitions — and on each transition:
//!
//! * records [`TraceEvent::Alert`] into the flight recorder
//!   (unconditionally — alerts bypass the span/kernel gates),
//! * bumps the `adra.health.transitions` counter,
//! * and re-publishes the `adra.health.status{rule}` gauge
//!   (0 = ok, 1 = warn, 2 = critical) so scrapes carry current state.
//!
//! Hysteresis gives the testable no-flapping bound: a signal that
//! oscillates around a threshold every evaluation never accumulates a
//! sustain streak, so a sustained excursion produces EXACTLY ONE
//! transition in each direction.
//!
//! A signal that cannot be computed (cold ring, zero denominator, no
//! window samples) evaluates to `None` and the rule HOLDS — streaks
//! freeze rather than decay toward ok, so warmup can neither fire nor
//! clear an alert.

use std::sync::Mutex;

use super::registry::Registry;
use super::series::{
    counter_delta, counter_rate, delta_p95_ns, ewma_slope, gauge_ewma, violation_fraction,
    SeriesStore,
};
use super::trace::FlightRecorder;

/// Rule state, ordered by severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleState {
    Ok,
    Warn,
    Critical,
}

impl RuleState {
    pub fn name(&self) -> &'static str {
        match self {
            RuleState::Ok => "ok",
            RuleState::Warn => "warn",
            RuleState::Critical => "critical",
        }
    }

    /// The `adra.health.status` gauge encoding.
    pub fn as_gauge(&self) -> f64 {
        match self {
            RuleState::Ok => 0.0,
            RuleState::Warn => 1.0,
            RuleState::Critical => 2.0,
        }
    }
}

/// Which side of the threshold is unhealthy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Larger is worse (rates, burn, starvation).
    Above,
    /// Smaller is worse (hit rates, margins).
    Below,
}

/// Owned label filter for a signal (series whose labels are a superset
/// match — see [`SeriesStore::matching`]).
pub type LabelFilter = Vec<(String, String)>;

fn as_refs(labels: &LabelFilter) -> Vec<(&str, &str)> {
    labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect()
}

/// A windowed derivation over the store.  Windows are trailing point
/// counts (one point per serve round at the default cadence).
#[derive(Clone, Debug)]
pub enum Signal {
    /// Per-second rate of a counter, SUMMED across matching series
    /// (e.g. mismatches across every tier).
    CounterRate { name: String, labels: LabelFilter, window: usize },
    /// EWMA of a gauge over the window.  Across matching series the
    /// worst one wins (direction-aware: `Above` takes the max EWMA,
    /// `Below` the min).  `abs` smooths magnitudes — signed errors
    /// (planner prediction error) must not cancel.
    GaugeEwma { name: String, labels: LabelFilter, window: usize, alpha: f64, abs: bool },
    /// Per-second slope of the EWMA-smoothed gauge (drift detector);
    /// worst matching series wins, direction-aware like `GaugeEwma`.
    GaugeEwmaSlope { name: String, labels: LabelFilter, window: usize, alpha: f64, abs: bool },
    /// `delta(num) / delta(den)` over the window, both deltas summed
    /// across their matching series.  `None` when the denominator
    /// didn't move — a quiet window is not a collapsed ratio.
    WindowRatio {
        num: String,
        num_labels: LabelFilter,
        den: String,
        den_labels: LabelFilter,
        window: usize,
    },
    /// Windowed p95 (ns) from histogram bucket deltas; worst matching
    /// series wins (p95 is only ever used with `Above`).
    P95Ns { name: String, labels: LabelFilter, window: usize },
    /// SLO burn rate: fraction of window samples over `slo_ns`, divided
    /// by the error `budget`, taken over BOTH a fast and a slow window
    /// and combined with `min` — the multiwindow burn-rate idiom: the
    /// fast window gives reaction speed, the slow window vetoes blips,
    /// and both must burn for the rule to see > 1.
    SloBurn {
        name: String,
        labels: LabelFilter,
        slo_ns: f64,
        budget: f64,
        fast: usize,
        slow: usize,
    },
}

impl Signal {
    /// Combine per-series results so the WORST series drives the rule.
    fn worst(vals: impl Iterator<Item = f64>, direction: Direction) -> Option<f64> {
        match direction {
            Direction::Above => vals.fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v)))),
            Direction::Below => vals.fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.min(v)))),
        }
    }

    /// Evaluate against the store; `None` means "cannot judge yet".
    pub fn eval(&self, store: &SeriesStore, direction: Direction) -> Option<f64> {
        match self {
            Signal::CounterRate { name, labels, window } => {
                let mut total = 0.0;
                let mut any = false;
                for (_, pts) in store.matching(name, &as_refs(labels)) {
                    if let Some(r) = counter_rate(&pts, *window) {
                        total += r;
                        any = true;
                    }
                }
                any.then_some(total)
            }
            Signal::GaugeEwma { name, labels, window, alpha, abs } => Self::worst(
                store
                    .matching(name, &as_refs(labels))
                    .iter()
                    .filter_map(|(_, pts)| gauge_ewma(pts, *window, *alpha, *abs)),
                direction,
            ),
            Signal::GaugeEwmaSlope { name, labels, window, alpha, abs } => Self::worst(
                store
                    .matching(name, &as_refs(labels))
                    .iter()
                    .filter_map(|(_, pts)| ewma_slope(pts, *window, *alpha, *abs)),
                direction,
            ),
            Signal::WindowRatio { num, num_labels, den, den_labels, window } => {
                let sum_delta = |name: &str, labels: &LabelFilter| -> u64 {
                    store
                        .matching(name, &as_refs(labels))
                        .iter()
                        .filter_map(|(_, pts)| counter_delta(pts, *window))
                        .sum()
                };
                let d = sum_delta(den, den_labels);
                if d == 0 {
                    return None;
                }
                Some(sum_delta(num, num_labels) as f64 / d as f64)
            }
            Signal::P95Ns { name, labels, window } => Self::worst(
                store
                    .matching(name, &as_refs(labels))
                    .iter()
                    .filter_map(|(_, pts)| delta_p95_ns(pts, *window)),
                direction,
            ),
            Signal::SloBurn { name, labels, slo_ns, budget, fast, slow } => {
                let burn = |window: usize| -> Option<f64> {
                    Self::worst(
                        store
                            .matching(name, &as_refs(labels))
                            .iter()
                            .filter_map(|(_, pts)| violation_fraction(pts, window, *slo_ns)),
                        Direction::Above,
                    )
                    .map(|f| f / budget.max(1e-12))
                };
                Some(burn(*fast)?.min(burn(*slow)?))
            }
        }
    }
}

/// One declarative rule.  `warn`/`critical` are thresholds on the
/// signal value in `direction`; `sustain_up`/`sustain_down` are the
/// consecutive-evaluation streaks hysteresis requires to escalate /
/// clear.
#[derive(Clone, Debug)]
pub struct HealthRule {
    pub name: String,
    pub signal: Signal,
    pub direction: Direction,
    pub warn: f64,
    pub critical: f64,
    pub sustain_up: u32,
    pub sustain_down: u32,
}

impl HealthRule {
    /// The state this rule's thresholds assign to `value` (before
    /// hysteresis).
    fn classify(&self, value: f64) -> RuleState {
        let breached = |threshold: f64| match self.direction {
            Direction::Above => value >= threshold,
            Direction::Below => value <= threshold,
        };
        if breached(self.critical) {
            RuleState::Critical
        } else if breached(self.warn) {
            RuleState::Warn
        } else {
            RuleState::Ok
        }
    }
}

/// A committed state change, also emitted as `TraceEvent::Alert`.
#[derive(Clone, Debug)]
pub struct Transition {
    pub rule: String,
    pub from: RuleState,
    pub to: RuleState,
    pub value: f64,
}

struct RuleRuntime {
    rule: HealthRule,
    state: RuleState,
    /// The state the current streak is accumulating toward.
    pending: RuleState,
    streak: u32,
    last_value: Option<f64>,
}

/// Evaluates rules, applies hysteresis, emits alerts.  Single-threaded
/// by design — the global instance lives behind a `Mutex` and is
/// evaluated from the serve scheduler thread and the REPL.
#[derive(Default)]
pub struct HealthEngine {
    rules: Vec<RuleRuntime>,
    transitions: u64,
}

impl HealthEngine {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_rule(&mut self, rule: HealthRule) {
        let state = RuleState::Ok;
        self.rules.push(RuleRuntime { rule, state, pending: state, streak: 0, last_value: None });
    }

    pub fn rule_count(&self) -> usize {
        self.rules.len()
    }

    /// Total committed transitions since construction.
    pub fn transition_count(&self) -> u64 {
        self.transitions
    }

    /// Current state of a rule by name.
    pub fn state_of(&self, name: &str) -> Option<RuleState> {
        self.rules.iter().find(|r| r.rule.name == name).map(|r| r.state)
    }

    /// Worst state across all rules (the one-line health summary).
    pub fn overall(&self) -> RuleState {
        self.rules.iter().map(|r| r.state).max().unwrap_or(RuleState::Ok)
    }

    /// Evaluate every rule once.  Commits hysteresis-approved
    /// transitions, records alerts into `recorder`, publishes
    /// `adra.health.*` into `registry`, and returns the transitions.
    pub fn evaluate(
        &mut self,
        store: &SeriesStore,
        registry: &Registry,
        recorder: &FlightRecorder,
    ) -> Vec<Transition> {
        let mut out = Vec::new();
        for rt in &mut self.rules {
            if let Some(value) = rt.rule.signal.eval(store, rt.rule.direction) {
                rt.last_value = Some(value);
                let target = rt.rule.classify(value);
                if target == rt.state {
                    // back in line with the committed state: abandon any
                    // half-accumulated excursion
                    rt.pending = rt.state;
                    rt.streak = 0;
                } else {
                    if target == rt.pending {
                        rt.streak += 1;
                    } else {
                        rt.pending = target;
                        rt.streak = 1;
                    }
                    let required = if target > rt.state {
                        rt.rule.sustain_up
                    } else {
                        rt.rule.sustain_down
                    };
                    if rt.streak >= required.max(1) {
                        let tr = Transition {
                            rule: rt.rule.name.clone(),
                            from: rt.state,
                            to: target,
                            value,
                        };
                        rt.state = target;
                        rt.pending = target;
                        rt.streak = 0;
                        self.transitions += 1;
                        recorder.record_alert(&tr.rule, tr.from.name(), tr.to.name(), value);
                        registry
                            .counter(
                                "adra.health.transitions",
                                "Committed health-rule state transitions.",
                                &[("rule", &tr.rule)],
                            )
                            .inc();
                        out.push(tr);
                    }
                }
            }
            // always republish current state so every scrape carries it
            registry
                .gauge(
                    "adra.health.status",
                    "Health-rule state: 0=ok, 1=warn, 2=critical.",
                    &[("rule", &rt.rule.name)],
                )
                .set(rt.state.as_gauge());
        }
        out
    }

    /// Human-readable report (the REPL `health` command).
    pub fn report(&self) -> String {
        let mut out = format!("overall: {}\n", self.overall().name());
        for rt in &self.rules {
            let value = rt
                .last_value
                .map(|v| format!("{v:.4}"))
                .unwrap_or_else(|| "-".to_string());
            let pending = if rt.streak > 0 {
                format!("  pending {} ({}x)", rt.pending.name(), rt.streak)
            } else {
                String::new()
            };
            out.push_str(&format!(
                "  {:<28} {:<8} value={value}{pending}\n",
                rt.rule.name,
                rt.state.name()
            ));
        }
        out
    }
}

fn owned(labels: &[(&str, &str)]) -> LabelFilter {
    labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

/// The standard ADRA rule set over the metric families the stack
/// publishes (DESIGN.md §12).  Windows are serve rounds at the default
/// `sample_every = 1` cadence.
pub fn standard_rules() -> Vec<HealthRule> {
    vec![
        // Digital-tier guard: sampled digital-vs-analog cross-validation
        // mismatches per check.  Any sustained nonzero rate says the
        // margin masks are stale (PAPER.md §IV).
        HealthRule {
            name: "xval_mismatch_ratio".into(),
            signal: Signal::WindowRatio {
                num: "adra.array.xval_mismatches".into(),
                num_labels: owned(&[]),
                den: "adra.array.xval_checks".into(),
                den_labels: owned(&[]),
                window: 8,
            },
            direction: Direction::Above,
            warn: 1e-4,
            critical: 1e-2,
            sustain_up: 2,
            sustain_down: 4,
        },
        // Drift detector on the deterministic-column fraction: a falling
        // EWMA means variation is eating the digital fast path.
        HealthRule {
            name: "det_col_fraction_drift".into(),
            signal: Signal::GaugeEwmaSlope {
                name: "adra.array.det_fraction".into(),
                labels: owned(&[]),
                window: 16,
                alpha: 0.3,
                abs: false,
            },
            direction: Direction::Below,
            warn: -0.01,
            critical: -0.05,
            sustain_up: 2,
            sustain_down: 4,
        },
        // Serving cache effectiveness collapse.
        HealthRule {
            name: "cache_hit_rate".into(),
            signal: Signal::GaugeEwma {
                name: "adra.serve.cache_hit_rate".into(),
                labels: owned(&[]),
                window: 8,
                alpha: 0.5,
                abs: false,
            },
            direction: Direction::Below,
            warn: 0.10,
            critical: 0.01,
            sustain_up: 3,
            sustain_down: 4,
        },
        // p95 round-wall SLO burn, fast/slow dual window.  slo_ns/budget
        // mirror the batch controller's target_p95 (serve::BatchController).
        HealthRule {
            name: "round_wall_slo_burn".into(),
            signal: Signal::SloBurn {
                name: "adra.serve.round_wall_ns".into(),
                labels: owned(&[]),
                slo_ns: 2e6,
                budget: 0.05,
                fast: 4,
                slow: 16,
            },
            direction: Direction::Above,
            warn: 1.0,
            critical: 4.0,
            sustain_up: 2,
            sustain_down: 4,
        },
        // Planner model drift, per op class: the worst |prediction
        // error| EWMA across every `{kind, op_class}` series of the
        // signed relative-error gauge.  This is the exact series the
        // adaptive cost model (ROADMAP item 1) reads.
        HealthRule {
            name: "planner_prediction_drift".into(),
            signal: Signal::GaugeEwma {
                name: "adra.planner.prediction_error".into(),
                labels: owned(&[]),
                window: 16,
                alpha: 0.3,
                abs: true,
            },
            direction: Direction::Above,
            warn: 0.25,
            critical: 0.75,
            sustain_up: 3,
            sustain_down: 4,
        },
        // Tenant quota starvation: fraction of admissions deferred by
        // quota clamping.
        HealthRule {
            name: "tenant_quota_starvation".into(),
            signal: Signal::WindowRatio {
                num: "adra.serve.deferred_programs".into(),
                num_labels: owned(&[]),
                den: "adra.serve.programs".into(),
                den_labels: owned(&[]),
                window: 8,
            },
            direction: Direction::Above,
            warn: 0.5,
            critical: 2.0,
            sustain_up: 2,
            sustain_down: 4,
        },
        // Calibration runaway: the worst correction-factor distortion
        // max(f, 1/f) the adaptive cost model is applying
        // (`planner::calibrate`).  Factors live in [0.25, 4]; a
        // sustained EWMA near the clamp edge means the analytic tables
        // are off by more than calibration should be papering over —
        // fix the model, don't trust the patch.
        HealthRule {
            name: "calibration_runaway".into(),
            signal: Signal::GaugeEwma {
                name: "adra.planner.calibration_distortion".into(),
                labels: owned(&[]),
                window: 16,
                alpha: 0.3,
                abs: false,
            },
            direction: Direction::Above,
            warn: 2.5,
            critical: 3.9,
            sustain_up: 3,
            sustain_down: 4,
        },
        // Array wear rate: aggregate write throughput across every
        // shard's endurance tracker (the per-shard `adra.array.writes`
        // counters the serve loop publishes each sample).  Budgets are
        // sized against low-end HZO FeFET endurance (~1e5 cycles): at
        // 5e4 writes/s a focused workload burns a hot row's whole
        // cycle budget in seconds unless wear steering spreads it
        // (warn — check `adra.array.wear_imbalance` and the migration
        // counter), and a sustained 5e6 writes/s means leveling has
        // lost and the array is being consumed (critical).
        HealthRule {
            name: "array_wear_rate".into(),
            signal: Signal::CounterRate {
                name: "adra.array.writes".into(),
                labels: owned(&[("source", "endurance")]),
                window: 16,
            },
            direction: Direction::Above,
            warn: 5e4,
            critical: 5e6,
            sustain_up: 3,
            sustain_down: 4,
        },
    ]
}

/// A fresh engine loaded with [`standard_rules`].
pub fn standard_engine() -> HealthEngine {
    let mut e = HealthEngine::new();
    for r in standard_rules() {
        e.add_rule(r);
    }
    e
}

/// Global engine guard type (see `observe::health()`).
pub type SharedHealthEngine = Mutex<HealthEngine>;

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::series::SampleValue;

    fn gauge_rule(warn: f64, critical: f64, up: u32, down: u32) -> HealthRule {
        HealthRule {
            name: "t".into(),
            signal: Signal::GaugeEwma {
                name: "g".into(),
                labels: vec![],
                window: 0,
                alpha: 1.0,
                abs: false,
            },
            direction: Direction::Above,
            warn,
            critical,
            sustain_up: up,
            sustain_down: down,
        }
    }

    /// Feed one gauge value and evaluate once.
    fn step(
        engine: &mut HealthEngine,
        store: &SeriesStore,
        t: &mut u64,
        v: f64,
    ) -> Vec<Transition> {
        *t += 1;
        store.ingest("g", &[], *t, SampleValue::Gauge(v));
        let reg = Registry::new();
        let rec = FlightRecorder::with_capacity(16);
        engine.evaluate(store, &reg, &rec)
    }

    #[test]
    fn sustained_breach_transitions_exactly_once() {
        let store = SeriesStore::with_capacity(32);
        let mut e = HealthEngine::new();
        e.add_rule(gauge_rule(1.0, 10.0, 2, 2));
        let mut t = 0;
        assert!(step(&mut e, &store, &mut t, 0.5).is_empty());
        assert!(step(&mut e, &store, &mut t, 2.0).is_empty(), "streak 1 < sustain_up");
        let tr = step(&mut e, &store, &mut t, 2.0);
        assert_eq!(tr.len(), 1);
        assert_eq!((tr[0].from, tr[0].to), (RuleState::Ok, RuleState::Warn));
        // still breached: NO further transitions (the no-flapping bound)
        for _ in 0..5 {
            assert!(step(&mut e, &store, &mut t, 2.0).is_empty());
        }
        assert_eq!(e.state_of("t"), Some(RuleState::Warn));
        assert_eq!(e.transition_count(), 1);
    }

    #[test]
    fn flapping_input_never_transitions() {
        let store = SeriesStore::with_capacity(64);
        let mut e = HealthEngine::new();
        e.add_rule(gauge_rule(1.0, 10.0, 2, 2));
        let mut t = 0;
        for i in 0..20 {
            let v = if i % 2 == 0 { 2.0 } else { 0.5 }; // oscillates every eval
            assert!(step(&mut e, &store, &mut t, v).is_empty(), "eval {i}");
        }
        assert_eq!(e.state_of("t"), Some(RuleState::Ok));
        assert_eq!(e.transition_count(), 0);
    }

    #[test]
    fn escalation_clearing_and_hysteresis_asymmetry() {
        let store = SeriesStore::with_capacity(64);
        let mut e = HealthEngine::new();
        e.add_rule(gauge_rule(1.0, 10.0, 1, 3)); // instant up, slow down
        let mut t = 0;
        let tr = step(&mut e, &store, &mut t, 50.0);
        assert_eq!((tr[0].from, tr[0].to), (RuleState::Ok, RuleState::Critical), "multi-level jump");
        // de-escalating to warn needs sustain_down=3
        assert!(step(&mut e, &store, &mut t, 2.0).is_empty());
        assert!(step(&mut e, &store, &mut t, 2.0).is_empty());
        let tr = step(&mut e, &store, &mut t, 2.0);
        assert_eq!((tr[0].from, tr[0].to), (RuleState::Critical, RuleState::Warn));
        // a blip back to critical resets the clear streak
        assert!(step(&mut e, &store, &mut t, 0.1).is_empty());
        assert!(step(&mut e, &store, &mut t, 0.1).is_empty());
        let tr = step(&mut e, &store, &mut t, 50.0); // sustain_up=1: fires at once
        assert_eq!((tr[0].from, tr[0].to), (RuleState::Warn, RuleState::Critical));
    }

    #[test]
    fn no_data_holds_state_and_streak() {
        let store = SeriesStore::with_capacity(64);
        let mut e = HealthEngine::new();
        e.add_rule(gauge_rule(1.0, 10.0, 2, 2));
        let reg = Registry::new();
        let rec = FlightRecorder::with_capacity(16);
        // empty store: eval returns None, rule holds at ok with no panic
        assert!(e.evaluate(&store, &reg, &rec).is_empty());
        assert_eq!(e.state_of("t"), Some(RuleState::Ok));
        let mut t = 0;
        step(&mut e, &store, &mut t, 2.0); // streak 1
        // series goes quiet (no new points): streak freezes, then resumes
        assert!(e.evaluate(&store, &reg, &rec).len() <= 1);
    }

    #[test]
    fn alerts_and_status_gauges_are_published() {
        let store = SeriesStore::with_capacity(16);
        let mut e = HealthEngine::new();
        e.add_rule(gauge_rule(1.0, 10.0, 1, 1));
        let reg = Registry::new();
        let rec = FlightRecorder::with_capacity(16);
        store.ingest("g", &[], 1, SampleValue::Gauge(5.0));
        let tr = e.evaluate(&store, &reg, &rec);
        assert_eq!(tr.len(), 1);
        assert_eq!(rec.len(), 1, "alert recorded in the flight recorder");
        assert!(rec.to_jsonl().contains("\"kind\":\"alert\""));
        let status = reg.gauge("adra.health.status", "", &[("rule", "t")]);
        assert_eq!(status.get(), 1.0);
        let transitions = reg.counter("adra.health.transitions", "", &[("rule", "t")]);
        assert_eq!(transitions.get(), 1);
        assert_eq!(e.overall(), RuleState::Warn);
        assert!(e.report().contains("warn"));
    }

    #[test]
    fn below_direction_and_window_ratio_none_on_quiet_denominator() {
        let store = SeriesStore::with_capacity(16);
        // hit-rate collapse style rule
        let rule = HealthRule {
            name: "ratio".into(),
            signal: Signal::WindowRatio {
                num: "n".into(),
                num_labels: vec![],
                den: "d".into(),
                den_labels: vec![],
                window: 4,
            },
            direction: Direction::Above,
            warn: 0.5,
            critical: 0.9,
            sustain_up: 1,
            sustain_down: 1,
        };
        // denominator flat => None => no transition ever
        store.ingest("n", &[], 1, SampleValue::Counter(0));
        store.ingest("d", &[], 1, SampleValue::Counter(10));
        store.ingest("n", &[], 2, SampleValue::Counter(100));
        store.ingest("d", &[], 2, SampleValue::Counter(10));
        assert_eq!(rule.signal.eval(&store, Direction::Above), None);
        // denominator moves => ratio computes
        store.ingest("n", &[], 3, SampleValue::Counter(130));
        store.ingest("d", &[], 3, SampleValue::Counter(60));
        let v = rule.signal.eval(&store, Direction::Above).unwrap();
        assert!((v - 2.6).abs() < 1e-12, "{v}");
    }

    /// The wear rule's budgets against realistic aggregate write
    /// rates: background serving is quiet, a hot tenant breaches warn,
    /// a flood (leveling lost) escalates to critical once the trailing
    /// window turns over.
    #[test]
    fn array_wear_rate_rule_escalates_on_hot_writes() {
        let store = SeriesStore::with_capacity(64);
        let mut e = HealthEngine::new();
        e.add_rule(
            standard_rules()
                .into_iter()
                .find(|r| r.name == "array_wear_rate")
                .expect("standard wear rule"),
        );
        let reg = Registry::new();
        let rec = FlightRecorder::with_capacity(64);
        let labels: &[(&str, &str)] = &[("source", "endurance"), ("shard", "0")];
        let mut t = 0u64; // microseconds; one sample per second
        let mut total = 0u64;
        // healthy background: 1k writes/s
        for _ in 0..6 {
            t += 1_000_000;
            total += 1_000;
            store.ingest("adra.array.writes", labels, t, SampleValue::Counter(total));
            assert!(e.evaluate(&store, &reg, &rec).is_empty());
        }
        assert_eq!(e.state_of("array_wear_rate"), Some(RuleState::Ok));
        // hot tenant: 1M writes/s — above warn (5e4), below critical
        for _ in 0..4 {
            t += 1_000_000;
            total += 1_000_000;
            store.ingest("adra.array.writes", labels, t, SampleValue::Counter(total));
            e.evaluate(&store, &reg, &rec);
        }
        assert_eq!(e.state_of("array_wear_rate"), Some(RuleState::Warn));
        // flood: 20M writes/s — critical once the windowed rate clears 5e6
        for _ in 0..8 {
            t += 1_000_000;
            total += 20_000_000;
            store.ingest("adra.array.writes", labels, t, SampleValue::Counter(total));
            e.evaluate(&store, &reg, &rec);
        }
        assert_eq!(e.state_of("array_wear_rate"), Some(RuleState::Critical));
    }

    #[test]
    fn standard_rules_cover_the_issue_set() {
        let e = standard_engine();
        assert_eq!(e.rule_count(), 8);
        for name in [
            "xval_mismatch_ratio",
            "det_col_fraction_drift",
            "cache_hit_rate",
            "round_wall_slo_burn",
            "planner_prediction_drift",
            "tenant_quota_starvation",
            "calibration_runaway",
            "array_wear_rate",
        ] {
            assert!(e.state_of(name).is_some(), "missing standard rule {name}");
        }
        assert_eq!(e.overall(), RuleState::Ok);
    }
}
