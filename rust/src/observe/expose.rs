//! Prometheus text-format and JSON exposition of a [`Registry`] snapshot.
//!
//! The text format follows the Prometheus 0.0.4 exposition conventions:
//! `# HELP` / `# TYPE` per family, dotted registry names sanitized to the
//! `[a-zA-Z_:][a-zA-Z0-9_:]*` metric charset, label values escaped
//! (`\\`, `\"`, `\n`), and histograms rendered as the
//! `_bucket{le=...}` / `_sum` / `_count` triple with CUMULATIVE bucket
//! counts and a closing `le="+Inf"` bucket equal to `_count`.

use crate::metrics::LatencyHistogram;

use super::registry::{MetricKind, Registry, Series};

/// Sanitize a dotted registry name into the Prometheus metric charset.
pub fn sanitize_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, ch) in name.chars().enumerate() {
        let ok = ch.is_ascii_alphabetic() || ch == '_' || ch == ':' || (i > 0 && ch.is_ascii_digit());
        out.push(if ok { ch } else { '_' });
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Escape a label value per the exposition format.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape a HELP docstring (only `\\` and `\n` per the format).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Render `{k="v",...}` (empty string for an empty label set), with an
/// optional extra label appended (the histogram `le`).
fn render_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", sanitize_name(k), escape_label_value(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Format a sample value: integers render bare, floats via `{}` (which
/// prints `inf`/`NaN` in Rust; map to the exposition spellings).
fn render_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".into() } else { "-Inf".into() }
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// The `le` spelling of bucket `i`'s upper bound.
fn le_bound(i: usize) -> String {
    let (_, hi) = LatencyHistogram::bucket_bounds(i);
    if hi.is_infinite() {
        "+Inf".into()
    } else {
        format!("{}", hi as u64)
    }
}

/// Render the registry in Prometheus text exposition format.
pub fn expose_text(registry: &Registry) -> String {
    let mut out = String::new();
    for fam in registry.snapshot() {
        let name = sanitize_name(&fam.name);
        out.push_str(&format!("# HELP {name} {}\n", escape_help(&fam.help)));
        out.push_str(&format!("# TYPE {name} {}\n", fam.kind.name()));
        for (labels, series) in &fam.series {
            match series {
                Series::Counter(c) => {
                    out.push_str(&format!(
                        "{name}{} {}\n",
                        render_labels(labels, None),
                        c.get()
                    ));
                }
                Series::Gauge(g) => {
                    out.push_str(&format!(
                        "{name}{} {}\n",
                        render_labels(labels, None),
                        render_f64(g.get())
                    ));
                }
                Series::Histogram(h) => {
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, c) in counts.iter().enumerate() {
                        cum = cum.saturating_add(*c);
                        out.push_str(&format!(
                            "{name}_bucket{} {cum}\n",
                            render_labels(labels, Some(("le", &le_bound(i))))
                        ));
                    }
                    out.push_str(&format!(
                        "{name}_sum{} {}\n",
                        render_labels(labels, None),
                        render_f64(h.sum())
                    ));
                    out.push_str(&format!(
                        "{name}_count{} {}\n",
                        render_labels(labels, None),
                        h.count()
                    ));
                }
            }
        }
    }
    out
}

/// Escape a string for embedding in hand-rendered JSON (the exposition
/// and the trace JSONL share this — serde-free, so label/rule values
/// containing quotes or newlines still round-trip).
pub fn json_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len() + 2);
    for ch in v.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an f64 as a JSON value (Inf/NaN become strings — JSON has no
/// literals for them).
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        // JSON has no Inf/NaN literals; encode as strings
        format!("\"{v}\"")
    }
}

/// Render the registry as a JSON snapshot (same content as the text
/// exposition, machine-shaped: one object per family, one per series).
pub fn expose_json(registry: &Registry) -> String {
    let mut fams = Vec::new();
    for fam in registry.snapshot() {
        let mut series = Vec::new();
        for (labels, s) in &fam.series {
            let labels_json: Vec<String> = labels
                .iter()
                .map(|(k, v)| format!("\"{}\":\"{}\"", json_escape(k), json_escape(v)))
                .collect();
            let body = match s {
                Series::Counter(c) => format!("\"value\":{}", c.get()),
                Series::Gauge(g) => format!("\"value\":{}", json_f64(g.get())),
                Series::Histogram(h) => {
                    let buckets: Vec<String> =
                        h.bucket_counts().iter().map(|c| c.to_string()).collect();
                    format!(
                        "\"count\":{},\"sum\":{},\"max\":{},\"buckets\":[{}]",
                        h.count(),
                        json_f64(h.sum()),
                        json_f64(h.max()),
                        buckets.join(",")
                    )
                }
            };
            series.push(format!(
                "{{\"labels\":{{{}}},{body}}}",
                labels_json.join(",")
            ));
        }
        fams.push(format!(
            "{{\"name\":\"{}\",\"kind\":\"{}\",\"help\":\"{}\",\"series\":[{}]}}",
            json_escape(&fam.name),
            fam.kind.name(),
            json_escape(&fam.help),
            series.join(",")
        ));
    }
    format!("{{\"families\":[{}]}}", fams.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_sanitize_to_prometheus_charset() {
        assert_eq!(sanitize_name("adra.serve.programs"), "adra_serve_programs");
        assert_eq!(sanitize_name("adra.round-wall ns"), "adra_round_wall_ns");
        assert_eq!(sanitize_name("9lives"), "_lives");
        assert_eq!(sanitize_name("a9"), "a9");
    }

    #[test]
    fn label_values_escape() {
        assert_eq!(escape_label_value("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn text_format_counter_and_gauge() {
        let r = Registry::new();
        r.counter("adra.serve.programs", "Programs served.", &[("queue", "0")]).add(7);
        r.gauge("adra.array.det_fraction", "Deterministic fraction.", &[]).set(0.5);
        let text = expose_text(&r);
        assert!(text.contains("# HELP adra_array_det_fraction Deterministic fraction.\n"));
        assert!(text.contains("# TYPE adra_array_det_fraction gauge\n"));
        assert!(text.contains("adra_array_det_fraction 0.5\n"));
        assert!(text.contains("# TYPE adra_serve_programs counter\n"));
        assert!(text.contains("adra_serve_programs{queue=\"0\"} 7\n"));
    }

    #[test]
    fn histogram_renders_cumulative_triple() {
        let r = Registry::new();
        let h = r.histogram("adra.t.lat_ns", "t", &[("tier", "digital")]);
        h.record(1.0); // bucket 0, le="2"
        h.record(3.0); // bucket 1, le="4"
        let text = expose_text(&r);
        assert!(text.contains("adra_t_lat_ns_bucket{tier=\"digital\",le=\"2\"} 1\n"), "{text}");
        assert!(text.contains("adra_t_lat_ns_bucket{tier=\"digital\",le=\"4\"} 2\n"), "{text}");
        assert!(text.contains("adra_t_lat_ns_bucket{tier=\"digital\",le=\"+Inf\"} 2\n"), "{text}");
        assert!(text.contains("adra_t_lat_ns_sum{tier=\"digital\"} 4\n"), "{text}");
        assert!(text.contains("adra_t_lat_ns_count{tier=\"digital\"} 2\n"), "{text}");
    }

    #[test]
    fn json_snapshot_is_parseable_shape() {
        let r = Registry::new();
        r.counter("adra.x", "x\"quoted\"", &[("k", "v")]).inc();
        r.histogram("adra.h", "h", &[]).record(5.0);
        let json = expose_json(&r);
        assert!(json.starts_with("{\"families\":["));
        assert!(json.contains("\"name\":\"adra.x\""));
        assert!(json.contains("\"x\\\"quoted\\\"\""));
        assert!(json.contains("\"buckets\":["));
    }
}
