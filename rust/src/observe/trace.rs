//! Trace spans and the flight recorder: a fixed-capacity ring buffer of
//! recent pipeline events, exportable as JSONL for postmortems.
//!
//! Two event classes share the ring:
//!
//! * **Serve spans** — per-round stage timings through the pipeline
//!   (`admit -> schedule -> coalesce -> fuse -> execute -> cache`) plus a
//!   per-program `admit` span (queue wait).  On by default: the serve
//!   scheduler runs per round, not per activation, so recording cost is
//!   negligible.
//! * **Kernel events** — one event per dual-row activation at the tier
//!   boundary (digital / masked / analog / exact routing, span width,
//!   marginal-column count) plus the sampled digital-vs-analog
//!   cross-validation checks.  OFF by default — the packed kernel runs
//!   millions of activations per second and the hotpath trajectory gate
//!   must not pay a mutex per activation; a disabled recorder costs one
//!   relaxed atomic load.
//!
//! The ring keeps the newest `capacity` events; older ones are dropped
//! and counted (`dropped()`), so a postmortem export is always the tail
//! of history, never a partial head.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::expose::{json_escape, json_f64};

/// Serve-pipeline stage of a span event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Queue wait: submission to round selection (per program).
    Admit,
    /// Round selection (WFQ/FIFO pass over the backlog).
    Schedule,
    /// Cross-program coalescing + write dedup + cache lookups.
    Coalesce,
    /// Fusion planning (annotation span: counts ride `ops`, the work is
    /// executed inside the shard batches).
    Fuse,
    /// Shard batch execution through the pool.
    Execute,
    /// Result assembly + cache memoization.
    Cache,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Admit => "admit",
            Stage::Schedule => "schedule",
            Stage::Coalesce => "coalesce",
            Stage::Fuse => "fuse",
            Stage::Execute => "execute",
            Stage::Cache => "cache",
        }
    }
}

/// Which path served a dual-row activation at the kernel tier boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelRoute {
    /// Whole span from the bit-packed shadow plane (`vt_sigma == 0`).
    Digital,
    /// Masked packed path: deterministic majority from the planes,
    /// marginal minority through the exact backend.
    Masked,
    /// Analog pipeline (LUT / behavioral backends).
    Analog,
    /// Closed-form exact tier.
    Exact,
}

impl KernelRoute {
    pub fn name(&self) -> &'static str {
        match self {
            KernelRoute::Digital => "digital",
            KernelRoute::Masked => "masked",
            KernelRoute::Analog => "analog",
            KernelRoute::Exact => "exact",
        }
    }
}

/// One recorded event.  `t_us` is microseconds since the recorder was
/// created (a process-relative monotonic clock, stable across export).
#[derive(Clone, Debug)]
pub enum TraceEvent {
    Span {
        /// Round sequence number (0 for events outside a round).
        round: u64,
        /// Tenant id for per-program spans; `u64::MAX` for round-level.
        tenant: u64,
        stage: Stage,
        wall_ns: u64,
        /// Stage-specific magnitude: programs admitted, ops coalesced,
        /// activations fused, ops executed, steps cached...
        ops: u64,
    },
    Kernel {
        route: KernelRoute,
        row_a: u32,
        row_b: u32,
        /// Columns the activation spanned.
        cols: u32,
        /// Columns routed through the analog pipeline by the mask.
        marginal_cols: u32,
    },
    /// Sampled digital-vs-analog cross-validation check.
    Xval { mismatch: bool },
    /// A health-rule state transition (`observe::health`).  Alerts are
    /// recorded unconditionally — they are rare by construction
    /// (hysteresis bounds flapping) and are exactly what a postmortem
    /// export exists to capture.
    Alert {
        /// The rule's name (free-form: escaped on export).
        rule: String,
        /// States as `RuleState::name()` (`ok` / `warn` / `critical`).
        from: &'static str,
        to: &'static str,
        /// The signal value that drove the transition.
        value: f64,
    },
}

/// A sequenced, timestamped ring entry.
#[derive(Clone, Debug)]
pub struct Recorded {
    pub seq: u64,
    pub t_us: u64,
    pub event: TraceEvent,
}

/// The fixed-capacity event ring.  See the module doc.
pub struct FlightRecorder {
    spans_on: AtomicBool,
    kernel_on: AtomicBool,
    seq: AtomicU64,
    dropped: AtomicU64,
    /// Runtime-adjustable (`set_capacity`): postmortem depth is a knob,
    /// not a rebuild.
    capacity: AtomicUsize,
    epoch: Instant,
    ring: Mutex<VecDeque<Recorded>>,
}

/// Default ring capacity (events). ~100 rounds of serve spans or the
/// last ~4k kernel activations.
pub const DEFAULT_CAPACITY: usize = 4096;

impl FlightRecorder {
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            spans_on: AtomicBool::new(true),
            kernel_on: AtomicBool::new(false),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            capacity: AtomicUsize::new(capacity.max(1)),
            epoch: Instant::now(),
            ring: Mutex::new(VecDeque::with_capacity(capacity.max(1).min(4096))),
        }
    }

    /// Current ring capacity (events).
    pub fn capacity(&self) -> usize {
        self.capacity.load(Ordering::Relaxed)
    }

    /// Resize the ring (REPL `trace cap <n>`).  Shrinking drops the
    /// oldest events immediately (counted in `dropped()`); growing takes
    /// effect on the next push.
    pub fn set_capacity(&self, capacity: usize) {
        let capacity = capacity.max(1);
        self.capacity.store(capacity, Ordering::Relaxed);
        let mut ring = self.ring.lock().expect("recorder lock");
        while ring.len() > capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn spans_enabled(&self) -> bool {
        self.spans_on.load(Ordering::Relaxed)
    }

    pub fn kernel_enabled(&self) -> bool {
        self.kernel_on.load(Ordering::Relaxed)
    }

    pub fn set_span_events(&self, on: bool) {
        self.spans_on.store(on, Ordering::Relaxed);
    }

    pub fn set_kernel_events(&self, on: bool) {
        self.kernel_on.store(on, Ordering::Relaxed);
    }

    fn push(&self, event: TraceEvent) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let capacity = self.capacity.load(Ordering::Relaxed);
        let mut ring = self.ring.lock().expect("recorder lock");
        while ring.len() >= capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(Recorded { seq, t_us, event });
    }

    /// Record a serve-pipeline span (no-op when span events are off).
    pub fn record_span(&self, round: u64, tenant: Option<u64>, stage: Stage, wall_ns: u64, ops: u64) {
        if !self.spans_enabled() {
            return;
        }
        self.push(TraceEvent::Span {
            round,
            tenant: tenant.unwrap_or(u64::MAX),
            stage,
            wall_ns,
            ops,
        });
    }

    /// Record a kernel-tier activation event (no-op when kernel events
    /// are off — callers should pre-check `kernel_enabled()` on hot
    /// paths to skip argument marshalling too).
    pub fn record_kernel(
        &self,
        route: KernelRoute,
        row_a: usize,
        row_b: usize,
        cols: usize,
        marginal_cols: usize,
    ) {
        if !self.kernel_enabled() {
            return;
        }
        self.push(TraceEvent::Kernel {
            route,
            row_a: row_a as u32,
            row_b: row_b as u32,
            cols: cols as u32,
            marginal_cols: marginal_cols as u32,
        });
    }

    /// Record a sampled cross-validation check.
    pub fn record_xval(&self, mismatch: bool) {
        if !self.kernel_enabled() {
            return;
        }
        self.push(TraceEvent::Xval { mismatch });
    }

    /// Record a health-rule state transition.  Unconditional: alerts are
    /// rare (hysteresis bounds flapping) and are the one event class a
    /// postmortem must never miss.
    pub fn record_alert(&self, rule: &str, from: &'static str, to: &'static str, value: f64) {
        self.push(TraceEvent::Alert {
            rule: rule.to_string(),
            from,
            to,
            value,
        });
    }

    /// Events currently held (<= capacity).
    pub fn len(&self) -> usize {
        self.ring.lock().expect("recorder lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted by capacity pressure since creation/clear.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn clear(&self) {
        self.ring.lock().expect("recorder lock").clear();
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// Copy of the ring, oldest first.
    pub fn snapshot(&self) -> Vec<Recorded> {
        self.ring.lock().expect("recorder lock").iter().cloned().collect()
    }

    /// Export the ring as JSONL (one JSON object per line, oldest first)
    /// — the postmortem format `scripts/` and humans both read.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in self.snapshot() {
            let body = match &r.event {
                TraceEvent::Span { round, tenant, stage, wall_ns, ops } => {
                    let tenant_field = if *tenant == u64::MAX {
                        String::from("null")
                    } else {
                        tenant.to_string()
                    };
                    format!(
                        "\"kind\":\"span\",\"round\":{round},\"tenant\":{tenant_field},\
                         \"stage\":\"{}\",\"wall_ns\":{wall_ns},\"ops\":{ops}",
                        stage.name()
                    )
                }
                TraceEvent::Kernel { route, row_a, row_b, cols, marginal_cols } => format!(
                    "\"kind\":\"kernel\",\"route\":\"{}\",\"row_a\":{row_a},\
                     \"row_b\":{row_b},\"cols\":{cols},\"marginal_cols\":{marginal_cols}",
                    route.name()
                ),
                TraceEvent::Xval { mismatch } => {
                    format!("\"kind\":\"xval\",\"mismatch\":{mismatch}")
                }
                TraceEvent::Alert { rule, from, to, value } => format!(
                    "\"kind\":\"alert\",\"rule\":\"{}\",\"from\":\"{from}\",\
                     \"to\":\"{to}\",\"value\":{}",
                    json_escape(rule),
                    json_f64(*value)
                ),
            };
            out.push_str(&format!("{{\"seq\":{},\"t_us\":{},{body}}}\n", r.seq, r.t_us));
        }
        out
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let r = FlightRecorder::with_capacity(3);
        for i in 0..5u64 {
            r.record_span(i, None, Stage::Execute, 10, 1);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let snap = r.snapshot();
        // newest 3 survive, oldest first
        assert_eq!(snap[0].seq, 2);
        assert_eq!(snap[2].seq, 4);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn kernel_events_gate_on_flag() {
        let r = FlightRecorder::with_capacity(8);
        r.record_kernel(KernelRoute::Digital, 0, 1, 64, 0);
        assert!(r.is_empty(), "kernel events default off");
        r.set_kernel_events(true);
        r.record_kernel(KernelRoute::Masked, 0, 1, 64, 3);
        r.record_xval(false);
        assert_eq!(r.len(), 2);
        r.set_span_events(false);
        r.record_span(1, Some(4), Stage::Admit, 5, 1);
        assert_eq!(r.len(), 2, "span events gated independently");
    }

    #[test]
    fn capacity_knob_shrinks_and_grows() {
        let r = FlightRecorder::with_capacity(8);
        for i in 0..8u64 {
            r.record_span(i, None, Stage::Execute, 1, 1);
        }
        assert_eq!(r.capacity(), 8);
        r.set_capacity(3);
        assert_eq!(r.capacity(), 3);
        assert_eq!(r.len(), 3, "shrink trims oldest immediately");
        assert_eq!(r.dropped(), 5);
        let snap = r.snapshot();
        assert_eq!(snap[0].seq, 5, "newest survive a shrink");
        r.set_capacity(16);
        for i in 8..20u64 {
            r.record_span(i, None, Stage::Execute, 1, 1);
        }
        assert_eq!(r.len(), 15, "grow takes effect on the next push");
        r.set_capacity(0);
        assert_eq!(r.capacity(), 1, "capacity floors at 1");
    }

    #[test]
    fn alerts_record_unconditionally_and_escape_in_jsonl() {
        let r = FlightRecorder::with_capacity(8);
        r.set_span_events(false);
        r.set_kernel_events(false);
        r.record_alert("slo\"burn\nfast", "ok", "warn", 1.5);
        r.record_alert("quota", "warn", "critical", f64::INFINITY);
        assert_eq!(r.len(), 2, "alerts ignore the span/kernel gates");
        let jsonl = r.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(
            lines[0].contains("\"rule\":\"slo\\\"burn\\nfast\""),
            "quotes/newlines in rule names must round-trip: {}",
            lines[0]
        );
        assert!(lines[0].contains("\"from\":\"ok\"") && lines[0].contains("\"to\":\"warn\""));
        assert!(lines[0].contains("\"value\":1.5"));
        assert!(lines[1].contains("\"value\":\"inf\""), "{}", lines[1]);
    }

    #[test]
    fn jsonl_export_shape() {
        let r = FlightRecorder::with_capacity(8);
        r.set_kernel_events(true);
        r.record_span(7, Some(3), Stage::Coalesce, 1234, 9);
        r.record_span(7, None, Stage::Execute, 50, 2);
        r.record_kernel(KernelRoute::Digital, 2, 5, 256, 0);
        r.record_xval(true);
        let jsonl = r.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("\"kind\":\"span\"") && lines[0].contains("\"tenant\":3"));
        assert!(lines[0].contains("\"stage\":\"coalesce\"") && lines[0].contains("\"ops\":9"));
        assert!(lines[1].contains("\"tenant\":null"));
        assert!(lines[2].contains("\"route\":\"digital\"") && lines[2].contains("\"cols\":256"));
        assert!(lines[3].contains("\"kind\":\"xval\"") && lines[3].contains("\"mismatch\":true"));
        for l in &lines {
            assert!(l.starts_with('{') && l.ends_with('}'), "JSONL line shape: {l}");
        }
    }
}
