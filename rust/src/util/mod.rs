//! Infrastructure substrates built in-tree (no clap/criterion/proptest/rand
//! in the offline environment — see DESIGN.md §3).

pub mod args;
pub mod bench;
pub mod quick;
pub mod rng;
pub mod table;
