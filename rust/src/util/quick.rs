//! quickcheck-lite: property-based testing without the `proptest` crate.
//!
//! Generates random cases from a deterministic RNG, runs the property, and
//! on failure performs greedy shrinking via the case's `shrink` candidates
//! before reporting the minimal counterexample.  Used throughout the crate
//! for coordinator invariants, arithmetic identities, and energy-model
//! monotonicity properties.

use super::rng::Rng;

/// A generatable, shrinkable test case.
pub trait Arbitrary: Sized + Clone + std::fmt::Debug {
    fn generate(rng: &mut Rng) -> Self;

    /// Candidate smaller versions of `self` (tried in order).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

/// Configuration for a property run.
pub struct Quick {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Quick {
    fn default() -> Self {
        Self { cases: 256, seed: 0xAD2A_u64, max_shrink_steps: 500 }
    }
}

impl Quick {
    pub fn with_cases(cases: usize) -> Self {
        Self { cases, ..Self::default() }
    }

    /// Check `prop` over `cases` generated inputs; panics with the shrunk
    /// counterexample on failure.
    pub fn check<T: Arbitrary, P: Fn(&T) -> bool>(&self, name: &str, prop: P) {
        let mut rng = Rng::new(self.seed);
        for case_idx in 0..self.cases {
            let case = T::generate(&mut rng);
            if !prop(&case) {
                let minimal = self.shrink_failure(&case, &prop);
                panic!(
                    "property {name:?} failed on case {case_idx}\n\
                     original: {case:?}\n\
                     shrunk:   {minimal:?}"
                );
            }
        }
    }

    fn shrink_failure<T: Arbitrary, P: Fn(&T) -> bool>(&self, case: &T, prop: &P) -> T {
        let mut current = case.clone();
        let mut steps = 0;
        'outer: while steps < self.max_shrink_steps {
            for candidate in current.shrink() {
                steps += 1;
                if !prop(&candidate) {
                    current = candidate;
                    continue 'outer;
                }
                if steps >= self.max_shrink_steps {
                    break 'outer;
                }
            }
            break; // no shrink candidate still fails -> minimal
        }
        current
    }
}

// ---- Arbitrary instances for common shapes -------------------------------

impl Arbitrary for u64 {
    fn generate(rng: &mut Rng) -> Self {
        // favor small and boundary values — arithmetic bugs live there
        match rng.below(8) {
            0 => 0,
            1 => 1,
            2 => u64::MAX,
            3 => rng.below(256),
            _ => rng.next_u64(),
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if *self > 0 {
            v.push(0);
            v.push(self / 2);
            v.push(self - 1);
        }
        v
    }
}

impl Arbitrary for bool {
    fn generate(rng: &mut Rng) -> Self {
        rng.bool()
    }

    fn shrink(&self) -> Vec<Self> {
        if *self { vec![false] } else { vec![] }
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn generate(rng: &mut Rng) -> Self {
        (A::generate(rng), B::generate(rng))
    }

    fn shrink(&self) -> Vec<Self> {
        let mut v: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        v.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        v
    }
}

impl<A: Arbitrary, B: Arbitrary, C: Arbitrary> Arbitrary for (A, B, C) {
    fn generate(rng: &mut Rng) -> Self {
        (A::generate(rng), B::generate(rng), C::generate(rng))
    }

    fn shrink(&self) -> Vec<Self> {
        let mut v: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        v.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        v.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        v
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn generate(rng: &mut Rng) -> Self {
        let len = rng.below(33) as usize;
        (0..len).map(|_| T::generate(rng)).collect()
    }

    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if !self.is_empty() {
            v.push(Vec::new());
            v.push(self[..self.len() / 2].to_vec());
            let mut tail = self.clone();
            tail.remove(0);
            v.push(tail);
            // shrink one element
            if let Some(shrunk_first) = self[0].shrink().into_iter().next() {
                let mut c = self.clone();
                c[0] = shrunk_first;
                v.push(c);
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Quick::with_cases(200).check::<u64, _>("x == x", |x| *x == *x);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let caught = std::panic::catch_unwind(|| {
            Quick::with_cases(500).check::<u64, _>("x < 100", |x| *x < 100);
        });
        let msg = match caught {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        // greedy shrink should land on exactly 100 (smallest failing value)
        assert!(msg.contains("shrunk:   100"), "message: {msg}");
    }

    #[test]
    fn tuple_generation_and_shrinking() {
        let caught = std::panic::catch_unwind(|| {
            Quick::with_cases(500)
                .check::<(u64, u64), _>("sum < 50", |(a, b)| a.wrapping_add(*b) < 50);
        });
        assert!(caught.is_err());
    }

    #[test]
    fn vec_shrink_candidates_are_smaller_or_equal() {
        let v: Vec<u64> = vec![5, 6, 7, 8];
        for c in v.shrink() {
            assert!(c.len() <= v.len());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        // same seed -> same first generated case
        let mut r1 = Rng::new(99);
        let mut r2 = Rng::new(99);
        assert_eq!(u64::generate(&mut r1), u64::generate(&mut r2));
    }
}
