//! Minimal CLI argument parser (the offline environment has no `clap`).
//!
//! Supports the subset the `adra` binary needs: subcommands, `--flag`,
//! `--key value` / `--key=value`, repeated keys, and positional arguments,
//! with generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec for one flag/option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments: options by name, plus positionals.
#[derive(Debug, Default)]
pub struct Parsed {
    opts: BTreeMap<String, Vec<String>>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.opts.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|e| format!("--{name}: invalid integer {s:?}: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|e| format!("--{name}: invalid number {s:?}: {e}")),
        }
    }
}

/// Parser for one (sub)command.
pub struct ArgParser {
    pub command: &'static str,
    pub about: &'static str,
    specs: Vec<OptSpec>,
}

impl ArgParser {
    pub fn new(command: &'static str, about: &'static str) -> Self {
        Self { command, about, specs: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, takes_value: false, default: None, help });
        self
    }

    pub fn opt(
        mut self,
        name: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        self.specs.push(OptSpec { name, takes_value: true, default, help });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\noptions:\n", self.command, self.about);
        for s in &self.specs {
            let val = if s.takes_value { " <value>" } else { "" };
            let def = match s.default {
                Some(d) => format!(" [default: {d}]"),
                None => String::new(),
            };
            out.push_str(&format!("  --{}{val}\n      {}{def}\n", s.name, s.help));
        }
        out
    }

    /// Parse a raw arg list (without argv[0] / the subcommand itself).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut parsed = Parsed::default();
        // seed defaults
        for s in &self.specs {
            if let Some(d) = s.default {
                parsed.opts.insert(s.name.to_string(), vec![d.to_string()]);
            }
        }
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                let value = if !spec.takes_value {
                    if inline_val.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| format!("--{name} requires a value"))?
                        .clone()
                };
                let entry = parsed.opts.entry(name.to_string()).or_default();
                if spec.default.is_some() && entry.len() == 1 && entry[0] == spec.default.unwrap()
                {
                    entry.clear(); // replace default rather than append to it
                }
                entry.push(value);
            } else {
                parsed.positionals.push(arg.clone());
            }
        }
        Ok(parsed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parser() -> ArgParser {
        ArgParser::new("test", "test parser")
            .flag("verbose", "enable verbosity")
            .opt("size", Some("1024"), "array size")
            .opt("name", None, "a name")
    }

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = parser().parse(&argv(&[])).unwrap();
        assert_eq!(p.get("size"), Some("1024"));
        assert!(!p.flag("verbose"));
        assert_eq!(p.get("name"), None);
    }

    #[test]
    fn space_and_equals_forms() {
        let p = parser().parse(&argv(&["--size", "256", "--name=foo"])).unwrap();
        assert_eq!(p.get("size"), Some("256"));
        assert_eq!(p.get("name"), Some("foo"));
    }

    #[test]
    fn flags_and_positionals() {
        let p = parser().parse(&argv(&["--verbose", "pos1", "pos2"])).unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.positionals, vec!["pos1", "pos2"]);
    }

    #[test]
    fn repeated_option_overrides_default_then_appends() {
        let p = parser()
            .parse(&argv(&["--size", "128", "--size", "512"]))
            .unwrap();
        assert_eq!(p.get_all("size"), &["128".to_string(), "512".to_string()]);
        assert_eq!(p.get("size"), Some("512")); // last wins
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parser().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parser().parse(&argv(&["--name"])).is_err());
    }

    #[test]
    fn numeric_accessors() {
        let p = parser().parse(&argv(&["--size", "42"])).unwrap();
        assert_eq!(p.get_usize("size").unwrap(), Some(42));
        let bad = parser().parse(&argv(&["--size", "x"])).unwrap();
        assert!(bad.get_usize("size").is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = parser().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--size"));
        assert!(err.contains("array size"));
    }
}
