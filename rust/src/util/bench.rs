//! Micro-benchmark harness (the offline environment has no `criterion`).
//!
//! Provides warmup, calibrated iteration counts, and robust statistics
//! (median / p10 / p90 over sample batches), printed in a stable format
//! the `cargo bench` targets under `rust/benches/` share.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics, in nanoseconds per iteration.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples_ns: Vec<f64>,
}

impl BenchStats {
    fn sorted(&self) -> Vec<f64> {
        let mut v = self.samples_ns.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    pub fn median_ns(&self) -> f64 {
        percentile(&self.sorted(), 50.0)
    }

    pub fn p10_ns(&self) -> f64 {
        percentile(&self.sorted(), 10.0)
    }

    pub fn p90_ns(&self) -> f64 {
        percentile(&self.sorted(), 90.0)
    }

    /// Iterations per second implied by the median.
    pub fn throughput(&self) -> f64 {
        1e9 / self.median_ns()
    }

    pub fn report(&self) -> String {
        format!(
            "bench {:<44} {:>12.1} ns/iter (p10 {:>10.1}, p90 {:>10.1}) {:>14.0} it/s",
            self.name,
            self.median_ns(),
            self.p10_ns(),
            self.p90_ns(),
            self.throughput()
        )
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Harness: calibrates iteration count to the target sample duration, runs
/// `samples` batches, reports statistics.
pub struct Bench {
    pub warmup: Duration,
    pub target_sample: Duration,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(150),
            target_sample: Duration::from_millis(60),
            samples: 15,
        }
    }
}

impl Bench {
    /// Quick profile for expensive end-to-end benches.
    pub fn coarse() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            target_sample: Duration::from_millis(120),
            samples: 7,
        }
    }

    /// Run `f` repeatedly; `f` should perform ONE logical iteration and
    /// return a value the harness black-boxes to defeat dead-code elim.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchStats {
        // Warmup + calibration: find iters/sample such that a sample takes
        // roughly target_sample.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let elapsed = t0.elapsed().as_nanos() as f64;
            samples_ns.push(elapsed / iters as f64);
        }
        let stats = BenchStats {
            name: name.to_string(),
            iters_per_sample: iters,
            samples_ns,
        };
        println!("{}", stats.report());
        stats
    }
}

/// Serialize bench results as a JSON array of
/// `{"name", "ns_per_iter", "p10_ns", "p90_ns", "iters"}` objects — the
/// machine-readable companion of the printed table, consumed by the perf
/// trajectory (CI uploads `BENCH_hotpath.json`).
pub fn to_json(stats: &[BenchStats]) -> String {
    to_json_with_meta(stats, &[])
}

/// `to_json` plus trailing metric records `{"name", "value"}` — scalar
/// side-channels of a bench run (e.g. the deterministic-column fraction
/// of the masked tier) that regression tooling reads alongside the
/// timings.
pub fn to_json_with_meta(stats: &[BenchStats], meta: &[(&str, f64)]) -> String {
    let total = stats.len() + meta.len();
    let mut s = String::from("[\n");
    for (i, b) in stats.iter().enumerate() {
        let comma = if i + 1 == total { "" } else { "," };
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"ns_per_iter\": {:.3}, \"p10_ns\": {:.3}, \
             \"p90_ns\": {:.3}, \"iters\": {}}}{}\n",
            json_escape(&b.name),
            b.median_ns(),
            b.p10_ns(),
            b.p90_ns(),
            b.iters_per_sample,
            comma
        ));
    }
    for (i, (name, value)) in meta.iter().enumerate() {
        let comma = if stats.len() + i + 1 == total { "" } else { "," };
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"value\": {:.6}}}{}\n",
            json_escape(name),
            value,
            comma
        ));
    }
    s.push_str("]\n");
    s
}

/// JSON string escaping (Rust's `{:?}` uses `\u{..}` syntax, which is not
/// valid JSON).  Non-ASCII passes through raw — JSON is UTF-8.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write bench results to `path` as JSON (see [`to_json`]).
pub fn write_json(path: &str, stats: &[BenchStats]) -> std::io::Result<()> {
    std::fs::write(path, to_json(stats))
}

/// Write bench results + scalar metrics (see [`to_json_with_meta`]).
pub fn write_json_with_meta(
    path: &str,
    stats: &[BenchStats],
    meta: &[(&str, f64)],
) -> std::io::Result<()> {
    std::fs::write(path, to_json_with_meta(stats, meta))
}

/// Optimization barrier. `std::hint::black_box` is stable since 1.66.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_on_known_data() {
        let s = BenchStats {
            name: "t".into(),
            iters_per_sample: 1,
            samples_ns: vec![5.0, 1.0, 3.0, 2.0, 4.0],
        };
        assert_eq!(s.median_ns(), 3.0);
        assert_eq!(s.p10_ns(), 1.0);
        assert_eq!(s.p90_ns(), 5.0);
    }

    #[test]
    fn runs_and_produces_positive_stats() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            target_sample: Duration::from_millis(2),
            samples: 3,
        };
        let mut acc = 0u64;
        let stats = b.run("noop-ish", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert!(stats.median_ns() > 0.0);
        assert!(stats.throughput() > 0.0);
        assert_eq!(stats.samples_ns.len(), 3);
    }

    #[test]
    fn json_output_is_well_formed() {
        let stats = vec![
            BenchStats {
                name: "alpha".into(),
                iters_per_sample: 10,
                samples_ns: vec![10.0, 12.0, 11.0],
            },
            BenchStats {
                name: "beta".into(),
                iters_per_sample: 3,
                samples_ns: vec![5.0],
            },
        ];
        let json = to_json(&stats);
        assert!(json.starts_with("[\n"));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\"name\": \"alpha\""));
        assert!(json.contains("\"name\": \"beta\""));
        assert!(json.contains("\"ns_per_iter\": 11.000"));
        assert!(json.contains("\"iters\": 3"));
        // exactly one trailing comma between the two records
        assert_eq!(json.matches("},").count(), 1);
        // escaping: quotes/backslashes/control chars become valid JSON
        assert_eq!(json_escape("a\"b\\c\nd µs"), "a\\\"b\\\\c\\u000ad µs");

        let path = std::env::temp_dir().join("bench_json_test.json");
        let path = path.to_str().unwrap();
        write_json(path, &stats).unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), json);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn json_meta_records_appended() {
        let stats = vec![BenchStats {
            name: "a".into(),
            iters_per_sample: 1,
            samples_ns: vec![1.0],
        }];
        let j = to_json_with_meta(&stats, &[("det-fraction", 0.987654)]);
        assert!(j.contains("\"name\": \"det-fraction\", \"value\": 0.987654"), "{j}");
        assert!(j.trim_end().ends_with(']'));
        // one separator between the bench record and the metric record
        assert_eq!(j.matches("},").count(), 1);
    }

    #[test]
    fn report_contains_name() {
        let s = BenchStats {
            name: "mybench".into(),
            iters_per_sample: 10,
            samples_ns: vec![10.0; 5],
        };
        assert!(s.report().contains("mybench"));
    }
}
