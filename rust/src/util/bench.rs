//! Micro-benchmark harness (the offline environment has no `criterion`).
//!
//! Provides warmup, calibrated iteration counts, and robust statistics
//! (median / p10 / p90 over sample batches), printed in a stable format
//! the `cargo bench` targets under `rust/benches/` share.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics, in nanoseconds per iteration.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples_ns: Vec<f64>,
}

impl BenchStats {
    fn sorted(&self) -> Vec<f64> {
        let mut v = self.samples_ns.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    pub fn median_ns(&self) -> f64 {
        percentile(&self.sorted(), 50.0)
    }

    pub fn p10_ns(&self) -> f64 {
        percentile(&self.sorted(), 10.0)
    }

    pub fn p90_ns(&self) -> f64 {
        percentile(&self.sorted(), 90.0)
    }

    /// Iterations per second implied by the median.
    pub fn throughput(&self) -> f64 {
        1e9 / self.median_ns()
    }

    pub fn report(&self) -> String {
        format!(
            "bench {:<44} {:>12.1} ns/iter (p10 {:>10.1}, p90 {:>10.1}) {:>14.0} it/s",
            self.name,
            self.median_ns(),
            self.p10_ns(),
            self.p90_ns(),
            self.throughput()
        )
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Harness: calibrates iteration count to the target sample duration, runs
/// `samples` batches, reports statistics.
pub struct Bench {
    pub warmup: Duration,
    pub target_sample: Duration,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(150),
            target_sample: Duration::from_millis(60),
            samples: 15,
        }
    }
}

impl Bench {
    /// Quick profile for expensive end-to-end benches.
    pub fn coarse() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            target_sample: Duration::from_millis(120),
            samples: 7,
        }
    }

    /// Run `f` repeatedly; `f` should perform ONE logical iteration and
    /// return a value the harness black-boxes to defeat dead-code elim.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchStats {
        // Warmup + calibration: find iters/sample such that a sample takes
        // roughly target_sample.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            let elapsed = t0.elapsed().as_nanos() as f64;
            samples_ns.push(elapsed / iters as f64);
        }
        let stats = BenchStats {
            name: name.to_string(),
            iters_per_sample: iters,
            samples_ns,
        };
        println!("{}", stats.report());
        stats
    }
}

/// Optimization barrier. `std::hint::black_box` is stable since 1.66.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_on_known_data() {
        let s = BenchStats {
            name: "t".into(),
            iters_per_sample: 1,
            samples_ns: vec![5.0, 1.0, 3.0, 2.0, 4.0],
        };
        assert_eq!(s.median_ns(), 3.0);
        assert_eq!(s.p10_ns(), 1.0);
        assert_eq!(s.p90_ns(), 5.0);
    }

    #[test]
    fn runs_and_produces_positive_stats() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            target_sample: Duration::from_millis(2),
            samples: 3,
        };
        let mut acc = 0u64;
        let stats = b.run("noop-ish", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert!(stats.median_ns() > 0.0);
        assert!(stats.throughput() > 0.0);
        assert_eq!(stats.samples_ns.len(), 3);
    }

    #[test]
    fn report_contains_name() {
        let s = BenchStats {
            name: "mybench".into(),
            iters_per_sample: 10,
            samples_ns: vec![10.0; 5],
        };
        assert!(s.report().contains("mybench"));
    }
}
