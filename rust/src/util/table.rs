//! ASCII table rendering for figure harnesses and reports.
//!
//! The figure commands print the same rows/series the paper's plots show;
//! this module keeps the formatting consistent everywhere.

/// A simple right-aligned column table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: impl Into<String>) -> Self {
        self.title = Some(title.into());
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch: {} vs {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:>width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers used across the figure harnesses.
pub fn fmt_si(value: f64, unit: &str) -> String {
    let (scaled, prefix) = si_scale(value);
    format!("{scaled:.3} {prefix}{unit}")
}

pub fn si_scale(value: f64) -> (f64, &'static str) {
    let a = value.abs();
    if a == 0.0 {
        (0.0, "")
    } else if a >= 1.0 {
        if a >= 1e9 {
            (value / 1e9, "G")
        } else if a >= 1e6 {
            (value / 1e6, "M")
        } else if a >= 1e3 {
            (value / 1e3, "k")
        } else {
            (value, "")
        }
    } else if a >= 1e-3 {
        (value * 1e3, "m")
    } else if a >= 1e-6 {
        (value * 1e6, "u")
    } else if a >= 1e-9 {
        (value * 1e9, "n")
    } else if a >= 1e-12 {
        (value * 1e12, "p")
    } else {
        (value * 1e15, "f")
    }
}

pub fn fmt_pct(frac: f64) -> String {
    format!("{:.2}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "12345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[0].contains("name") && lines[0].contains("value"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn si_scaling() {
        assert_eq!(fmt_si(204.8e-15, "J"), "204.800 fJ");
        assert_eq!(fmt_si(1.94e6, "Hz"), "1.940 MHz");
        assert_eq!(fmt_si(35.5e-6, "A"), "35.500 uA");
        assert_eq!(fmt_si(0.05, "V"), "50.000 mV");
        assert_eq!(fmt_si(2.5e-9, "s"), "2.500 ns");
    }

    #[test]
    fn pct_format() {
        assert_eq!(fmt_pct(0.4118), "41.18%");
    }

    #[test]
    fn title_appears() {
        let t = Table::new(&["x"]).with_title("Fig 4(a)");
        assert!(t.render().starts_with("== Fig 4(a) =="));
    }
}
