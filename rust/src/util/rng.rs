//! Deterministic PRNGs for workload generation and property testing.
//!
//! The offline environment ships no `rand` crate, so we carry our own
//! SplitMix64 (seeding / stream splitting) and xoshiro256++ (bulk
//! generation).  Both are the reference algorithms from Blackman &
//! Vigna; they are deterministic across platforms, which the workload
//! traces and quickcheck-lite shrinking rely on.

/// SplitMix64: tiny, high-quality 64-bit mixer. Used to seed xoshiro and
/// to derive independent streams from a master seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the general-purpose generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64, per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-thread workloads).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller (used for V_T variation).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 0.0 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Zipf-like rank sampler over [0, n): rank ~ 1/(k+1)^s, via rejection.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // inverse-CDF over the harmonic-ish weights using a coarse
        // normalization; exactness is unnecessary for workload skew.
        debug_assert!(n > 0);
        let hn: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.next_f64() * hn;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::new(7);
        let mut c1 = a.split();
        let mut c2 = a.split();
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            let x = r.uniform(-2.5, 7.5);
            assert!((-2.5..7.5).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_bounds() {
        let mut r = Rng::new(5);
        let mut seen = [0u32; 7];
        for _ in 0..70_000 {
            seen[r.below(7) as usize] += 1;
        }
        for (i, &c) in seen.iter().enumerate() {
            assert!(c > 8_000, "bucket {i} count {c}");
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(6);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range_u64(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_skews_toward_low_ranks() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 16];
        for _ in 0..20_000 {
            counts[r.zipf(16, 1.2) as usize] += 1;
        }
        assert!(counts[0] > counts[8] * 4);
        assert!(counts.iter().all(|&c| c > 0) || counts[15] == 0);
    }
}
