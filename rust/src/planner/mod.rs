//! The cost-model-driven CiM query planner.
//!
//! Callers used to hand-build `CimOp` streams against a single engine;
//! the planner is the layer above the engines that decides *which*
//! executor runs each op and *where* it runs:
//!
//! * [`ir`] — a tiny program IR for bulk bitwise/arithmetic column
//!   programs (filter, compare, subtract, aggregate over record ranges);
//! * [`cost`] — calibrated per-op price tables for the ADRA engine vs the
//!   two-read near-memory baseline, derived from the same
//!   `energy::EnergyModel` the engines charge, plus the
//!   objective-driven routing decision;
//! * [`engine`] — the cost-routed hybrid engine one coordinator shard
//!   runs, dispatching each op to the executor the model picked;
//! * [`lower`] — IR -> routed `CimOp` stream, with serial and
//!   fusion-aware (`coordinator::fuse`) cost predictions;
//! * [`place`] — shard-aware placement over the `Coordinator` worker
//!   pool, parallel execution, output merging, and predicted-vs-measured
//!   reporting through `metrics::PredictionReport`.
//!
//! ```text
//!   Program (ir) --lower--> RoutedOp stream --place--> per-shard batches
//!        |                        |                         |
//!    cost tables            predictions            Coordinator workers
//!        |                        |                  (PlannedEngine)
//!        +---- PlanCostModel -----+--- PredictionReport <-- metrics
//! ```

pub mod calibrate;
pub mod cost;
pub mod engine;
pub mod ir;
pub mod lower;
pub mod place;

pub use calibrate::{
    place_calibrated, CalibratedCostModel, CalibrationFactor, CalibrationSample,
    CalibrationStore, SharedCalibration,
};
pub use cost::{
    class_of, CostTable, Decision, Executor, Objective, OpClass, PlanCostModel, TableCost,
    TierCostModel,
};
pub use engine::{planned_coordinator, PlannedEngine};
pub use ir::{AggKind, IrOp, Layout, PlanError, Predicate, Program, RecordRange, ScratchRow};
pub use lower::{lower, LoweredProgram, RoutedOp, StepSpan};
pub use place::{
    place, place_with, ExecError, ExecutionReport, Placement, Reduction, ShardPlan, StepOutput,
};
