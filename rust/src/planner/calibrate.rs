//! The calibration actuator: closes the loop from measured op costs back
//! into the planner's price tables and routing (ROADMAP item 1).
//!
//! PRs 6–7 built the *measurement* side — `Placement::assemble` publishes
//! per-op-class prediction error to the observe registry and the series
//! store keeps its windowed EWMA — but the tables stayed purely analytic.
//! This module is the missing actuator, shaped like optd's
//! `AdaptiveCostModel` + `RuntimeAdaptionStorage`: a base analytic
//! [`PlanCostModel`] wrapped by runtime-adaption storage keyed by
//! (shard, op class, executor).
//!
//! The loop, per serve round (or per `Placement` run):
//!
//! ```text
//!   assemble() samples --> CalibratedCostModel::absorb
//!        |                       |
//!   measured/predicted     EWMA factor store (clamped [0.25, 4])
//!   cost ratios                  |
//!                          preferred executor per (shard, class)
//!                                |  sustain-streak hysteresis
//!                          committed routing pins
//!                                |
//!              per-shard effective PlanCostModels (scaled tables)
//!                 |                         |
//!       place_calibrated lowering   Coordinator::set_routing
//!                                   (workers honor the flip)
//! ```
//!
//! Safety properties:
//! * factors are EWMA-folded (`ALPHA`) and clamped to
//!   [`CalibrationFactor::MIN`], [`CalibrationFactor::MAX`] — a single
//!   wild run cannot blow up a price;
//! * routing follows the *committed* decision, which flips only after
//!   the scaled-score preference disagrees for `sustain` consecutive
//!   absorbs — a single noisy run cannot flip routing;
//! * with exact tables (the repo default) measured == predicted, factors
//!   stay ~1.0 and behavior is bit-identical to the analytic model.
//!
//! [`CalibrationStore::save`]/[`load`] persist the learned factors and
//! committed routing as a small hand-rolled JSON snapshot (the crate is
//! serde-free), so a restarted daemon keeps its corrections; a corrupt
//! or missing snapshot falls back to the analytic tables.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use crate::coordinator::Coordinator;
use crate::energy::OpCost;
use crate::observe::Registry;

use super::cost::{Executor, Objective, OpClass, PlanCostModel};
use super::ir::{PlanError, Program};
use super::place::{place_with, Placement};
use crate::config::SimConfig;

/// New-sample weight of the factor EWMA.
const ALPHA: f64 = 0.3;

/// One run's predicted-vs-measured aggregate for one
/// (shard, op class, executor) cell — produced by
/// `Placement::assemble`, consumed by [`CalibratedCostModel::absorb`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibrationSample {
    pub shard: usize,
    pub op_class: OpClass,
    pub executor: Executor,
    /// Summed predicted cost of the executed ops (from the lowering's
    /// effective model — i.e. already carrying the current factors).
    pub predicted: OpCost,
    /// Summed engine-charged cost of the same ops.
    pub measured: OpCost,
    pub ops: u64,
}

/// EWMA correction factor for one (shard, op class, executor) cell:
/// the multiplier that maps the ANALYTIC table price to the measured
/// price.  1.0 = the table is exact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibrationFactor {
    pub energy: f64,
    pub latency: f64,
    /// Absorbed runs (not ops) behind this estimate.
    pub samples: u64,
}

impl CalibrationFactor {
    /// Clamp band: a correction can at most quarter or quadruple a
    /// price.  Anything drifting past the band is a modeling bug, not a
    /// calibration target — the `calibration_runaway` health rule warns
    /// near the edge.
    pub const MIN: f64 = 0.25;
    pub const MAX: f64 = 4.0;

    fn fold(&mut self, target_energy: f64, target_latency: f64) {
        self.energy = (self.energy + ALPHA * (target_energy - self.energy))
            .clamp(Self::MIN, Self::MAX);
        self.latency = (self.latency + ALPHA * (target_latency - self.latency))
            .clamp(Self::MIN, Self::MAX);
        self.samples += 1;
    }

    /// The larger of the factor's distortion ratios: max(f, 1/f) over
    /// both dimensions.  1.0 = no correction.
    pub fn distortion(&self) -> f64 {
        let d = |f: f64| if f >= 1.0 { f } else { 1.0 / f };
        d(self.energy).max(d(self.latency))
    }
}

impl Default for CalibrationFactor {
    fn default() -> Self {
        Self { energy: 1.0, latency: 1.0, samples: 0 }
    }
}

fn executor_index(e: Executor) -> usize {
    match e {
        Executor::Adra => 0,
        Executor::Baseline => 1,
    }
}

fn executor_from_index(i: usize) -> Option<Executor> {
    match i {
        0 => Some(Executor::Adra),
        1 => Some(Executor::Baseline),
        _ => None,
    }
}

fn executor_from_name(name: &str) -> Option<Executor> {
    match name {
        "adra" => Some(Executor::Adra),
        "baseline" => Some(Executor::Baseline),
        _ => None,
    }
}

fn class_from_name(name: &str) -> Option<OpClass> {
    OpClass::ALL.into_iter().find(|c| c.name() == name)
}

/// The runtime-adaption storage: learned correction factors plus the
/// committed routing decisions, persistable as a JSON snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CalibrationStore {
    /// (shard, op class index, executor index) -> factor.
    factors: BTreeMap<(usize, usize, usize), CalibrationFactor>,
    /// (shard, op class index) -> committed executor (the routing pin).
    committed: BTreeMap<(usize, usize), Executor>,
    /// (shard, op class index) -> (candidate executor, disagreement
    /// streak).  Volatile — not persisted: a restart re-earns the flip.
    pending: BTreeMap<(usize, usize), (Executor, u32)>,
    /// Per-op-class EWMA of |measured/predicted - 1| (energy), over the
    /// EFFECTIVE (calibrated) predictions — the convergence witness.
    error_ewma: BTreeMap<usize, f64>,
}

impl CalibrationStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn factor(&self, shard: usize, class: OpClass, executor: Executor) -> CalibrationFactor {
        self.factors
            .get(&(shard, class as usize, executor_index(executor)))
            .copied()
            .unwrap_or_default()
    }

    pub fn committed(&self, shard: usize, class: OpClass) -> Option<Executor> {
        self.committed.get(&(shard, class as usize)).copied()
    }

    /// The per-class prediction-error EWMA (energy), if any run was
    /// absorbed for the class.
    pub fn class_error(&self, class: OpClass) -> Option<f64> {
        self.error_ewma.get(&(class as usize)).copied()
    }

    pub fn is_empty(&self) -> bool {
        self.factors.is_empty() && self.committed.is_empty()
    }

    /// Worst distortion across every stored factor (1.0 when empty).
    pub fn max_distortion(&self) -> f64 {
        self.factors.values().map(|f| f.distortion()).fold(1.0, f64::max)
    }

    pub fn clear(&mut self) {
        *self = Self::default();
    }

    /// Human-readable table for the REPL `calibration` command.
    pub fn report(&self) -> String {
        if self.is_empty() {
            return "calibration: empty (analytic tables in effect)".to_string();
        }
        let mut out = String::from("calibration factors (measured/analytic):\n");
        for (&(shard, ci, ei), f) in &self.factors {
            let class = OpClass::ALL[ci];
            let exec = executor_from_index(ei).expect("stored executor index");
            out.push_str(&format!(
                "  shard {shard} {:<11} {:<8} energy x{:.3} latency x{:.3} ({} runs)\n",
                class.name(),
                exec.name(),
                f.energy,
                f.latency,
                f.samples
            ));
        }
        for (&(shard, ci), exec) in &self.committed {
            out.push_str(&format!(
                "  routing: shard {shard} {} -> {}\n",
                OpClass::ALL[ci].name(),
                exec.name()
            ));
        }
        for (&ci, err) in &self.error_ewma {
            out.push_str(&format!(
                "  error EWMA {}: {:.4}\n",
                OpClass::ALL[ci].name(),
                err
            ));
        }
        out.push_str(&format!("  max distortion: {:.3}", self.max_distortion()));
        out
    }

    // ---- persistence (hand-rolled JSON; the crate is serde-free) ----

    /// Serialize factors + committed routing.  Streaks are volatile and
    /// deliberately dropped: a restarted daemon must re-earn any flip.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\"version\":1,\"factors\":[");
        for (i, (&(shard, ci, ei), f)) in self.factors.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"shard\":{shard},\"op_class\":\"{}\",\"executor\":\"{}\",\
                 \"energy\":{:.17},\"latency\":{:.17},\"samples\":{}}}",
                OpClass::ALL[ci].name(),
                executor_from_index(ei).expect("stored executor index").name(),
                f.energy,
                f.latency,
                f.samples
            ));
        }
        s.push_str("],\"committed\":[");
        for (i, (&(shard, ci), exec)) in self.committed.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"shard\":{shard},\"op_class\":\"{}\",\"executor\":\"{}\"}}",
                OpClass::ALL[ci].name(),
                exec.name()
            ));
        }
        s.push_str("]}");
        s
    }

    /// Parse a snapshot; `None` on anything malformed (caller falls back
    /// to the analytic tables).
    pub fn from_json(text: &str) -> Option<Self> {
        if (json_num(text, "version")? - 1.0).abs() > 1e-9 {
            return None;
        }
        let mut store = Self::default();
        for obj in json_array_objects(text, "factors")? {
            let shard = json_num(&obj, "shard")? as usize;
            let class = class_from_name(&json_str(&obj, "op_class")?)?;
            let exec = executor_from_name(&json_str(&obj, "executor")?)?;
            let energy = json_num(&obj, "energy")?;
            let latency = json_num(&obj, "latency")?;
            let samples = json_num(&obj, "samples")? as u64;
            if !energy.is_finite() || !latency.is_finite() {
                return None;
            }
            store.factors.insert(
                (shard, class as usize, executor_index(exec)),
                CalibrationFactor {
                    energy: energy.clamp(CalibrationFactor::MIN, CalibrationFactor::MAX),
                    latency: latency.clamp(CalibrationFactor::MIN, CalibrationFactor::MAX),
                    samples,
                },
            );
        }
        for obj in json_array_objects(text, "committed")? {
            let shard = json_num(&obj, "shard")? as usize;
            let class = class_from_name(&json_str(&obj, "op_class")?)?;
            let exec = executor_from_name(&json_str(&obj, "executor")?)?;
            store.committed.insert((shard, class as usize), exec);
        }
        Some(store)
    }

    /// Load a snapshot; a missing or corrupt file yields the empty store
    /// (pure analytic fallback), never an error.
    pub fn load(path: &Path) -> Self {
        std::fs::read_to_string(path)
            .ok()
            .and_then(|t| Self::from_json(&t))
            .unwrap_or_default()
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json())
    }
}

// ---- minimal JSON field scanners (flat objects, string/number values) ----

/// The raw text after `"key":`, if present.
fn json_value_after<'a>(text: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = text.find(&pat)?;
    Some(text[at + pat.len()..].trim_start())
}

fn json_num(text: &str, key: &str) -> Option<f64> {
    let rest = json_value_after(text, key)?;
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn json_str(text: &str, key: &str) -> Option<String> {
    let rest = json_value_after(text, key)?;
    let rest = rest.strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

/// The `{...}` objects inside the flat array at `"key": [...]` (no
/// nested objects or strings containing braces — true for our format).
fn json_array_objects(text: &str, key: &str) -> Option<Vec<String>> {
    let rest = json_value_after(text, key)?;
    let rest = rest.strip_prefix('[')?;
    let body = &rest[..rest.find(']')?];
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in body.char_indices() {
        match c {
            '{' => {
                if depth == 0 {
                    start = i;
                }
                depth += 1;
            }
            '}' => {
                depth = depth.checked_sub(1)?;
                if depth == 0 {
                    out.push(body[start..=i].to_string());
                }
            }
            _ => {}
        }
    }
    if depth != 0 {
        return None;
    }
    Some(out)
}

/// Process-global shared store handle: the REPL's `calibration`
/// commands and long-lived daemons read/reset through this; serve
/// queues mirror their store into it after every absorb.
pub type SharedCalibration = Arc<Mutex<CalibrationStore>>;

static SHARED: OnceLock<SharedCalibration> = OnceLock::new();

/// The process-global [`SharedCalibration`] cell.
pub fn shared() -> &'static SharedCalibration {
    SHARED.get_or_init(|| Arc::new(Mutex::new(CalibrationStore::new())))
}

/// The adaptive cost model: a base analytic [`PlanCostModel`] wrapped by
/// the runtime-adaption store, exposing one EFFECTIVE model per shard
/// (scaled tables + committed routing pins).
#[derive(Clone, Debug)]
pub struct CalibratedCostModel {
    base: PlanCostModel,
    store: CalibrationStore,
    shards: usize,
    /// Routing flips commit only after this many consecutive absorbs
    /// prefer the same non-committed executor.
    sustain: u32,
    effective: Vec<PlanCostModel>,
}

impl CalibratedCostModel {
    /// Default flip hysteresis: three consecutive disagreeing absorbs.
    pub const DEFAULT_SUSTAIN: u32 = 3;

    pub fn new(base: PlanCostModel, shards: usize) -> Self {
        Self::with_store(base, shards, CalibrationStore::new())
    }

    /// Wrap `base` with a pre-loaded store (e.g. a persisted snapshot).
    pub fn with_store(base: PlanCostModel, shards: usize, store: CalibrationStore) -> Self {
        let mut m = Self {
            base,
            store,
            shards: shards.max(1),
            sustain: Self::DEFAULT_SUSTAIN,
            effective: Vec::new(),
        };
        m.rebuild();
        m
    }

    pub fn set_sustain(&mut self, sustain: u32) {
        self.sustain = sustain.max(1);
    }

    pub fn objective(&self) -> Objective {
        self.base.objective
    }

    pub fn base(&self) -> &PlanCostModel {
        &self.base
    }

    pub fn store(&self) -> &CalibrationStore {
        &self.store
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The effective model for one shard (scaled tables + routing pin).
    pub fn shard_model(&self, shard: usize) -> &PlanCostModel {
        &self.effective[shard.min(self.effective.len() - 1)]
    }

    /// The effective routing decision for one (shard, class).
    pub fn choose_class(&self, shard: usize, class: OpClass) -> Executor {
        self.shard_model(shard).choose_class(class).executor
    }

    /// Whether the fused dual datapath applies: every shard's dual ops
    /// route to ADRA under the current calibration.
    pub fn fuse_dual_on_adra(&self) -> bool {
        (0..self.shards).all(|s| self.choose_class(s, OpClass::Dual) == Executor::Adra)
    }

    /// Fold one run's samples into the store: EWMA the correction
    /// factors, advance the flip hysteresis, rebuild the effective
    /// models.  Returns `true` when any committed routing changed (the
    /// caller should re-sync worker routing).
    pub fn absorb(&mut self, samples: &[CalibrationSample]) -> bool {
        let mut touched: Vec<(usize, usize)> = Vec::new();
        // per-class error accumulation for the convergence EWMA
        let mut class_err: BTreeMap<usize, (f64, f64)> = BTreeMap::new();
        for s in samples {
            if s.ops == 0 {
                continue;
            }
            let pe = s.predicted.energy.total();
            let pl = s.predicted.latency;
            if pe <= 0.0 || pl <= 0.0 {
                continue;
            }
            let ratio_e = s.measured.energy.total() / pe;
            let ratio_l = s.measured.latency / pl;
            if !ratio_e.is_finite() || !ratio_l.is_finite() {
                continue;
            }
            let key = (s.shard, s.op_class as usize, executor_index(s.executor));
            let f = self.store.factors.entry(key).or_default();
            // `predicted` already carries the current factor, so the new
            // TOTAL factor target is current * (measured / predicted)
            f.fold(f.energy * ratio_e, f.latency * ratio_l);
            if !touched.contains(&(s.shard, s.op_class as usize)) {
                touched.push((s.shard, s.op_class as usize));
            }
            let e = class_err.entry(s.op_class as usize).or_insert((0.0, 0.0));
            e.0 += s.measured.energy.total();
            e.1 += pe;
        }
        for (ci, (meas, pred)) in class_err {
            let err = (meas / pred - 1.0).abs();
            let slot = self.store.error_ewma.entry(ci).or_insert(err);
            *slot += ALPHA * (err - *slot);
        }

        // hysteresis: the scaled-score preference must disagree with the
        // committed decision for `sustain` consecutive absorbs to flip
        let mut flipped = false;
        for (shard, ci) in touched {
            let class = OpClass::ALL[ci];
            let preferred = self.preferred(shard, class);
            let committed = *self
                .store
                .committed
                .entry((shard, ci))
                .or_insert_with(|| self.base.choose_class(class).executor);
            if preferred == committed {
                self.store.pending.remove(&(shard, ci));
                continue;
            }
            let entry = self.store.pending.entry((shard, ci)).or_insert((preferred, 0));
            if entry.0 != preferred {
                *entry = (preferred, 0);
            }
            entry.1 += 1;
            if entry.1 >= self.sustain {
                self.store.committed.insert((shard, ci), preferred);
                self.store.pending.remove(&(shard, ci));
                flipped = true;
            }
        }
        self.rebuild();
        flipped
    }

    /// What the scaled (factor-corrected, UNpinned) tables prefer for
    /// one (shard, class) — the hysteresis candidate.
    fn preferred(&self, shard: usize, class: OpClass) -> Executor {
        let m = self.scaled_model(shard, false);
        m.choose_class(class).executor
    }

    /// Build one shard's model from the base tables scaled by the
    /// stored factors; `pin` additionally applies committed routing.
    fn scaled_model(&self, shard: usize, pin: bool) -> PlanCostModel {
        let mut adra = self.base.adra().clone();
        let mut baseline = self.base.baseline().clone();
        for class in OpClass::ALL {
            let fa = self.store.factor(shard, class, Executor::Adra);
            adra = adra.scaled_class(class, fa.energy, fa.latency);
            let fb = self.store.factor(shard, class, Executor::Baseline);
            baseline = baseline.scaled_class(class, fb.energy, fb.latency);
        }
        let mut m = PlanCostModel::with_tables(self.base.objective, adra, baseline);
        if pin {
            for class in OpClass::ALL {
                if let Some(exec) = self.store.committed(shard, class) {
                    m.pin_class(class, Some(exec));
                }
            }
        }
        m
    }

    fn rebuild(&mut self) {
        let models: Vec<PlanCostModel> =
            (0..self.shards).map(|s| self.scaled_model(s, true)).collect();
        self.effective = models;
    }

    /// Replace the store wholesale (REPL `calibration reset` path).
    pub fn reset(&mut self) {
        self.store.clear();
        self.rebuild();
    }

    /// Push the committed routing pins down to the coordinator's
    /// workers so their `PlannedEngine`s dispatch the way the
    /// calibrated plan was priced.  Fire-and-forget is safe: per-worker
    /// channels are FIFO, so the pins land before any later batch.
    pub fn sync_routing(&self, coord: &Coordinator) {
        for shard in 0..self.shards {
            let mut forced = [None; 4];
            for class in OpClass::ALL {
                forced[class as usize] = self.store.committed(shard, class);
            }
            // a shard the coordinator doesn't have is simply skipped
            let _ = coord.set_routing(shard, forced);
        }
    }

    /// Publish the factor gauges + the runaway-watch distortion gauge.
    pub fn publish(&self, reg: &Registry) {
        for (&(shard, ci, ei), f) in &self.store.factors {
            let shard_s = shard.to_string();
            let class = OpClass::ALL[ci].name();
            let exec = executor_from_index(ei).expect("stored executor index").name();
            for (kind, v) in [("energy", f.energy), ("latency", f.latency)] {
                reg.gauge(
                    "adra.planner.calibration",
                    "runtime correction factor (measured/analytic) per shard/class/executor",
                    &[
                        ("op_class", class),
                        ("shard", shard_s.as_str()),
                        ("executor", exec),
                        ("kind", kind),
                    ],
                )
                .set(v);
            }
        }
        reg.gauge(
            "adra.planner.calibration_distortion",
            "worst calibration factor distortion max(f, 1/f); 1.0 = analytic",
            &[],
        )
        .set(self.store.max_distortion());
        for (&ci, err) in &self.store.error_ewma {
            reg.gauge(
                "adra.planner.calibration_error",
                "EWMA of |measured/predicted - 1| (energy) under calibration",
                &[("op_class", OpClass::ALL[ci].name())],
            )
            .set(*err);
        }
    }
}

/// Shard-aware placement through the calibrated model: shard `i` is
/// lowered with `cal.shard_model(i)` so both prices and routing carry
/// that shard's learned corrections.
pub fn place_calibrated(
    program: &Program,
    cfg: &SimConfig,
    shards: usize,
    cal: &CalibratedCostModel,
) -> Result<Placement, PlanError> {
    place_with(program, cfg, shards, |s| cal.shard_model(s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};
    use crate::energy::EnergyBreakdown;

    fn base(scheme: SensingScheme, objective: Objective) -> PlanCostModel {
        PlanCostModel::new(&SimConfig::square(1024, scheme), objective)
    }

    fn cost(energy: f64, latency: f64) -> OpCost {
        OpCost { energy: EnergyBreakdown { rbl: energy, ..Default::default() }, latency }
    }

    fn sample(
        shard: usize,
        class: OpClass,
        executor: Executor,
        predicted: OpCost,
        measured: OpCost,
    ) -> CalibrationSample {
        CalibrationSample { shard, op_class: class, executor, predicted, measured, ops: 8 }
    }

    #[test]
    fn exact_tables_leave_factors_and_routing_untouched() {
        let mut cal = CalibratedCostModel::new(base(SensingScheme::Current, Objective::Edp), 2);
        let p = cost(1.0, 1.0);
        for _ in 0..5 {
            let flipped = cal.absorb(&[sample(0, OpClass::Dual, Executor::Adra, p, p)]);
            assert!(!flipped);
        }
        let f = cal.store().factor(0, OpClass::Dual, Executor::Adra);
        assert!((f.energy - 1.0).abs() < 1e-12 && (f.latency - 1.0).abs() < 1e-12);
        assert_eq!(cal.choose_class(0, OpClass::Dual), Executor::Adra);
        assert!(cal.fuse_dual_on_adra());
        assert!(cal.store().class_error(OpClass::Dual).unwrap() < 1e-12);
    }

    #[test]
    fn factors_converge_to_measured_ratio_and_stay_clamped() {
        let mut cal = CalibratedCostModel::new(base(SensingScheme::Current, Objective::Edp), 1);
        // measured energy is consistently 2x the (current effective)
        // prediction; note absorb rebuilds the effective model, so the
        // sample's predicted must track the evolving factor — emulate a
        // real loop by pricing through the shard model each round
        for _ in 0..64 {
            let table = cal.shard_model(0).adra().dual.cost;
            let meas_base = cal.base().adra().dual.cost;
            let measured = OpCost {
                energy: meas_base.energy.scale(2.0),
                latency: meas_base.latency,
            };
            cal.absorb(&[sample(0, OpClass::Dual, Executor::Adra, table, measured)]);
        }
        let f = cal.store().factor(0, OpClass::Dual, Executor::Adra);
        assert!((f.energy - 2.0).abs() < 1e-3, "factor converges to 2.0: {}", f.energy);
        assert!((f.latency - 1.0).abs() < 1e-6);
        // the convergence witness: error EWMA has shrunk to ~0
        assert!(cal.store().class_error(OpClass::Dual).unwrap() < 0.02);

        // a wild run cannot leave the clamp band
        let table = cal.shard_model(0).adra().dual.cost;
        let wild = OpCost { energy: table.energy.scale(1e6), latency: table.latency * 1e6 };
        cal.absorb(&[sample(0, OpClass::Dual, Executor::Adra, table, wild)]);
        let f = cal.store().factor(0, OpClass::Dual, Executor::Adra);
        assert!(f.energy <= CalibrationFactor::MAX && f.latency <= CalibrationFactor::MAX);
    }

    /// Synthetic tables with controlled dual prices (every other class
    /// priced 1.0 on both executors) — makes the preference boundary
    /// exact so the hysteresis timing is deterministic.
    fn synth(adra_dual: f64, baseline_dual: f64) -> PlanCostModel {
        use super::super::cost::{CostTable, TableCost};
        let mk = |e: f64| TableCost { cost: cost(e, 1.0), accesses: 1 };
        let adra = CostTable {
            executor: Executor::Adra,
            read: mk(1.0),
            write: mk(1.0),
            commutative: mk(1.0),
            dual: mk(adra_dual),
        };
        let baseline = CostTable {
            executor: Executor::Baseline,
            read: mk(1.0),
            write: mk(1.0),
            commutative: mk(1.0),
            dual: mk(baseline_dual),
        };
        PlanCostModel::with_tables(Objective::Energy, adra, baseline)
    }

    /// One drift round: measured energy is `k` times the current
    /// effective prediction (latency agrees).
    fn drift_round(cal: &mut CalibratedCostModel, k: f64) -> bool {
        let predicted = cal.shard_model(0).adra().dual.cost;
        let measured = OpCost { energy: predicted.energy.scale(k), latency: predicted.latency };
        cal.absorb(&[sample(0, OpClass::Dual, Executor::Adra, predicted, measured)])
    }

    #[test]
    fn routing_flips_only_after_sustain_threshold() {
        // analytic tables say ADRA dual (1.0) beats baseline (3.0);
        // every measured round says ADRA really costs 8x its prediction,
        // which slams the factor past the boundary in one fold
        let mut cal = CalibratedCostModel::new(synth(1.0, 3.0), 1);
        cal.set_sustain(3);
        let mut flip_round = None;
        for round in 1..=6 {
            let flipped = drift_round(&mut cal, 8.0);
            let routed = cal.choose_class(0, OpClass::Dual);
            if flipped {
                assert!(flip_round.is_none(), "at most one flip");
                flip_round = Some(round);
            }
            if flip_round.is_none() {
                assert_eq!(
                    routed,
                    Executor::Adra,
                    "round {round}: committed routing holds until sustain"
                );
            } else {
                assert_eq!(routed, Executor::Baseline, "round {round}");
            }
        }
        assert_eq!(flip_round, Some(3), "flip commits exactly at the sustain threshold");
        assert!(!cal.fuse_dual_on_adra(), "fusion follows the calibrated routing");
        assert_eq!(cal.store().committed(0, OpClass::Dual), Some(Executor::Baseline));
    }

    #[test]
    fn agreeing_round_resets_the_flip_streak() {
        let mut cal = CalibratedCostModel::new(synth(1.0, 3.0), 1);
        cal.set_sustain(3);
        // round 1: slam -> factor 3.1, preference disagrees (streak 1)
        assert!(!drift_round(&mut cal, 8.0));
        assert_eq!(cal.choose_class(0, OpClass::Dual), Executor::Adra);
        // round 2: measurement matches the ANALYTIC price again -> the
        // factor decays under the boundary, preference agrees, streak
        // resets
        let predicted = cal.shard_model(0).adra().dual.cost;
        assert!(!cal.absorb(&[sample(
            0,
            OpClass::Dual,
            Executor::Adra,
            predicted,
            cost(1.0, 1.0),
        )]));
        // rounds 3-4: drift resumes; had the streak NOT reset, round 4
        // would commit the flip
        assert!(!drift_round(&mut cal, 8.0));
        assert!(!drift_round(&mut cal, 8.0), "round 4 must not flip — the streak was reset");
        assert_eq!(cal.choose_class(0, OpClass::Dual), Executor::Adra);
        // round 5 completes a fresh 3-round streak
        assert!(drift_round(&mut cal, 8.0));
        assert_eq!(cal.choose_class(0, OpClass::Dual), Executor::Baseline);
    }

    /// The paper-grounded scenario: scheme 1 + Energy objective, where
    /// the TRUE optimum for dual ops is the baseline (Fig. 6: ADRA costs
    /// ~1.21x the baseline's energy).  A base model that underprices
    /// ADRA dual energy wrongly routes dual -> ADRA; honest measurements
    /// walk the factor up until routing converges to the measured
    /// optimum.
    #[test]
    fn gradual_drift_flips_routing_to_measured_optimum() {
        let honest = base(SensingScheme::VoltagePrecharged, Objective::Energy);
        assert_eq!(honest.choose_class(OpClass::Dual).executor, Executor::Baseline);
        let lying_adra = honest.adra().scaled_class(OpClass::Dual, 0.5, 1.0);
        let lying =
            PlanCostModel::with_tables(Objective::Energy, lying_adra, honest.baseline().clone());
        assert_eq!(lying.choose_class(OpClass::Dual).executor, Executor::Adra);

        let mut cal = CalibratedCostModel::new(lying, 1);
        cal.set_sustain(3);
        let mut flip_round = None;
        for round in 1..=16 {
            let predicted = cal.shard_model(0).adra().dual.cost;
            let measured = honest.adra().dual.cost; // the hardware doesn't lie
            if cal.absorb(&[sample(0, OpClass::Dual, Executor::Adra, predicted, measured)]) {
                flip_round = Some(round);
                break;
            }
        }
        let flip = flip_round.expect("sustained honest drift must flip routing");
        assert!(flip >= 3, "no flip before the sustain threshold: {flip}");
        assert_eq!(cal.choose_class(0, OpClass::Dual), Executor::Baseline);
        // and the correction converged toward the real 2x energy ratio
        let f = cal.store().factor(0, OpClass::Dual, Executor::Adra);
        assert!(f.energy > 1.5, "factor walked toward 2.0: {}", f.energy);
    }

    #[test]
    fn snapshot_roundtrip_preserves_factors_and_routing() {
        let mut cal = CalibratedCostModel::new(base(SensingScheme::Current, Objective::Edp), 3);
        let p = cost(4.0, 2.0);
        let m = cost(6.0, 2.5);
        cal.absorb(&[
            sample(0, OpClass::Dual, Executor::Adra, p, m),
            sample(2, OpClass::Commutative, Executor::Baseline, p, cost(2.0, 1.0)),
        ]);
        let store = cal.store().clone();
        assert!(!store.is_empty());

        let dir = std::env::temp_dir().join(format!("adra_cal_{}", std::process::id()));
        let path = dir.join("snapshot.json");
        store.save(&path).expect("save snapshot");
        let loaded = CalibrationStore::load(&path);
        for shard in 0..3 {
            for class in OpClass::ALL {
                for exec in [Executor::Adra, Executor::Baseline] {
                    let a = store.factor(shard, class, exec);
                    let b = loaded.factor(shard, class, exec);
                    assert!((a.energy - b.energy).abs() < 1e-12, "{shard} {class:?} {exec:?}");
                    assert!((a.latency - b.latency).abs() < 1e-12);
                    assert_eq!(a.samples, b.samples);
                }
                assert_eq!(store.committed(shard, class), loaded.committed(shard, class));
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_or_corrupt_snapshot_falls_back_to_analytic() {
        let missing = CalibrationStore::load(Path::new("/nonexistent/adra/cal.json"));
        assert!(missing.is_empty());

        let dir = std::env::temp_dir().join(format!("adra_cal_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (name, text) in [
            ("truncated.json", "{\"version\":1,\"factors\":[{\"shard\":0,"),
            ("not_json.json", "hello world"),
            ("wrong_version.json", "{\"version\":9,\"factors\":[],\"committed\":[]}"),
            (
                "nan.json",
                "{\"version\":1,\"factors\":[{\"shard\":0,\"op_class\":\"dual\",\
                 \"executor\":\"adra\",\"energy\":NaN,\"latency\":1.0,\"samples\":1}],\
                 \"committed\":[]}",
            ),
        ] {
            let p = dir.join(name);
            std::fs::write(&p, text).unwrap();
            let loaded = CalibrationStore::load(&p);
            assert!(loaded.is_empty(), "{name} must fall back to the analytic store");
        }
        // a loaded out-of-band factor is clamped into the safety band
        let p = dir.join("outband.json");
        std::fs::write(
            &p,
            "{\"version\":1,\"factors\":[{\"shard\":0,\"op_class\":\"dual\",\
             \"executor\":\"adra\",\"energy\":99.0,\"latency\":0.001,\"samples\":2}],\
             \"committed\":[]}",
        )
        .unwrap();
        let f = CalibrationStore::load(&p).factor(0, OpClass::Dual, Executor::Adra);
        assert_eq!(f.energy, CalibrationFactor::MAX);
        assert_eq!(f.latency, CalibrationFactor::MIN);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restored_store_keeps_committed_routing_without_new_samples() {
        let honest = base(SensingScheme::VoltagePrecharged, Objective::Energy);
        let lying_adra = honest.adra().scaled_class(OpClass::Dual, 0.5, 1.0);
        let lying =
            PlanCostModel::with_tables(Objective::Energy, lying_adra, honest.baseline().clone());
        let mut cal = CalibratedCostModel::new(lying.clone(), 1);
        cal.set_sustain(2);
        for _ in 0..4 {
            let predicted = cal.shard_model(0).adra().dual.cost;
            let measured = honest.adra().dual.cost;
            cal.absorb(&[sample(0, OpClass::Dual, Executor::Adra, predicted, measured)]);
        }
        assert_eq!(cal.choose_class(0, OpClass::Dual), Executor::Baseline);
        // "restart": a fresh wrapper around the same (persisted) store
        let restored = CalibratedCostModel::with_store(lying, 1, cal.store().clone());
        assert_eq!(
            restored.choose_class(0, OpClass::Dual),
            Executor::Baseline,
            "committed routing survives the restart"
        );
    }
}
