//! The planner's calibrated cost model: per-op price tables for the ADRA
//! and baseline executors, derived from the SAME `energy::EnergyModel`
//! the engines charge at execution time — which is what makes predicted
//! cost track measured cost.
//!
//! Each executor gets a [`CostTable`] with one [`TableCost`] row per op
//! class (read / write / commutative-CiM / dual).  The classes mirror the
//! engines' dispatch exactly:
//! * ADRA executes every dual-row op in ONE asymmetric activation
//!   (`cim_cost`), the paper's contribution;
//! * the baseline executes commutative ops with prior-work symmetric CiM
//!   (`cim_cost`) but needs TWO full reads + near-memory compute
//!   (`baseline_cost`) for anything that wants A and B separately — the
//!   many-to-one mapping problem of Section II.A.
//!
//! [`PlanCostModel::choose`] picks the executor minimizing the configured
//! [`Objective`].  The decision is scheme-dependent for real: under
//! voltage scheme 1 the ADRA access costs ~21% MORE energy than the
//! two-read baseline (paper Fig. 6) while still winning on latency and
//! EDP, so an energy-minimizing planner routes dual ops to the baseline
//! and an EDP-minimizing planner routes them to ADRA.

use crate::cim::CimOp;
use crate::config::{FidelityTier, MaskPolicy, SimConfig};
use crate::energy::{EnergyModel, OpCost};
use crate::sensing::DvtBudget;

/// Which executor runs an op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Executor {
    /// Single-access asymmetric dual-row activation.
    Adra,
    /// Prior-work engine: symmetric CiM where possible, two reads +
    /// near-memory compute otherwise.
    Baseline,
}

impl Executor {
    pub fn name(&self) -> &'static str {
        match self {
            Executor::Adra => "adra",
            Executor::Baseline => "baseline",
        }
    }
}

/// What the planner minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    Energy,
    Latency,
    /// Energy-delay product — the paper's headline figure of merit.
    Edp,
}

impl Objective {
    /// Scalar score of a cost under this objective (lower is better).
    pub fn score(&self, c: &OpCost) -> f64 {
        match self {
            Objective::Energy => c.energy.total(),
            Objective::Latency => c.latency,
            Objective::Edp => c.edp(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Energy => "energy",
            Objective::Latency => "latency",
            Objective::Edp => "EDP",
        }
    }
}

/// Operation classes the price tables are keyed by.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    Read,
    Write,
    /// Single-access on BOTH engines (commutative Boolean, add).
    Commutative,
    /// Needs A and B separately (read2, sub, compare, non-commutative
    /// Boolean) — the ops ADRA exists for.
    Dual,
}

impl OpClass {
    /// Every class, in table order (also the registry label order).
    pub const ALL: [OpClass; 4] =
        [OpClass::Read, OpClass::Write, OpClass::Commutative, OpClass::Dual];

    /// Stable `op_class` label value in the observe registry.
    pub fn name(&self) -> &'static str {
        match self {
            OpClass::Read => "read",
            OpClass::Write => "write",
            OpClass::Commutative => "commutative",
            OpClass::Dual => "dual",
        }
    }
}

/// Classify a `CimOp` the same way the engines dispatch it.
pub fn class_of(op: &CimOp) -> OpClass {
    match op {
        CimOp::Write { .. } => OpClass::Write,
        CimOp::Read(_) => OpClass::Read,
        CimOp::Bool { f, .. } => {
            if f.commutative() {
                OpClass::Commutative
            } else {
                OpClass::Dual
            }
        }
        CimOp::Add { .. } => OpClass::Commutative,
        CimOp::Read2 { .. } | CimOp::Sub { .. } | CimOp::Compare { .. } => OpClass::Dual,
    }
}

/// One row of an executor's price table: modeled cost plus the array
/// accesses (activations or reads) the op issues.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TableCost {
    pub cost: OpCost,
    pub accesses: u64,
}

/// Per-executor price table.
#[derive(Clone, Debug)]
pub struct CostTable {
    pub executor: Executor,
    pub read: TableCost,
    pub write: TableCost,
    pub commutative: TableCost,
    pub dual: TableCost,
}

impl CostTable {
    /// Price list of the ADRA engine: every dual-row op is one
    /// asymmetric activation.
    pub fn adra(model: &EnergyModel) -> Self {
        Self {
            executor: Executor::Adra,
            read: TableCost { cost: model.read_cost(), accesses: 1 },
            write: TableCost { cost: model.write_cost(), accesses: 1 },
            commutative: TableCost { cost: model.cim_cost(), accesses: 1 },
            dual: TableCost { cost: model.cim_cost(), accesses: 1 },
        }
    }

    /// Price list of the near-memory baseline: dual ops pay two full
    /// reads + the near-memory compute.
    pub fn baseline(model: &EnergyModel) -> Self {
        Self {
            executor: Executor::Baseline,
            read: TableCost { cost: model.read_cost(), accesses: 1 },
            write: TableCost { cost: model.write_cost(), accesses: 1 },
            commutative: TableCost { cost: model.cim_cost(), accesses: 1 },
            dual: TableCost { cost: model.baseline_cost(), accesses: 2 },
        }
    }

    /// Price one op on this executor.
    pub fn price(&self, op: &CimOp) -> TableCost {
        self.price_class(class_of(op))
    }

    pub fn price_class(&self, class: OpClass) -> TableCost {
        match class {
            OpClass::Read => self.read,
            OpClass::Write => self.write,
            OpClass::Commutative => self.commutative,
            OpClass::Dual => self.dual,
        }
    }

    /// A copy of this table with one class's energy and latency scaled by
    /// runtime correction factors.  Accesses are untouched — calibration
    /// corrects prices, never the access-count accounting.
    pub fn scaled_class(&self, class: OpClass, energy_k: f64, latency_k: f64) -> Self {
        let mut t = self.clone();
        let row = match class {
            OpClass::Read => &mut t.read,
            OpClass::Write => &mut t.write,
            OpClass::Commutative => &mut t.commutative,
            OpClass::Dual => &mut t.dual,
        };
        row.cost.energy = row.cost.energy.scale(energy_k);
        row.cost.latency *= latency_k;
        t
    }
}

/// The planner's routing decision for one op.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    pub executor: Executor,
    pub cost: TableCost,
}

/// Host-side simulation cost of the tiered activation kernel.
///
/// Since the margin masks (DESIGN.md §10), digital-vs-analog routing is
/// **per-column-fraction, not all-or-nothing**: under `vt_sigma > 0` a
/// masked activation serves the deterministic column fraction from the
/// packed planes and only the marginal remainder through the analog
/// pipeline.  This model prices that blend so schedulers can reason
/// about expected host throughput (the modeled HARDWARE cost stays
/// tier-invariant by construction — see
/// `fidelity_tier_leaves_price_tables_unchanged`).
///
/// Costs are relative units calibrated against the hotpath bench shape:
/// one packed 64-column word op ~ unit cost; one analog column eval is
/// a few tens of units (LUT pipeline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TierCostModel {
    /// Expected deterministically-served CELL fraction in [0, 1].
    pub cell_det_fraction: f64,
    /// Host cost of one packed 64-column word operation.
    pub packed_word_cost: f64,
    /// Host cost of one analog column evaluation.
    pub analog_col_cost: f64,
}

impl TierCostModel {
    /// Default relative calibration (hotpath bench shape).
    const PACKED_WORD_COST: f64 = 1.0;
    const ANALOG_COL_COST: f64 = 40.0;

    /// Derive the expected deterministic fraction from the config: 1.0
    /// for the clean digital tier, the mask-classified fraction under
    /// variation, 0.0 for analog tiers or masks off.
    pub fn from_config(cfg: &SimConfig) -> Self {
        let cell = match cfg.tier {
            FidelityTier::Digital if cfg.vt_sigma == 0.0 => 1.0,
            FidelityTier::Digital if cfg.mask_policy != MaskPolicy::Off => {
                let f = DvtBudget::deterministic_cell_fraction(cfg);
                // below the engine's engagement floor the masked path
                // stays off and everything runs analog
                if f >= crate::cim::AdraEngine::MASKED_MIN_DET_FRACTION {
                    f
                } else {
                    0.0
                }
            }
            _ => 0.0,
        };
        Self {
            cell_det_fraction: cell,
            packed_word_cost: Self::PACKED_WORD_COST,
            analog_col_cost: Self::ANALOG_COL_COST,
        }
    }

    /// Expected deterministic COLUMN fraction: a dual-row column is
    /// packed only when BOTH its cells are deterministic.
    pub fn column_det_fraction(&self) -> f64 {
        self.cell_det_fraction * self.cell_det_fraction
    }

    /// Expected host cost of one `width`-column dual-row activation:
    /// packed word ops for the whole span plus analog evaluation of the
    /// expected marginal minority.  A fully-analog blend
    /// (`cell_det_fraction == 0`) never fills the packed plane, so the
    /// packed-word term is charged only when the packed path engages.
    pub fn activation_host_cost(&self, width: usize) -> f64 {
        let marginal = (1.0 - self.column_det_fraction()) * width as f64;
        let packed = if self.cell_det_fraction > 0.0 {
            (((width + 63) / 64) as f64) * self.packed_word_cost
        } else {
            0.0
        };
        packed + marginal * self.analog_col_cost
    }
}

/// Cost model binding both executors' tables to one array configuration
/// and an optimization objective.
#[derive(Clone, Debug)]
pub struct PlanCostModel {
    pub objective: Objective,
    adra: CostTable,
    baseline: CostTable,
    /// Host-side tier cost (per-column-fraction digital/analog blend);
    /// advisory — never feeds the modeled-hardware routing above.
    tier: TierCostModel,
    /// Per-class routing pins, indexed by `OpClass as usize`.  `None`
    /// (the default everywhere) keeps score-based routing; `Some` forces
    /// the executor regardless of the score comparison.  The calibration
    /// layer (`planner::calibrate`) uses pins to hold a committed routing
    /// decision steady under hysteresis.
    pinned: [Option<Executor>; 4],
}

impl PlanCostModel {
    pub fn new(cfg: &SimConfig, objective: Objective) -> Self {
        let mut m = Self::from_model(&EnergyModel::new(cfg), objective);
        m.tier = TierCostModel::from_config(cfg);
        m
    }

    pub fn from_model(model: &EnergyModel, objective: Objective) -> Self {
        Self::with_tables(objective, CostTable::adra(model), CostTable::baseline(model))
    }

    /// Build a model directly from price tables (no score re-derivation,
    /// no config).  This is how the calibration layer builds per-shard
    /// effective models with runtime-scaled tables, and how tests inject
    /// deliberately mis-calibrated prices.
    pub fn with_tables(objective: Objective, adra: CostTable, baseline: CostTable) -> Self {
        Self {
            objective,
            adra,
            baseline,
            // callers without a SimConfig get the clean-digital blend
            tier: TierCostModel {
                cell_det_fraction: 1.0,
                packed_word_cost: TierCostModel::PACKED_WORD_COST,
                analog_col_cost: TierCostModel::ANALOG_COL_COST,
            },
            pinned: [None; 4],
        }
    }

    /// Pin (or unpin, with `None`) the routing decision for one op
    /// class.  Pinned classes bypass the score comparison in
    /// [`choose_class`] but keep reporting the pinned executor's table
    /// price, so predictions stay honest.
    pub fn pin_class(&mut self, class: OpClass, executor: Option<Executor>) {
        self.pinned[class as usize] = executor;
    }

    /// The current pin for one op class (`None` = score-based routing).
    pub fn pinned_class(&self, class: OpClass) -> Option<Executor> {
        self.pinned[class as usize]
    }

    /// The host-side tier cost model (per-column-fraction blend).
    pub fn tier_model(&self) -> &TierCostModel {
        &self.tier
    }

    pub fn adra(&self) -> &CostTable {
        &self.adra
    }

    pub fn baseline(&self) -> &CostTable {
        &self.baseline
    }

    /// Price one op on a specific executor.
    pub fn price(&self, op: &CimOp, executor: Executor) -> TableCost {
        match executor {
            Executor::Adra => self.adra.price(op),
            Executor::Baseline => self.baseline.price(op),
        }
    }

    /// Route one op to the executor with the lower objective score.
    /// Ties break toward ADRA (fewer array accesses, and fusable by
    /// `coordinator::fuse`).
    pub fn choose(&self, op: &CimOp) -> Decision {
        self.choose_class(class_of(op))
    }

    /// The routing decision for a whole op class (what `choose` applies
    /// per op; reporting/UI should call this rather than re-deriving the
    /// score comparison).
    pub fn choose_class(&self, class: OpClass) -> Decision {
        if let Some(executor) = self.pinned[class as usize] {
            let cost = match executor {
                Executor::Adra => self.adra.price_class(class),
                Executor::Baseline => self.baseline.price_class(class),
            };
            return Decision { executor, cost };
        }
        let a = self.adra.price_class(class);
        let b = self.baseline.price_class(class);
        if self.objective.score(&a.cost) <= self.objective.score(&b.cost) {
            Decision { executor: Executor::Adra, cost: a }
        } else {
            Decision { executor: Executor::Baseline, cost: b }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{BoolFn, WordAddr};
    use crate::config::SensingScheme;

    fn op_sub() -> CimOp {
        CimOp::Sub { row_a: 0, row_b: 1, word: 0 }
    }

    fn model(scheme: SensingScheme, objective: Objective) -> PlanCostModel {
        PlanCostModel::new(&SimConfig::square(1024, scheme), objective)
    }

    #[test]
    fn classification_mirrors_engine_dispatch() {
        assert_eq!(class_of(&op_sub()), OpClass::Dual);
        assert_eq!(class_of(&CimOp::Read2 { row_a: 0, row_b: 1, word: 0 }), OpClass::Dual);
        assert_eq!(class_of(&CimOp::Compare { row_a: 0, row_b: 1, word: 0 }), OpClass::Dual);
        assert_eq!(class_of(&CimOp::Add { row_a: 0, row_b: 1, word: 0 }), OpClass::Commutative);
        assert_eq!(
            class_of(&CimOp::Bool { f: BoolFn::Xor, row_a: 0, row_b: 1, word: 0 }),
            OpClass::Commutative
        );
        assert_eq!(
            class_of(&CimOp::Bool { f: BoolFn::AndNot, row_a: 0, row_b: 1, word: 0 }),
            OpClass::Dual
        );
        assert_eq!(class_of(&CimOp::Read(WordAddr { row: 0, word: 0 })), OpClass::Read);
        assert_eq!(
            class_of(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 1 }),
            OpClass::Write
        );
    }

    /// The acceptance-criterion decision: two-operand ops route to ADRA
    /// (every objective, current & voltage-2 sensing), and read-only ops
    /// are priced as plain reads on either executor.
    #[test]
    fn dual_ops_route_to_adra() {
        for scheme in [SensingScheme::Current, SensingScheme::VoltageDischarged] {
            for objective in [Objective::Energy, Objective::Latency, Objective::Edp] {
                let m = model(scheme, objective);
                let d = m.choose(&op_sub());
                assert_eq!(d.executor, Executor::Adra, "{scheme:?} {objective:?}");
                assert_eq!(d.cost.accesses, 1);
            }
        }
    }

    #[test]
    fn reads_are_priced_as_plain_reads() {
        let m = model(SensingScheme::Current, Objective::Edp);
        let read = CimOp::Read(WordAddr { row: 0, word: 0 });
        let d = m.choose(&read);
        let want = EnergyModel::new(&SimConfig::square(1024, SensingScheme::Current)).read_cost();
        assert_eq!(d.cost.cost, want, "read must not pay for an activation");
        assert_eq!(d.cost.accesses, 1);
        // and a read is strictly cheaper than any dual-op route
        assert!(d.cost.cost.energy.total() < m.adra().dual.cost.energy.total());
    }

    /// Scheme-1 energy objective is the case where the baseline WINS on
    /// dual ops (Fig. 6: ADRA costs ~1.21x the baseline's energy there)
    /// while EDP still routes to ADRA — the planner's decision is real.
    #[test]
    fn scheme1_energy_routes_dual_to_baseline_but_edp_to_adra() {
        let energy = model(SensingScheme::VoltagePrecharged, Objective::Energy);
        assert_eq!(energy.choose(&op_sub()).executor, Executor::Baseline);
        let edp = model(SensingScheme::VoltagePrecharged, Objective::Edp);
        assert_eq!(edp.choose(&op_sub()).executor, Executor::Adra);
        let lat = model(SensingScheme::VoltagePrecharged, Objective::Latency);
        assert_eq!(lat.choose(&op_sub()).executor, Executor::Adra);
    }

    /// The fidelity tier is a host wall-clock optimization only: the
    /// price tables — and therefore every routing decision — must be
    /// bit-identical across `Digital`/`Lut`/`Exact`.
    #[test]
    fn fidelity_tier_leaves_price_tables_unchanged() {
        use crate::config::FidelityTier;
        for scheme in SensingScheme::ALL {
            let mut cfg = SimConfig::square(1024, scheme);
            let tables: Vec<PlanCostModel> = FidelityTier::ALL
                .iter()
                .map(|&t| {
                    cfg.tier = t;
                    PlanCostModel::new(&cfg, Objective::Edp)
                })
                .collect();
            for m in &tables[1..] {
                for class in [OpClass::Read, OpClass::Write, OpClass::Commutative, OpClass::Dual] {
                    assert_eq!(
                        tables[0].adra().price_class(class),
                        m.adra().price_class(class),
                        "{scheme:?} {class:?}"
                    );
                    assert_eq!(
                        tables[0].baseline().price_class(class),
                        m.baseline().price_class(class),
                        "{scheme:?} {class:?}"
                    );
                    assert_eq!(
                        tables[0].choose_class(class).executor,
                        m.choose_class(class).executor
                    );
                }
            }
        }
    }

    /// The tier host-cost model prices digital-vs-analog routing as a
    /// per-column fraction: full packed at sigma 0, a blend under
    /// masked variation, full analog with masks off or on analog tiers.
    #[test]
    fn tier_host_cost_is_per_column_fraction() {
        use crate::config::MaskPolicy;
        let mut cfg = SimConfig::square(1024, SensingScheme::Current);

        let clean = TierCostModel::from_config(&cfg);
        assert_eq!(clean.cell_det_fraction, 1.0);
        assert_eq!(clean.column_det_fraction(), 1.0);
        // clean digital: 16 word ops for a 1024-col row, zero analog
        assert!((clean.activation_host_cost(1024) - 16.0).abs() < 1e-9);

        cfg.vt_sigma = 0.02;
        let masked = TierCostModel::from_config(&cfg);
        assert!(masked.cell_det_fraction > 0.95 && masked.cell_det_fraction < 1.0);
        let blend = masked.activation_host_cost(1024);

        cfg.mask_policy = MaskPolicy::Off;
        let off = TierCostModel::from_config(&cfg);
        assert_eq!(off.cell_det_fraction, 0.0);
        let analog = off.activation_host_cost(1024);

        assert!(
            16.0 < blend && blend < analog,
            "blend {blend} must sit between packed 16 and analog {analog}"
        );
        // the masked blend keeps most of the packed win: < 10% of analog
        assert!(blend < 0.1 * analog, "blend {blend} vs analog {analog}");

        cfg.mask_policy = MaskPolicy::Write;
        cfg.tier = crate::config::FidelityTier::Lut;
        assert_eq!(TierCostModel::from_config(&cfg).cell_det_fraction, 0.0);
    }

    /// Regression: a fully-analog blend must not be charged the packed
    /// word term — the packed path never engages, so the host cost is
    /// exactly `width * analog_col_cost`.
    #[test]
    fn fully_analog_blend_skips_packed_word_term() {
        let analog = TierCostModel {
            cell_det_fraction: 0.0,
            packed_word_cost: 1.0,
            analog_col_cost: 40.0,
        };
        let got = analog.activation_host_cost(1024);
        assert!(
            (got - 1024.0 * 40.0).abs() < 1e-9,
            "pure-analog cost must carry no packed term: {got}"
        );
        // any engaged packed fraction pays for the whole-span word ops
        let engaged = TierCostModel { cell_det_fraction: 0.5, ..analog };
        let want = 16.0 + (1.0 - 0.25) * 1024.0 * 40.0;
        assert!((engaged.activation_host_cost(1024) - want).abs() < 1e-9);
    }

    /// Routing pins bypass the score comparison (calibration hysteresis
    /// holds a committed decision through noisy rounds) but report the
    /// pinned executor's honest table price.
    #[test]
    fn pinned_class_overrides_score_based_routing() {
        let mut m = model(SensingScheme::Current, Objective::Edp);
        assert_eq!(m.pinned_class(OpClass::Dual), None);
        assert_eq!(m.choose(&op_sub()).executor, Executor::Adra);

        m.pin_class(OpClass::Dual, Some(Executor::Baseline));
        let d = m.choose(&op_sub());
        assert_eq!(d.executor, Executor::Baseline);
        assert_eq!(d.cost, m.baseline().price_class(OpClass::Dual), "pinned price is honest");
        // other classes keep score-based routing
        assert_eq!(m.choose_class(OpClass::Read).executor, Executor::Adra);

        m.pin_class(OpClass::Dual, None);
        assert_eq!(m.choose(&op_sub()).executor, Executor::Adra, "unpin restores scoring");
    }

    #[test]
    fn plan_model_exposes_tier_blend_without_touching_routing() {
        use crate::config::MaskPolicy;
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        cfg.vt_sigma = 0.02;
        let with_masks = PlanCostModel::new(&cfg, Objective::Edp);
        cfg.mask_policy = MaskPolicy::Off;
        let without = PlanCostModel::new(&cfg, Objective::Edp);
        assert!(
            with_masks.tier_model().column_det_fraction()
                > without.tier_model().column_det_fraction()
        );
        // modeled-hardware routing must be identical either way
        for class in [OpClass::Read, OpClass::Write, OpClass::Commutative, OpClass::Dual] {
            assert_eq!(
                with_masks.choose_class(class).executor,
                without.choose_class(class).executor
            );
            assert_eq!(
                with_masks.adra().price_class(class),
                without.adra().price_class(class)
            );
        }
    }

    #[test]
    fn commutative_ties_break_to_adra() {
        let m = model(SensingScheme::Current, Objective::Energy);
        let add = CimOp::Add { row_a: 0, row_b: 1, word: 0 };
        let d = m.choose(&add);
        assert_eq!(d.executor, Executor::Adra);
        assert_eq!(d.cost.cost, m.baseline().commutative.cost, "tie: same single-access price");
    }

    #[test]
    fn tables_match_engine_charges() {
        // the table prices must be EXACTLY what the engines charge, op for
        // op — that identity is what makes planner predictions accurate
        use crate::cim::{AdraEngine, BaselineEngine, Engine};
        let mut cfg = SimConfig::square(64, SensingScheme::Current);
        cfg.word_bits = 8;
        let m = PlanCostModel::new(&cfg, Objective::Edp);
        let mut adra = AdraEngine::new(&cfg);
        let mut base = BaselineEngine::new(&cfg);
        let w = CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 9 };
        let ops = [
            w,
            CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 4 },
            CimOp::Read(WordAddr { row: 0, word: 0 }),
            CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
            CimOp::Add { row_a: 0, row_b: 1, word: 0 },
            CimOp::Bool { f: BoolFn::AndNot, row_a: 0, row_b: 1, word: 0 },
        ];
        for op in &ops {
            let got_a = adra.execute(op).unwrap().cost;
            assert_eq!(got_a, m.price(op, Executor::Adra).cost, "adra {op:?}");
            let got_b = base.execute(op).unwrap().cost;
            assert_eq!(got_b, m.price(op, Executor::Baseline).cost, "baseline {op:?}");
        }
    }
}
