//! Shard-aware placement: split a program across the coordinator's
//! worker pool, execute it, and report predicted vs measured cost.
//!
//! Records are partitioned into contiguous chunks, one per shard; scratch
//! rows (broadcast constants) are replicated on every shard, so every
//! lowered op stays shard-local and the pool needs no cross-shard
//! traffic.  Each shard's subprogram is lowered independently; execution
//! drives all shards in parallel through `Coordinator::call_batch`,
//! merges per-record outputs back to global record indices, and checks
//! the planner's prediction against the measured per-op costs through
//! `metrics::PredictionReport`.

use crate::cim::{CimOp, CimValue, EngineError};
use crate::config::SimConfig;
use crate::coordinator::{Coordinator, RouteError};
use crate::energy::OpCost;
use crate::logic::CompareResult;
use crate::metrics::{PredictionReport, RunMetrics};

use super::cost::{class_of, OpClass, PlanCostModel};
use super::ir::{AggKind, IrOp, PlanError, Program};
use super::lower::{lower, LoweredProgram};

/// One shard's slice of a placed program.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Coordinator shard (array id) this slice runs on.
    pub shard: usize,
    /// Global index of this shard's record slot 0.
    pub record_offset: usize,
    /// The shard-local subprogram (record indices rebased to 0).
    pub program: Program,
    pub lowered: LoweredProgram,
    /// For each subprogram op index, the originating op index in the
    /// placed program (clipping can drop steps on some shards).
    pub ir_map: Vec<usize>,
}

/// A program split across coordinator shards.
#[derive(Clone, Debug)]
pub struct Placement {
    /// The placed program (outputs are indexed by its op list).
    pub program: Program,
    pub shards: Vec<ShardPlan>,
    /// Serial prediction summed across shards (compare against summed
    /// per-op measurements).
    pub predicted: OpCost,
    /// Parallel wall model: the slowest shard's predicted latency.
    pub predicted_makespan: f64,
    /// Total predicted array accesses.
    pub predicted_accesses: u64,
}

/// Split `program` across `shards` coordinator shards and lower each
/// slice through the cost model.
pub fn place(
    program: &Program,
    cfg: &SimConfig,
    shards: usize,
    model: &PlanCostModel,
) -> Result<Placement, PlanError> {
    place_with(program, cfg, shards, |_| model)
}

/// Placement with a per-shard cost model: the calibration layer lowers
/// each shard's slice through that shard's runtime-corrected effective
/// model (`planner::calibrate::place_calibrated`); `place` is the
/// constant-model special case.
pub fn place_with<'a, F>(
    program: &Program,
    cfg: &SimConfig,
    shards: usize,
    model_of: F,
) -> Result<Placement, PlanError>
where
    F: Fn(usize) -> &'a PlanCostModel,
{
    if shards == 0 {
        return Err(PlanError::Empty("0 shards".into()));
    }
    // reject malformed GLOBAL programs up front — clipping would
    // otherwise silently drop out-of-range ops (and an all-dropped
    // aggregate would surface its fold sentinel as data)
    program.validate_structure()?;
    let chunk = program.n_records.div_ceil(shards);
    let mut plans = Vec::new();
    for shard in 0..shards {
        let lo = shard * chunk;
        let hi = ((shard + 1) * chunk).min(program.n_records);
        if lo >= hi {
            break; // fewer records than shards: trailing shards stay idle
        }
        let mut sub = Program::new(hi - lo);
        sub.n_scratch = program.n_scratch;
        let mut ir_map = Vec::new();
        for (ir_index, op) in program.ops.iter().enumerate() {
            let clipped = clip_op(op, lo, hi);
            if let Some(c) = clipped {
                sub.ops.push(c);
                ir_map.push(ir_index);
            }
        }
        let lowered = lower(&sub, cfg, model_of(shard))?;
        plans.push(ShardPlan { shard, record_offset: lo, program: sub, lowered, ir_map });
    }
    let mut predicted = OpCost::default();
    let mut predicted_makespan = 0.0f64;
    let mut predicted_accesses = 0u64;
    for p in &plans {
        predicted = predicted.then(&p.lowered.predicted);
        predicted_makespan = predicted_makespan.max(p.lowered.predicted.latency);
        predicted_accesses += p.lowered.predicted_accesses;
    }
    Ok(Placement {
        program: program.clone(),
        shards: plans,
        predicted,
        predicted_makespan,
        predicted_accesses,
    })
}

/// Restrict one IR op to the record window `[lo, hi)`, rebasing record
/// indices to window-local.  `None` if nothing of it lands in the window.
fn clip_op(op: &IrOp, lo: usize, hi: usize) -> Option<IrOp> {
    match op {
        IrOp::Load { start, values } => {
            let s = (*start).max(lo);
            let e = (start + values.len()).min(hi);
            if s >= e {
                return None;
            }
            Some(IrOp::Load {
                start: s - lo,
                values: values[s - start..e - start].to_vec(),
            })
        }
        // broadcast constants are replicated on every shard
        IrOp::Broadcast { scratch, value } => {
            Some(IrOp::Broadcast { scratch: *scratch, value: *value })
        }
        IrOp::Compare { range, rhs } => {
            Some(IrOp::Compare { range: range.clip(lo, hi)?, rhs: *rhs })
        }
        IrOp::Filter { range, rhs, pred } => {
            Some(IrOp::Filter { range: range.clip(lo, hi)?, rhs: *rhs, pred: *pred })
        }
        IrOp::Sub { range, rhs } => Some(IrOp::Sub { range: range.clip(lo, hi)?, rhs: *rhs }),
        IrOp::Bool { f, range, rhs } => {
            Some(IrOp::Bool { f: *f, range: range.clip(lo, hi)?, rhs: *rhs })
        }
        IrOp::Scan { range } => Some(IrOp::Scan { range: range.clip(lo, hi)? }),
        IrOp::Aggregate { range, agg } => {
            Some(IrOp::Aggregate { range: range.clip(lo, hi)?, agg: *agg })
        }
    }
}

/// Host-side reduction results.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reduction {
    Min { index: usize, value: u64 },
    Max { index: usize, value: u64 },
    Sum(u128),
}

/// Merged output of one IR step, keyed by GLOBAL record index.
#[derive(Clone, Debug, PartialEq)]
pub enum StepOutput {
    /// Setup steps (load / broadcast) produce no output.
    None,
    /// Scan / Bool results per record.
    Words(Vec<(usize, u64)>),
    /// Sub results per record.
    Diffs(Vec<(usize, i128)>),
    /// Compare results per record.
    Orderings(Vec<(usize, CompareResult)>),
    /// Filter: accepted record indices, ascending.
    Matches(Vec<usize>),
    /// Aggregate result.
    Reduced(Reduction),
}

/// Execution failure.
#[derive(Debug)]
pub enum ExecError {
    Route(RouteError),
    Engine { op: CimOp, err: EngineError },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Route(e) => write!(f, "routing: {e}"),
            ExecError::Engine { op, err } => write!(f, "engine failed on {op:?}: {err}"),
        }
    }
}

impl std::error::Error for ExecError {}

/// What a placement execution returns.
#[derive(Clone, Debug)]
pub struct ExecutionReport {
    /// Per-IR-step outputs, indexed like `Program::ops`.
    pub outputs: Vec<StepOutput>,
    /// Measured cost summed from every op's engine-charged result.
    pub measured: OpCost,
    /// Predicted (placement) vs measured comparison.
    pub prediction: PredictionReport,
    /// The coordinator's cumulative metrics snapshot after the run.
    pub coordinator_metrics: RunMetrics,
    pub ops_executed: usize,
    /// Per-(shard, op class, executor) predicted-vs-measured aggregates
    /// over this run's EXECUTED ops — the calibration loop's input
    /// signal (`planner::calibrate::CalibratedCostModel::absorb`).
    pub samples: Vec<crate::planner::calibrate::CalibrationSample>,
}

impl Placement {
    /// Execute on a coordinator (one `call_batch` per shard, in
    /// parallel), merge outputs, and compare prediction to measurement.
    ///
    /// Routing fidelity: execute on a `planner::planned_coordinator`
    /// built with the SAME objective as the cost model so the workers
    /// dispatch each op to the executor the plan priced.  (Whenever the
    /// plan routes everything to ADRA — any objective under current or
    /// voltage-2 sensing — a plain `Coordinator::adra` measures
    /// identically.)
    pub fn execute(&self, coord: &Coordinator) -> Result<ExecutionReport, ExecError> {
        // run every shard's stream concurrently
        let batches: Vec<Result<Vec<Result<crate::cim::CimResult, EngineError>>, RouteError>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .shards
                    .iter()
                    .map(|sp| {
                        s.spawn(move || coord.call_batch(sp.shard, &sp.lowered.op_stream()))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("shard thread panicked")).collect()
            });

        let mut per_shard = Vec::with_capacity(self.shards.len());
        for batch in batches {
            let results = batch.map_err(ExecError::Route)?;
            per_shard.push(results.into_iter().map(Some).collect());
        }
        self.assemble(per_shard, coord.metrics())
    }

    /// Merge per-shard per-op results into global step outputs.
    ///
    /// `per_shard[i]` is aligned to `shards[i].lowered.ops`; a `None`
    /// entry means the op was skipped upstream (the serving layer's
    /// write dedup and result cache do this) and contributes neither
    /// measured cost nor merged output — its step's output is expected
    /// to be supplied by the caller (or to be `StepOutput::None`).
    pub fn assemble(
        &self,
        per_shard: Vec<Vec<Option<Result<crate::cim::CimResult, EngineError>>>>,
        coordinator_metrics: RunMetrics,
    ) -> Result<ExecutionReport, ExecError> {
        let mut outputs: Vec<StepOutput> = self.program.ops.iter().map(empty_output).collect();
        let mut measured = OpCost::default();
        let mut ops_executed = 0usize;
        // per-op-class predicted/measured accumulation over EXECUTED ops
        // only (skipped = deduped/cached ops predicted nothing measurable)
        let mut per_class = [(OpCost::default(), OpCost::default(), 0u64); 4];
        // finer-grained accumulation for the calibration loop: keyed by
        // (shard, class, executor) so corrections stay shard-local
        let mut samples: Vec<crate::planner::calibrate::CalibrationSample> = Vec::new();

        for (sp, results) in self.shards.iter().zip(&per_shard) {
            debug_assert_eq!(results.len(), sp.lowered.ops.len());
            for span in &sp.lowered.spans {
                let sub_op = &sp.program.ops[span.ir_index];
                let global_ir = sp.ir_map[span.ir_index];
                for k in 0..span.len {
                    let idx = span.start + k;
                    let r = match &results[idx] {
                        None => continue, // skipped (deduped / cached)
                        Some(Ok(r)) => r,
                        Some(Err(e)) => {
                            return Err(ExecError::Engine {
                                op: sp.lowered.ops[idx].op,
                                err: e.clone(),
                            })
                        }
                    };
                    measured = measured.then(&r.cost);
                    ops_executed += 1;
                    let routed = &sp.lowered.ops[idx];
                    let class = class_of(&routed.op);
                    let slot = &mut per_class[class as usize];
                    slot.0 = slot.0.then(&routed.predicted);
                    slot.1 = slot.1.then(&r.cost);
                    slot.2 += 1;
                    match samples.iter_mut().find(|s| {
                        s.shard == sp.shard && s.op_class == class && s.executor == routed.executor
                    }) {
                        Some(s) => {
                            s.predicted = s.predicted.then(&routed.predicted);
                            s.measured = s.measured.then(&r.cost);
                            s.ops += 1;
                        }
                        None => samples.push(crate::planner::calibrate::CalibrationSample {
                            shard: sp.shard,
                            op_class: class,
                            executor: routed.executor,
                            predicted: routed.predicted,
                            measured: r.cost,
                            ops: 1,
                        }),
                    }
                    merge_result(
                        &mut outputs[global_ir],
                        sub_op,
                        sp.record_offset,
                        k,
                        &r.value,
                    );
                }
            }
        }

        let prediction = PredictionReport::new(self.predicted, measured);
        // publish the calibration signal the adaptive cost model reads:
        // per-class errors plus the whole-program aggregate
        if ops_executed > 0 {
            let reg = crate::observe::global();
            for class in OpClass::ALL {
                let (pred, meas, n) = per_class[class as usize];
                if n > 0 {
                    PredictionReport::new(pred, meas).publish(reg, class.name());
                }
            }
            prediction.publish(reg, "all");
        }
        Ok(ExecutionReport {
            outputs,
            measured,
            prediction,
            coordinator_metrics,
            ops_executed,
            samples,
        })
    }
}

/// The empty accumulator for one IR step's output.
fn empty_output(op: &IrOp) -> StepOutput {
    match op {
        IrOp::Load { .. } | IrOp::Broadcast { .. } => StepOutput::None,
        IrOp::Compare { .. } => StepOutput::Orderings(Vec::new()),
        IrOp::Filter { .. } => StepOutput::Matches(Vec::new()),
        IrOp::Sub { .. } => StepOutput::Diffs(Vec::new()),
        IrOp::Bool { .. } | IrOp::Scan { .. } => StepOutput::Words(Vec::new()),
        IrOp::Aggregate { agg, .. } => StepOutput::Reduced(match agg {
            AggKind::Min => Reduction::Min { index: usize::MAX, value: u64::MAX },
            AggKind::Max => Reduction::Max { index: usize::MAX, value: 0 },
            AggKind::Sum => Reduction::Sum(0),
        }),
    }
}

/// Fold the `k`-th result of a (shard-local) IR step into the merged
/// output.  `sub_op` is the shard-local op, so its range is local; the
/// global record index is `offset + local_range.start + k`.
fn merge_result(
    out: &mut StepOutput,
    sub_op: &IrOp,
    offset: usize,
    k: usize,
    value: &CimValue,
) {
    let rec = |range_start: usize| offset + range_start + k;
    match (sub_op, value) {
        (IrOp::Load { .. }, _) | (IrOp::Broadcast { .. }, _) => {}
        (IrOp::Compare { range, .. }, CimValue::Ordering(o)) => {
            if let StepOutput::Orderings(v) = out {
                v.push((rec(range.start), *o));
            }
        }
        (IrOp::Filter { range, pred, .. }, CimValue::Ordering(o)) => {
            if let StepOutput::Matches(v) = out {
                if pred.accepts(*o) {
                    v.push(rec(range.start));
                }
            }
        }
        (IrOp::Sub { range, .. }, CimValue::Diff(d)) => {
            if let StepOutput::Diffs(v) = out {
                v.push((rec(range.start), *d));
            }
        }
        (IrOp::Bool { range, .. }, CimValue::Word(w))
        | (IrOp::Scan { range }, CimValue::Word(w)) => {
            if let StepOutput::Words(v) = out {
                v.push((rec(range.start), *w));
            }
        }
        (IrOp::Aggregate { range, agg }, CimValue::Word(w)) => {
            if let StepOutput::Reduced(red) = out {
                let rec = rec(range.start);
                match agg {
                    AggKind::Min => {
                        if let Reduction::Min { index, value } = red {
                            if *w < *value || *index == usize::MAX {
                                *red = Reduction::Min { index: rec, value: *w };
                            }
                        }
                    }
                    AggKind::Max => {
                        if let Reduction::Max { index, value } = red {
                            if *w > *value || *index == usize::MAX {
                                *red = Reduction::Max { index: rec, value: *w };
                            }
                        }
                    }
                    AggKind::Sum => {
                        if let Reduction::Sum(s) = red {
                            *s += *w as u128;
                        }
                    }
                }
            }
        }
        // value kinds are fixed per op kind; anything else is an engine
        // contract violation surfaced loudly in debug builds
        _ => debug_assert!(false, "unexpected value {value:?} for {sub_op:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{AdraEngine, Engine};
    use crate::config::SensingScheme;
    use crate::planner::cost::Objective;
    use crate::planner::engine::planned_coordinator;
    use crate::workload::programs::{analytics_scenario, AnalyticsScenario};

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c.max_batch = 16;
        c
    }

    /// The shared filter + compare + aggregate workload with host-side
    /// ground truth (same builder the example and bench drive).
    fn scenario(cfg: &SimConfig, n: usize, seed: u64) -> AnalyticsScenario {
        analytics_scenario(cfg, n, seed)
    }

    #[test]
    fn placement_partitions_records_and_replicates_scratch() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let p = scenario(&cfg, 100, 5).program;
        let pl = place(&p, &cfg, 4, &model).unwrap();
        assert_eq!(pl.shards.len(), 4);
        let sizes: Vec<usize> = pl.shards.iter().map(|s| s.program.n_records).collect();
        assert_eq!(sizes, vec![25, 25, 25, 25]);
        assert_eq!(pl.shards[2].record_offset, 50);
        for s in &pl.shards {
            // every shard re-broadcasts the threshold
            assert!(s.program.ops.iter().any(|o| matches!(o, IrOp::Broadcast { .. })));
            assert_eq!(s.program.n_scratch, 1);
        }
        // serial prediction decomposes over shards
        let sum: f64 = pl.shards.iter().map(|s| s.lowered.predicted.latency).sum();
        assert!((pl.predicted.latency - sum).abs() < 1e-15);
        assert!(pl.predicted_makespan <= pl.predicted.latency / 3.9);
    }

    #[test]
    fn fewer_records_than_shards_leaves_shards_idle() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let p = scenario(&cfg, 3, 6).program;
        let pl = place(&p, &cfg, 8, &model).unwrap();
        assert_eq!(pl.shards.len(), 3);
    }

    #[test]
    fn four_shard_execution_matches_single_engine_ground_truth() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let s = scenario(&cfg, 120, 77);
        let pl = place(&s.program, &cfg, 4, &model).unwrap();
        let coord = planned_coordinator(&cfg, 4, Objective::Edp);
        let rep = pl.execute(&coord).unwrap();

        // filter output == host ground truth
        assert_eq!(
            rep.outputs[s.filter_step],
            StepOutput::Matches(s.expected_matches.clone())
        );

        // compare output covers every record, in order
        if let StepOutput::Orderings(o) = &rep.outputs[s.compare_step] {
            assert_eq!(o.len(), 120);
            assert!(o.windows(2).all(|w| w[0].0 < w[1].0), "global order");
            for &(i, ord) in o {
                let want = match s.values[i].cmp(&s.threshold) {
                    std::cmp::Ordering::Less => CompareResult::Less,
                    std::cmp::Ordering::Equal => CompareResult::Equal,
                    std::cmp::Ordering::Greater => CompareResult::Greater,
                };
                assert_eq!(ord, want, "record {i}");
            }
        } else {
            panic!("expected orderings, got {:?}", rep.outputs[s.compare_step]);
        }

        // aggregate min == host min (ties: lowest record index)
        assert_eq!(
            rep.outputs[s.aggregate_step],
            StepOutput::Reduced(Reduction::Min {
                index: s.expected_min_index,
                value: s.values[s.expected_min_index],
            })
        );

        // cross-check against one unsharded engine replaying the same
        // plan: the ONLY energy delta sharding may introduce is the
        // scratch-row broadcast replicated on the 3 extra shards
        let single = place(&s.program, &cfg, 1, &model).unwrap();
        let mut engine = AdraEngine::new(&cfg);
        let mut single_measured = OpCost::default();
        for r in &single.shards[0].lowered.ops {
            let res = engine.execute(&r.op).unwrap();
            single_measured = single_measured.then(&res.cost);
        }
        let extra_writes = (pl.shards.len() - 1) * cfg.words_per_row();
        let extra_energy =
            model.adra().write.cost.energy.total() * extra_writes as f64;
        assert!(
            (single_measured.energy.total() + extra_energy - rep.measured.energy.total())
                .abs()
                <= 1e-9 * rep.measured.energy.total(),
            "sharding must only add the replicated broadcasts: single {:e} + extra {:e} vs sharded {:e}",
            single_measured.energy.total(),
            extra_energy,
            rep.measured.energy.total()
        );
        assert_eq!(
            rep.ops_executed,
            single.shards[0].lowered.ops.len() + extra_writes,
            "op-count delta must be exactly the replicated broadcast writes"
        );
    }

    /// The acceptance criterion: predicted within 20% of measured — and
    /// in fact the tables are exact, so pin much tighter than 20%.
    #[test]
    fn prediction_within_tolerance_of_measured_metrics() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let p = scenario(&cfg, 200, 99).program;
        let pl = place(&p, &cfg, 4, &model).unwrap();
        let coord = planned_coordinator(&cfg, 4, Objective::Edp);
        let rep = pl.execute(&coord).unwrap();
        assert!(rep.prediction.within(0.2), "{}", rep.prediction.report("planner"));
        assert!(rep.prediction.within(1e-6), "tables are exact: {}", rep.prediction.report("planner"));
        // and the coordinator's own metrics agree with the summed results
        let m = rep.coordinator_metrics.total_cost();
        assert!(
            (m.energy.total() - rep.measured.energy.total()).abs()
                <= 1e-9 * rep.measured.energy.total()
        );
        assert_eq!(rep.coordinator_metrics.ops as usize, rep.ops_executed);
    }

    /// Mixed routing under scheme 1 + energy objective: the planner sends
    /// dual ops to the baseline executor and the planned coordinator
    /// honors it — prediction still matches measurement.
    #[test]
    fn mixed_routing_prediction_matches_on_planned_coordinator() {
        let mut cfg = cfg();
        cfg.scheme = SensingScheme::VoltagePrecharged;
        let model = PlanCostModel::new(&cfg, Objective::Energy);
        let s = scenario(&cfg, 60, 42);
        let pl = place(&s.program, &cfg, 2, &model).unwrap();
        let (adra_ops, baseline_ops) = pl.shards[0].lowered.executor_counts();
        assert!(baseline_ops > 0, "scheme1/energy must route compares to baseline");
        assert!(adra_ops > 0, "writes/reads stay on the default path");
        let coord = planned_coordinator(&cfg, 2, Objective::Energy);
        let rep = pl.execute(&coord).unwrap();
        assert!(rep.prediction.within(1e-6), "{}", rep.prediction.report("mixed"));
        assert_eq!(
            rep.outputs[s.filter_step],
            StepOutput::Matches(s.expected_matches.clone())
        );
    }

    #[test]
    fn place_rejects_malformed_global_programs() {
        use crate::planner::ir::{AggKind, PlanError, Program, RecordRange};
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        // out-of-bounds aggregate: must error, never be clipped away into
        // a sentinel result
        let mut p = Program::new(10);
        p.aggregate(RecordRange::new(12, 3), AggKind::Min);
        assert!(matches!(place(&p, &cfg, 2, &model), Err(PlanError::BadRange(_))));
        // partially out-of-bounds filter: rejected, not truncated
        let mut p2 = Program::new(10);
        let t = p2.scratch();
        p2.broadcast(t, 1);
        p2.filter(RecordRange::new(5, 10), t, crate::planner::ir::Predicate::Lt);
        assert!(matches!(place(&p2, &cfg, 2, &model), Err(PlanError::BadRange(_))));
    }

    #[test]
    fn route_error_on_missing_shard() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let p = scenario(&cfg, 40, 3).program;
        let pl = place(&p, &cfg, 4, &model).unwrap();
        let coord = Coordinator::adra(&cfg, 2); // too few shards
        assert!(matches!(pl.execute(&coord), Err(ExecError::Route(_))));
    }
}
