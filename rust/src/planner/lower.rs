//! Lowering: IR program -> cost-routed `CimOp` stream.
//!
//! Each IR op expands into its per-record `CimOp`s against the shard
//! layout; every emitted op carries the executor the cost model chose and
//! the predicted cost of running it there.  The lowered stream preserves
//! IR order (writes before the queries that read them), and records which
//! stream span each IR step produced so execution can map results back.
//!
//! `fused_prediction` re-prices the stream for the
//! `coordinator::fuse::execute_fused` path: dual ops over the same
//! operand pair share one activation, followers paying only the
//! compute-module increment — the planner predicts the fusion win without
//! executing anything.

use crate::cim::CimOp;
use crate::config::SimConfig;
use crate::coordinator::fuse::{follower_cost, fuse_batch, planned_activations, PlanStep};
use crate::energy::OpCost;

use super::cost::{Executor, PlanCostModel};
use super::ir::{IrOp, Layout, PlanError, Program};

/// One op of the lowered stream with its routing decision.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoutedOp {
    pub op: CimOp,
    pub executor: Executor,
    /// Modeled cost of this op on `executor` (from the price table).
    pub predicted: OpCost,
    /// Array accesses this op issues on `executor`.
    pub accesses: u64,
}

/// The contiguous stream span one IR step lowered to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepSpan {
    /// Index of the producing op in `Program::ops`.
    pub ir_index: usize,
    /// First op of the span in the lowered stream.
    pub start: usize,
    pub len: usize,
}

/// A lowered program: routed op stream + per-step spans + predictions.
#[derive(Clone, Debug)]
pub struct LoweredProgram {
    pub layout: Layout,
    pub ops: Vec<RoutedOp>,
    pub spans: Vec<StepSpan>,
    /// Serial prediction: every op at its routed executor's price.
    pub predicted: OpCost,
    /// Total array accesses predicted.
    pub predicted_accesses: u64,
}

impl LoweredProgram {
    /// The bare op stream (what `Coordinator::call_batch` consumes).
    pub fn op_stream(&self) -> Vec<CimOp> {
        self.ops.iter().map(|r| r.op).collect()
    }

    /// (ops routed to ADRA, ops routed to the baseline).
    pub fn executor_counts(&self) -> (usize, usize) {
        let adra = self.ops.iter().filter(|r| r.executor == Executor::Adra).count();
        (adra, self.ops.len() - adra)
    }

    /// Predicted cost and activation count if this stream ran through
    /// `coordinator::fuse::execute_fused` on one ADRA engine (fusion
    /// reprices everything at the ADRA tables: the fused path drives an
    /// `AdraEngine` regardless of per-op routing).
    pub fn fused_prediction(&self, model: &PlanCostModel) -> (OpCost, usize) {
        let stream = self.op_stream();
        let plan = fuse_batch(&stream);
        let mut total = OpCost::default();
        for step in &plan {
            match step {
                PlanStep::Passthrough(i) => {
                    total = total.then(&model.price(&stream[*i], Executor::Adra).cost);
                }
                PlanStep::Fused { indices, .. } => {
                    let full = model.price(&stream[indices[0]], Executor::Adra).cost;
                    total = total.then(&full);
                    if indices.len() > 1 {
                        let followers = follower_cost(&full).repeat(indices.len() as u64 - 1);
                        total = total.then(&followers);
                    }
                }
            }
        }
        (total, planned_activations(&plan))
    }
}

/// Lower a program onto one shard's layout, routing every op through the
/// cost model.
pub fn lower(
    program: &Program,
    cfg: &SimConfig,
    model: &PlanCostModel,
) -> Result<LoweredProgram, PlanError> {
    program.validate(cfg)?;
    let layout = Layout::of(cfg, program.n_records);
    let mut ops: Vec<RoutedOp> = Vec::with_capacity(program.op_count(cfg));
    let mut spans = Vec::with_capacity(program.ops.len());
    let mut predicted = OpCost::default();
    let mut predicted_accesses = 0u64;

    let mut route = |ops: &mut Vec<RoutedOp>, op: CimOp| {
        let d = model.choose(&op);
        predicted = predicted.then(&d.cost.cost);
        predicted_accesses += d.cost.accesses;
        ops.push(RoutedOp {
            op,
            executor: d.executor,
            predicted: d.cost.cost,
            accesses: d.cost.accesses,
        });
    };

    for (ir_index, ir) in program.ops.iter().enumerate() {
        let start = ops.len();
        match ir {
            IrOp::Load { start: s, values } => {
                for (i, &v) in values.iter().enumerate() {
                    route(&mut ops, CimOp::Write { addr: layout.record_addr(s + i), value: v });
                }
            }
            IrOp::Broadcast { scratch, value } => {
                let row = layout.scratch_row(*scratch);
                for word in 0..layout.words_per_row {
                    route(
                        &mut ops,
                        CimOp::Write {
                            addr: crate::cim::WordAddr { row, word },
                            value: *value,
                        },
                    );
                }
            }
            IrOp::Compare { range, rhs } | IrOp::Filter { range, rhs, .. } => {
                let row_b = layout.scratch_row(*rhs);
                for i in range.start..range.end() {
                    let a = layout.record_addr(i);
                    route(&mut ops, CimOp::Compare { row_a: a.row, row_b, word: a.word });
                }
            }
            IrOp::Sub { range, rhs } => {
                let row_b = layout.scratch_row(*rhs);
                for i in range.start..range.end() {
                    let a = layout.record_addr(i);
                    route(&mut ops, CimOp::Sub { row_a: a.row, row_b, word: a.word });
                }
            }
            IrOp::Bool { f, range, rhs } => {
                let row_b = layout.scratch_row(*rhs);
                for i in range.start..range.end() {
                    let a = layout.record_addr(i);
                    route(&mut ops, CimOp::Bool { f: *f, row_a: a.row, row_b, word: a.word });
                }
            }
            IrOp::Scan { range } | IrOp::Aggregate { range, .. } => {
                for i in range.start..range.end() {
                    route(&mut ops, CimOp::Read(layout.record_addr(i)));
                }
            }
        }
        spans.push(StepSpan { ir_index, start, len: ops.len() - start });
    }

    Ok(LoweredProgram { layout, ops, spans, predicted, predicted_accesses })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::AdraEngine;
    use crate::config::SensingScheme;
    use crate::coordinator::fuse::execute_fused;
    use crate::planner::cost::Objective;
    use crate::planner::ir::{AggKind, Predicate};
    use crate::util::rng::Rng;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c
    }

    fn filter_program(n: usize) -> Program {
        let mut p = Program::new(n);
        let t = p.scratch();
        let all = p.all();
        let mut rng = Rng::new(7);
        let values: Vec<u64> = (0..n).map(|_| rng.below(128)).collect();
        p.load(0, values);
        p.broadcast(t, 64);
        p.filter(all, t, Predicate::Lt);
        p.aggregate(all, AggKind::Min);
        p
    }

    #[test]
    fn lowered_stream_shape_and_spans() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let p = filter_program(20);
        let l = lower(&p, &cfg, &model).unwrap();
        // 20 loads + 8 broadcast + 20 compares + 20 reads
        assert_eq!(l.ops.len(), 68);
        assert_eq!(l.spans.len(), 4);
        assert_eq!(l.spans[0], StepSpan { ir_index: 0, start: 0, len: 20 });
        assert_eq!(l.spans[1], StepSpan { ir_index: 1, start: 20, len: 8 });
        assert_eq!(l.spans[2], StepSpan { ir_index: 2, start: 28, len: 20 });
        assert_eq!(l.spans[3], StepSpan { ir_index: 3, start: 48, len: 20 });
        // filter lowers to dual-row compares routed to ADRA...
        for r in &l.ops[28..48] {
            assert!(matches!(r.op, CimOp::Compare { .. }));
            assert_eq!(r.executor, Executor::Adra);
            assert_eq!(r.accesses, 1, "ADRA compare is single-access");
        }
        // ...and the aggregate lowers to PLAIN READS (no activation paid)
        let read_cost = model.adra().read.cost;
        for r in &l.ops[48..68] {
            assert!(matches!(r.op, CimOp::Read(_)));
            assert_eq!(r.predicted, read_cost);
        }
    }

    #[test]
    fn prediction_is_sum_of_table_prices() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let p = filter_program(16);
        let l = lower(&p, &cfg, &model).unwrap();
        let mut want = OpCost::default();
        for r in &l.ops {
            want = want.then(&r.predicted);
        }
        assert_eq!(l.predicted, want);
        assert_eq!(
            l.predicted_accesses,
            l.ops.iter().map(|r| r.accesses).sum::<u64>()
        );
    }

    /// The fused prediction must equal what `execute_fused` actually
    /// charges, and must beat the unfused prediction on a fusion-heavy
    /// stream.
    #[test]
    fn fused_prediction_matches_fused_execution() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        // fusion-heavy: compare + sub + bool all on the same operand pair
        let mut p = Program::new(8);
        let t = p.scratch();
        let all = p.all();
        p.load(0, (0..8).map(|i| i as u64 * 3).collect());
        p.broadcast(t, 11);
        p.compare(all, t);
        p.sub(all, t);
        let l = lower(&p, &cfg, &model).unwrap();
        let (fused_pred, activations) = l.fused_prediction(&model);
        assert!(
            fused_pred.energy.total() < l.predicted.energy.total(),
            "fusion must be predicted cheaper: {:e} vs {:e}",
            fused_pred.energy.total(),
            l.predicted.energy.total()
        );
        // each record's compare+sub share one activation
        assert_eq!(activations, 8);

        let mut engine = AdraEngine::new(&cfg);
        let stream = l.op_stream();
        let results = execute_fused(&mut engine, &stream);
        let mut measured = OpCost::default();
        for r in &results {
            measured = measured.then(&r.as_ref().unwrap().cost);
        }
        assert_eq!(engine.array().stats().dual_activations, 8);
        assert!(
            (fused_pred.energy.total() - measured.energy.total()).abs()
                <= 1e-9 * measured.energy.total(),
            "fused prediction {:e} vs measured {:e}",
            fused_pred.energy.total(),
            measured.energy.total()
        );
        assert!(
            (fused_pred.latency - measured.latency).abs() <= 1e-9 * measured.latency,
            "fused latency prediction"
        );
    }

    #[test]
    fn lowering_rejects_invalid_programs() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let p = Program::new(100_000);
        assert!(lower(&p, &cfg, &model).is_err());
    }
}
