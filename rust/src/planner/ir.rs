//! The planner's program IR: bulk bitwise/arithmetic column programs.
//!
//! A [`Program`] describes a database-style query plan over a table of
//! records laid out row-major in an array shard (record `i` lives at row
//! `i / words_per_row`, word `i % words_per_row` — the same layout
//! `workload::database_filter_trace` uses), plus a small set of
//! *scratch rows* holding broadcast constants (thresholds, masks,
//! subtrahends) above the record region.
//!
//! The IR is deliberately static: every op's address stream is known
//! before execution, which is what lets `cost` price it, `lower` route it
//! per-op between the ADRA and baseline executors, and `place` split it
//! across coordinator shards.  Data-dependent reductions (min/max/sum)
//! lower to plain reads plus a host-side fold — read-only ops never pay
//! for an activation they don't need.

use crate::cim::{BoolFn, WordAddr};
use crate::config::SimConfig;
use crate::logic::CompareResult;

/// Comparison predicate a [`IrOp::Filter`] keeps records by
/// (two's-complement ordering, matching `CimOp::Compare`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Predicate {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl Predicate {
    /// Does a three-way compare outcome satisfy this predicate?
    pub fn accepts(&self, o: CompareResult) -> bool {
        match self {
            Predicate::Lt => o == CompareResult::Less,
            Predicate::Le => o != CompareResult::Greater,
            Predicate::Gt => o == CompareResult::Greater,
            Predicate::Ge => o != CompareResult::Less,
            Predicate::Eq => o == CompareResult::Equal,
            Predicate::Ne => o != CompareResult::Equal,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Predicate::Lt => "<",
            Predicate::Le => "<=",
            Predicate::Gt => ">",
            Predicate::Ge => ">=",
            Predicate::Eq => "==",
            Predicate::Ne => "!=",
        }
    }
}

/// Half-open range `[start, start + len)` of record slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecordRange {
    pub start: usize,
    pub len: usize,
}

impl RecordRange {
    pub fn new(start: usize, len: usize) -> Self {
        Self { start, len }
    }

    pub fn end(&self) -> usize {
        self.start + self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Intersect with the window `[lo, hi)` and rebase to window-local
    /// indices (record `lo` becomes 0).  `None` when disjoint.
    pub fn clip(&self, lo: usize, hi: usize) -> Option<RecordRange> {
        let s = self.start.max(lo);
        let e = self.end().min(hi);
        if s >= e {
            None
        } else {
            Some(RecordRange { start: s - lo, len: e - s })
        }
    }
}

/// Handle to a broadcast scratch row.  Scratch rows sit above the record
/// region and are replicated on every shard a program is placed on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScratchRow(pub usize);

/// Host-side reduction kinds (lowered to plain reads + a fold).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AggKind {
    Min,
    Max,
    Sum,
}

impl AggKind {
    pub fn name(&self) -> &'static str {
        match self {
            AggKind::Min => "min",
            AggKind::Max => "max",
            AggKind::Sum => "sum",
        }
    }
}

/// One IR operation over the record table.
#[derive(Clone, Debug, PartialEq)]
pub enum IrOp {
    /// Store `values[i]` into record slot `start + i` (setup writes).
    Load { start: usize, values: Vec<u64> },
    /// Broadcast `value` into every word of a scratch row, so any record
    /// can be compared/combined against it column-locally.
    Broadcast { scratch: ScratchRow, value: u64 },
    /// Three-way compare of every record in `range` against `rhs`.
    Compare { range: RecordRange, rhs: ScratchRow },
    /// Keep the records in `range` whose compare against `rhs` satisfies
    /// `pred` (`SELECT * WHERE value <pred> rhs`).
    Filter { range: RecordRange, rhs: ScratchRow, pred: Predicate },
    /// Signed per-record difference `record - rhs`.
    Sub { range: RecordRange, rhs: ScratchRow },
    /// Bitwise `f(record, rhs)` per record.
    Bool { f: BoolFn, range: RecordRange, rhs: ScratchRow },
    /// Plain readout of every record in `range`.
    Scan { range: RecordRange },
    /// Host-side reduction over plain reads of `range`.
    Aggregate { range: RecordRange, agg: AggKind },
}

impl IrOp {
    pub fn name(&self) -> &'static str {
        match self {
            IrOp::Load { .. } => "load",
            IrOp::Broadcast { .. } => "broadcast",
            IrOp::Compare { .. } => "compare",
            IrOp::Filter { .. } => "filter",
            IrOp::Sub { .. } => "sub",
            IrOp::Bool { .. } => "bool",
            IrOp::Scan { .. } => "scan",
            IrOp::Aggregate { .. } => "aggregate",
        }
    }

    /// Number of `CimOp`s this lowers to, given the words-per-row of the
    /// target layout.
    pub fn op_count(&self, words_per_row: usize) -> usize {
        match self {
            IrOp::Load { values, .. } => values.len(),
            IrOp::Broadcast { .. } => words_per_row,
            IrOp::Compare { range, .. }
            | IrOp::Filter { range, .. }
            | IrOp::Sub { range, .. }
            | IrOp::Bool { range, .. }
            | IrOp::Scan { range }
            | IrOp::Aggregate { range, .. } => range.len,
        }
    }

    /// The record range a per-record op covers (`None` for setup ops).
    pub fn range(&self) -> Option<RecordRange> {
        match self {
            IrOp::Load { .. } | IrOp::Broadcast { .. } => None,
            IrOp::Compare { range, .. }
            | IrOp::Filter { range, .. }
            | IrOp::Sub { range, .. }
            | IrOp::Bool { range, .. }
            | IrOp::Scan { range }
            | IrOp::Aggregate { range, .. } => Some(*range),
        }
    }
}

/// Planner failure modes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// The program does not fit the array (rows needed vs available).
    Capacity { need_rows: usize, have_rows: usize },
    /// A range or load window lies outside the record table.
    BadRange(String),
    /// A scratch handle was never allocated via `Program::scratch`.
    BadScratch(String),
    /// Degenerate program (no records / no shards).
    Empty(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Capacity { need_rows, have_rows } => {
                write!(f, "program needs {need_rows} rows, array has {have_rows}")
            }
            PlanError::BadRange(s) => write!(f, "bad record range: {s}"),
            PlanError::BadScratch(s) => write!(f, "bad scratch row: {s}"),
            PlanError::Empty(s) => write!(f, "degenerate program: {s}"),
        }
    }
}

impl std::error::Error for PlanError {}

/// A bulk bitwise/arithmetic program over `n_records` record slots.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    pub n_records: usize,
    pub n_scratch: usize,
    pub ops: Vec<IrOp>,
}

impl Program {
    pub fn new(n_records: usize) -> Self {
        Self { n_records, n_scratch: 0, ops: Vec::new() }
    }

    /// Allocate a scratch row for broadcast constants.
    pub fn scratch(&mut self) -> ScratchRow {
        let s = ScratchRow(self.n_scratch);
        self.n_scratch += 1;
        s
    }

    /// The range covering every record slot.
    pub fn all(&self) -> RecordRange {
        RecordRange::new(0, self.n_records)
    }

    pub fn load(&mut self, start: usize, values: Vec<u64>) -> &mut Self {
        self.ops.push(IrOp::Load { start, values });
        self
    }

    pub fn broadcast(&mut self, scratch: ScratchRow, value: u64) -> &mut Self {
        self.ops.push(IrOp::Broadcast { scratch, value });
        self
    }

    pub fn compare(&mut self, range: RecordRange, rhs: ScratchRow) -> &mut Self {
        self.ops.push(IrOp::Compare { range, rhs });
        self
    }

    pub fn filter(&mut self, range: RecordRange, rhs: ScratchRow, pred: Predicate) -> &mut Self {
        self.ops.push(IrOp::Filter { range, rhs, pred });
        self
    }

    pub fn sub(&mut self, range: RecordRange, rhs: ScratchRow) -> &mut Self {
        self.ops.push(IrOp::Sub { range, rhs });
        self
    }

    pub fn bool_op(&mut self, f: BoolFn, range: RecordRange, rhs: ScratchRow) -> &mut Self {
        self.ops.push(IrOp::Bool { f, range, rhs });
        self
    }

    pub fn scan(&mut self, range: RecordRange) -> &mut Self {
        self.ops.push(IrOp::Scan { range });
        self
    }

    pub fn aggregate(&mut self, range: RecordRange, agg: AggKind) -> &mut Self {
        self.ops.push(IrOp::Aggregate { range, agg });
        self
    }

    /// Check the program against one array shard's geometry: structural
    /// checks plus the capacity check for THIS geometry.
    pub fn validate(&self, cfg: &SimConfig) -> Result<(), PlanError> {
        self.validate_structure()?;
        let layout = Layout::of(cfg, self.n_records);
        let need = layout.rows_needed(self.n_scratch);
        if need > cfg.rows {
            return Err(PlanError::Capacity { need_rows: need, have_rows: cfg.rows });
        }
        Ok(())
    }

    /// Geometry-independent checks (ranges, scratch handles, load
    /// windows).  `place` runs this on the GLOBAL program — whose record
    /// count may legitimately exceed one shard's capacity — so malformed
    /// ranges are rejected instead of being silently clipped away.
    pub fn validate_structure(&self) -> Result<(), PlanError> {
        if self.n_records == 0 {
            return Err(PlanError::Empty("0 records".into()));
        }
        for op in &self.ops {
            if let Some(range) = op.range() {
                if range.is_empty() {
                    // an empty per-record op is meaningless and (for
                    // aggregates) would surface the fold's sentinel as if
                    // it were data
                    return Err(PlanError::BadRange(format!(
                        "{} range at {} is empty",
                        op.name(),
                        range.start
                    )));
                }
                if range.end() > self.n_records {
                    return Err(PlanError::BadRange(format!(
                        "{} range [{}, {}) exceeds {} records",
                        op.name(),
                        range.start,
                        range.end(),
                        self.n_records
                    )));
                }
            }
            let scratch = match op {
                IrOp::Broadcast { scratch, .. } => Some(*scratch),
                IrOp::Compare { rhs, .. }
                | IrOp::Filter { rhs, .. }
                | IrOp::Sub { rhs, .. }
                | IrOp::Bool { rhs, .. } => Some(*rhs),
                _ => None,
            };
            if let Some(ScratchRow(s)) = scratch {
                if s >= self.n_scratch {
                    return Err(PlanError::BadScratch(format!(
                        "{} uses scratch {s}, only {} allocated",
                        op.name(),
                        self.n_scratch
                    )));
                }
            }
            if let IrOp::Load { start, values } = op {
                if start + values.len() > self.n_records {
                    return Err(PlanError::BadRange(format!(
                        "load [{}, {}) exceeds {} records",
                        start,
                        start + values.len(),
                        self.n_records
                    )));
                }
            }
        }
        Ok(())
    }

    /// Total `CimOp`s the program lowers to on the given geometry.
    pub fn op_count(&self, cfg: &SimConfig) -> usize {
        let words = cfg.words_per_row();
        self.ops.iter().map(|op| op.op_count(words)).sum()
    }
}

/// Physical layout of a program on ONE array shard: records first, then
/// scratch rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout {
    /// Words per row of the target geometry.
    pub words_per_row: usize,
    /// Records stored on this shard.
    pub n_records: usize,
    /// First scratch row (== number of record rows).
    pub scratch_base: usize,
}

impl Layout {
    pub fn of(cfg: &SimConfig, n_records: usize) -> Self {
        let words_per_row = cfg.words_per_row();
        Self {
            words_per_row,
            n_records,
            scratch_base: n_records.div_ceil(words_per_row.max(1)),
        }
    }

    /// Physical address of record slot `i`.
    pub fn record_addr(&self, i: usize) -> WordAddr {
        WordAddr { row: i / self.words_per_row, word: i % self.words_per_row }
    }

    /// Physical row of a scratch handle.
    pub fn scratch_row(&self, s: ScratchRow) -> usize {
        self.scratch_base + s.0
    }

    /// Rows the layout occupies with `n_scratch` scratch rows.
    pub fn rows_needed(&self, n_scratch: usize) -> usize {
        self.scratch_base + n_scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SensingScheme;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8; // 8 words per row
        c
    }

    #[test]
    fn predicate_semantics() {
        use CompareResult::*;
        assert!(Predicate::Lt.accepts(Less) && !Predicate::Lt.accepts(Equal));
        assert!(Predicate::Le.accepts(Equal) && !Predicate::Le.accepts(Greater));
        assert!(Predicate::Ge.accepts(Greater) && Predicate::Ge.accepts(Equal));
        assert!(Predicate::Eq.accepts(Equal) && !Predicate::Eq.accepts(Less));
        assert!(Predicate::Ne.accepts(Less) && !Predicate::Ne.accepts(Equal));
    }

    #[test]
    fn range_clip_rebases() {
        let r = RecordRange::new(10, 20); // [10, 30)
        assert_eq!(r.clip(0, 15), Some(RecordRange::new(10, 5)));
        assert_eq!(r.clip(15, 25), Some(RecordRange::new(0, 10)));
        assert_eq!(r.clip(25, 100), Some(RecordRange::new(0, 5)));
        assert_eq!(r.clip(30, 40), None);
        assert_eq!(r.clip(0, 10), None);
    }

    #[test]
    fn layout_addresses_records_row_major() {
        let cfg = cfg();
        let l = Layout::of(&cfg, 20); // 8 words/row -> 3 record rows
        assert_eq!(l.record_addr(0), WordAddr { row: 0, word: 0 });
        assert_eq!(l.record_addr(9), WordAddr { row: 1, word: 1 });
        assert_eq!(l.scratch_base, 3);
        assert_eq!(l.scratch_row(ScratchRow(1)), 4);
        assert_eq!(l.rows_needed(2), 5);
    }

    #[test]
    fn builder_and_validation() {
        let cfg = cfg();
        let mut p = Program::new(20);
        let t = p.scratch();
        let all = p.all();
        p.load(0, vec![1; 20])
            .broadcast(t, 42)
            .filter(all, t, Predicate::Lt)
            .aggregate(RecordRange::new(0, 10), AggKind::Min);
        assert!(p.validate(&cfg).is_ok());
        // 20 loads + 8 broadcast words + 20 compares + 10 reads
        assert_eq!(p.op_count(&cfg), 58);
    }

    #[test]
    fn validation_rejects_bad_programs() {
        let cfg = cfg();
        // range out of bounds
        let mut p = Program::new(10);
        let t = p.scratch();
        p.filter(RecordRange::new(5, 10), t, Predicate::Lt);
        assert!(matches!(p.validate(&cfg), Err(PlanError::BadRange(_))));
        // unallocated scratch
        let mut p2 = Program::new(10);
        p2.broadcast(ScratchRow(3), 1);
        assert!(matches!(p2.validate(&cfg), Err(PlanError::BadScratch(_))));
        // over capacity: 64 rows x 8 words = 512 record slots max
        let p3 = Program::new(10_000);
        assert!(matches!(p3.validate(&cfg), Err(PlanError::Capacity { .. })));
        // empty
        assert!(matches!(Program::new(0).validate(&cfg), Err(PlanError::Empty(_))));
        // load window out of bounds
        let mut p4 = Program::new(10);
        p4.load(8, vec![0; 5]);
        assert!(matches!(p4.validate(&cfg), Err(PlanError::BadRange(_))));
        // empty per-record range (would leak the aggregate sentinel)
        let mut p5 = Program::new(10);
        p5.aggregate(RecordRange::new(0, 0), AggKind::Min);
        assert!(matches!(p5.validate(&cfg), Err(PlanError::BadRange(_))));
        // structural checks are geometry-independent: a program too big
        // for ONE shard still structure-validates (place shards it)...
        let mut p6 = Program::new(10_000);
        let all6 = p6.all();
        p6.scan(all6);
        assert!(p6.validate_structure().is_ok());
        // ...while its bad-range variant is caught without any cfg
        let mut p7 = Program::new(10_000);
        p7.scan(RecordRange::new(9_999, 2));
        assert!(matches!(p7.validate_structure(), Err(PlanError::BadRange(_))));
    }
}
