//! The planned (hybrid) engine: one shard's executor pair, routed per-op
//! by the cost model.
//!
//! The coordinator's worker pool owns one engine per shard; to let the
//! planner route each op to whichever executor its cost table picked, a
//! `PlannedEngine` bundles an `AdraEngine` and a `BaselineEngine` over
//! mirrored array state and dispatches through `PlanCostModel::choose`.
//! Writes execute on the ADRA array (charged once) and are mirrored into
//! the baseline array state, so either executor sees consistent data
//! whenever the router sends it an op.

use crate::cim::{AdraEngine, BaselineEngine, CimOp, CimResult, Engine, EngineError};
use crate::config::SimConfig;
use crate::coordinator::Coordinator;

use super::cost::{Executor, Objective, PlanCostModel};

/// Cost-routed engine over mirrored ADRA + baseline array state.
pub struct PlannedEngine {
    adra: AdraEngine,
    baseline: BaselineEngine,
    model: PlanCostModel,
}

impl PlannedEngine {
    pub fn new(cfg: &SimConfig, objective: Objective) -> Self {
        Self {
            adra: AdraEngine::new(cfg),
            baseline: BaselineEngine::new(cfg),
            model: PlanCostModel::new(cfg, objective),
        }
    }

    pub fn model(&self) -> &PlanCostModel {
        &self.model
    }

    pub fn adra_engine(&self) -> &AdraEngine {
        &self.adra
    }

    pub fn baseline_engine(&self) -> &BaselineEngine {
        &self.baseline
    }

    /// The fidelity tier the ADRA half runs at (threaded from
    /// `SimConfig::tier`; the price tables are tier-invariant — see
    /// `planner::cost`).
    pub fn tier(&self) -> crate::config::FidelityTier {
        self.adra.tier()
    }
}

impl Engine for PlannedEngine {
    fn execute(&mut self, op: &CimOp) -> Result<CimResult, EngineError> {
        if let CimOp::Write { addr, value } = *op {
            // charge the write once (ADRA path), then mirror the data into
            // the baseline array so both executors stay consistent.  The
            // mirror bumps the baseline array's write *stat*, not its cost.
            let r = self.adra.execute(op)?;
            self.baseline.array_mut().write_word(addr.row, addr.word, value);
            return Ok(r);
        }
        match self.model.choose(op).executor {
            Executor::Adra => self.adra.execute(op),
            Executor::Baseline => self.baseline.execute(op),
        }
    }

    /// Fused execution drives the ADRA half regardless of per-op routing
    /// (same contract as `LoweredProgram::fused_prediction`); successful
    /// writes are mirrored into the baseline array afterwards so later
    /// routed ops still see consistent state.
    fn execute_fused(&mut self, ops: &[CimOp]) -> Option<Vec<Result<CimResult, EngineError>>> {
        let results = crate::coordinator::fuse::execute_fused(&mut self.adra, ops);
        for (op, r) in ops.iter().zip(&results) {
            if let (CimOp::Write { addr, value }, Ok(_)) = (*op, r) {
                self.baseline.array_mut().write_word(addr.row, addr.word, value);
            }
        }
        Some(results)
    }

    /// Calibrated routing override: pin each op class to the executor the
    /// calibration loop committed (`None` restores score-based choice), so
    /// this engine dispatches the way the calibrated plan was priced.
    fn set_routing(&mut self, forced: [Option<Executor>; 4]) {
        use super::cost::OpClass;
        for class in [OpClass::Read, OpClass::Write, OpClass::Commutative, OpClass::Dual] {
            self.model.pin_class(class, forced[class as usize]);
        }
    }

    fn array_stats(&self) -> Option<crate::array::ArrayStats> {
        // both halves touch real array state; report the sum so the pool
        // sees every access (the baseline mirror's writes included)
        Some(
            self.adra
                .array()
                .stats()
                .merged(&self.baseline.array().stats()),
        )
    }

    fn name(&self) -> &'static str {
        "planned"
    }
}

/// A coordinator whose every shard runs a cost-routed `PlannedEngine`
/// with the given objective — the deployment the planner's placements
/// execute on.
pub fn planned_coordinator(cfg: &SimConfig, shards: usize, objective: Objective) -> Coordinator {
    let cfg2 = cfg.clone();
    Coordinator::new(cfg, shards, move |_| {
        Box::new(PlannedEngine::new(&cfg2, objective)) as Box<dyn Engine>
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{CimValue, WordAddr};
    use crate::config::SensingScheme;
    use crate::workload::{OpMix, WorkloadGen};

    fn cfg(scheme: SensingScheme) -> SimConfig {
        let mut c = SimConfig::square(64, scheme);
        c.word_bits = 8;
        c
    }

    #[test]
    fn planned_engine_matches_adra_values() {
        let cfg = cfg(SensingScheme::Current);
        let mut planned = PlannedEngine::new(&cfg, Objective::Edp);
        let mut adra = AdraEngine::new(&cfg);
        let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 321);
        for op in gen.batch(600) {
            let a = planned.execute(&op);
            let b = adra.execute(&op);
            match (a, b) {
                (Ok(ra), Ok(rb)) => assert_eq!(ra.value, rb.value, "op {op:?}"),
                (Err(_), Err(_)) => {}
                (a, b) => panic!("divergence on {op:?}: {a:?} vs {b:?}"),
            }
        }
    }

    /// Under scheme 1 + energy objective the router must send dual ops to
    /// the baseline executor — observable as READS (not activations) on
    /// the baseline array, with values still correct.
    #[test]
    fn scheme1_energy_objective_runs_dual_ops_on_baseline() {
        let cfg = cfg(SensingScheme::VoltagePrecharged);
        let mut e = PlannedEngine::new(&cfg, Objective::Energy);
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 40 }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 15 }).unwrap();
        let r = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(25), "baseline route must still be correct");
        assert_eq!(e.baseline_engine().array().stats().reads, 2, "two-read baseline path");
        assert_eq!(e.adra_engine().array().stats().dual_activations, 0);

        // same scheme, EDP objective: routed to ADRA instead
        let mut e2 = PlannedEngine::new(&cfg, Objective::Edp);
        e2.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 40 }).unwrap();
        e2.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 15 }).unwrap();
        let r2 = e2.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r2.value, CimValue::Diff(25));
        assert_eq!(e2.adra_engine().array().stats().dual_activations, 1);
        assert_eq!(e2.baseline_engine().array().stats().reads, 0);
    }

    /// The digital fast path must ride through the planned engine
    /// untouched: default tier serves dual ops digitally, and the
    /// reported costs equal the analog tiers' (tier-invariant pricing).
    #[test]
    fn digital_tier_rides_through_planned_engine() {
        let cfg = cfg(SensingScheme::Current);
        assert_eq!(cfg.tier, crate::config::FidelityTier::Digital);
        let mut e = PlannedEngine::new(&cfg, Objective::Edp);
        assert_eq!(e.tier(), crate::config::FidelityTier::Digital);
        e.execute(&CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 40 }).unwrap();
        e.execute(&CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 15 }).unwrap();
        let r = e.execute(&CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(25));
        let s = e.adra_engine().array().stats();
        assert_eq!(s.dual_activations, 1);
        assert_eq!(s.digital_activations, 1, "dual op must ride the packed path");
        // aggregated stats include the baseline mirror's writes
        let merged = e.array_stats().unwrap();
        assert_eq!(merged.digital_activations, 1);
        assert!(merged.writes >= 4);
    }

    #[test]
    fn planned_coordinator_round_trip() {
        let cfg = cfg(SensingScheme::Current);
        let coord = planned_coordinator(&cfg, 2, Objective::Edp);
        coord
            .call(1, CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 7 })
            .unwrap();
        coord
            .call(1, CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 3 })
            .unwrap();
        let r = coord.call(1, CimOp::Sub { row_a: 0, row_b: 1, word: 0 }).unwrap();
        assert_eq!(r.value, CimValue::Diff(4));
    }
}
