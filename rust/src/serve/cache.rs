//! The serving layer's versioned result cache and its view of the shared
//! table.
//!
//! [`TableState`] shadows what the serving layer knows about array
//! contents: per-record-slot masked words with a monotone version, and
//! per-scratch-row broadcast contents.  Two uses:
//!
//! * **write dedup** — a write whose masked value provably equals what
//!   the cell already stores is a state no-op (`FefetArray::write_bit`
//!   sets polarization deterministically, no drift), so the coalescer can
//!   drop it and save the write energy;
//! * **cache keys** — a query step's result is fully determined by
//!   (op kind, record-range contents, broadcast-row contents).  The key
//!   captures range contents through a monotone fingerprint (max slot
//!   version) and rhs contents by value, so any overlapping
//!   content-changing load bumps the fingerprint and strands stale
//!   entries without an explicit invalidation walk.

use std::collections::HashMap;

use crate::cim::BoolFn;
use crate::config::SimConfig;
use crate::planner::{AggKind, IrOp, Predicate, RecordRange, ScratchRow, StepOutput};
use crate::store::{TableImage, WalOp};

/// What the serving layer knows about the shared table's contents.
#[derive(Clone, Debug)]
pub struct TableState {
    n_records: usize,
    word_mask: u64,
    /// Known masked contents per record slot (`None` = never written
    /// through the serving layer; fresh arrays hold 0 but we only dedupe
    /// against *observed* writes).
    records: Vec<Option<u64>>,
    /// Monotone per-slot version, bumped by content-changing writes.
    versions: Vec<u64>,
    /// Known broadcast contents per scratch row index.
    scratch: Vec<Option<u64>>,
    epoch: u64,
    /// Content-changing record writes observed (cache-invalidating).
    pub invalidating_writes: u64,
    /// When armed, every content-changing write is journaled here for
    /// the durable store's WAL (`None` = journaling off, zero cost).
    journal: Option<Vec<WalOp>>,
}

impl TableState {
    pub fn new(cfg: &SimConfig, n_records: usize) -> Self {
        let word_mask = if cfg.word_bits >= 64 {
            u64::MAX
        } else {
            (1u64 << cfg.word_bits) - 1
        };
        Self {
            n_records,
            word_mask,
            records: vec![None; n_records],
            versions: vec![0; n_records],
            scratch: Vec::new(),
            epoch: 0,
            invalidating_writes: 0,
            journal: None,
        }
    }

    pub fn n_records(&self) -> usize {
        self.n_records
    }

    /// Observe a write to a record slot.  Returns `true` when the write
    /// is redundant (known-equal masked contents) and safe to drop.
    pub fn record_write(&mut self, slot: usize, value: u64) -> bool {
        debug_assert!(slot < self.n_records, "slot {slot} out of table");
        let v = value & self.word_mask;
        if self.records[slot] == Some(v) {
            return true;
        }
        self.records[slot] = Some(v);
        self.epoch += 1;
        self.versions[slot] = self.epoch;
        self.invalidating_writes += 1;
        if let Some(j) = &mut self.journal {
            j.push(WalOp::Record { slot: slot as u64, value: v, version: self.epoch });
        }
        false
    }

    /// Observe a broadcast to a scratch row.  Returns `true` when
    /// redundant (the row already holds this masked value everywhere).
    pub fn scratch_write(&mut self, idx: usize, value: u64) -> bool {
        let v = value & self.word_mask;
        if self.scratch.len() <= idx {
            self.scratch.resize(idx + 1, None);
        }
        if self.scratch[idx] == Some(v) {
            return true;
        }
        self.scratch[idx] = Some(v);
        if let Some(j) = &mut self.journal {
            j.push(WalOp::Scratch { idx: idx as u64, value: v });
        }
        false
    }

    /// Known broadcast contents of a scratch row.
    pub fn scratch_value(&self, idx: usize) -> Option<u64> {
        self.scratch.get(idx).copied().flatten()
    }

    /// Known masked contents of a record slot (`None` = never written
    /// through the serving layer; the physical cell holds 0).
    pub fn record_value(&self, slot: usize) -> Option<u64> {
        self.records.get(slot).copied().flatten()
    }

    /// Scratch rows this state has observed broadcasts for (the replay
    /// path walks `0..scratch_len()`).
    pub fn scratch_len(&self) -> usize {
        self.scratch.len()
    }

    /// Monotone fingerprint of a record range: the max slot version.
    /// Any content-changing write inside the range strictly increases it.
    pub fn range_fingerprint(&self, range: RecordRange) -> u64 {
        self.versions[range.start..range.end().min(self.n_records)]
            .iter()
            .copied()
            .max()
            .unwrap_or(0)
    }

    /// Arm the WAL journal: subsequent content-changing writes are
    /// recorded for [`take_journal`](Self::take_journal).
    pub fn enable_journal(&mut self) {
        if self.journal.is_none() {
            self.journal = Some(Vec::new());
        }
    }

    /// Drain the journaled writes accumulated since the last call
    /// (empty when journaling is off).
    pub fn take_journal(&mut self) -> Vec<WalOp> {
        match &mut self.journal {
            Some(j) => std::mem::take(j),
            None => Vec::new(),
        }
    }

    /// Serializable image of this state (the durable store's snapshot
    /// payload).
    pub fn image(&self) -> TableImage {
        TableImage {
            n_records: self.n_records as u64,
            word_mask: self.word_mask,
            epoch: self.epoch,
            invalidating_writes: self.invalidating_writes,
            records: self.records.clone(),
            versions: self.versions.clone(),
            scratch: self.scratch.clone(),
        }
    }

    /// Rebuild a state from a recovered image (fresh-start recovery:
    /// versions, epoch, and contents come back exactly as checkpointed).
    pub fn from_image(img: &TableImage) -> Self {
        Self {
            n_records: img.n_records as usize,
            word_mask: img.word_mask,
            records: img.records.clone(),
            versions: img.versions.clone(),
            scratch: img.scratch.clone(),
            epoch: img.epoch,
            invalidating_writes: img.invalidating_writes,
            journal: None,
        }
    }

    /// Apply one recovered WAL record.  Record writes carry the version
    /// assigned at write time and are skipped when the snapshot already
    /// covers them (`version <= epoch`), so replaying a WAL that
    /// overlaps the snapshot is idempotent and versions reproduce the
    /// fault-free run exactly.  Replay never journals.
    pub fn apply_wal(&mut self, op: &WalOp) {
        match *op {
            WalOp::Record { slot, value, version } => {
                let slot = slot as usize;
                if version <= self.epoch || slot >= self.n_records {
                    return;
                }
                self.records[slot] = Some(value & self.word_mask);
                self.versions[slot] = version;
                self.epoch = version;
                self.invalidating_writes += 1;
            }
            WalOp::Scratch { idx, value } => {
                let idx = idx as usize;
                if self.scratch.len() <= idx {
                    self.scratch.resize(idx + 1, None);
                }
                self.scratch[idx] = Some(value & self.word_mask);
            }
        }
    }

    /// Restore checkpointed contents INTO a live state (REPL `restore`).
    ///
    /// Contents and versions come from the image, but the epoch
    /// CONTINUES from `max(live, image)`: cached results were keyed at
    /// fingerprints up to the live epoch, so post-restore writes must
    /// version strictly above every fingerprint ever handed out —
    /// otherwise a pre-restore cached result could alias a post-restore
    /// write (the `ResultCache` staleness bug this PR pins).  Entries
    /// whose fingerprints match restored versions are CORRECT to serve:
    /// identical versions imply identical contents.
    pub fn restore_into(&mut self, img: &TableImage) {
        let epoch = self.epoch.max(img.epoch);
        let invalidating = self.invalidating_writes.max(img.invalidating_writes);
        let journal = self.journal.take();
        *self = Self::from_image(img);
        self.epoch = epoch;
        self.invalidating_writes = invalidating;
        self.journal = journal.map(|_| Vec::new());
    }
}

/// Query-step kinds the cache distinguishes (a Filter(Lt) and a Compare
/// over the same range are different results).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QueryKind {
    Compare,
    Filter(Predicate),
    Sub,
    Bool(BoolFn),
    Scan,
    Aggregate(AggKind),
}

/// Cache key: everything a query step's output depends on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub kind: QueryKind,
    pub start: usize,
    pub len: usize,
    /// Broadcast-row CONTENTS the step reads (`None` for scan/aggregate,
    /// which read records only).
    pub rhs: Option<u64>,
    /// `TableState::range_fingerprint` at key-construction time.
    pub fingerprint: u64,
}

/// Cache key for a global IR step under the current table state; `None`
/// when the step is not cacheable (setup steps, or rhs contents the
/// serving layer has never observed).
pub fn key_for(op: &IrOp, state: &TableState) -> Option<CacheKey> {
    let (kind, range, rhs) = match op {
        IrOp::Load { .. } | IrOp::Broadcast { .. } => return None,
        IrOp::Compare { range, rhs } => (QueryKind::Compare, *range, Some(*rhs)),
        IrOp::Filter { range, rhs, pred } => (QueryKind::Filter(*pred), *range, Some(*rhs)),
        IrOp::Sub { range, rhs } => (QueryKind::Sub, *range, Some(*rhs)),
        IrOp::Bool { f, range, rhs } => (QueryKind::Bool(*f), *range, Some(*rhs)),
        IrOp::Scan { range } => (QueryKind::Scan, *range, None),
        IrOp::Aggregate { range, agg } => (QueryKind::Aggregate(*agg), *range, None),
    };
    let rhs = match rhs {
        Some(ScratchRow(s)) => Some(state.scratch_value(s)?),
        None => None,
    };
    Some(CacheKey {
        kind,
        start: range.start,
        len: range.len,
        rhs,
        fingerprint: state.range_fingerprint(range),
    })
}

/// Payload elements a cached output carries (its dominant heap cost).
fn payload_elems(out: &StepOutput) -> usize {
    match out {
        StepOutput::None | StepOutput::Reduced(_) => 0,
        StepOutput::Words(v) => v.len(),
        StepOutput::Diffs(v) => v.len(),
        StepOutput::Orderings(v) => v.len(),
        StepOutput::Matches(v) => v.len(),
    }
}

/// Negative result: a filter that matched nothing.  These recur under
/// dashboard polling (the same empty `WHERE` clause asked again and
/// again), carry no payload, and deserve to survive capacity pressure —
/// they are stored at zero weight.
fn is_negative(kind: &QueryKind, out: &StepOutput) -> bool {
    matches!(kind, QueryKind::Filter(_))
        && matches!(out, StepOutput::Matches(m) if m.is_empty())
}

#[derive(Clone, Debug)]
struct Entry {
    out: StepOutput,
    /// Slots this entry charges against the budget (0 for negatives).
    weight: usize,
    /// LRU clock value of the last lookup/insert that touched it.
    last_used: u64,
    negative: bool,
}

/// Memoized query-step outputs with size-aware LRU eviction.
///
/// The budget is counted in SLOTS: a small output costs one slot, and
/// every [`ELEMS_PER_SLOT`] payload elements cost one more, so a handful
/// of whole-table scans cannot silently pin the memory a thousand tiny
/// filters would share.  Negative results (empty filters) weigh zero and
/// are bounded by the entry cap instead.
///
/// At capacity the cache first sweeps stale entries (older fingerprint
/// than their range's current one — they can never match a fresh key),
/// then evicts live entries in least-recently-used order until the
/// incoming entry fits.  Entries for untouched ranges are kept — the
/// PR 2 wholesale `clear()` is gone.
#[derive(Clone, Debug)]
pub struct ResultCache {
    map: HashMap<CacheKey, Entry>,
    /// Slot budget (see struct docs).
    budget: usize,
    /// Entry-cap multiplier (normally [`ENTRY_CAP_FACTOR`]); the brownout
    /// ladder widens it so negative entries absorb overload polling.
    cap_factor: usize,
    /// Slots currently charged by live entries.
    used: usize,
    /// Monotone LRU clock.
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    /// Hits answered by zero-weight negative entries (also counted in
    /// `hits`).
    pub negative_hits: u64,
    /// Live entries evicted in LRU order under capacity pressure.
    pub evictions: u64,
    /// Stale entries dropped by the pre-eviction sweep.
    pub swept: u64,
}

/// Payload elements per budget slot (see [`ResultCache`]).
pub const ELEMS_PER_SLOT: usize = 16;

/// Total entries are capped at `budget * ENTRY_CAP_FACTOR` so zero-weight
/// negative entries stay bounded too.
pub const ENTRY_CAP_FACTOR: usize = 4;

impl ResultCache {
    pub fn new(budget: usize) -> Self {
        Self {
            map: HashMap::new(),
            budget: budget.max(1),
            cap_factor: ENTRY_CAP_FACTOR,
            used: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            negative_hits: 0,
            evictions: 0,
            swept: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Slot budget this cache evicts toward.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Current entry-cap multiplier (total entries are capped at
    /// `budget * entry_cap_factor`).
    pub fn entry_cap_factor(&self) -> usize {
        self.cap_factor
    }

    /// Retune the entry cap (floored at 1).  Widening is instant;
    /// narrowing takes effect lazily on the next insert's `make_room`,
    /// so walking a brownout back never mass-evicts mid-round.
    pub fn set_entry_cap_factor(&mut self, factor: usize) {
        self.cap_factor = factor.max(1);
    }

    /// Slots currently charged (invariant: `used <= budget` except for a
    /// single oversized entry).
    pub fn used_slots(&self) -> usize {
        self.used
    }

    pub fn lookup(&mut self, key: &CacheKey) -> Option<StepOutput> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_used = self.tick;
                self.hits += 1;
                if e.negative {
                    self.negative_hits += 1;
                }
                Some(e.out.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert an entry, evicting stale-then-LRU entries as needed.  An
    /// entry too large for the whole budget is still admitted (alone) —
    /// the cache is a performance layer, never a correctness one.
    pub fn insert(&mut self, key: CacheKey, out: StepOutput, state: &TableState) {
        self.tick += 1;
        let negative = is_negative(&key.kind, &out);
        let weight = if negative { 0 } else { 1 + payload_elems(&out) / ELEMS_PER_SLOT };
        if let Some(old) = self.map.remove(&key) {
            self.used -= old.weight;
        }
        if self.used + weight > self.budget
            || self.map.len() + 1 > self.budget * self.cap_factor
        {
            self.make_room(weight, state);
        }
        self.used += weight;
        self.map.insert(key, Entry { out, weight, last_used: self.tick, negative });
    }

    /// Free space for an incoming entry of `incoming` slots: sweep stale
    /// entries, then evict live ones least-recently-used first.  Valid
    /// entries for untouched ranges survive unless the LRU order says
    /// they must go.
    fn make_room(&mut self, incoming: usize, state: &TableState) {
        let before = self.map.len();
        let mut freed = 0usize;
        self.map.retain(|k, e| {
            let live =
                k.fingerprint >= state.range_fingerprint(RecordRange::new(k.start, k.len));
            if !live {
                freed += e.weight;
            }
            live
        });
        self.swept += (before - self.map.len()) as u64;
        self.used -= freed;

        let entry_cap = self.budget * self.cap_factor;
        loop {
            let over_slots = self.used + incoming > self.budget;
            let over_entries = self.map.len() + 1 > entry_cap;
            if !(over_slots || over_entries) || self.map.is_empty() {
                break;
            }
            // O(n) victim scan; eviction is the rare path and maps are
            // budget-bounded, so an index structure isn't worth carrying.
            // Slot pressure can only be relieved by entries that charge
            // slots — zero-weight negatives are never sacrificed for it
            // (they go only under entry-cap pressure), otherwise a cold
            // negative would be evicted for zero freed slots.
            let positive_lru = self
                .map
                .iter()
                .filter(|(_, e)| e.weight > 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let victim = match (over_slots, positive_lru) {
                (true, Some(k)) => Some(k),
                // slot pressure with only zero-weight entries left: fall
                // through to entry-cap eviction if that also applies
                (true, None) | (false, _) if over_entries => {
                    self.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k)
                }
                _ => None, // nothing left that frees slots
            };
            let Some(victim) = victim else { break };
            let e = self.map.remove(&victim).expect("victim present");
            self.used -= e.weight;
            self.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};
    use crate::planner::Program;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c
    }

    #[test]
    fn record_writes_dedupe_and_version() {
        let mut s = TableState::new(&cfg(), 10);
        assert!(!s.record_write(3, 42), "first write is not redundant");
        assert!(s.record_write(3, 42), "identical rewrite is redundant");
        // masked equality: 0x142 & 0xFF == 0x42
        assert!(s.record_write(3, 0x142), "masked-equal rewrite is redundant");
        let fp = s.range_fingerprint(RecordRange::new(0, 10));
        assert!(!s.record_write(3, 7), "new content is not redundant");
        assert!(
            s.range_fingerprint(RecordRange::new(0, 10)) > fp,
            "content change must bump the fingerprint"
        );
        // disjoint range is untouched
        assert_eq!(s.range_fingerprint(RecordRange::new(4, 6)), 0);
        assert_eq!(s.invalidating_writes, 2);
    }

    #[test]
    fn scratch_writes_dedupe_by_contents() {
        let mut s = TableState::new(&cfg(), 4);
        assert_eq!(s.scratch_value(0), None);
        assert!(!s.scratch_write(0, 9));
        assert!(s.scratch_write(0, 9));
        assert!(!s.scratch_write(0, 10), "new value re-broadcasts");
        assert_eq!(s.scratch_value(0), Some(10));
    }

    #[test]
    fn keys_capture_contents_and_versions() {
        let mut s = TableState::new(&cfg(), 20);
        let mut p = Program::new(20);
        let t = p.scratch();
        let all = p.all();
        p.broadcast(t, 5).filter(all, t, Predicate::Lt);

        // rhs unknown -> uncacheable
        assert!(key_for(&p.ops[1], &s).is_none());
        s.scratch_write(0, 5);
        let k1 = key_for(&p.ops[1], &s).unwrap();
        assert_eq!(k1.rhs, Some(5));

        // same query after an overlapping content change: different key
        s.record_write(7, 1);
        let k2 = key_for(&p.ops[1], &s).unwrap();
        assert_ne!(k1, k2, "load must strand the old key");

        // different predicate, different key
        let mut p2 = Program::new(20);
        let t2 = p2.scratch();
        let all2 = p2.all();
        p2.broadcast(t2, 5).filter(all2, t2, Predicate::Gt);
        assert_ne!(key_for(&p2.ops[1], &s).unwrap(), k2);
    }

    #[test]
    fn cache_round_trip_and_stale_sweep() {
        let mut s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(2);
        let range = RecordRange::new(0, 8);
        let key = CacheKey {
            kind: QueryKind::Scan,
            start: 0,
            len: 8,
            rhs: None,
            fingerprint: s.range_fingerprint(range),
        };
        assert!(c.lookup(&key).is_none());
        c.insert(key, StepOutput::Words(vec![(0, 1)]), &s);
        assert_eq!(c.lookup(&key), Some(StepOutput::Words(vec![(0, 1)])));
        assert_eq!((c.hits, c.misses), (1, 1));

        // stale the entry, then fill past capacity: sweep drops it
        s.record_write(2, 9);
        for start in 0..2usize {
            let k = CacheKey {
                kind: QueryKind::Scan,
                start,
                len: 1,
                rhs: None,
                fingerprint: s.range_fingerprint(RecordRange::new(start, 1)),
            };
            c.insert(k, StepOutput::Words(Vec::new()), &s);
        }
        assert!(c.len() <= 2, "capacity respected, stale entry swept");
        assert!(c.lookup(&key).is_none(), "stale entry gone");
        assert_eq!(c.swept, 1, "the stale entry was swept, not LRU-evicted");
        assert_eq!(c.evictions, 0);
    }

    fn scan_key(s: &TableState, start: usize, len: usize) -> CacheKey {
        CacheKey {
            kind: QueryKind::Scan,
            start,
            len,
            rhs: None,
            fingerprint: s.range_fingerprint(RecordRange::new(start, len)),
        }
    }

    #[test]
    fn lru_order_respected_under_capacity_pressure() {
        let s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(2);
        let (a, b, d) = (scan_key(&s, 0, 1), scan_key(&s, 1, 1), scan_key(&s, 2, 1));
        c.insert(a, StepOutput::Words(vec![(0, 1)]), &s);
        c.insert(b, StepOutput::Words(vec![(1, 2)]), &s);
        // touch `a` so `b` becomes least recently used
        assert!(c.lookup(&a).is_some());
        c.insert(d, StepOutput::Words(vec![(2, 3)]), &s);
        assert!(c.lookup(&a).is_some(), "recently-used entry survives");
        assert!(c.lookup(&b).is_none(), "LRU entry evicted");
        assert!(c.lookup(&d).is_some(), "incoming entry admitted");
        assert_eq!(c.evictions, 1);
        assert_eq!(c.swept, 0, "no entry was stale — the fix: no wholesale clear");
    }

    #[test]
    fn eviction_keeps_valid_entries_for_untouched_ranges() {
        // the PR 2 bug: at capacity with all-live entries the whole map
        // was cleared, dropping entries for ranges nothing had written
        let s = TableState::new(&cfg(), 16);
        let mut c = ResultCache::new(4);
        let keys: Vec<CacheKey> = (0..4).map(|i| scan_key(&s, i, 1)).collect();
        for (i, k) in keys.iter().enumerate() {
            c.insert(*k, StepOutput::Words(vec![(i, i as u64)]), &s);
        }
        c.insert(scan_key(&s, 9, 1), StepOutput::Words(vec![(9, 9)]), &s);
        // exactly one live entry (the LRU head) made room; the other
        // three valid entries survive
        assert_eq!(c.evictions, 1);
        assert_eq!(c.len(), 4);
        let survivors = keys.iter().filter(|k| c.lookup(k).is_some()).count();
        assert_eq!(survivors, 3, "valid entries must be kept when evicting");
    }

    #[test]
    fn size_aware_weights_charge_large_payloads_more() {
        let s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(4);
        let big: Vec<(usize, u64)> = (0..3 * ELEMS_PER_SLOT).map(|i| (i, i as u64)).collect();
        c.insert(scan_key(&s, 0, 8), StepOutput::Words(big), &s);
        assert_eq!(c.used_slots(), 4, "1 + 48/16 slots");
        // the big entry fills the budget; the next insert must evict it
        c.insert(scan_key(&s, 1, 1), StepOutput::Words(vec![(1, 1)]), &s);
        assert_eq!(c.evictions, 1);
        assert_eq!(c.used_slots(), 1);
    }

    #[test]
    fn negative_entries_are_free_and_invalidated_by_version_bumps() {
        let mut s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(2);
        let range = RecordRange::new(0, 8);
        let nkey = CacheKey {
            kind: QueryKind::Filter(crate::planner::Predicate::Lt),
            start: 0,
            len: 8,
            rhs: Some(0),
            fingerprint: s.range_fingerprint(range),
        };
        c.insert(nkey, StepOutput::Matches(Vec::new()), &s);
        assert_eq!(c.used_slots(), 0, "negative entries weigh nothing");
        assert_eq!(c.lookup(&nkey), Some(StepOutput::Matches(Vec::new())));
        assert_eq!(c.negative_hits, 1);

        // fill the budget with positives: the negative survives pressure
        c.insert(scan_key(&s, 0, 1), StepOutput::Words(vec![(0, 1)]), &s);
        c.insert(scan_key(&s, 1, 1), StepOutput::Words(vec![(1, 2)]), &s);
        assert!(c.lookup(&nkey).is_some(), "zero-weight entry needs no slot");

        // a content-changing write bumps the range version: the old key
        // can never be asked again, and the sweep reclaims the entry
        s.record_write(3, 77);
        let fresh = CacheKey { fingerprint: s.range_fingerprint(range), ..nkey };
        assert_ne!(fresh, nkey, "version bump strands the negative key");
        assert!(c.lookup(&fresh).is_none(), "stale negative must not serve");
        c.insert(scan_key(&s, 2, 1), StepOutput::Words(vec![(2, 3)]), &s);
        c.insert(scan_key(&s, 4, 1), StepOutput::Words(vec![(4, 5)]), &s);
        assert!(c.lookup(&nkey).is_none(), "swept after the version bump");
        assert!(c.swept >= 1, "stale negative reclaimed by the sweep");
    }

    /// The reviewer trap: a negative entry that is NOT recently used must
    /// still survive slot pressure — evicting it would free zero slots.
    #[test]
    fn cold_negative_entries_survive_slot_pressure()  {
        let s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(2);
        let nkey = CacheKey {
            kind: QueryKind::Filter(crate::planner::Predicate::Lt),
            start: 0,
            len: 8,
            rhs: Some(0),
            fingerprint: s.range_fingerprint(RecordRange::new(0, 8)),
        };
        c.insert(nkey, StepOutput::Matches(Vec::new()), &s);
        // five positives through a budget of two: constant LRU eviction,
        // the untouched negative is always the LRU-oldest entry
        for i in 0..5 {
            c.insert(scan_key(&s, i, 1), StepOutput::Words(vec![(i, 1)]), &s);
        }
        assert!(c.evictions >= 3, "positives churned: {}", c.evictions);
        assert_eq!(
            c.lookup(&nkey),
            Some(StepOutput::Matches(Vec::new())),
            "slot pressure must never evict a zero-weight negative"
        );
    }

    #[test]
    fn hit_rate_counters_match_observed_hits() {
        let mut s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(8);
        let k1 = scan_key(&s, 0, 2);
        let neg = CacheKey {
            kind: QueryKind::Filter(crate::planner::Predicate::Gt),
            start: 0,
            len: 8,
            rhs: Some(255),
            fingerprint: s.range_fingerprint(RecordRange::new(0, 8)),
        };
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut neg_hits = 0u64;
        assert!(c.lookup(&k1).is_none());
        misses += 1;
        c.insert(k1, StepOutput::Words(vec![(0, 1)]), &s);
        c.insert(neg, StepOutput::Matches(Vec::new()), &s);
        for _ in 0..3 {
            assert!(c.lookup(&k1).is_some());
            hits += 1;
            assert!(c.lookup(&neg).is_some());
            hits += 1;
            neg_hits += 1;
        }
        s.record_write(1, 9);
        let stale_probe = scan_key(&s, 0, 2); // fresh fingerprint: miss
        assert!(c.lookup(&stale_probe).is_none());
        misses += 1;
        assert_eq!((c.hits, c.misses, c.negative_hits), (hits, misses, neg_hits));
        assert!(c.negative_hits <= c.hits, "negative hits are a subset of hits");
    }

    #[test]
    fn journal_captures_changes_and_replays_idempotently() {
        let mut s1 = TableState::new(&cfg(), 8);
        s1.enable_journal();
        assert!(!s1.record_write(0, 5));
        assert!(s1.record_write(0, 5), "redundant write must not journal");
        assert!(!s1.scratch_write(1, 7));
        assert!(!s1.record_write(2, 8));
        let wal = s1.take_journal();
        assert_eq!(
            wal,
            vec![
                crate::store::WalOp::Record { slot: 0, value: 5, version: 1 },
                crate::store::WalOp::Scratch { idx: 1, value: 7 },
                crate::store::WalOp::Record { slot: 2, value: 8, version: 2 },
            ]
        );
        assert!(s1.take_journal().is_empty(), "journal drains");

        // replay into a fresh state reproduces versions bit-for-bit
        let mut s2 = TableState::new(&cfg(), 8);
        for op in &wal {
            s2.apply_wal(op);
        }
        assert_eq!(s1.image(), s2.image());

        // replay over an already-covered state is a no-op (the
        // checkpoint-race window: snapshot written, WAL not truncated)
        for op in &wal {
            s2.apply_wal(op);
        }
        assert_eq!(s1.image(), s2.image(), "overlap replay must be idempotent");
    }

    #[test]
    fn image_round_trips_through_from_image() {
        let mut s = TableState::new(&cfg(), 6);
        s.record_write(1, 3);
        s.scratch_write(0, 9);
        s.record_write(4, 250);
        let img = s.image();
        let back = TableState::from_image(&img);
        assert_eq!(back.image(), img);
        assert_eq!(
            back.range_fingerprint(RecordRange::new(0, 6)),
            s.range_fingerprint(RecordRange::new(0, 6))
        );
    }

    /// Satellite regression: a snapshot+restore round-trip must never
    /// let the cache serve a pre-restore result for a post-restore
    /// write.  Epoch continuation (`restore_into`) guarantees every
    /// post-restore version exceeds every fingerprint ever handed out.
    #[test]
    fn restore_cannot_serve_pre_restore_results_for_post_restore_writes() {
        let mut s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(8);
        let range = RecordRange::new(0, 8);

        s.record_write(3, 1); // write A (epoch 1)
        let snapshot = s.image();
        let a_key = scan_key(&s, 0, 8);
        c.insert(a_key, StepOutput::Words(vec![(3, 1)]), &s);

        s.record_write(3, 2); // write B (epoch 2)
        let b_key = scan_key(&s, 0, 8);
        c.insert(b_key, StepOutput::Words(vec![(3, 2)]), &s);

        s.restore_into(&snapshot); // back to A-contents
        // restored fingerprints match the A-era key: serving the A-era
        // entry is CORRECT (identical versions imply identical contents)
        assert_eq!(s.range_fingerprint(range), 1);
        assert_eq!(c.lookup(&scan_key(&s, 0, 8)), Some(StepOutput::Words(vec![(3, 1)])));

        s.record_write(3, 9); // write C, post-restore
        let c_key = scan_key(&s, 0, 8);
        assert_ne!(c_key, b_key, "post-restore version must exceed B's fingerprint");
        assert!(
            c.lookup(&c_key).is_none(),
            "stale pre-restore result served for a post-restore write"
        );
    }

    /// Model check: random lookup/insert/write traffic against a tiny
    /// cache — a lookup may miss at any time, but whenever it HITS the
    /// value must equal what an unbounded, always-correct memo table
    /// holds for that exact key.
    #[test]
    fn prop_lru_cache_never_serves_a_wrong_value() {
        use crate::util::quick::{Arbitrary, Quick};
        use crate::util::rng::Rng;

        #[derive(Clone, Debug)]
        struct TrafficSeed(u64);
        impl Arbitrary for TrafficSeed {
            fn generate(rng: &mut Rng) -> Self {
                TrafficSeed(rng.next_u64())
            }
        }

        Quick::with_cases(40).check::<TrafficSeed, _>("lru model check", |seed| {
            let cfg = cfg();
            let mut rng = Rng::new(seed.0);
            let mut state = TableState::new(&cfg, 16);
            let mut cache = ResultCache::new(3);
            let mut model: std::collections::HashMap<CacheKey, StepOutput> =
                std::collections::HashMap::new();
            for step in 0..200u64 {
                match rng.below(4) {
                    0 => {
                        // content-changing write strands overlapping keys
                        state.record_write(rng.below(16) as usize, rng.below(256));
                    }
                    1 => {
                        let start = rng.below(12) as usize;
                        let len = 1 + rng.below(4) as usize;
                        let key = CacheKey {
                            kind: QueryKind::Scan,
                            start,
                            len,
                            rhs: None,
                            fingerprint: state
                                .range_fingerprint(RecordRange::new(start, len)),
                        };
                        let out = StepOutput::Words(vec![(start, step)]);
                        cache.insert(key, out.clone(), &state);
                        model.insert(key, out);
                    }
                    _ => {
                        let start = rng.below(12) as usize;
                        let len = 1 + rng.below(4) as usize;
                        let key = CacheKey {
                            kind: QueryKind::Scan,
                            start,
                            len,
                            rhs: None,
                            fingerprint: state
                                .range_fingerprint(RecordRange::new(start, len)),
                        };
                        if let Some(got) = cache.lookup(&key) {
                            if model.get(&key) != Some(&got) {
                                return false; // served a wrong value
                            }
                        }
                    }
                }
                if cache.used_slots() > cache.budget() {
                    return false; // budget invariant violated
                }
            }
            true
        });
    }
}
