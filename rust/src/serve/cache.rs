//! The serving layer's versioned result cache and its view of the shared
//! table.
//!
//! [`TableState`] shadows what the serving layer knows about array
//! contents: per-record-slot masked words with a monotone version, and
//! per-scratch-row broadcast contents.  Two uses:
//!
//! * **write dedup** — a write whose masked value provably equals what
//!   the cell already stores is a state no-op (`FefetArray::write_bit`
//!   sets polarization deterministically, no drift), so the coalescer can
//!   drop it and save the write energy;
//! * **cache keys** — a query step's result is fully determined by
//!   (op kind, record-range contents, broadcast-row contents).  The key
//!   captures range contents through a monotone fingerprint (max slot
//!   version) and rhs contents by value, so any overlapping
//!   content-changing load bumps the fingerprint and strands stale
//!   entries without an explicit invalidation walk.

use std::collections::HashMap;

use crate::cim::BoolFn;
use crate::config::SimConfig;
use crate::planner::{AggKind, IrOp, Predicate, RecordRange, ScratchRow, StepOutput};

/// What the serving layer knows about the shared table's contents.
#[derive(Clone, Debug)]
pub struct TableState {
    n_records: usize,
    word_mask: u64,
    /// Known masked contents per record slot (`None` = never written
    /// through the serving layer; fresh arrays hold 0 but we only dedupe
    /// against *observed* writes).
    records: Vec<Option<u64>>,
    /// Monotone per-slot version, bumped by content-changing writes.
    versions: Vec<u64>,
    /// Known broadcast contents per scratch row index.
    scratch: Vec<Option<u64>>,
    epoch: u64,
    /// Content-changing record writes observed (cache-invalidating).
    pub invalidating_writes: u64,
}

impl TableState {
    pub fn new(cfg: &SimConfig, n_records: usize) -> Self {
        let word_mask = if cfg.word_bits >= 64 {
            u64::MAX
        } else {
            (1u64 << cfg.word_bits) - 1
        };
        Self {
            n_records,
            word_mask,
            records: vec![None; n_records],
            versions: vec![0; n_records],
            scratch: Vec::new(),
            epoch: 0,
            invalidating_writes: 0,
        }
    }

    pub fn n_records(&self) -> usize {
        self.n_records
    }

    /// Observe a write to a record slot.  Returns `true` when the write
    /// is redundant (known-equal masked contents) and safe to drop.
    pub fn record_write(&mut self, slot: usize, value: u64) -> bool {
        debug_assert!(slot < self.n_records, "slot {slot} out of table");
        let v = value & self.word_mask;
        if self.records[slot] == Some(v) {
            return true;
        }
        self.records[slot] = Some(v);
        self.epoch += 1;
        self.versions[slot] = self.epoch;
        self.invalidating_writes += 1;
        false
    }

    /// Observe a broadcast to a scratch row.  Returns `true` when
    /// redundant (the row already holds this masked value everywhere).
    pub fn scratch_write(&mut self, idx: usize, value: u64) -> bool {
        let v = value & self.word_mask;
        if self.scratch.len() <= idx {
            self.scratch.resize(idx + 1, None);
        }
        if self.scratch[idx] == Some(v) {
            return true;
        }
        self.scratch[idx] = Some(v);
        false
    }

    /// Known broadcast contents of a scratch row.
    pub fn scratch_value(&self, idx: usize) -> Option<u64> {
        self.scratch.get(idx).copied().flatten()
    }

    /// Monotone fingerprint of a record range: the max slot version.
    /// Any content-changing write inside the range strictly increases it.
    pub fn range_fingerprint(&self, range: RecordRange) -> u64 {
        self.versions[range.start..range.end().min(self.n_records)]
            .iter()
            .copied()
            .max()
            .unwrap_or(0)
    }
}

/// Query-step kinds the cache distinguishes (a Filter(Lt) and a Compare
/// over the same range are different results).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QueryKind {
    Compare,
    Filter(Predicate),
    Sub,
    Bool(BoolFn),
    Scan,
    Aggregate(AggKind),
}

/// Cache key: everything a query step's output depends on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    pub kind: QueryKind,
    pub start: usize,
    pub len: usize,
    /// Broadcast-row CONTENTS the step reads (`None` for scan/aggregate,
    /// which read records only).
    pub rhs: Option<u64>,
    /// `TableState::range_fingerprint` at key-construction time.
    pub fingerprint: u64,
}

/// Cache key for a global IR step under the current table state; `None`
/// when the step is not cacheable (setup steps, or rhs contents the
/// serving layer has never observed).
pub fn key_for(op: &IrOp, state: &TableState) -> Option<CacheKey> {
    let (kind, range, rhs) = match op {
        IrOp::Load { .. } | IrOp::Broadcast { .. } => return None,
        IrOp::Compare { range, rhs } => (QueryKind::Compare, *range, Some(*rhs)),
        IrOp::Filter { range, rhs, pred } => (QueryKind::Filter(*pred), *range, Some(*rhs)),
        IrOp::Sub { range, rhs } => (QueryKind::Sub, *range, Some(*rhs)),
        IrOp::Bool { f, range, rhs } => (QueryKind::Bool(*f), *range, Some(*rhs)),
        IrOp::Scan { range } => (QueryKind::Scan, *range, None),
        IrOp::Aggregate { range, agg } => (QueryKind::Aggregate(*agg), *range, None),
    };
    let rhs = match rhs {
        Some(ScratchRow(s)) => Some(state.scratch_value(s)?),
        None => None,
    };
    Some(CacheKey {
        kind,
        start: range.start,
        len: range.len,
        rhs,
        fingerprint: state.range_fingerprint(range),
    })
}

/// Memoized query-step outputs.  Stale entries (older fingerprint than
/// their range's current one) can never match a fresh key; they are
/// swept lazily when the cache fills.
#[derive(Clone, Debug)]
pub struct ResultCache {
    map: HashMap<CacheKey, StepOutput>,
    capacity: usize,
    pub hits: u64,
    pub misses: u64,
}

impl ResultCache {
    pub fn new(capacity: usize) -> Self {
        Self { map: HashMap::new(), capacity: capacity.max(1), hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn lookup(&mut self, key: &CacheKey) -> Option<StepOutput> {
        match self.map.get(key) {
            Some(out) => {
                self.hits += 1;
                Some(out.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert an entry.  At capacity, stale entries are swept first; if
    /// every entry is still live the whole map is dropped — the cache is
    /// a performance layer, never a correctness one.
    pub fn insert(&mut self, key: CacheKey, out: StepOutput, state: &TableState) {
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            self.map.retain(|k, _| {
                k.fingerprint >= state.range_fingerprint(RecordRange::new(k.start, k.len))
            });
            if self.map.len() >= self.capacity {
                self.map.clear();
            }
        }
        self.map.insert(key, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};
    use crate::planner::Program;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c
    }

    #[test]
    fn record_writes_dedupe_and_version() {
        let mut s = TableState::new(&cfg(), 10);
        assert!(!s.record_write(3, 42), "first write is not redundant");
        assert!(s.record_write(3, 42), "identical rewrite is redundant");
        // masked equality: 0x142 & 0xFF == 0x42
        assert!(s.record_write(3, 0x142), "masked-equal rewrite is redundant");
        let fp = s.range_fingerprint(RecordRange::new(0, 10));
        assert!(!s.record_write(3, 7), "new content is not redundant");
        assert!(
            s.range_fingerprint(RecordRange::new(0, 10)) > fp,
            "content change must bump the fingerprint"
        );
        // disjoint range is untouched
        assert_eq!(s.range_fingerprint(RecordRange::new(4, 6)), 0);
        assert_eq!(s.invalidating_writes, 2);
    }

    #[test]
    fn scratch_writes_dedupe_by_contents() {
        let mut s = TableState::new(&cfg(), 4);
        assert_eq!(s.scratch_value(0), None);
        assert!(!s.scratch_write(0, 9));
        assert!(s.scratch_write(0, 9));
        assert!(!s.scratch_write(0, 10), "new value re-broadcasts");
        assert_eq!(s.scratch_value(0), Some(10));
    }

    #[test]
    fn keys_capture_contents_and_versions() {
        let mut s = TableState::new(&cfg(), 20);
        let mut p = Program::new(20);
        let t = p.scratch();
        let all = p.all();
        p.broadcast(t, 5).filter(all, t, Predicate::Lt);

        // rhs unknown -> uncacheable
        assert!(key_for(&p.ops[1], &s).is_none());
        s.scratch_write(0, 5);
        let k1 = key_for(&p.ops[1], &s).unwrap();
        assert_eq!(k1.rhs, Some(5));

        // same query after an overlapping content change: different key
        s.record_write(7, 1);
        let k2 = key_for(&p.ops[1], &s).unwrap();
        assert_ne!(k1, k2, "load must strand the old key");

        // different predicate, different key
        let mut p2 = Program::new(20);
        let t2 = p2.scratch();
        let all2 = p2.all();
        p2.broadcast(t2, 5).filter(all2, t2, Predicate::Gt);
        assert_ne!(key_for(&p2.ops[1], &s).unwrap(), k2);
    }

    #[test]
    fn cache_round_trip_and_stale_sweep() {
        let mut s = TableState::new(&cfg(), 8);
        let mut c = ResultCache::new(2);
        let range = RecordRange::new(0, 8);
        let key = CacheKey {
            kind: QueryKind::Scan,
            start: 0,
            len: 8,
            rhs: None,
            fingerprint: s.range_fingerprint(range),
        };
        assert!(c.lookup(&key).is_none());
        c.insert(key, StepOutput::Words(vec![(0, 1)]), &s);
        assert_eq!(c.lookup(&key), Some(StepOutput::Words(vec![(0, 1)])));
        assert_eq!((c.hits, c.misses), (1, 1));

        // stale the entry, then fill past capacity: sweep drops it
        s.record_write(2, 9);
        for start in 0..2usize {
            let k = CacheKey {
                kind: QueryKind::Scan,
                start,
                len: 1,
                rhs: None,
                fingerprint: s.range_fingerprint(RecordRange::new(start, 1)),
            };
            c.insert(k, StepOutput::Words(Vec::new()), &s);
        }
        assert!(c.len() <= 2, "capacity respected, stale entry swept");
        assert!(c.lookup(&key).is_none(), "stale entry gone");
    }
}
