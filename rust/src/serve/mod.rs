//! L4 serving layer: multi-tenant admission in front of the planner and
//! the coordinator pool.
//!
//! One `Placement::execute` ships a private batch per shard and pays the
//! array for every op, even when many concurrent clients are asking
//! near-identical questions about the same rows.  ADRA's core property —
//! one asymmetric activation answers *every* dual-row question about a
//! row pair — makes cross-client sharing unusually profitable, so this
//! layer batches *programs*, not ops:
//!
//! * [`queue`] — [`ServeQueue`]: admission from many concurrent clients
//!   (OS threads + channels, same no-tokio style as `coordinator::pool`).
//!   Programs queued while a round is in flight are coalesced into the
//!   next round; each client gets a [`Ticket`] to wait on.
//! * [`control`] — the control plane: [`FairScheduler`] picks each round
//!   by weighted fair queueing with per-tenant quotas (no tenant can
//!   flood a round), and [`BatchController`] adapts `max_round` with an
//!   EWMA over observed round wall time against a p95 target.
//! * [`coalesce`] — the per-shard coalescer: merges the round's shard
//!   streams into one batch per shard (admission order preserved, so the
//!   result is bit-identical to sequential per-program execution — shard
//!   state is private, and per shard the op sequence is exactly the
//!   sequential one), dedupes writes whose masked contents are already
//!   in the array, and lets `coordinator::fuse` fuse dual ops across
//!   program boundaries.
//! * [`cache`] — the versioned result cache: query steps are keyed on
//!   (op kind, broadcast-row *contents*, record range, range version);
//!   any content-changing load bumps the range version, so overlapping
//!   entries can never serve stale data.
//! * [`metrics`] — [`ServeMetrics`]: queue depth / batch occupancy,
//!   fused share, cache hit rate, and per-tenant latency histograms.
//!
//! With `ServeConfig::store_dir` set the scheduler is durable: every
//! content-changing write is journaled to a checksummed WAL
//! (`crate::store`), snapshots rotate on a round cadence, and a
//! restarted queue replays snapshot + WAL back into both the
//! `TableState` and the physical arrays before serving (bit-identical
//! recovery — see `tests/durability.rs`).  The scheduler also retries
//! route errors by respawning the dead worker and replaying its shard,
//! and (with `wear_spare_rows > 0`) steers hot rows onto spare
//! physical rows using the per-shard `WearTracker`.
//!
//! The overload-survival layer (DESIGN.md §15) keeps the queue useful
//! when demand or faults exceed capacity: per-program deadlines and
//! tenant [`CancelHandle`]s (doomed programs are swept BEFORE placement
//! and never touch the array), bounded per-tenant backlogs with load
//! shedding (`Rejected(Overloaded)`), per-shard [`CircuitBreaker`]s
//! that fail fast (`Rejected(ShardDown)`) while a shard is down and
//! heal through half-open respawn-and-replay probes, and — when
//! `ServeConfig::brownout` arms it — a [`DegradeController`] brownout
//! ladder stepped by the committed `round_wall_slo_burn` health state.
//!
//! ```text
//!   tenants --submit--> ServeQueue --place--> round of Placements
//!                           |                      |
//!                      coalesce_round     TableState + ResultCache
//!                           |                      |
//!              per-shard fused batches    cached / deduped steps
//!                           |
//!              Coordinator::call_batch_fused (WorkerMsg::FusedBatch)
//!                           |
//!              demux -> Placement::assemble -> ServeReport per ticket
//! ```

pub mod cache;
pub mod coalesce;
pub mod control;
pub mod metrics;
pub mod queue;

pub use cache::{key_for, CacheKey, QueryKind, ResultCache, TableState};
pub use coalesce::{coalesce_round, CoalescedRound, ProgramActions, RoundStats, ShardBatch, StepAction};
pub use control::{
    service_weights, AdmissionPolicy, BatchController, BatchPolicy, BreakerState,
    CircuitBreaker, DegradeController, DegradeLevel, FairScheduler, RoundAdmission,
    ServiceWindow,
};
pub use metrics::ServeMetrics;
pub use queue::{
    CancelHandle, LifecycleReport, RejectReason, ServeConfig, ServeError, ServeQueue,
    ServeReport, SubmitOptions, Ticket,
};
