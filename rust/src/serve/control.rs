//! The serving control plane: weighted fair admission over the backlog
//! and the adaptive round-size controller.
//!
//! PR 2's scheduler admitted FIFO up to a static `max_round`, which let a
//! heavy tenant flood a round and made round size a guess.  This module
//! replaces both knobs with closed-loop policies:
//!
//! * [`FairScheduler`] — the backlog.  Under [`AdmissionPolicy::Fair`] a
//!   round is selected by weighted fair queueing with per-tenant quotas:
//!   a breadth pass admits every pending tenant's head program in
//!   virtual-time order, a quota pass tops tenants up to their fair
//!   share, and a work-conserving fill pass spends leftover capacity.
//!   Per-tenant FIFO order is always preserved (bit-identity with that
//!   tenant's sequential program order depends on it); only the
//!   interleaving ACROSS tenants changes.  Weights come from the
//!   RECENT service window ([`ServiceWindow`] + [`service_weights`]):
//!   per-round deltas of each tenant's served-program count and modeled
//!   energy (calibrated, see `planner::calibrate`) are EWMA-folded, and
//!   a tenant whose windowed share of either exceeds the fair share has
//!   its weight scaled down, so its virtual time advances faster and it
//!   cedes slots — while an ex-heavy tenant's depressed weight decays
//!   back to 1.0 as its window empties.
//! * [`BatchController`] — an EWMA controller over observed round wall
//!   time with a p95 latency target.  While rounds saturate the current
//!   ceiling, wall above target shrinks `max_round` one step (smaller
//!   rounds bound tail latency) and wall under half the target grows it
//!   one step (bigger rounds recover fusion/dedup opportunities); the
//!   band in between holds, so a steady-state trace cannot oscillate
//!   past one step (pinned by the deterministic trace test below).
//!   Unsaturated rounds always hold — their wall is set by the programs
//!   themselves, and moving a ceiling nothing hits would let one slow
//!   program ratchet `max_round` to 1 and serialize every later burst.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::metrics::LatencyHistogram;

/// How the scheduler picks a round from the backlog.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Global arrival order, no quotas (PR 2 behavior).
    Fifo,
    /// Weighted fair queueing with per-tenant quotas.
    Fair,
}

/// Whether `max_round` is a static knob or controller-driven.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchPolicy {
    /// `ServeConfig::max_round` is used as-is.
    Static,
    /// EWMA controller with this p95 round-wall target (seconds);
    /// `ServeConfig::max_round` is the ceiling and starting point.
    Adaptive { target_p95: f64 },
}

/// One selected round plus the fairness counters it generated.
pub struct RoundAdmission<T> {
    /// Admitted items, in execution order (per-tenant FIFO preserved).
    pub admitted: Vec<T>,
    /// Tenants that exhausted their per-round fair-share quota while
    /// still holding pending programs (the dominance the policy caps).
    pub quota_hits: u64,
    /// Programs still pending after this round's selection.
    pub deferred: u64,
}

/// Windowed per-tenant service accounting behind [`service_weights`].
///
/// The histograms in `ServeMetrics` are CUMULATIVE, so dividing by
/// `h.count()` weighted tenants by their lifetime history: an ex-heavy
/// tenant stayed depressed forever.  This window keeps, per tenant, the
/// delta since the last round (the same counter-delta derivation the
/// `SeriesStore` uses) folded into an EWMA, so only *recent* service
/// share moves the weight and a reformed tenant decays back to 1.0.
#[derive(Debug, Default)]
pub struct ServiceWindow {
    /// Last cumulative (programs, energy) snapshot per tenant.
    last: HashMap<usize, (u64, f64)>,
    /// EWMA of the per-round (programs, energy) deltas per tenant.
    recent: HashMap<usize, (f64, f64)>,
    alpha: f64,
}

impl ServiceWindow {
    /// Default new-sample weight: heavy history decays below the 0.25
    /// clamp's reach within a handful of quiet rounds.
    const ALPHA: f64 = 0.5;

    pub fn new() -> Self {
        Self::with_alpha(Self::ALPHA)
    }

    pub fn with_alpha(alpha: f64) -> Self {
        Self { last: HashMap::new(), recent: HashMap::new(), alpha: alpha.clamp(0.01, 1.0) }
    }

    /// Fold one round's cumulative snapshots (per-tenant latency
    /// histograms + modeled energy totals) into the window.  The first
    /// observation of a tenant seeds its EWMA at the full delta, so a
    /// flood registers immediately.
    pub fn observe(
        &mut self,
        latency: &HashMap<usize, LatencyHistogram>,
        energy: &HashMap<usize, f64>,
    ) {
        for (&t, h) in latency {
            let cum_p = h.count();
            let cum_e = energy.get(&t).copied().unwrap_or(0.0);
            let (last_p, last_e) = self.last.get(&t).copied().unwrap_or((0, 0.0));
            let dp = cum_p.saturating_sub(last_p) as f64;
            let de = (cum_e - last_e).max(0.0);
            self.last.insert(t, (cum_p, cum_e));
            match self.recent.get_mut(&t) {
                Some((rp, re)) => {
                    *rp += self.alpha * (dp - *rp);
                    *re += self.alpha * (de - *re);
                }
                None => {
                    self.recent.insert(t, (dp, de));
                }
            }
        }
    }

    /// The tenant's recent served-program EWMA (testing/reporting).
    pub fn recent_programs(&self, tenant: usize) -> f64 {
        self.recent.get(&tenant).map(|&(p, _)| p).unwrap_or(0.0)
    }
}

/// Admission weights from the RECENT per-tenant service window: a tenant
/// whose windowed share of served programs — or of calibrated modeled
/// energy, whichever is more dominant — exceeds the fair share has its
/// weight scaled down, clamped to [0.25, 1.0].  Tenants with no recent
/// service recover full weight as their window decays; tenants with no
/// history default to 1.0 at the call site.
pub fn service_weights(
    window: &mut ServiceWindow,
    latency: &HashMap<usize, LatencyHistogram>,
    energy: &HashMap<usize, f64>,
) -> HashMap<usize, f64> {
    window.observe(latency, energy);
    let n = latency.len();
    if n < 2 {
        return latency.keys().map(|&t| (t, 1.0)).collect();
    }
    let recent: Vec<(usize, f64, f64)> = latency
        .keys()
        .map(|&t| {
            let (p, e) = window.recent.get(&t).copied().unwrap_or((0.0, 0.0));
            (t, p, e)
        })
        .collect();
    let total_p: f64 = recent.iter().map(|&(_, p, _)| p).sum();
    let total_e: f64 = recent.iter().map(|&(_, _, e)| e).sum();
    if total_p <= f64::EPSILON {
        return latency.keys().map(|&t| (t, 1.0)).collect();
    }
    let fair_p = total_p / n as f64;
    let fair_e = total_e / n as f64;
    recent
        .iter()
        .map(|&(t, p, e)| {
            let wp = (fair_p / p.max(f64::EPSILON)).clamp(0.25, 1.0);
            let w = if total_e > f64::EPSILON {
                wp.min((fair_e / e.max(f64::EPSILON)).clamp(0.25, 1.0))
            } else {
                wp
            };
            // EWMA residue never reaches exactly zero; a near-neutral
            // weight snaps to 1.0 so a reformed tenant fully recovers
            (t, if w >= 0.98 { 1.0 } else { w })
        })
        .collect()
}

/// The multi-tenant backlog and round selector.
pub struct FairScheduler<T> {
    policy: AdmissionPolicy,
    /// Per-tenant FIFO queues; items carry a global arrival sequence so
    /// the FIFO policy can reconstruct arrival order exactly.
    pending: BTreeMap<usize, VecDeque<(u64, T)>>,
    /// WFQ virtual finish time per tenant (persists across idle spells).
    vtime: BTreeMap<usize, f64>,
    /// High-water virtual time; newly active tenants anchor here so idle
    /// time earns no credit.
    global_vtime: f64,
    next_seq: u64,
    len: usize,
}

impl<T> FairScheduler<T> {
    pub fn new(policy: AdmissionPolicy) -> Self {
        Self {
            policy,
            pending: BTreeMap::new(),
            vtime: BTreeMap::new(),
            global_vtime: 0.0,
            next_seq: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue one program for `tenant` (FIFO within the tenant).
    pub fn push(&mut self, tenant: usize, item: T) {
        if self.pending.get(&tenant).map_or(true, |q| q.is_empty()) {
            let vt = self.vtime.entry(tenant).or_insert(self.global_vtime);
            if *vt < self.global_vtime {
                *vt = self.global_vtime;
            }
        }
        self.pending.entry(tenant).or_default().push_back((self.next_seq, item));
        self.next_seq += 1;
        self.len += 1;
    }

    /// Pop `tenant`'s head item and charge its virtual time.
    fn take(&mut self, tenant: usize, weight: f64) -> T {
        let (_, item) = self
            .pending
            .get_mut(&tenant)
            .and_then(|q| q.pop_front())
            .expect("take from tenant with pending work");
        self.len -= 1;
        let w = if weight.is_finite() && weight > 0.0 { weight.clamp(1e-3, 1e3) } else { 1.0 };
        let vt = self.vtime.entry(tenant).or_insert(self.global_vtime);
        if *vt > self.global_vtime {
            self.global_vtime = *vt;
        }
        *vt += 1.0 / w;
        item
    }

    /// Tenant with pending work minimizing (virtual time, id), optionally
    /// restricted by a per-round admission count limit.
    fn min_vt_tenant(&self, taken: &BTreeMap<usize, usize>, limit: Option<usize>) -> Option<usize> {
        self.pending
            .iter()
            .filter(|&(t, q)| {
                !q.is_empty()
                    && limit.map_or(true, |l| taken.get(t).copied().unwrap_or(0) < l)
            })
            .map(|(&t, _)| (self.vtime.get(&t).copied().unwrap_or(self.global_vtime), t))
            .min_by(|a, b| a.partial_cmp(b).expect("finite virtual times"))
            .map(|(_, t)| t)
    }

    /// Select the next round: at most `cap` programs, per the policy.
    /// `weight(tenant)` supplies the WFQ weight (1.0 = neutral).
    pub fn next_round<W: Fn(usize) -> f64>(&mut self, cap: usize, weight: W) -> RoundAdmission<T> {
        let cap = cap.max(1);
        let mut admitted = Vec::new();
        let mut quota_hits = 0u64;
        match self.policy {
            AdmissionPolicy::Fifo => {
                while admitted.len() < cap {
                    // head with the smallest arrival sequence = global FIFO
                    let t = match self
                        .pending
                        .iter()
                        .filter(|(_, q)| !q.is_empty())
                        .min_by_key(|(_, q)| q.front().expect("non-empty").0)
                        .map(|(&t, _)| t)
                    {
                        Some(t) => t,
                        None => break,
                    };
                    let item = self.take(t, 1.0);
                    admitted.push(item);
                }
            }
            AdmissionPolicy::Fair => {
                let active: Vec<usize> = self
                    .pending
                    .iter()
                    .filter(|(_, q)| !q.is_empty())
                    .map(|(&t, _)| t)
                    .collect();
                if !active.is_empty() {
                    let quota = ((cap + active.len() - 1) / active.len()).max(1);
                    let mut taken: BTreeMap<usize, usize> = BTreeMap::new();
                    // breadth pass: every active tenant's head, in virtual-
                    // time order — this is what makes starvation impossible
                    let mut order: Vec<(f64, usize)> = active
                        .iter()
                        .map(|&t| (self.vtime.get(&t).copied().unwrap_or(self.global_vtime), t))
                        .collect();
                    order.sort_by(|a, b| a.partial_cmp(b).expect("finite virtual times"));
                    for (_, t) in order {
                        if admitted.len() >= cap {
                            break;
                        }
                        admitted.push(self.take(t, weight(t)));
                        *taken.entry(t).or_insert(0) += 1;
                    }
                    // quota pass: top tenants up to their fair share
                    while admitted.len() < cap {
                        match self.min_vt_tenant(&taken, Some(quota)) {
                            Some(t) => {
                                admitted.push(self.take(t, weight(t)));
                                *taken.entry(t).or_insert(0) += 1;
                            }
                            None => break,
                        }
                    }
                    quota_hits = self
                        .pending
                        .iter()
                        .filter(|&(t, q)| {
                            !q.is_empty() && taken.get(t).copied().unwrap_or(0) >= quota
                        })
                        .count() as u64;
                    // fill pass: stay work-conserving — leftover capacity
                    // goes to whoever is pending, still in WFQ order
                    while admitted.len() < cap {
                        match self.min_vt_tenant(&taken, None) {
                            Some(t) => {
                                admitted.push(self.take(t, weight(t)));
                                *taken.entry(t).or_insert(0) += 1;
                            }
                            None => break,
                        }
                    }
                }
            }
        }
        RoundAdmission { admitted, quota_hits, deferred: self.len as u64 }
    }
}

/// EWMA round-size controller.  Saturated rounds (occupancy at the
/// ceiling): shrink when observed round wall exceeds the p95 target,
/// grow when comfortably under it, hold in the hysteresis band between.
/// Unsaturated rounds: always hold (see [`BatchController::observe`]).
#[derive(Clone, Debug)]
pub struct BatchController {
    adaptive: bool,
    /// p95 round-wall target, seconds.
    target: f64,
    /// EWMA gain for new observations.
    alpha: f64,
    /// Grow only below `low_frac * target` (hysteresis floor).
    low_frac: f64,
    ewma: Option<f64>,
    max_round: usize,
    lo: usize,
    hi: usize,
    pub grows: u64,
    pub shrinks: u64,
    pub holds: u64,
    /// Latency spikes absorbed by multiplicative decrease (a subset of
    /// `shrinks` rounds).
    pub spikes: u64,
}

impl BatchController {
    /// Adaptive controller starting at (and capped by) `max_round`.
    pub fn adaptive(max_round: usize, target_p95: f64) -> Self {
        let hi = max_round.max(1);
        Self {
            adaptive: true,
            target: target_p95.max(f64::MIN_POSITIVE),
            alpha: 0.3,
            low_frac: 0.5,
            ewma: None,
            max_round: hi,
            lo: 1,
            hi,
            grows: 0,
            shrinks: 0,
            holds: 0,
            spikes: 0,
        }
    }

    /// Static `max_round` (the PR 2 knob); `observe` only counts holds.
    pub fn fixed(max_round: usize) -> Self {
        let m = max_round.max(1);
        Self { adaptive: false, max_round: m, lo: m, hi: m, ..Self::adaptive(m, 1.0) }
    }

    pub fn max_round(&self) -> usize {
        self.max_round
    }

    /// Smoothed round wall seconds (`None` before the first observation).
    pub fn ewma(&self) -> Option<f64> {
        self.ewma
    }

    /// Feed one round's wall seconds and occupancy (programs admitted).
    ///
    /// BOTH directions are gated on saturation (`occupancy >=
    /// max_round`): shrinking below the occupancy actually observed
    /// cannot reduce round wall (a single slow program would otherwise
    /// ratchet the ceiling to 1 and pin it there, serializing every
    /// later burst), and growing an unsaturated ceiling would only
    /// inflate a bound nothing is hitting.
    /// Instantaneous round wall beyond `SPIKE_FACTOR * target` is a load
    /// cliff (fault-injected stall, tenant flood), not EWMA drift — the
    /// one-step additive decrease would take `max_round - lo` saturated
    /// rounds to react, serving the whole cliff at the stale ceiling.
    /// The factor sits far above the additive band (shrink triggers at
    /// `1x`, and the pinned additive trajectories feed up to `5x`), so
    /// ordinary over-target rounds never take the multiplicative path.
    pub const SPIKE_FACTOR: f64 = 8.0;

    pub fn observe(&mut self, round_wall: f64, occupancy: usize) {
        let e = match self.ewma {
            None => round_wall,
            Some(prev) => self.alpha * round_wall + (1.0 - self.alpha) * prev,
        };
        self.ewma = Some(e);
        if !self.adaptive || occupancy < self.max_round {
            self.holds += 1;
            return;
        }
        // multiplicative decrease on latency spikes: halve toward the
        // floor on the INSTANTANEOUS observation (the EWMA is too slow
        // for a cliff), recover by the ordinary additive grow path
        if round_wall > Self::SPIKE_FACTOR * self.target && self.max_round > self.lo {
            self.max_round = (self.max_round / 2).max(self.lo);
            self.shrinks += 1;
            self.spikes += 1;
            return;
        }
        if e > self.target && self.max_round > self.lo {
            self.max_round -= 1;
            self.shrinks += 1;
        } else if e < self.low_frac * self.target && self.max_round < self.hi {
            self.max_round += 1;
            self.grows += 1;
        } else {
            self.holds += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::{Arbitrary, Quick};
    use crate::util::rng::Rng;

    #[test]
    fn fifo_policy_reconstructs_arrival_order() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fifo);
        s.push(2, "a");
        s.push(0, "b");
        s.push(2, "c");
        s.push(1, "d");
        let r = s.next_round(3, |_| 1.0);
        assert_eq!(r.admitted, vec!["a", "b", "c"]);
        assert_eq!(r.quota_hits, 0);
        assert_eq!(r.deferred, 1);
        assert_eq!(s.next_round(4, |_| 1.0).admitted, vec!["d"]);
        assert!(s.is_empty());
    }

    #[test]
    fn fair_round_interleaves_tenants_and_counts_quota_hits() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fair);
        for i in 0..10 {
            s.push(0, (0, i)); // the heavy tenant floods first
        }
        s.push(1, (1, 0));
        s.push(2, (2, 0));
        let r = s.next_round(6, |_| 1.0);
        // breadth: 0, 1, 2 get one each; quota ceil(6/3)=2 tops heavy to
        // 2; fill spends the rest on the only pending tenant (heavy)
        let tenants: Vec<usize> = r.admitted.iter().map(|&(t, _)| t).collect();
        assert!(tenants.contains(&1) && tenants.contains(&2), "{tenants:?}");
        assert_eq!(r.admitted.len(), 6);
        assert_eq!(r.quota_hits, 1, "heavy tenant capped at its quota");
        assert_eq!(r.deferred, 6);
        // per-tenant FIFO: heavy's admitted programs are 0.. in order
        let heavy: Vec<usize> =
            r.admitted.iter().filter(|&&(t, _)| t == 0).map(|&(_, i)| i).collect();
        assert_eq!(heavy, (0..heavy.len()).collect::<Vec<_>>());
    }

    #[test]
    fn light_tenant_admitted_even_at_cap_one() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fair);
        for i in 0..8 {
            s.push(0, (0, i));
        }
        s.push(1, (1, 0));
        // cap 1: rounds alternate by virtual time, so the light tenant is
        // served within #active_tenants rounds of arriving
        let mut light_round = None;
        for round in 0..4 {
            let r = s.next_round(1, |_| 1.0);
            if r.admitted.iter().any(|&(t, _)| t == 1) {
                light_round = Some(round);
                break;
            }
        }
        assert!(light_round.unwrap() <= 2, "{light_round:?}");
    }

    #[test]
    fn down_weighted_tenant_cedes_slots() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fair);
        for i in 0..12 {
            s.push(0, (0, i));
            s.push(1, (1, i));
        }
        // tenant 0 at minimum weight: its virtual time advances 4x per
        // admission, so tenant 1 takes the lion's share of each round
        // (cap 9 keeps the two quotas from simply splitting the round)
        let w = |t: usize| if t == 0 { 0.25 } else { 1.0 };
        let r = s.next_round(9, w);
        let t0 = r.admitted.iter().filter(|&&(t, _)| t == 0).count();
        let t1 = r.admitted.iter().filter(|&&(t, _)| t == 1).count();
        assert!(t1 > t0, "weighting must bias admission: t0={t0} t1={t1}");
        assert!(t0 >= 1, "breadth pass still serves the down-weighted tenant");
    }

    /// Random arrivals + random caps: no pending tenant's HEAD program
    /// waits more than (active tenants) rounds — starvation-freedom of
    /// the selector itself, independent of the serve loop.
    #[derive(Clone, Debug)]
    struct ArrivalPlan(u64);

    impl Arbitrary for ArrivalPlan {
        fn generate(rng: &mut Rng) -> Self {
            ArrivalPlan(rng.next_u64())
        }
    }

    #[test]
    fn prop_head_of_line_wait_is_bounded() {
        Quick::with_cases(60).check::<ArrivalPlan, _>("bounded head wait", |plan| {
            let mut rng = Rng::new(plan.0);
            let tenants = 2 + rng.below(5) as usize;
            let cap = 1 + rng.below(6) as usize;
            // with equal weights, every service moves a competitor's
            // virtual time up by one and anchored activation keeps the
            // spread under one service unit, so a pending head is served
            // within ~2 * #tenants rounds even at cap 1
            let bound = 2 * tenants as u32 + 2;
            let mut s: FairScheduler<usize> = FairScheduler::new(AdmissionPolicy::Fair);
            // head_age[t] = consecutive rounds tenant t has had pending
            // work without being served
            let mut head_age = vec![0u32; tenants];
            for _ in 0..60 {
                for t in 0..tenants {
                    // heavy tenant 0 floods, others trickle
                    let n = if t == 0 { 3 } else { u64::from(rng.below(2) == 0) };
                    for _ in 0..n {
                        s.push(t, t);
                    }
                }
                let r = s.next_round(cap, |_| 1.0);
                let mut served = vec![false; tenants];
                for &t in &r.admitted {
                    served[t] = true;
                }
                for t in 0..tenants {
                    let pending = s.pending.get(&t).map_or(0, |q| q.len());
                    if served[t] || pending == 0 {
                        head_age[t] = 0;
                    } else {
                        head_age[t] += 1;
                        if head_age[t] > bound {
                            return false; // starved past the bound
                        }
                    }
                }
            }
            true
        });
    }

    /// Deterministic trace: constant over-target wall shrinks one step a
    /// round to the floor, then holds — the pinned trajectory.
    #[test]
    fn controller_shrinks_to_floor_and_holds() {
        let mut c = BatchController::adaptive(6, 1e-3);
        let mut trajectory = Vec::new();
        for _ in 0..8 {
            c.observe(5e-3, c.max_round()); // way over target
            trajectory.push(c.max_round());
        }
        assert_eq!(trajectory, vec![5, 4, 3, 2, 1, 1, 1, 1]);
        assert_eq!(c.shrinks, 5);
        assert_eq!(c.holds, 3);
        assert_eq!(c.grows, 0);
    }

    /// Closed loop: wall is a linear function of max_round.  The
    /// controller must converge and, at steady state, never oscillate
    /// past one step.
    #[test]
    fn controller_converges_without_oscillation() {
        let mut c = BatchController::adaptive(16, 2.4e-3);
        let mut last = Vec::new();
        for round in 0..60 {
            let wall = 0.3e-3 * c.max_round() as f64;
            c.observe(wall, c.max_round()); // saturated rounds
            if round >= 45 {
                last.push(c.max_round());
            }
        }
        let lo = *last.iter().min().unwrap();
        let hi = *last.iter().max().unwrap();
        assert!(hi - lo <= 1, "steady state oscillates: {last:?}");
        assert!((4..=8).contains(&lo), "converged outside the band: {last:?}");
    }

    /// Growth needs BOTH low latency and saturated rounds; an idle system
    /// must not inflate max_round.
    #[test]
    fn controller_grows_only_when_saturated() {
        let mut c = BatchController::adaptive(8, 2e-3);
        for _ in 0..4 {
            c.observe(4e-3, 8); // over target: shrink
        }
        assert_eq!(c.max_round(), 4);
        for _ in 0..20 {
            c.observe(1e-4, 1); // fast but UNSATURATED rounds
        }
        // unsaturated rounds hold in BOTH directions: the wall belongs
        // to the programs, not the ceiling
        assert_eq!(c.max_round(), 4, "unsaturated rounds must hold");
        assert_eq!(c.grows, 0, "idle rounds must not inflate max_round");
        for _ in 0..20 {
            c.observe(1e-4, c.max_round()); // fast AND saturated: grow
        }
        assert_eq!(c.max_round(), 8, "grows back to the ceiling");
        assert!(c.grows >= 4);
    }

    /// The ratchet trap: a single slow program (occupancy 1, wall over
    /// target) must NOT shrink the ceiling — round size is not the
    /// cause, and shrinking to 1 would serialize every later burst.
    #[test]
    fn slow_unsaturated_rounds_do_not_ratchet_the_ceiling_down() {
        let mut c = BatchController::adaptive(8, 2e-3);
        for _ in 0..30 {
            c.observe(10e-3, 1); // way over target, but occupancy 1
        }
        assert_eq!(c.max_round(), 8, "shrink requires saturation");
        assert_eq!(c.shrinks, 0);
    }

    #[test]
    fn fixed_controller_never_moves() {
        let mut c = BatchController::fixed(7);
        for _ in 0..10 {
            c.observe(1.0, 7);
        }
        assert_eq!(c.max_round(), 7);
        assert_eq!((c.grows, c.shrinks), (0, 0));
        assert_eq!(c.holds, 10);
    }

    /// A latency cliff (instantaneous wall far past target) halves the
    /// ceiling instead of stepping down by one — reaching the floor in
    /// O(log) rounds — while merely-over-target rounds keep the additive
    /// path (the pinned `shrinks_to_floor` trajectory feeds 5x target
    /// and must NOT halve).
    #[test]
    fn latency_spike_triggers_multiplicative_decrease() {
        let mut c = BatchController::adaptive(64, 1e-3);
        c.observe(20e-3, c.max_round()); // 20x target: spike
        assert_eq!(c.max_round(), 32, "halved, not stepped");
        assert_eq!((c.spikes, c.shrinks), (1, 1));
        c.observe(20e-3, c.max_round());
        c.observe(20e-3, c.max_round());
        assert_eq!(c.max_round(), 8, "64 -> 32 -> 16 -> 8 in three rounds");
        // merely over target (additive band): one step, no spike
        c.observe(2e-3, c.max_round());
        assert_eq!(c.max_round(), 7);
        assert_eq!(c.spikes, 3);
        // spikes respect the floor
        let mut f = BatchController::adaptive(2, 1e-3);
        f.observe(1.0, 2);
        f.observe(1.0, f.max_round().max(1));
        assert_eq!(f.max_round(), 1, "never below lo");
    }

    /// Unsaturated spikes still hold: a single stalled program does not
    /// indict the ceiling (same reasoning as the additive ratchet trap).
    #[test]
    fn unsaturated_spikes_do_not_halve() {
        let mut c = BatchController::adaptive(8, 1e-3);
        for _ in 0..10 {
            c.observe(1.0, 1);
        }
        assert_eq!(c.max_round(), 8);
        assert_eq!(c.spikes, 0);
    }

    /// Property: under a `heavy_tenant_scenario`-style flood (sustained
    /// saturated spikes of random magnitude), the controller collapses to
    /// the floor within O(log hi) rounds, and once the flood clears it
    /// recovers to the pre-flood ceiling in at most `hi` fast saturated
    /// rounds — bounded recovery, no sticky collapse.
    #[test]
    fn prop_spike_collapse_and_recovery_are_bounded() {
        #[derive(Clone, Debug)]
        struct Flood {
            hi: usize,
            spike_factor: f64,
            flood_rounds: usize,
        }
        impl Arbitrary for Flood {
            fn generate(rng: &mut Rng) -> Self {
                Flood {
                    hi: 2 + rng.below(63) as usize,
                    spike_factor: 9.0 + rng.below(100) as f64,
                    flood_rounds: 1 + rng.below(12) as usize,
                }
            }
        }

        Quick::with_cases(60).check::<Flood, _>("spike collapse/recovery", |f| {
            let target = 1e-3;
            let mut c = BatchController::adaptive(f.hi, target);
            // flood: every round saturated and spiking
            for _ in 0..f.flood_rounds {
                c.observe(f.spike_factor * target, c.max_round());
            }
            let collapse_budget = (f.hi as f64).log2().ceil() as usize + 1;
            if f.flood_rounds >= collapse_budget && c.max_round() != 1 {
                return false; // log-bounded collapse failed
            }
            // flood clears: fast saturated rounds (EWMA decays, then the
            // additive grow path climbs one step per round)
            let mut recovered_in = None;
            for round in 0..(f.hi + 40) {
                c.observe(0.1 * target, c.max_round());
                if c.max_round() == f.hi {
                    recovered_in = Some(round + 1);
                    break;
                }
            }
            match recovered_in {
                // a few EWMA-decay rounds, then one grow per round
                Some(n) => n <= f.hi + 40,
                None => false,
            }
        });
    }

    #[test]
    fn weights_scale_down_heavy_tenants() {
        use crate::metrics::LatencyHistogram;
        let mut lat: HashMap<usize, LatencyHistogram> = HashMap::new();
        for _ in 0..30 {
            lat.entry(0).or_default().record(1e-3);
        }
        for _ in 0..5 {
            lat.entry(1).or_default().record(1e-3);
        }
        lat.entry(2).or_default().record(1e-3);
        let mut win = ServiceWindow::new();
        let w = service_weights(&mut win, &lat, &HashMap::new());
        assert!(w[&0] < w[&1], "{w:?}");
        assert_eq!(w[&1], 1.0, "fair-share tenants keep full weight");
        assert_eq!(w[&2], 1.0);
        assert!(w[&0] >= 0.25, "clamped");
        // degenerate cases: empty and single-tenant maps are all-neutral
        assert!(service_weights(&mut ServiceWindow::new(), &HashMap::new(), &HashMap::new())
            .is_empty());
        let mut solo = HashMap::new();
        for _ in 0..9 {
            solo.entry(4usize).or_default().record(1e-3);
        }
        assert_eq!(service_weights(&mut ServiceWindow::new(), &solo, &HashMap::new())[&4], 1.0);
    }

    /// Regression for the lifetime-count bug: a tenant that WAS heavy
    /// but stops flooding must recover weight 1.0 as its window decays —
    /// cumulative history alone can never depress it again.
    #[test]
    fn reformed_heavy_tenant_recovers_full_weight() {
        use crate::metrics::LatencyHistogram;
        let mut lat: HashMap<usize, LatencyHistogram> = HashMap::new();
        let mut win = ServiceWindow::new();

        // round 1: tenant 0 floods (50 programs), tenant 1 serves 2
        for _ in 0..50 {
            lat.entry(0).or_default().record(1e-3);
        }
        for _ in 0..2 {
            lat.entry(1).or_default().record(1e-3);
        }
        let w = service_weights(&mut win, &lat, &HashMap::new());
        // fair share is 26 of 52; the flooder took 50 -> weight ~0.52
        assert!(w[&0] < 0.6, "flooding tenant is depressed: {w:?}");
        assert_eq!(w[&1], 1.0);

        // later rounds: both tenants serve 1 program each — the flood is
        // history, but the CUMULATIVE counts stay wildly lopsided (51+ vs
        // 3+); the lifetime-count bug kept tenant 0 at the floor forever
        let mut recovered = Vec::new();
        for _ in 0..12 {
            lat.entry(0).or_default().record(1e-3);
            lat.entry(1).or_default().record(1e-3);
            recovered = vec![service_weights(&mut win, &lat, &HashMap::new())];
        }
        let w = recovered.pop().unwrap();
        assert_eq!(w[&0], 1.0, "reformed tenant must recover full weight: {w:?}");
        assert_eq!(w[&1], 1.0);
    }

    /// The energy dimension: equal program counts but lopsided modeled
    /// energy scales the energy-heavy tenant down.
    #[test]
    fn energy_share_depresses_equal_program_tenants() {
        use crate::metrics::LatencyHistogram;
        let mut lat: HashMap<usize, LatencyHistogram> = HashMap::new();
        for t in 0..2usize {
            for _ in 0..4 {
                lat.entry(t).or_default().record(1e-3);
            }
        }
        let mut energy = HashMap::new();
        energy.insert(0usize, 100.0);
        energy.insert(1usize, 1.0);
        let w = service_weights(&mut ServiceWindow::new(), &lat, &energy);
        assert!(w[&0] < 1.0, "energy-dominant tenant is scaled down: {w:?}");
        assert_eq!(w[&1], 1.0, "light-energy tenant keeps full weight");
        // without the energy signal the same counts are perfectly fair
        let w = service_weights(&mut ServiceWindow::new(), &lat, &HashMap::new());
        assert_eq!((w[&0], w[&1]), (1.0, 1.0));
    }
}
