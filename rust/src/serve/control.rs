//! The serving control plane: weighted fair admission over the backlog
//! and the adaptive round-size controller.
//!
//! PR 2's scheduler admitted FIFO up to a static `max_round`, which let a
//! heavy tenant flood a round and made round size a guess.  This module
//! replaces both knobs with closed-loop policies:
//!
//! * [`FairScheduler`] — the backlog.  Under [`AdmissionPolicy::Fair`] a
//!   round is selected by weighted fair queueing with per-tenant quotas:
//!   a breadth pass admits every pending tenant's head program in
//!   virtual-time order, a quota pass tops tenants up to their fair
//!   share, and a work-conserving fill pass spends leftover capacity.
//!   Per-tenant FIFO order is always preserved (bit-identity with that
//!   tenant's sequential program order depends on it); only the
//!   interleaving ACROSS tenants changes.  Weights come from the
//!   RECENT service window ([`ServiceWindow`] + [`service_weights`]):
//!   per-round deltas of each tenant's served-program count and modeled
//!   energy (calibrated, see `planner::calibrate`) are EWMA-folded, and
//!   a tenant whose windowed share of either exceeds the fair share has
//!   its weight scaled down, so its virtual time advances faster and it
//!   cedes slots — while an ex-heavy tenant's depressed weight decays
//!   back to 1.0 as its window empties.
//! * [`BatchController`] — an EWMA controller over observed round wall
//!   time with a p95 latency target.  While rounds saturate the current
//!   ceiling, wall above target shrinks `max_round` one step (smaller
//!   rounds bound tail latency) and wall under half the target grows it
//!   one step (bigger rounds recover fusion/dedup opportunities); the
//!   band in between holds, so a steady-state trace cannot oscillate
//!   past one step (pinned by the deterministic trace test below).
//!   Unsaturated rounds always hold — their wall is set by the programs
//!   themselves, and moving a ceiling nothing hits would let one slow
//!   program ratchet `max_round` to 1 and serialize every later burst.
//! * [`DegradeController`] — the health-driven brownout ladder (DESIGN.md
//!   §15).  Committed `round_wall_slo_burn` transitions step service
//!   through pin-routing → widen-cache → reduce-sampling → shed and walk
//!   back on recovery; hysteresis is inherited from the `HealthEngine`'s
//!   sustain streaks.
//! * [`CircuitBreaker`] — per-shard fail-fast over the serve retry loop:
//!   consecutive `RouteError` retry exhaustions open a shard, open shards
//!   reject placements immediately (`Rejected(ShardDown)`), and a
//!   half-open respawn-and-replay probe closes them again.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::metrics::LatencyHistogram;
use crate::observe::RuleState;

/// How the scheduler picks a round from the backlog.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Global arrival order, no quotas (PR 2 behavior).
    Fifo,
    /// Weighted fair queueing with per-tenant quotas.
    Fair,
}

/// Whether `max_round` is a static knob or controller-driven.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchPolicy {
    /// `ServeConfig::max_round` is used as-is.
    Static,
    /// EWMA controller with this p95 round-wall target (seconds);
    /// `ServeConfig::max_round` is the ceiling and starting point.
    Adaptive { target_p95: f64 },
}

/// One selected round plus the fairness counters it generated.
pub struct RoundAdmission<T> {
    /// Admitted items, in execution order (per-tenant FIFO preserved).
    pub admitted: Vec<T>,
    /// Tenants that exhausted their per-round fair-share quota while
    /// still holding pending programs (the dominance the policy caps).
    pub quota_hits: u64,
    /// Programs still pending after this round's selection.
    pub deferred: u64,
}

/// Windowed per-tenant service accounting behind [`service_weights`].
///
/// The histograms in `ServeMetrics` are CUMULATIVE, so dividing by
/// `h.count()` weighted tenants by their lifetime history: an ex-heavy
/// tenant stayed depressed forever.  This window keeps, per tenant, the
/// delta since the last round (the same counter-delta derivation the
/// `SeriesStore` uses) folded into an EWMA, so only *recent* service
/// share moves the weight and a reformed tenant decays back to 1.0.
#[derive(Debug, Default)]
pub struct ServiceWindow {
    /// Last cumulative (programs, energy) snapshot per tenant.
    last: HashMap<usize, (u64, f64)>,
    /// EWMA of the per-round (programs, energy) deltas per tenant.
    recent: HashMap<usize, (f64, f64)>,
    alpha: f64,
}

impl ServiceWindow {
    /// Default new-sample weight: heavy history decays below the 0.25
    /// clamp's reach within a handful of quiet rounds.
    const ALPHA: f64 = 0.5;

    pub fn new() -> Self {
        Self::with_alpha(Self::ALPHA)
    }

    pub fn with_alpha(alpha: f64) -> Self {
        Self { last: HashMap::new(), recent: HashMap::new(), alpha: alpha.clamp(0.01, 1.0) }
    }

    /// Fold one round's cumulative snapshots (per-tenant latency
    /// histograms + modeled energy totals) into the window.  The first
    /// observation of a tenant seeds its EWMA at the full delta, so a
    /// flood registers immediately.
    pub fn observe(
        &mut self,
        latency: &HashMap<usize, LatencyHistogram>,
        energy: &HashMap<usize, f64>,
    ) {
        for (&t, h) in latency {
            let cum_p = h.count();
            let cum_e = energy.get(&t).copied().unwrap_or(0.0);
            let (last_p, last_e) = self.last.get(&t).copied().unwrap_or((0, 0.0));
            let dp = cum_p.saturating_sub(last_p) as f64;
            let de = (cum_e - last_e).max(0.0);
            self.last.insert(t, (cum_p, cum_e));
            match self.recent.get_mut(&t) {
                Some((rp, re)) => {
                    *rp += self.alpha * (dp - *rp);
                    *re += self.alpha * (de - *re);
                }
                None => {
                    self.recent.insert(t, (dp, de));
                }
            }
        }
    }

    /// The tenant's recent served-program EWMA (testing/reporting).
    pub fn recent_programs(&self, tenant: usize) -> f64 {
        self.recent.get(&tenant).map(|&(p, _)| p).unwrap_or(0.0)
    }
}

/// Admission weights from the RECENT per-tenant service window: a tenant
/// whose windowed share of served programs — or of calibrated modeled
/// energy, whichever is more dominant — exceeds the fair share has its
/// weight scaled down, clamped to [0.25, 1.0].  Tenants with no recent
/// service recover full weight as their window decays; tenants with no
/// history default to 1.0 at the call site.
pub fn service_weights(
    window: &mut ServiceWindow,
    latency: &HashMap<usize, LatencyHistogram>,
    energy: &HashMap<usize, f64>,
) -> HashMap<usize, f64> {
    window.observe(latency, energy);
    let n = latency.len();
    if n < 2 {
        return latency.keys().map(|&t| (t, 1.0)).collect();
    }
    let recent: Vec<(usize, f64, f64)> = latency
        .keys()
        .map(|&t| {
            let (p, e) = window.recent.get(&t).copied().unwrap_or((0.0, 0.0));
            (t, p, e)
        })
        .collect();
    let total_p: f64 = recent.iter().map(|&(_, p, _)| p).sum();
    let total_e: f64 = recent.iter().map(|&(_, _, e)| e).sum();
    if total_p <= f64::EPSILON {
        return latency.keys().map(|&t| (t, 1.0)).collect();
    }
    let fair_p = total_p / n as f64;
    let fair_e = total_e / n as f64;
    recent
        .iter()
        .map(|&(t, p, e)| {
            let wp = (fair_p / p.max(f64::EPSILON)).clamp(0.25, 1.0);
            let w = if total_e > f64::EPSILON {
                wp.min((fair_e / e.max(f64::EPSILON)).clamp(0.25, 1.0))
            } else {
                wp
            };
            // EWMA residue never reaches exactly zero; a near-neutral
            // weight snaps to 1.0 so a reformed tenant fully recovers
            (t, if w >= 0.98 { 1.0 } else { w })
        })
        .collect()
}

/// The multi-tenant backlog and round selector.
pub struct FairScheduler<T> {
    policy: AdmissionPolicy,
    /// Per-tenant FIFO queues; items carry a global arrival sequence so
    /// the FIFO policy can reconstruct arrival order exactly.
    pending: BTreeMap<usize, VecDeque<(u64, T)>>,
    /// WFQ virtual finish time per tenant (persists across idle spells).
    vtime: BTreeMap<usize, f64>,
    /// High-water virtual time; newly active tenants anchor here so idle
    /// time earns no credit.
    global_vtime: f64,
    next_seq: u64,
    len: usize,
}

impl<T> FairScheduler<T> {
    pub fn new(policy: AdmissionPolicy) -> Self {
        Self {
            policy,
            pending: BTreeMap::new(),
            vtime: BTreeMap::new(),
            global_vtime: 0.0,
            next_seq: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue one program for `tenant` (FIFO within the tenant).
    pub fn push(&mut self, tenant: usize, item: T) {
        if self.pending.get(&tenant).map_or(true, |q| q.is_empty()) {
            let vt = self.vtime.entry(tenant).or_insert(self.global_vtime);
            if *vt < self.global_vtime {
                *vt = self.global_vtime;
            }
        }
        self.pending.entry(tenant).or_default().push_back((self.next_seq, item));
        self.next_seq += 1;
        self.len += 1;
    }

    /// Pop `tenant`'s head item and charge its virtual time.
    fn take(&mut self, tenant: usize, weight: f64) -> T {
        let (_, item) = self
            .pending
            .get_mut(&tenant)
            .and_then(|q| q.pop_front())
            .expect("take from tenant with pending work");
        self.len -= 1;
        let w = if weight.is_finite() && weight > 0.0 { weight.clamp(1e-3, 1e3) } else { 1.0 };
        let vt = self.vtime.entry(tenant).or_insert(self.global_vtime);
        if *vt > self.global_vtime {
            self.global_vtime = *vt;
        }
        *vt += 1.0 / w;
        item
    }

    /// Tenant with pending work minimizing (virtual time, id), optionally
    /// restricted by a per-round admission count limit.
    fn min_vt_tenant(&self, taken: &BTreeMap<usize, usize>, limit: Option<usize>) -> Option<usize> {
        self.pending
            .iter()
            .filter(|&(t, q)| {
                !q.is_empty()
                    && limit.map_or(true, |l| taken.get(t).copied().unwrap_or(0) < l)
            })
            .map(|(&t, _)| (self.vtime.get(&t).copied().unwrap_or(self.global_vtime), t))
            .min_by(|a, b| a.partial_cmp(b).expect("finite virtual times"))
            .map(|(_, t)| t)
    }

    /// Select the next round: at most `cap` programs, per the policy.
    /// `weight(tenant)` supplies the WFQ weight (1.0 = neutral).
    pub fn next_round<W: Fn(usize) -> f64>(&mut self, cap: usize, weight: W) -> RoundAdmission<T> {
        let cap = cap.max(1);
        let mut admitted = Vec::new();
        let mut quota_hits = 0u64;
        match self.policy {
            AdmissionPolicy::Fifo => {
                while admitted.len() < cap {
                    // head with the smallest arrival sequence = global FIFO
                    let t = match self
                        .pending
                        .iter()
                        .filter(|(_, q)| !q.is_empty())
                        .min_by_key(|(_, q)| q.front().expect("non-empty").0)
                        .map(|(&t, _)| t)
                    {
                        Some(t) => t,
                        None => break,
                    };
                    let item = self.take(t, 1.0);
                    admitted.push(item);
                }
            }
            AdmissionPolicy::Fair => {
                let active: Vec<usize> = self
                    .pending
                    .iter()
                    .filter(|(_, q)| !q.is_empty())
                    .map(|(&t, _)| t)
                    .collect();
                if !active.is_empty() {
                    let quota = ((cap + active.len() - 1) / active.len()).max(1);
                    let mut taken: BTreeMap<usize, usize> = BTreeMap::new();
                    // breadth pass: every active tenant's head, in virtual-
                    // time order — this is what makes starvation impossible
                    let mut order: Vec<(f64, usize)> = active
                        .iter()
                        .map(|&t| (self.vtime.get(&t).copied().unwrap_or(self.global_vtime), t))
                        .collect();
                    order.sort_by(|a, b| a.partial_cmp(b).expect("finite virtual times"));
                    for (_, t) in order {
                        if admitted.len() >= cap {
                            break;
                        }
                        admitted.push(self.take(t, weight(t)));
                        *taken.entry(t).or_insert(0) += 1;
                    }
                    // quota pass: top tenants up to their fair share
                    while admitted.len() < cap {
                        match self.min_vt_tenant(&taken, Some(quota)) {
                            Some(t) => {
                                admitted.push(self.take(t, weight(t)));
                                *taken.entry(t).or_insert(0) += 1;
                            }
                            None => break,
                        }
                    }
                    quota_hits = self
                        .pending
                        .iter()
                        .filter(|&(t, q)| {
                            !q.is_empty() && taken.get(t).copied().unwrap_or(0) >= quota
                        })
                        .count() as u64;
                    // fill pass: stay work-conserving — leftover capacity
                    // goes to whoever is pending, still in WFQ order
                    while admitted.len() < cap {
                        match self.min_vt_tenant(&taken, None) {
                            Some(t) => {
                                admitted.push(self.take(t, weight(t)));
                                *taken.entry(t).or_insert(0) += 1;
                            }
                            None => break,
                        }
                    }
                }
            }
        }
        RoundAdmission { admitted, quota_hits, deferred: self.len as u64 }
    }

    /// Queued (not yet scheduled) programs currently held by `tenant`.
    /// Admission control's per-tenant backlog bound reads this.
    pub fn tenant_backlog(&self, tenant: usize) -> usize {
        self.pending.get(&tenant).map_or(0, |q| q.len())
    }

    /// Tenants with at least one queued program.
    pub fn active_tenants(&self) -> usize {
        self.pending.values().filter(|q| !q.is_empty()).count()
    }

    /// Remove every queued item `doomed` selects (the lifecycle sweep:
    /// deadline expiry, cancellation, tenant-wide cancel).  The relative
    /// order of survivors is untouched, so per-tenant FIFO — and with it
    /// bit-identity of the *answered* results — is preserved.  Returns
    /// the removed items with their tenants so the caller can answer
    /// each one exactly once.
    pub fn sweep<F: FnMut(usize, &T) -> bool>(&mut self, mut doomed: F) -> Vec<(usize, T)> {
        let mut removed = Vec::new();
        for (&t, q) in self.pending.iter_mut() {
            let mut kept = VecDeque::with_capacity(q.len());
            for (seq, item) in q.drain(..) {
                if doomed(t, &item) {
                    removed.push((t, item));
                } else {
                    kept.push_back((seq, item));
                }
            }
            *q = kept;
        }
        self.len -= removed.len();
        removed
    }
}

/// Brownout ladder steps, mildest first.  Each level implies every
/// milder one; the numeric order is what [`DegradeController`] walks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradeLevel {
    /// Full service.
    Normal = 0,
    /// Pin the calibrated energy-optimal routing: stop absorbing new
    /// calibration samples so overload noise cannot churn executor
    /// choices mid-incident.
    PinRouting = 1,
    /// Widen the result cache's entry cap so cheap negative entries
    /// absorb repeated empty-result polling without touching the array.
    WidenCache = 2,
    /// Stretch the observability sampling cadence (`sample_every`).
    ReduceSampling = 3,
    /// Shed over-quota admissions outright (`Rejected(Overloaded)`).
    Shed = 4,
}

impl DegradeLevel {
    const LADDER: [DegradeLevel; 5] = [
        DegradeLevel::Normal,
        DegradeLevel::PinRouting,
        DegradeLevel::WidenCache,
        DegradeLevel::ReduceSampling,
        DegradeLevel::Shed,
    ];

    pub fn name(self) -> &'static str {
        match self {
            DegradeLevel::Normal => "normal",
            DegradeLevel::PinRouting => "pin-routing",
            DegradeLevel::WidenCache => "widen-cache",
            DegradeLevel::ReduceSampling => "reduce-sampling",
            DegradeLevel::Shed => "shed",
        }
    }

    pub fn as_gauge(self) -> u64 {
        self as u64
    }
}

/// The health-driven brownout ladder.  Fed one COMMITTED state of the
/// watched health rule per evaluation cadence: critical climbs one step,
/// ok walks one step back, warn holds.  Flap damping comes for free from
/// the `HealthEngine`'s sustain-streak hysteresis — this controller can
/// never move faster than the rule commits.
#[derive(Debug, Default)]
pub struct DegradeController {
    level: usize,
    pub step_ups: u64,
    pub step_downs: u64,
}

impl DegradeController {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn level(&self) -> DegradeLevel {
        DegradeLevel::LADDER[self.level]
    }

    /// Fold one committed health evaluation; returns the transition when
    /// the level moved.
    pub fn on_health(&mut self, state: RuleState) -> Option<(DegradeLevel, DegradeLevel)> {
        let from = self.level;
        match state {
            RuleState::Critical if self.level + 1 < DegradeLevel::LADDER.len() => {
                self.level += 1;
                self.step_ups += 1;
            }
            RuleState::Ok if self.level > 0 => {
                self.level -= 1;
                self.step_downs += 1;
            }
            _ => {}
        }
        (from != self.level)
            .then(|| (DegradeLevel::LADDER[from], DegradeLevel::LADDER[self.level]))
    }

    /// ≥ [`DegradeLevel::PinRouting`]: the scheduler skips calibration
    /// absorption, freezing the current routing.
    pub fn pin_routing(&self) -> bool {
        self.level() >= DegradeLevel::PinRouting
    }

    /// Entry-cap factor for the result cache: the configured baseline
    /// below [`DegradeLevel::WidenCache`], 4x it at or above.
    pub fn cache_cap_factor(&self) -> usize {
        if self.level() >= DegradeLevel::WidenCache {
            super::cache::ENTRY_CAP_FACTOR * 4
        } else {
            super::cache::ENTRY_CAP_FACTOR
        }
    }

    /// Multiplier on the `sample_every` observability cadence.
    pub fn sample_stride(&self) -> u64 {
        if self.level() >= DegradeLevel::ReduceSampling {
            4
        } else {
            1
        }
    }

    /// At the top of the ladder: admission sheds over-quota programs.
    pub fn shedding(&self) -> bool {
        self.level() >= DegradeLevel::Shed
    }
}

/// Per-shard circuit breaker states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: placements flow.
    Closed,
    /// Tripped: placements touching the shard fail fast with
    /// `Rejected(ShardDown)` instead of queueing into a dead retry loop.
    Open,
    /// Probe in flight: one respawn-and-replay attempt decides whether
    /// the breaker closes or re-opens.
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }

    pub fn as_gauge(self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }
}

#[derive(Debug)]
struct ShardBreaker {
    state: BreakerState,
    /// Consecutive retry-loop exhaustions (reset on any success).
    consecutive: u32,
    /// Scheduling passes waited while open.
    waited: u64,
}

/// Per-shard circuit breaker over the serve retry loop.  `threshold`
/// consecutive retry-loop exhaustions open a shard's breaker; an open
/// breaker waits `probe_after` SCHEDULING PASSES (not rounds — when every
/// admission is rejected pre-round the round number never advances, and a
/// round-based cadence would hold the breaker open forever) and then goes
/// half-open, owing the caller one respawn-and-replay probe.
/// [`CircuitBreaker::record_success`] closes it, `record_failure`
/// re-opens it.  `threshold == 0` disables the breaker entirely.
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    probe_after: u64,
    shards: Vec<ShardBreaker>,
    pub opens: u64,
    pub closes: u64,
}

impl CircuitBreaker {
    pub fn new(shards: usize, threshold: u32, probe_after: u64) -> Self {
        let shards = (0..shards)
            .map(|_| ShardBreaker { state: BreakerState::Closed, consecutive: 0, waited: 0 })
            .collect();
        Self { threshold, probe_after, shards, opens: 0, closes: 0 }
    }

    /// Out-of-range shards read as closed (never block a placement).
    pub fn state(&self, shard: usize) -> BreakerState {
        self.shards.get(shard).map_or(BreakerState::Closed, |b| b.state)
    }

    pub fn is_open(&self, shard: usize) -> bool {
        self.state(shard) == BreakerState::Open
    }

    /// One retry-loop exhaustion (or failed probe) on `shard`.  Returns
    /// the transition when the breaker state changed.
    pub fn record_failure(&mut self, shard: usize) -> Option<(BreakerState, BreakerState)> {
        if self.threshold == 0 {
            return None;
        }
        let b = self.shards.get_mut(shard)?;
        b.consecutive = b.consecutive.saturating_add(1);
        let open = match b.state {
            BreakerState::Closed => b.consecutive >= self.threshold,
            BreakerState::HalfOpen => true, // failed probe re-opens
            BreakerState::Open => false,
        };
        if !open {
            return None;
        }
        let from = b.state;
        b.state = BreakerState::Open;
        b.waited = 0;
        self.opens += 1;
        Some((from, BreakerState::Open))
    }

    /// A successful batch (or probe) on `shard`: resets the consecutive
    /// failure count and closes a non-closed breaker.
    pub fn record_success(&mut self, shard: usize) -> Option<(BreakerState, BreakerState)> {
        let b = self.shards.get_mut(shard)?;
        b.consecutive = 0;
        match b.state {
            BreakerState::Closed => None,
            from => {
                b.state = BreakerState::Closed;
                self.closes += 1;
                Some((from, BreakerState::Closed))
            }
        }
    }

    /// Advance every open shard's probe wait by one scheduling pass;
    /// shards whose wait reached `probe_after` flip to half-open and are
    /// returned — each owes the caller one probe.
    pub fn due_probes(&mut self) -> Vec<usize> {
        let mut due = Vec::new();
        for (s, b) in self.shards.iter_mut().enumerate() {
            if b.state == BreakerState::Open {
                b.waited += 1;
                if b.waited >= self.probe_after {
                    b.state = BreakerState::HalfOpen;
                    due.push(s);
                }
            }
        }
        due
    }

    pub fn any_open(&self) -> bool {
        self.shards.iter().any(|b| b.state == BreakerState::Open)
    }
}

/// EWMA round-size controller.  Saturated rounds (occupancy at the
/// ceiling): shrink when observed round wall exceeds the p95 target,
/// grow when comfortably under it, hold in the hysteresis band between.
/// Unsaturated rounds: always hold (see [`BatchController::observe`]).
#[derive(Clone, Debug)]
pub struct BatchController {
    adaptive: bool,
    /// p95 round-wall target, seconds.
    target: f64,
    /// EWMA gain for new observations.
    alpha: f64,
    /// Grow only below `low_frac * target` (hysteresis floor).
    low_frac: f64,
    ewma: Option<f64>,
    max_round: usize,
    lo: usize,
    hi: usize,
    pub grows: u64,
    pub shrinks: u64,
    pub holds: u64,
    /// Latency spikes absorbed by multiplicative decrease (a subset of
    /// `shrinks` rounds).
    pub spikes: u64,
}

impl BatchController {
    /// Adaptive controller starting at (and capped by) `max_round`.
    pub fn adaptive(max_round: usize, target_p95: f64) -> Self {
        let hi = max_round.max(1);
        Self {
            adaptive: true,
            target: target_p95.max(f64::MIN_POSITIVE),
            alpha: 0.3,
            low_frac: 0.5,
            ewma: None,
            max_round: hi,
            lo: 1,
            hi,
            grows: 0,
            shrinks: 0,
            holds: 0,
            spikes: 0,
        }
    }

    /// Static `max_round` (the PR 2 knob); `observe` only counts holds.
    pub fn fixed(max_round: usize) -> Self {
        let m = max_round.max(1);
        Self { adaptive: false, max_round: m, lo: m, hi: m, ..Self::adaptive(m, 1.0) }
    }

    pub fn max_round(&self) -> usize {
        self.max_round
    }

    /// Smoothed round wall seconds (`None` before the first observation).
    pub fn ewma(&self) -> Option<f64> {
        self.ewma
    }

    /// Feed one round's wall seconds and occupancy (programs admitted).
    ///
    /// BOTH directions are gated on saturation (`occupancy >=
    /// max_round`): shrinking below the occupancy actually observed
    /// cannot reduce round wall (a single slow program would otherwise
    /// ratchet the ceiling to 1 and pin it there, serializing every
    /// later burst), and growing an unsaturated ceiling would only
    /// inflate a bound nothing is hitting.
    /// Instantaneous round wall beyond `SPIKE_FACTOR * target` is a load
    /// cliff (fault-injected stall, tenant flood), not EWMA drift — the
    /// one-step additive decrease would take `max_round - lo` saturated
    /// rounds to react, serving the whole cliff at the stale ceiling.
    /// The factor sits far above the additive band (shrink triggers at
    /// `1x`, and the pinned additive trajectories feed up to `5x`), so
    /// ordinary over-target rounds never take the multiplicative path.
    pub const SPIKE_FACTOR: f64 = 8.0;

    pub fn observe(&mut self, round_wall: f64, occupancy: usize) {
        let e = match self.ewma {
            None => round_wall,
            Some(prev) => self.alpha * round_wall + (1.0 - self.alpha) * prev,
        };
        self.ewma = Some(e);
        if !self.adaptive || occupancy < self.max_round {
            self.holds += 1;
            return;
        }
        // multiplicative decrease on latency spikes: halve toward the
        // floor on the INSTANTANEOUS observation (the EWMA is too slow
        // for a cliff), recover by the ordinary additive grow path
        if round_wall > Self::SPIKE_FACTOR * self.target && self.max_round > self.lo {
            self.max_round = (self.max_round / 2).max(self.lo);
            self.shrinks += 1;
            self.spikes += 1;
            return;
        }
        if e > self.target && self.max_round > self.lo {
            self.max_round -= 1;
            self.shrinks += 1;
        } else if e < self.low_frac * self.target && self.max_round < self.hi {
            self.max_round += 1;
            self.grows += 1;
        } else {
            self.holds += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::{Arbitrary, Quick};
    use crate::util::rng::Rng;

    #[test]
    fn fifo_policy_reconstructs_arrival_order() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fifo);
        s.push(2, "a");
        s.push(0, "b");
        s.push(2, "c");
        s.push(1, "d");
        let r = s.next_round(3, |_| 1.0);
        assert_eq!(r.admitted, vec!["a", "b", "c"]);
        assert_eq!(r.quota_hits, 0);
        assert_eq!(r.deferred, 1);
        assert_eq!(s.next_round(4, |_| 1.0).admitted, vec!["d"]);
        assert!(s.is_empty());
    }

    #[test]
    fn fair_round_interleaves_tenants_and_counts_quota_hits() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fair);
        for i in 0..10 {
            s.push(0, (0, i)); // the heavy tenant floods first
        }
        s.push(1, (1, 0));
        s.push(2, (2, 0));
        let r = s.next_round(6, |_| 1.0);
        // breadth: 0, 1, 2 get one each; quota ceil(6/3)=2 tops heavy to
        // 2; fill spends the rest on the only pending tenant (heavy)
        let tenants: Vec<usize> = r.admitted.iter().map(|&(t, _)| t).collect();
        assert!(tenants.contains(&1) && tenants.contains(&2), "{tenants:?}");
        assert_eq!(r.admitted.len(), 6);
        assert_eq!(r.quota_hits, 1, "heavy tenant capped at its quota");
        assert_eq!(r.deferred, 6);
        // per-tenant FIFO: heavy's admitted programs are 0.. in order
        let heavy: Vec<usize> =
            r.admitted.iter().filter(|&&(t, _)| t == 0).map(|&(_, i)| i).collect();
        assert_eq!(heavy, (0..heavy.len()).collect::<Vec<_>>());
    }

    #[test]
    fn light_tenant_admitted_even_at_cap_one() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fair);
        for i in 0..8 {
            s.push(0, (0, i));
        }
        s.push(1, (1, 0));
        // cap 1: rounds alternate by virtual time, so the light tenant is
        // served within #active_tenants rounds of arriving
        let mut light_round = None;
        for round in 0..4 {
            let r = s.next_round(1, |_| 1.0);
            if r.admitted.iter().any(|&(t, _)| t == 1) {
                light_round = Some(round);
                break;
            }
        }
        assert!(light_round.unwrap() <= 2, "{light_round:?}");
    }

    #[test]
    fn down_weighted_tenant_cedes_slots() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fair);
        for i in 0..12 {
            s.push(0, (0, i));
            s.push(1, (1, i));
        }
        // tenant 0 at minimum weight: its virtual time advances 4x per
        // admission, so tenant 1 takes the lion's share of each round
        // (cap 9 keeps the two quotas from simply splitting the round)
        let w = |t: usize| if t == 0 { 0.25 } else { 1.0 };
        let r = s.next_round(9, w);
        let t0 = r.admitted.iter().filter(|&&(t, _)| t == 0).count();
        let t1 = r.admitted.iter().filter(|&&(t, _)| t == 1).count();
        assert!(t1 > t0, "weighting must bias admission: t0={t0} t1={t1}");
        assert!(t0 >= 1, "breadth pass still serves the down-weighted tenant");
    }

    /// Random arrivals + random caps: no pending tenant's HEAD program
    /// waits more than (active tenants) rounds — starvation-freedom of
    /// the selector itself, independent of the serve loop.
    #[derive(Clone, Debug)]
    struct ArrivalPlan(u64);

    impl Arbitrary for ArrivalPlan {
        fn generate(rng: &mut Rng) -> Self {
            ArrivalPlan(rng.next_u64())
        }
    }

    #[test]
    fn prop_head_of_line_wait_is_bounded() {
        Quick::with_cases(60).check::<ArrivalPlan, _>("bounded head wait", |plan| {
            let mut rng = Rng::new(plan.0);
            let tenants = 2 + rng.below(5) as usize;
            let cap = 1 + rng.below(6) as usize;
            // with equal weights, every service moves a competitor's
            // virtual time up by one and anchored activation keeps the
            // spread under one service unit, so a pending head is served
            // within ~2 * #tenants rounds even at cap 1
            let bound = 2 * tenants as u32 + 2;
            let mut s: FairScheduler<usize> = FairScheduler::new(AdmissionPolicy::Fair);
            // head_age[t] = consecutive rounds tenant t has had pending
            // work without being served
            let mut head_age = vec![0u32; tenants];
            for _ in 0..60 {
                for t in 0..tenants {
                    // heavy tenant 0 floods, others trickle
                    let n = if t == 0 { 3 } else { u64::from(rng.below(2) == 0) };
                    for _ in 0..n {
                        s.push(t, t);
                    }
                }
                let r = s.next_round(cap, |_| 1.0);
                let mut served = vec![false; tenants];
                for &t in &r.admitted {
                    served[t] = true;
                }
                for t in 0..tenants {
                    let pending = s.pending.get(&t).map_or(0, |q| q.len());
                    if served[t] || pending == 0 {
                        head_age[t] = 0;
                    } else {
                        head_age[t] += 1;
                        if head_age[t] > bound {
                            return false; // starved past the bound
                        }
                    }
                }
            }
            true
        });
    }

    /// Deterministic trace: constant over-target wall shrinks one step a
    /// round to the floor, then holds — the pinned trajectory.
    #[test]
    fn controller_shrinks_to_floor_and_holds() {
        let mut c = BatchController::adaptive(6, 1e-3);
        let mut trajectory = Vec::new();
        for _ in 0..8 {
            c.observe(5e-3, c.max_round()); // way over target
            trajectory.push(c.max_round());
        }
        assert_eq!(trajectory, vec![5, 4, 3, 2, 1, 1, 1, 1]);
        assert_eq!(c.shrinks, 5);
        assert_eq!(c.holds, 3);
        assert_eq!(c.grows, 0);
    }

    /// Closed loop: wall is a linear function of max_round.  The
    /// controller must converge and, at steady state, never oscillate
    /// past one step.
    #[test]
    fn controller_converges_without_oscillation() {
        let mut c = BatchController::adaptive(16, 2.4e-3);
        let mut last = Vec::new();
        for round in 0..60 {
            let wall = 0.3e-3 * c.max_round() as f64;
            c.observe(wall, c.max_round()); // saturated rounds
            if round >= 45 {
                last.push(c.max_round());
            }
        }
        let lo = *last.iter().min().unwrap();
        let hi = *last.iter().max().unwrap();
        assert!(hi - lo <= 1, "steady state oscillates: {last:?}");
        assert!((4..=8).contains(&lo), "converged outside the band: {last:?}");
    }

    /// Growth needs BOTH low latency and saturated rounds; an idle system
    /// must not inflate max_round.
    #[test]
    fn controller_grows_only_when_saturated() {
        let mut c = BatchController::adaptive(8, 2e-3);
        for _ in 0..4 {
            c.observe(4e-3, 8); // over target: shrink
        }
        assert_eq!(c.max_round(), 4);
        for _ in 0..20 {
            c.observe(1e-4, 1); // fast but UNSATURATED rounds
        }
        // unsaturated rounds hold in BOTH directions: the wall belongs
        // to the programs, not the ceiling
        assert_eq!(c.max_round(), 4, "unsaturated rounds must hold");
        assert_eq!(c.grows, 0, "idle rounds must not inflate max_round");
        for _ in 0..20 {
            c.observe(1e-4, c.max_round()); // fast AND saturated: grow
        }
        assert_eq!(c.max_round(), 8, "grows back to the ceiling");
        assert!(c.grows >= 4);
    }

    /// The ratchet trap: a single slow program (occupancy 1, wall over
    /// target) must NOT shrink the ceiling — round size is not the
    /// cause, and shrinking to 1 would serialize every later burst.
    #[test]
    fn slow_unsaturated_rounds_do_not_ratchet_the_ceiling_down() {
        let mut c = BatchController::adaptive(8, 2e-3);
        for _ in 0..30 {
            c.observe(10e-3, 1); // way over target, but occupancy 1
        }
        assert_eq!(c.max_round(), 8, "shrink requires saturation");
        assert_eq!(c.shrinks, 0);
    }

    #[test]
    fn fixed_controller_never_moves() {
        let mut c = BatchController::fixed(7);
        for _ in 0..10 {
            c.observe(1.0, 7);
        }
        assert_eq!(c.max_round(), 7);
        assert_eq!((c.grows, c.shrinks), (0, 0));
        assert_eq!(c.holds, 10);
    }

    /// A latency cliff (instantaneous wall far past target) halves the
    /// ceiling instead of stepping down by one — reaching the floor in
    /// O(log) rounds — while merely-over-target rounds keep the additive
    /// path (the pinned `shrinks_to_floor` trajectory feeds 5x target
    /// and must NOT halve).
    #[test]
    fn latency_spike_triggers_multiplicative_decrease() {
        let mut c = BatchController::adaptive(64, 1e-3);
        c.observe(20e-3, c.max_round()); // 20x target: spike
        assert_eq!(c.max_round(), 32, "halved, not stepped");
        assert_eq!((c.spikes, c.shrinks), (1, 1));
        c.observe(20e-3, c.max_round());
        c.observe(20e-3, c.max_round());
        assert_eq!(c.max_round(), 8, "64 -> 32 -> 16 -> 8 in three rounds");
        // merely over target (additive band): one step, no spike
        c.observe(2e-3, c.max_round());
        assert_eq!(c.max_round(), 7);
        assert_eq!(c.spikes, 3);
        // spikes respect the floor
        let mut f = BatchController::adaptive(2, 1e-3);
        f.observe(1.0, 2);
        f.observe(1.0, f.max_round().max(1));
        assert_eq!(f.max_round(), 1, "never below lo");
    }

    /// Unsaturated spikes still hold: a single stalled program does not
    /// indict the ceiling (same reasoning as the additive ratchet trap).
    #[test]
    fn unsaturated_spikes_do_not_halve() {
        let mut c = BatchController::adaptive(8, 1e-3);
        for _ in 0..10 {
            c.observe(1.0, 1);
        }
        assert_eq!(c.max_round(), 8);
        assert_eq!(c.spikes, 0);
    }

    /// Property: under a `heavy_tenant_scenario`-style flood (sustained
    /// saturated spikes of random magnitude), the controller collapses to
    /// the floor within O(log hi) rounds, and once the flood clears it
    /// recovers to the pre-flood ceiling in at most `hi` fast saturated
    /// rounds — bounded recovery, no sticky collapse.
    #[test]
    fn prop_spike_collapse_and_recovery_are_bounded() {
        #[derive(Clone, Debug)]
        struct Flood {
            hi: usize,
            spike_factor: f64,
            flood_rounds: usize,
        }
        impl Arbitrary for Flood {
            fn generate(rng: &mut Rng) -> Self {
                Flood {
                    hi: 2 + rng.below(63) as usize,
                    spike_factor: 9.0 + rng.below(100) as f64,
                    flood_rounds: 1 + rng.below(12) as usize,
                }
            }
        }

        Quick::with_cases(60).check::<Flood, _>("spike collapse/recovery", |f| {
            let target = 1e-3;
            let mut c = BatchController::adaptive(f.hi, target);
            // flood: every round saturated and spiking
            for _ in 0..f.flood_rounds {
                c.observe(f.spike_factor * target, c.max_round());
            }
            let collapse_budget = (f.hi as f64).log2().ceil() as usize + 1;
            if f.flood_rounds >= collapse_budget && c.max_round() != 1 {
                return false; // log-bounded collapse failed
            }
            // flood clears: fast saturated rounds (EWMA decays, then the
            // additive grow path climbs one step per round)
            let mut recovered_in = None;
            for round in 0..(f.hi + 40) {
                c.observe(0.1 * target, c.max_round());
                if c.max_round() == f.hi {
                    recovered_in = Some(round + 1);
                    break;
                }
            }
            match recovered_in {
                // a few EWMA-decay rounds, then one grow per round
                Some(n) => n <= f.hi + 40,
                None => false,
            }
        });
    }

    #[test]
    fn weights_scale_down_heavy_tenants() {
        use crate::metrics::LatencyHistogram;
        let mut lat: HashMap<usize, LatencyHistogram> = HashMap::new();
        for _ in 0..30 {
            lat.entry(0).or_default().record(1e-3);
        }
        for _ in 0..5 {
            lat.entry(1).or_default().record(1e-3);
        }
        lat.entry(2).or_default().record(1e-3);
        let mut win = ServiceWindow::new();
        let w = service_weights(&mut win, &lat, &HashMap::new());
        assert!(w[&0] < w[&1], "{w:?}");
        assert_eq!(w[&1], 1.0, "fair-share tenants keep full weight");
        assert_eq!(w[&2], 1.0);
        assert!(w[&0] >= 0.25, "clamped");
        // degenerate cases: empty and single-tenant maps are all-neutral
        assert!(service_weights(&mut ServiceWindow::new(), &HashMap::new(), &HashMap::new())
            .is_empty());
        let mut solo = HashMap::new();
        for _ in 0..9 {
            solo.entry(4usize).or_default().record(1e-3);
        }
        assert_eq!(service_weights(&mut ServiceWindow::new(), &solo, &HashMap::new())[&4], 1.0);
    }

    /// Regression for the lifetime-count bug: a tenant that WAS heavy
    /// but stops flooding must recover weight 1.0 as its window decays —
    /// cumulative history alone can never depress it again.
    #[test]
    fn reformed_heavy_tenant_recovers_full_weight() {
        use crate::metrics::LatencyHistogram;
        let mut lat: HashMap<usize, LatencyHistogram> = HashMap::new();
        let mut win = ServiceWindow::new();

        // round 1: tenant 0 floods (50 programs), tenant 1 serves 2
        for _ in 0..50 {
            lat.entry(0).or_default().record(1e-3);
        }
        for _ in 0..2 {
            lat.entry(1).or_default().record(1e-3);
        }
        let w = service_weights(&mut win, &lat, &HashMap::new());
        // fair share is 26 of 52; the flooder took 50 -> weight ~0.52
        assert!(w[&0] < 0.6, "flooding tenant is depressed: {w:?}");
        assert_eq!(w[&1], 1.0);

        // later rounds: both tenants serve 1 program each — the flood is
        // history, but the CUMULATIVE counts stay wildly lopsided (51+ vs
        // 3+); the lifetime-count bug kept tenant 0 at the floor forever
        let mut recovered = Vec::new();
        for _ in 0..12 {
            lat.entry(0).or_default().record(1e-3);
            lat.entry(1).or_default().record(1e-3);
            recovered = vec![service_weights(&mut win, &lat, &HashMap::new())];
        }
        let w = recovered.pop().unwrap();
        assert_eq!(w[&0], 1.0, "reformed tenant must recover full weight: {w:?}");
        assert_eq!(w[&1], 1.0);
    }

    /// The energy dimension: equal program counts but lopsided modeled
    /// energy scales the energy-heavy tenant down.
    #[test]
    fn energy_share_depresses_equal_program_tenants() {
        use crate::metrics::LatencyHistogram;
        let mut lat: HashMap<usize, LatencyHistogram> = HashMap::new();
        for t in 0..2usize {
            for _ in 0..4 {
                lat.entry(t).or_default().record(1e-3);
            }
        }
        let mut energy = HashMap::new();
        energy.insert(0usize, 100.0);
        energy.insert(1usize, 1.0);
        let w = service_weights(&mut ServiceWindow::new(), &lat, &energy);
        assert!(w[&0] < 1.0, "energy-dominant tenant is scaled down: {w:?}");
        assert_eq!(w[&1], 1.0, "light-energy tenant keeps full weight");
        // without the energy signal the same counts are perfectly fair
        let w = service_weights(&mut ServiceWindow::new(), &lat, &HashMap::new());
        assert_eq!((w[&0], w[&1]), (1.0, 1.0));
    }

    // ---- lifecycle sweep -------------------------------------------------

    #[test]
    fn sweep_removes_matches_and_preserves_survivor_order() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fair);
        for i in 0..6 {
            s.push(i % 2, i);
        }
        assert_eq!(s.tenant_backlog(0), 3);
        assert_eq!(s.tenant_backlog(1), 3);
        assert_eq!(s.active_tenants(), 2);

        let removed = s.sweep(|tenant, &item| tenant == 1 || item == 2);
        let mut gone: Vec<(usize, i32)> = removed;
        gone.sort();
        assert_eq!(gone, vec![(0, 2), (1, 1), (1, 3), (1, 5)]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.tenant_backlog(1), 0);
        assert_eq!(s.active_tenants(), 1);

        // survivors drain in their original FIFO order
        let round = s.next_round(8, |_| 1.0);
        assert_eq!(round.admitted, vec![0, 4]);
        assert!(s.is_empty());
    }

    #[test]
    fn sweep_of_nothing_is_a_noop() {
        let mut s = FairScheduler::new(AdmissionPolicy::Fifo);
        s.push(0, "a");
        s.push(0, "b");
        assert!(s.sweep(|_, _| false).is_empty());
        assert_eq!(s.len(), 2);
        assert_eq!(s.next_round(4, |_| 1.0).admitted, vec!["a", "b"]);
    }

    // ---- brownout ladder -------------------------------------------------

    #[test]
    fn degrade_ladder_steps_up_on_critical_and_walks_back_on_ok() {
        let mut d = DegradeController::new();
        assert_eq!(d.level(), DegradeLevel::Normal);
        assert!(!d.pin_routing() && d.sample_stride() == 1 && !d.shedding());
        assert_eq!(d.cache_cap_factor(), super::super::cache::ENTRY_CAP_FACTOR);

        // each committed critical climbs exactly one step
        let up: Vec<_> = (0..6).filter_map(|_| d.on_health(RuleState::Critical)).collect();
        assert_eq!(
            up,
            vec![
                (DegradeLevel::Normal, DegradeLevel::PinRouting),
                (DegradeLevel::PinRouting, DegradeLevel::WidenCache),
                (DegradeLevel::WidenCache, DegradeLevel::ReduceSampling),
                (DegradeLevel::ReduceSampling, DegradeLevel::Shed),
            ],
            "the ladder saturates at Shed"
        );
        assert_eq!(d.step_ups, 4);
        assert!(d.pin_routing() && d.shedding());
        assert_eq!(d.sample_stride(), 4);
        assert_eq!(d.cache_cap_factor(), super::super::cache::ENTRY_CAP_FACTOR * 4);

        // warn holds the current level (hysteresis band)
        assert_eq!(d.on_health(RuleState::Warn), None);
        assert_eq!(d.level(), DegradeLevel::Shed);

        // each committed ok walks exactly one step back down
        let down: Vec<_> = (0..6).filter_map(|_| d.on_health(RuleState::Ok)).collect();
        assert_eq!(down.len(), 4, "walk-back retraces the ladder: {down:?}");
        assert_eq!(down[3], (DegradeLevel::PinRouting, DegradeLevel::Normal));
        assert_eq!(d.step_downs, 4);
        assert_eq!(d.level(), DegradeLevel::Normal);
        assert!(!d.pin_routing());
    }

    // ---- circuit breaker -------------------------------------------------

    #[test]
    fn breaker_open_half_open_close_trajectory() {
        let mut b = CircuitBreaker::new(2, 3, 2);
        assert_eq!(b.state(0), BreakerState::Closed);
        assert!(!b.any_open());

        // two failures stay under threshold; a success resets the streak
        assert_eq!(b.record_failure(0), None);
        assert_eq!(b.record_failure(0), None);
        assert_eq!(b.record_success(0), None, "closed stays closed");
        assert_eq!(b.record_failure(0), None);
        assert_eq!(b.record_failure(0), None);
        // third CONSECUTIVE failure trips the breaker
        assert_eq!(b.record_failure(0), Some((BreakerState::Closed, BreakerState::Open)));
        assert!(b.is_open(0) && b.any_open());
        assert_eq!(b.opens, 1);
        assert_eq!(b.state(1), BreakerState::Closed, "other shards are untouched");

        // probe cadence counts scheduling passes, not rounds
        assert_eq!(b.due_probes(), Vec::<usize>::new(), "pass 1 of 2: still open");
        assert!(b.is_open(0));
        assert_eq!(b.due_probes(), vec![0], "pass 2: half-open, probe owed");
        assert_eq!(b.state(0), BreakerState::HalfOpen);
        assert!(!b.is_open(0), "half-open admits the probe, not a rejection");

        // failed probe re-opens; the next successful one closes
        assert_eq!(b.record_failure(0), Some((BreakerState::HalfOpen, BreakerState::Open)));
        assert_eq!(b.opens, 2);
        assert_eq!(b.due_probes(), Vec::<usize>::new());
        assert_eq!(b.due_probes(), vec![0]);
        assert_eq!(b.record_success(0), Some((BreakerState::HalfOpen, BreakerState::Closed)));
        assert_eq!(b.closes, 1);
        assert_eq!(b.state(0), BreakerState::Closed);
        assert!(!b.any_open());
    }

    #[test]
    fn breaker_threshold_zero_disables_it() {
        let mut b = CircuitBreaker::new(1, 0, 1);
        for _ in 0..10 {
            assert_eq!(b.record_failure(0), None);
        }
        assert_eq!(b.state(0), BreakerState::Closed);
        assert_eq!(b.opens, 0);
    }

    #[test]
    fn breaker_out_of_range_shard_reads_closed() {
        let mut b = CircuitBreaker::new(1, 1, 1);
        assert_eq!(b.state(7), BreakerState::Closed);
        assert!(!b.is_open(7));
        assert_eq!(b.record_failure(7), None);
        assert_eq!(b.record_success(7), None);
    }
}
