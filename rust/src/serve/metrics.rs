//! Serving-layer observability: admission/round counters, coalescing and
//! fusion effectiveness, cache hit rate, and per-tenant wall latency.

use std::collections::HashMap;

use crate::metrics::LatencyHistogram;

/// Counters the `ServeQueue` scheduler maintains across rounds.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Programs admitted and answered.
    pub programs: u64,
    /// Coalescing rounds executed.
    pub rounds: u64,
    /// Largest round (programs found queued at a round start — the
    /// observed queue depth).
    pub max_round_occupancy: u64,
    /// Lowered ops across all programs before dedup/caching.
    pub submitted_ops: u64,
    /// Ops actually shipped to the worker pool.
    pub coalesced_ops: u64,
    /// Writes dropped because the masked contents were already stored.
    pub skipped_writes: u64,
    /// Query steps answered from the result cache.
    pub cached_steps: u64,
    /// Query steps that missed the cache (and were memoized).
    pub cache_misses: u64,
    /// Dual-row ops shipped (fusion candidates).
    pub dual_ops: u64,
    /// Asymmetric activations the fused batches issue.
    pub activations: u64,
    /// Dual ops served as followers of an already-latched activation.
    pub fused_followers: u64,
    /// Followers riding an activation opened by a DIFFERENT program.
    pub cross_program_fused_ops: u64,
    /// Content-changing record writes (each strands overlapping cache
    /// entries).
    pub invalidating_writes: u64,
    /// Times a tenant exhausted its per-round fair-share quota while
    /// still holding pending programs (the dominance WFQ caps).
    pub quota_hits: u64,
    /// Programs left pending when a round's admission closed, summed
    /// over rounds (how much the fairness policy deferred).
    pub deferred_programs: u64,
    /// Adaptive `max_round` controller decisions.
    pub controller_grows: u64,
    pub controller_shrinks: u64,
    pub controller_holds: u64,
    /// The controller's current round-size ceiling.
    pub current_max_round: u64,
    /// Live cache entries evicted in LRU order under capacity pressure.
    pub cache_evictions: u64,
    /// Stale cache entries reclaimed by the pre-eviction sweep.
    pub cache_swept: u64,
    /// Cache hits served by zero-weight negative (empty-filter) entries.
    pub negative_hits: u64,
    /// Engine-level dual activations across all shards (snapshot of the
    /// pool's `RunMetrics::array` at the last round).
    pub array_dual_activations: u64,
    /// Of those, activations served entirely by the bit-packed digital
    /// tier.
    pub array_digital_activations: u64,
    /// Activations served by the masked packed path under variation.
    pub array_masked_activations: u64,
    /// Columns served straight from the packed planes (deterministic).
    pub array_det_cols: u64,
    /// Columns the masked path routed through the analog pipeline.
    pub array_marginal_cols: u64,
    /// Digital-vs-analog cross-validation mismatches (must stay 0).
    pub array_xval_mismatches: u64,
    /// Submission-to-reply wall latency per tenant.
    pub tenant_latency: HashMap<usize, LatencyHistogram>,
}

impl ServeMetrics {
    pub fn record_latency(&mut self, tenant: usize, seconds: f64) {
        self.tenant_latency.entry(tenant).or_default().record(seconds);
    }

    /// Mean programs per round.
    pub fn batch_occupancy(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.programs as f64 / self.rounds as f64
        }
    }

    /// Fraction of query steps answered from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cached_steps + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cached_steps as f64 / total as f64
        }
    }

    /// Fraction of shipped dual ops served as fusion followers.
    pub fn fused_share(&self) -> f64 {
        if self.dual_ops == 0 {
            0.0
        } else {
            self.fused_followers as f64 / self.dual_ops as f64
        }
    }

    /// Fraction of packed-path columns served deterministically —
    /// delegates to `ArrayStats::det_col_fraction` so the empty-trajectory
    /// convention lives in one place.
    pub fn array_det_fraction(&self) -> f64 {
        crate::array::ArrayStats {
            det_cols: self.array_det_cols,
            marginal_cols: self.array_marginal_cols,
            ..Default::default()
        }
        .det_col_fraction()
    }

    /// Single-line counter summary (REPL `stats` prints this).
    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: {} programs / {} rounds (occupancy {:.2}, max {}), \
             {}/{} ops shipped ({} writes deduped), \
             {} activations for {} dual ops (fused share {:.1}%, {} cross-program), \
             cache {} hits / {} misses ({:.1}% hit rate, {} negative hits, \
             {} evictions, {} swept), {} invalidating writes, \
             fairness {} quota hits / {} deferrals, \
             controller max_round {} ({}+ {}- {}=), \
             tiered kernel {}/{} activations digital + {} masked \
             (det-col fraction {:.1}%, {} xval mismatches)",
            self.programs,
            self.rounds,
            self.batch_occupancy(),
            self.max_round_occupancy,
            self.coalesced_ops,
            self.submitted_ops,
            self.skipped_writes,
            self.activations,
            self.dual_ops,
            self.fused_share() * 100.0,
            self.cross_program_fused_ops,
            self.cached_steps,
            self.cache_misses,
            self.cache_hit_rate() * 100.0,
            self.negative_hits,
            self.cache_evictions,
            self.cache_swept,
            self.invalidating_writes,
            self.quota_hits,
            self.deferred_programs,
            self.current_max_round,
            self.controller_grows,
            self.controller_shrinks,
            self.controller_holds,
            self.array_digital_activations,
            self.array_dual_activations,
            self.array_masked_activations,
            self.array_det_fraction() * 100.0,
            self.array_xval_mismatches,
        )
    }

    /// p95 wall latency (ns) over every tenant EXCEPT `tenant` — the
    /// fairness yardstick: what the heavy tenant's neighbors experience.
    pub fn p95_ns_excluding(&self, tenant: usize) -> f64 {
        let mut merged = LatencyHistogram::default();
        for (t, h) in &self.tenant_latency {
            if *t != tenant {
                merged.merge(h);
            }
        }
        merged.percentile_ns(95.0)
    }

    /// Per-tenant latency lines (tenant id ascending), for the example
    /// and bench reports.
    pub fn tenant_report(&self) -> Vec<String> {
        let mut tenants: Vec<_> = self.tenant_latency.iter().collect();
        tenants.sort_by_key(|(t, _)| **t);
        tenants
            .into_iter()
            .map(|(t, h)| {
                format!(
                    "tenant {t}: {} programs, wall p50/p95/p99 {:.1}/{:.1}/{:.1} us (mean {:.1} us)",
                    h.count(),
                    h.percentile_ns(50.0) / 1e3,
                    h.percentile_ns(95.0) / 1e3,
                    h.percentile_ns(99.0) / 1e3,
                    h.mean_ns() / 1e3,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_rates() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.batch_occupancy(), 0.0);
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert_eq!(m.fused_share(), 0.0);
        m.programs = 12;
        m.rounds = 4;
        m.cached_steps = 3;
        m.cache_misses = 1;
        m.dual_ops = 10;
        m.fused_followers = 5;
        assert!((m.batch_occupancy() - 3.0).abs() < 1e-12);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.fused_share() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reports_are_informative() {
        let mut m = ServeMetrics::default();
        m.programs = 2;
        m.rounds = 1;
        m.quota_hits = 3;
        m.deferred_programs = 4;
        m.current_max_round = 9;
        m.cache_evictions = 5;
        m.negative_hits = 1;
        m.array_dual_activations = 12;
        m.array_digital_activations = 11;
        m.array_masked_activations = 6;
        m.array_det_cols = 90;
        m.array_marginal_cols = 10;
        m.record_latency(7, 3e-6);
        m.record_latency(7, 5e-6);
        let r = m.report("serve");
        assert!(r.contains("2 programs"));
        assert!(r.contains("hit rate"));
        assert!(r.contains("3 quota hits / 4 deferrals"), "{r}");
        assert!(r.contains("controller max_round 9"), "{r}");
        assert!(r.contains("5 evictions"), "{r}");
        assert!(r.contains("1 negative hits"), "{r}");
        assert!(r.contains("tiered kernel 11/12 activations digital"), "{r}");
        assert!(r.contains("6 masked"), "{r}");
        assert!(r.contains("det-col fraction 90.0%"), "{r}");
        assert!((m.array_det_fraction() - 0.9).abs() < 1e-12);
        let t = m.tenant_report();
        assert_eq!(t.len(), 1);
        assert!(t[0].starts_with("tenant 7: 2 programs"));
    }

    #[test]
    fn p95_excluding_merges_only_other_tenants() {
        let mut m = ServeMetrics::default();
        // tenant 0 (the heavy one): slow; tenants 1, 2: fast
        for _ in 0..20 {
            m.record_latency(0, 1e-3);
            m.record_latency(1, 1e-6);
            m.record_latency(2, 2e-6);
        }
        let without_heavy = m.p95_ns_excluding(0);
        let with_heavy = m.p95_ns_excluding(9); // 9 never served: merge all
        assert!(without_heavy < 1e5, "{without_heavy}");
        assert!(with_heavy > 1e5, "{with_heavy}");
        assert_eq!(m.p95_ns_excluding(0), without_heavy, "deterministic");
    }
}
