//! Serving-layer observability: admission/round counters, coalescing and
//! fusion effectiveness, cache hit rate, and per-tenant wall latency.
//!
//! `ServeMetrics` stays the scheduler's in-process accumulator (cheap to
//! clone, rendered by `report`); [`ServeMetrics::publish`] mirrors it
//! into the `observe` registry as the `adra.serve.*` families, which is
//! what the Prometheus exposition scrapes.  All accumulation saturates
//! at `u64::MAX` — overflow hygiene for soak runs (see the
//! `u64::MAX`-vicinity test).

use std::collections::HashMap;

use crate::array::ArrayStats;
use crate::metrics::LatencyHistogram;
use crate::observe::Registry;

use super::coalesce::RoundStats;

#[inline]
fn sat(counter: &mut u64, n: u64) {
    *counter = counter.saturating_add(n);
}

/// Counters the `ServeQueue` scheduler maintains across rounds.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Programs admitted and answered.
    pub programs: u64,
    /// Coalescing rounds executed.
    pub rounds: u64,
    /// Largest round (programs found queued at a round start — the
    /// observed queue depth).
    pub max_round_occupancy: u64,
    /// Lowered ops across all programs before dedup/caching.
    pub submitted_ops: u64,
    /// Ops actually shipped to the worker pool.
    pub coalesced_ops: u64,
    /// Writes dropped because the masked contents were already stored.
    pub skipped_writes: u64,
    /// Query steps answered from the result cache.
    pub cached_steps: u64,
    /// Query steps that missed the cache (and were memoized).
    pub cache_misses: u64,
    /// Dual-row ops shipped (fusion candidates).
    pub dual_ops: u64,
    /// Asymmetric activations the fused batches issue.
    pub activations: u64,
    /// Dual ops served as followers of an already-latched activation.
    pub fused_followers: u64,
    /// Followers riding an activation opened by a DIFFERENT program.
    pub cross_program_fused_ops: u64,
    /// Content-changing record writes (each strands overlapping cache
    /// entries).
    pub invalidating_writes: u64,
    /// Times a tenant exhausted its per-round fair-share quota while
    /// still holding pending programs (the dominance WFQ caps).
    pub quota_hits: u64,
    /// Programs left pending when a round's admission closed, summed
    /// over rounds (how much the fairness policy deferred).
    pub deferred_programs: u64,
    /// Adaptive `max_round` controller decisions.
    pub controller_grows: u64,
    pub controller_shrinks: u64,
    pub controller_holds: u64,
    /// The controller's current round-size ceiling.
    pub current_max_round: u64,
    /// Live cache entries evicted in LRU order under capacity pressure.
    pub cache_evictions: u64,
    /// Stale cache entries reclaimed by the pre-eviction sweep.
    pub cache_swept: u64,
    /// Cache hits served by zero-weight negative (empty-filter) entries.
    pub negative_hits: u64,
    /// Engine-level dual activations across all shards (snapshot of the
    /// pool's `RunMetrics::array` at the last round).
    pub array_dual_activations: u64,
    /// Of those, activations served entirely by the bit-packed digital
    /// tier.
    pub array_digital_activations: u64,
    /// Activations served by the masked packed path under variation.
    pub array_masked_activations: u64,
    /// Columns served straight from the packed planes (deterministic).
    pub array_det_cols: u64,
    /// Columns the masked path routed through the analog pipeline.
    pub array_marginal_cols: u64,
    /// Digital-vs-analog cross-validation mismatches (must stay 0).
    pub array_xval_mismatches: u64,
    /// Times the scheduler rebuilt serving state from the durable store
    /// (startup WAL replay or an explicit `restore`).
    pub recoveries: u64,
    /// Route-error retries issued (respawn + replay + re-dispatch
    /// attempts, successful or not).
    pub route_retries: u64,
    /// Shard batches that failed with a route error and were recovered
    /// by the retry path within the same round.
    pub recovered_shards: u64,
    /// Hot-row migrations the wear-aware placement performed.
    pub wear_migrations: u64,
    /// Workers respawned after death (snapshot of the pool's counter).
    pub worker_respawns: u64,
    /// Controller multiplicative decreases triggered by latency spikes
    /// (snapshot of the controller's counter; subset of
    /// `controller_shrinks`).
    pub spike_shrinks: u64,
    /// Admissions rejected outright by load shedding (backlog bound or
    /// the brownout ladder's shed step) — `Rejected(Overloaded)`.
    pub shed: u64,
    /// Programs answered `DeadlineExceeded` by the lifecycle sweep; none
    /// of them reached the array.
    pub deadline_expired: u64,
    /// Programs answered `Cancelled` (swept while queued, or abandoned
    /// cooperatively in flight).
    pub cancelled: u64,
    /// Placements refused because a needed shard's circuit breaker was
    /// open — `Rejected(ShardDown)`.
    pub breaker_rejected: u64,
    /// Circuit-breaker open transitions (snapshot of the breaker).
    pub breaker_opens: u64,
    /// Circuit-breaker close transitions (snapshot of the breaker).
    pub breaker_closes: u64,
    /// Brownout ladder step-ups / walk-backs (snapshots of the
    /// `DegradeController`).
    pub degrade_step_ups: u64,
    pub degrade_step_downs: u64,
    /// Current brownout level (0 normal … 4 shed; gauge snapshot).
    pub degrade_level: u64,
    /// Submission-to-reply wall latency per tenant.
    pub tenant_latency: HashMap<usize, LatencyHistogram>,
    /// Cumulative modeled (calibrated) energy charged per tenant — the
    /// second service dimension `service_weights` windows.
    pub tenant_energy: HashMap<usize, f64>,
}

impl ServeMetrics {
    pub fn record_latency(&mut self, tenant: usize, seconds: f64) {
        self.tenant_latency.entry(tenant).or_default().record(seconds);
    }

    /// Fold one served program into the tenant's latency histogram AND
    /// its cumulative modeled-energy total.
    pub fn record_service(&mut self, tenant: usize, seconds: f64, energy: f64) {
        self.record_latency(tenant, seconds);
        *self.tenant_energy.entry(tenant).or_insert(0.0) += energy.max(0.0);
    }

    /// Fold one executed round into the counters (saturating).
    pub fn observe_round(
        &mut self,
        occupancy: u64,
        st: &RoundStats,
        quota_hits: u64,
        deferred: u64,
    ) {
        sat(&mut self.rounds, 1);
        sat(&mut self.programs, occupancy);
        self.max_round_occupancy = self.max_round_occupancy.max(occupancy);
        sat(&mut self.submitted_ops, st.submitted_ops);
        sat(&mut self.coalesced_ops, st.coalesced_ops);
        sat(&mut self.skipped_writes, st.skipped_writes);
        sat(&mut self.cached_steps, st.cached_steps);
        sat(&mut self.cache_misses, st.cache_misses);
        sat(&mut self.negative_hits, st.negative_hits);
        sat(&mut self.dual_ops, st.dual_ops);
        sat(&mut self.activations, st.activations);
        sat(&mut self.fused_followers, st.fused_followers);
        sat(&mut self.cross_program_fused_ops, st.cross_program_fused_ops);
        sat(&mut self.quota_hits, quota_hits);
        sat(&mut self.deferred_programs, deferred);
    }

    /// Snapshot the batch controller's cumulative decision counters.
    pub fn observe_controller(&mut self, grows: u64, shrinks: u64, holds: u64, max_round: u64) {
        self.controller_grows = grows;
        self.controller_shrinks = shrinks;
        self.controller_holds = holds;
        self.current_max_round = max_round;
    }

    /// Snapshot the engine-level per-tier activation split from the
    /// pool's cumulative `ArrayStats`.
    pub fn observe_array(&mut self, array: &ArrayStats) {
        self.array_dual_activations = array.dual_activations;
        self.array_digital_activations = array.digital_activations;
        self.array_masked_activations = array.masked_activations;
        self.array_det_cols = array.det_cols;
        self.array_marginal_cols = array.marginal_cols;
        self.array_xval_mismatches = array.xval_mismatches;
    }

    /// Mirror the counters into the registry as the `adra.serve.*`
    /// families, labeled by queue instance.  Counters ratchet
    /// (`set_at_least`) against this struct's cumulative totals, so the
    /// publish is idempotent and exposition counters stay monotone; the
    /// kernel-tier `array_*` snapshot is NOT published here — the
    /// scheduler publishes the pool's `RunMetrics` (same source) into
    /// the `adra.run.*` / `adra.array.*` families instead.
    pub fn publish(&self, reg: &Registry, queue: &str) {
        let l: [(&str, &str); 1] = [("queue", queue)];
        for (name, help, value) in [
            ("adra.serve.programs", "Programs admitted and answered.", self.programs),
            ("adra.serve.rounds", "Coalescing rounds executed.", self.rounds),
            ("adra.serve.submitted_ops", "Lowered ops before dedup/caching.", self.submitted_ops),
            ("adra.serve.coalesced_ops", "Ops shipped to the worker pool.", self.coalesced_ops),
            ("adra.serve.skipped_writes", "Writes dropped by content dedup.", self.skipped_writes),
            ("adra.serve.cached_steps", "Query steps answered from the result cache.", self.cached_steps),
            ("adra.serve.cache_misses", "Query steps that missed the cache.", self.cache_misses),
            ("adra.serve.negative_hits", "Cache hits served by negative (empty-filter) entries.", self.negative_hits),
            ("adra.serve.dual_ops", "Dual-row ops shipped (fusion candidates).", self.dual_ops),
            ("adra.serve.fused_activations", "Asymmetric activations issued by fused batches.", self.activations),
            ("adra.serve.fused_followers", "Dual ops served as followers of a latched activation.", self.fused_followers),
            ("adra.serve.cross_program_fused_ops", "Followers riding another program's activation.", self.cross_program_fused_ops),
            ("adra.serve.invalidating_writes", "Content-changing record writes.", self.invalidating_writes),
            ("adra.serve.quota_hits", "Rounds where a tenant exhausted its fair-share quota.", self.quota_hits),
            ("adra.serve.deferred_programs", "Programs left pending at round admission close.", self.deferred_programs),
            ("adra.serve.controller_grows", "Adaptive max_round grow decisions.", self.controller_grows),
            ("adra.serve.controller_shrinks", "Adaptive max_round shrink decisions.", self.controller_shrinks),
            ("adra.serve.controller_holds", "Adaptive max_round hold decisions.", self.controller_holds),
            ("adra.serve.cache_evictions", "Live cache entries evicted under pressure.", self.cache_evictions),
            ("adra.serve.cache_swept", "Stale cache entries reclaimed by the sweep.", self.cache_swept),
            ("adra.serve.recoveries", "Serving-state rebuilds from the durable store.", self.recoveries),
            ("adra.serve.route_retries", "Route-error retry attempts (respawn + replay).", self.route_retries),
            ("adra.serve.recovered_shards", "Shard batches recovered by the retry path.", self.recovered_shards),
            ("adra.serve.wear_migrations", "Hot-row migrations by wear-aware placement.", self.wear_migrations),
            ("adra.serve.worker_respawns", "Workers respawned after death.", self.worker_respawns),
            ("adra.serve.spike_shrinks", "Controller multiplicative decreases on latency spikes.", self.spike_shrinks),
            ("adra.serve.shed", "Admissions rejected outright by load shedding.", self.shed),
            ("adra.serve.deadline_expired", "Programs answered DeadlineExceeded before execution.", self.deadline_expired),
            ("adra.serve.cancelled", "Programs answered Cancelled (swept or abandoned in flight).", self.cancelled),
            ("adra.serve.breaker_rejected", "Placements refused on an open circuit breaker.", self.breaker_rejected),
            ("adra.serve.breaker_opens", "Circuit-breaker open transitions.", self.breaker_opens),
            ("adra.serve.breaker_closes", "Circuit-breaker close transitions.", self.breaker_closes),
            ("adra.serve.degrade_step_ups", "Brownout ladder step-ups.", self.degrade_step_ups),
            ("adra.serve.degrade_step_downs", "Brownout ladder walk-backs.", self.degrade_step_downs),
        ] {
            reg.counter(name, help, &l).set_at_least(value);
        }
        // max occupancy is a running maximum: ratchet so concurrent
        // publishers can never move it backwards
        reg.gauge(
            "adra.serve.max_round_occupancy",
            "Largest observed round occupancy.",
            &l,
        )
        .set_at_least(self.max_round_occupancy as f64);
        for (name, help, value) in [
            ("adra.serve.current_max_round", "The controller's current round-size ceiling.", self.current_max_round as f64),
            ("adra.serve.batch_occupancy", "Mean programs per round.", self.batch_occupancy()),
            ("adra.serve.cache_hit_rate", "Fraction of query steps answered from the cache.", self.cache_hit_rate()),
            ("adra.serve.fused_share", "Fraction of shipped dual ops served as followers.", self.fused_share()),
            ("adra.serve.deferral_ratio", "Deferred programs per admitted program (quota starvation signal).", self.deferral_ratio()),
            ("adra.serve.degrade_level", "Current brownout level (0 normal .. 4 shed).", self.degrade_level as f64),
        ] {
            reg.gauge(name, help, &l).set(value);
        }
        for (tenant, h) in &self.tenant_latency {
            let t = tenant.to_string();
            reg.histogram(
                "adra.serve.tenant_wall_ns",
                "Submission-to-reply wall latency per tenant (ns).",
                &[("queue", queue), ("tenant", &t)],
            )
            .set_to_snapshot(h);
        }
        for (tenant, e) in &self.tenant_energy {
            let t = tenant.to_string();
            reg.gauge(
                "adra.serve.tenant_energy",
                "Cumulative modeled (calibrated) energy charged per tenant.",
                &[("queue", queue), ("tenant", &t)],
            )
            .set(*e);
        }
    }

    /// Mean programs per round.
    pub fn batch_occupancy(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.programs as f64 / self.rounds as f64
        }
    }

    /// Fraction of query steps answered from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cached_steps + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cached_steps as f64 / total as f64
        }
    }

    /// Fraction of shipped dual ops served as fusion followers.
    pub fn fused_share(&self) -> f64 {
        if self.dual_ops == 0 {
            0.0
        } else {
            self.fused_followers as f64 / self.dual_ops as f64
        }
    }

    /// Deferred programs per admitted program — the quota-starvation
    /// signal the health engine watches (> 1 means the backlog defers
    /// more work each round than it serves).
    pub fn deferral_ratio(&self) -> f64 {
        if self.programs == 0 {
            0.0
        } else {
            self.deferred_programs as f64 / self.programs as f64
        }
    }

    /// Fraction of packed-path columns served deterministically —
    /// delegates to `ArrayStats::det_col_fraction` so the empty-trajectory
    /// convention lives in one place.
    pub fn array_det_fraction(&self) -> f64 {
        crate::array::ArrayStats {
            det_cols: self.array_det_cols,
            marginal_cols: self.array_marginal_cols,
            ..Default::default()
        }
        .det_col_fraction()
    }

    /// Single-line counter summary (REPL `stats` prints this).
    pub fn report(&self, label: &str) -> String {
        format!(
            "{label}: {} programs / {} rounds (occupancy {:.2}, max {}), \
             {}/{} ops shipped ({} writes deduped), \
             {} activations for {} dual ops (fused share {:.1}%, {} cross-program), \
             cache {} hits / {} misses ({:.1}% hit rate, {} negative hits, \
             {} evictions, {} swept), {} invalidating writes, \
             fairness {} quota hits / {} deferrals, \
             controller max_round {} ({}+ {}- {}= {}spike), \
             robustness {} recoveries / {} respawns / {} retries \
             ({} shards recovered, {} wear migrations), \
             lifecycle {} shed / {} expired / {} cancelled, \
             breaker {} opens / {} closes ({} rejected), \
             degrade level {} ({}^ {}v), \
             tiered kernel {}/{} activations digital + {} masked \
             (det-col fraction {:.1}%, {} xval mismatches)",
            self.programs,
            self.rounds,
            self.batch_occupancy(),
            self.max_round_occupancy,
            self.coalesced_ops,
            self.submitted_ops,
            self.skipped_writes,
            self.activations,
            self.dual_ops,
            self.fused_share() * 100.0,
            self.cross_program_fused_ops,
            self.cached_steps,
            self.cache_misses,
            self.cache_hit_rate() * 100.0,
            self.negative_hits,
            self.cache_evictions,
            self.cache_swept,
            self.invalidating_writes,
            self.quota_hits,
            self.deferred_programs,
            self.current_max_round,
            self.controller_grows,
            self.controller_shrinks,
            self.controller_holds,
            self.spike_shrinks,
            self.recoveries,
            self.worker_respawns,
            self.route_retries,
            self.recovered_shards,
            self.wear_migrations,
            self.shed,
            self.deadline_expired,
            self.cancelled,
            self.breaker_opens,
            self.breaker_closes,
            self.breaker_rejected,
            self.degrade_level,
            self.degrade_step_ups,
            self.degrade_step_downs,
            self.array_digital_activations,
            self.array_dual_activations,
            self.array_masked_activations,
            self.array_det_fraction() * 100.0,
            self.array_xval_mismatches,
        )
    }

    /// p95 wall latency (ns) over every tenant EXCEPT `tenant` — the
    /// fairness yardstick: what the heavy tenant's neighbors experience.
    pub fn p95_ns_excluding(&self, tenant: usize) -> f64 {
        let mut merged = LatencyHistogram::default();
        for (t, h) in &self.tenant_latency {
            if *t != tenant {
                merged.merge(h);
            }
        }
        merged.percentile_ns(95.0)
    }

    /// Per-tenant latency lines (tenant id ascending), for the example
    /// and bench reports.
    pub fn tenant_report(&self) -> Vec<String> {
        let mut tenants: Vec<_> = self.tenant_latency.iter().collect();
        tenants.sort_by_key(|(t, _)| **t);
        tenants
            .into_iter()
            .map(|(t, h)| {
                format!(
                    "tenant {t}: {} programs, wall p50/p95/p99 {:.1}/{:.1}/{:.1} us (mean {:.1} us)",
                    h.count(),
                    h.percentile_ns(50.0) / 1e3,
                    h.percentile_ns(95.0) / 1e3,
                    h.percentile_ns(99.0) / 1e3,
                    h.mean_ns() / 1e3,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_rates() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.batch_occupancy(), 0.0);
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert_eq!(m.fused_share(), 0.0);
        m.programs = 12;
        m.rounds = 4;
        m.cached_steps = 3;
        m.cache_misses = 1;
        m.dual_ops = 10;
        m.fused_followers = 5;
        m.deferred_programs = 18;
        assert!((m.batch_occupancy() - 3.0).abs() < 1e-12);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.fused_share() - 0.5).abs() < 1e-12);
        assert!((m.deferral_ratio() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn reports_are_informative() {
        let mut m = ServeMetrics::default();
        m.programs = 2;
        m.rounds = 1;
        m.quota_hits = 3;
        m.deferred_programs = 4;
        m.current_max_round = 9;
        m.cache_evictions = 5;
        m.negative_hits = 1;
        m.array_dual_activations = 12;
        m.array_digital_activations = 11;
        m.array_masked_activations = 6;
        m.array_det_cols = 90;
        m.array_marginal_cols = 10;
        m.record_latency(7, 3e-6);
        m.record_latency(7, 5e-6);
        let r = m.report("serve");
        assert!(r.contains("2 programs"));
        assert!(r.contains("hit rate"));
        assert!(r.contains("3 quota hits / 4 deferrals"), "{r}");
        assert!(r.contains("controller max_round 9"), "{r}");
        assert!(r.contains("5 evictions"), "{r}");
        assert!(r.contains("1 negative hits"), "{r}");
        assert!(r.contains("tiered kernel 11/12 activations digital"), "{r}");
        assert!(r.contains("6 masked"), "{r}");
        assert!(r.contains("det-col fraction 90.0%"), "{r}");
        assert!((m.array_det_fraction() - 0.9).abs() < 1e-12);
        let t = m.tenant_report();
        assert_eq!(t.len(), 1);
        assert!(t[0].starts_with("tenant 7: 2 programs"));
    }

    /// Overflow hygiene: round accumulation at the u64::MAX vicinity
    /// clamps instead of panicking in debug builds (soak runs).
    #[test]
    fn observe_round_saturates_at_u64_max() {
        let mut m = ServeMetrics::default();
        m.programs = u64::MAX - 1;
        m.submitted_ops = u64::MAX;
        m.rounds = u64::MAX;
        let st = RoundStats {
            submitted_ops: 100,
            coalesced_ops: 90,
            dual_ops: 5,
            ..Default::default()
        };
        m.observe_round(8, &st, u64::MAX, 3);
        m.observe_round(8, &st, u64::MAX, 3); // second round: everything clamped
        assert_eq!(m.programs, u64::MAX);
        assert_eq!(m.submitted_ops, u64::MAX);
        assert_eq!(m.rounds, u64::MAX);
        assert_eq!(m.quota_hits, u64::MAX);
        assert_eq!(m.coalesced_ops, 180, "unclamped counters still accumulate");
        assert_eq!(m.deferred_programs, 6);
    }

    #[test]
    fn publish_mirrors_counters_into_registry() {
        let reg = crate::observe::Registry::new();
        let mut m = ServeMetrics::default();
        let st = RoundStats {
            submitted_ops: 10,
            coalesced_ops: 7,
            cached_steps: 3,
            cache_misses: 1,
            ..Default::default()
        };
        m.observe_round(2, &st, 1, 4);
        m.observe_controller(5, 2, 9, 16);
        m.recoveries = 1;
        m.worker_respawns = 2;
        m.wear_migrations = 3;
        m.record_service(3, 2e-6, 1.5);
        m.record_service(3, 2e-6, 1.0);
        assert_eq!(m.tenant_latency[&3].count(), 2);
        assert!((m.tenant_energy[&3] - 2.5).abs() < 1e-12);
        m.publish(&reg, "0");
        m.publish(&reg, "0"); // idempotent: totals unchanged
        let text = crate::observe::expose_text(&reg);
        assert!(text.contains("adra_serve_programs{queue=\"0\"} 2"), "{text}");
        assert!(text.contains("adra_serve_rounds{queue=\"0\"} 1"), "{text}");
        assert!(text.contains("adra_serve_submitted_ops{queue=\"0\"} 10"), "{text}");
        assert!(text.contains("adra_serve_quota_hits{queue=\"0\"} 1"), "{text}");
        assert!(text.contains("adra_serve_controller_grows{queue=\"0\"} 5"), "{text}");
        assert!(text.contains("adra_serve_recoveries{queue=\"0\"} 1"), "{text}");
        assert!(text.contains("adra_serve_worker_respawns{queue=\"0\"} 2"), "{text}");
        assert!(text.contains("adra_serve_wear_migrations{queue=\"0\"} 3"), "{text}");
        assert!(text.contains("adra_serve_current_max_round{queue=\"0\"} 16"), "{text}");
        assert!(text.contains("adra_serve_cache_hit_rate{queue=\"0\"} 0.75"), "{text}");
        assert!(text.contains("adra_serve_deferral_ratio{queue=\"0\"} 2"), "{text}");
        // the occupancy ratchet survives a stale publisher
        let stale = ServeMetrics::default();
        stale.publish(&reg, "0");
        let text = crate::observe::expose_text(&reg);
        assert!(text.contains("adra_serve_max_round_occupancy{queue=\"0\"} 2"), "{text}");
        assert!(
            text.contains("adra_serve_tenant_wall_ns_count{queue=\"0\",tenant=\"3\"} 2"),
            "{text}"
        );
        assert!(
            text.contains("adra_serve_tenant_energy{queue=\"0\",tenant=\"3\"} 2.5"),
            "{text}"
        );
    }

    #[test]
    fn lifecycle_counters_reach_report_and_registry() {
        let reg = crate::observe::Registry::new();
        let mut m = ServeMetrics::default();
        m.shed = 4;
        m.deadline_expired = 3;
        m.cancelled = 2;
        m.breaker_rejected = 5;
        m.breaker_opens = 2;
        m.breaker_closes = 1;
        m.degrade_level = 3;
        m.degrade_step_ups = 3;
        m.degrade_step_downs = 1;
        let r = m.report("serve");
        assert!(r.contains("lifecycle 4 shed / 3 expired / 2 cancelled"), "{r}");
        assert!(r.contains("breaker 2 opens / 1 closes (5 rejected)"), "{r}");
        assert!(r.contains("degrade level 3 (3^ 1v)"), "{r}");
        m.publish(&reg, "0");
        let text = crate::observe::expose_text(&reg);
        assert!(text.contains("adra_serve_shed{queue=\"0\"} 4"), "{text}");
        assert!(text.contains("adra_serve_deadline_expired{queue=\"0\"} 3"), "{text}");
        assert!(text.contains("adra_serve_cancelled{queue=\"0\"} 2"), "{text}");
        assert!(text.contains("adra_serve_breaker_rejected{queue=\"0\"} 5"), "{text}");
        assert!(text.contains("adra_serve_breaker_opens{queue=\"0\"} 2"), "{text}");
        assert!(text.contains("adra_serve_degrade_level{queue=\"0\"} 3"), "{text}");
    }

    #[test]
    fn p95_excluding_merges_only_other_tenants() {
        let mut m = ServeMetrics::default();
        // tenant 0 (the heavy one): slow; tenants 1, 2: fast
        for _ in 0..20 {
            m.record_latency(0, 1e-3);
            m.record_latency(1, 1e-6);
            m.record_latency(2, 2e-6);
        }
        let without_heavy = m.p95_ns_excluding(0);
        let with_heavy = m.p95_ns_excluding(9); // 9 never served: merge all
        assert!(without_heavy < 1e5, "{without_heavy}");
        assert!(with_heavy > 1e5, "{with_heavy}");
        assert_eq!(m.p95_ns_excluding(0), without_heavy, "deterministic");
    }
}
