//! The multi-tenant serve queue: admission, round scheduling, fused
//! execution, and reply plumbing.
//!
//! One scheduler thread owns the coordinator, the cost model, the shared
//! `TableState`, and the `ResultCache`.  Clients (any number of OS
//! threads) `submit` planned programs and block on their [`Ticket`];
//! everything queued while a round executes is coalesced into the next
//! round, so batch occupancy rises exactly when the system is loaded —
//! the same backpressure-free design as `coordinator::pool`, one layer
//! up.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::array::WearTracker;
use crate::cim::{CimOp, CimResult, EngineError, WordAddr};
use crate::config::SimConfig;
use crate::coordinator::{Coordinator, RouteError};
use crate::energy::OpCost;
use crate::metrics::RunMetrics;
use crate::observe::{self, RuleState, Stage};
use crate::planner::{
    calibrate, place_calibrated, planned_coordinator, CalibratedCostModel, CalibrationSample,
    CalibrationStore, ExecError, Layout, Objective, PlanCostModel, PlanError, Placement, Program,
    ScratchRow, SharedCalibration, StepOutput,
};
use crate::store::{DurableState, DurableStore};

use super::cache::{ResultCache, TableState};
use super::coalesce::{coalesce_round, StepAction};
use super::control::{
    service_weights, AdmissionPolicy, BatchController, BatchPolicy, CircuitBreaker,
    DegradeController, FairScheduler, ServiceWindow,
};
use super::metrics::ServeMetrics;

/// Serving deployment parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub cfg: SimConfig,
    /// Coordinator shards (worker threads / array shards).
    pub shards: usize,
    /// Routing objective for the planned workers and cost model.
    pub objective: Objective,
    /// Shared table geometry; every admitted program must match it so
    /// record slots, shard partitioning, and scratch rows line up across
    /// tenants (a mismatch is rejected at submission).
    pub n_records: usize,
    /// Max programs coalesced into one round.  Under
    /// [`BatchPolicy::Adaptive`] this is the ceiling and starting point
    /// of the EWMA controller; under [`BatchPolicy::Static`] it is the
    /// round size, as in PR 2.
    pub max_round: usize,
    /// Result-cache budget in slots (see `cache::ResultCache`).
    pub cache_capacity: usize,
    /// How rounds are selected from the backlog.
    pub admission: AdmissionPolicy,
    /// How `max_round` is governed.
    pub batch: BatchPolicy,
    /// Sample the registry into the global `observe::series()` store
    /// (and evaluate the health rules) every N rounds; `0` disables
    /// per-round sampling.  Observation only — results and modeled
    /// costs are bit-identical at any setting.
    pub sample_every: u64,
    /// Absorb each round's predicted-vs-measured samples into the
    /// calibrated cost model every N rounds; `0` disables calibration
    /// entirely (pure analytic tables, the pre-calibration behavior).
    pub calibrate_every: u64,
    /// Persist the calibration store to this path after every absorb
    /// (and seed it from there at startup), so a restarted queue keeps
    /// its learned corrections.
    pub calibration_path: Option<std::path::PathBuf>,
    /// Externally-owned store handle: seeded from at startup (when
    /// non-empty) and mirrored into after every absorb.  `None` mirrors
    /// into the process-global `planner::calibrate::shared()` cell
    /// instead (what the REPL's `calibration` commands read).
    pub calibration: Option<SharedCalibration>,
    /// Durable-store directory (snapshot + WAL).  `Some` arms journaling
    /// of every content-changing write, periodic checkpoints, and
    /// recovery-on-start: the scheduler replays the recovered logical
    /// contents into its fresh arrays before serving the first round.
    /// `None` (the default) serves fully in-memory, as before this PR.
    pub store_dir: Option<PathBuf>,
    /// Checkpoint (snapshot + WAL truncate) every N rounds; `0` means
    /// WAL-only between explicit `snapshot` requests.
    pub checkpoint_every: u64,
    /// On a shard `RouteError` (worker death), respawn + replay + retry
    /// this many times before failing the round's programs.
    pub route_retries: u32,
    /// Base backoff between route retries (doubles per attempt).
    pub retry_backoff_ms: u64,
    /// Reserve this many top array rows per shard as wear-steering
    /// spares: when a serving row's write wear exceeds the coldest
    /// spare's by `wear_migrate_threshold`, its contents migrate there
    /// and the row map redirects all later ops.  `0` disables steering.
    pub wear_spare_rows: usize,
    /// Wear-delta (writes) that triggers a migration.
    pub wear_migrate_threshold: u64,
    /// Deadline applied at admission when the submission carries none
    /// ([`SubmitOptions::deadline`] wins).  `None` (the default): programs
    /// wait indefinitely, the pre-overload-layer behavior.
    pub default_deadline: Option<Duration>,
    /// Hard bound on one tenant's queued (not yet scheduled) programs:
    /// an admission beyond it answers `Rejected(Overloaded)` immediately
    /// instead of queueing to time out.  `0` = unbounded.
    pub max_tenant_backlog: usize,
    /// Total sleep budget (ms) for the route-retry backoff loop per
    /// round — one dead shard must not stall co-scheduled tenants past
    /// the round-wall target; on exhaustion the shard is handed to the
    /// circuit breaker.  `0` = unbounded (pre-overload-layer behavior).
    pub retry_budget_ms: u64,
    /// Consecutive retry-loop exhaustions that open a shard's circuit
    /// breaker (placements touching it then fail fast with
    /// `Rejected(ShardDown)` until a half-open probe heals it).  `0`
    /// disables the breaker.
    pub breaker_threshold: u32,
    /// Scheduling passes an open breaker waits before its half-open
    /// respawn-and-replay probe.
    pub breaker_probe_after: u64,
    /// Arm the health-driven brownout ladder (`DegradeController`):
    /// committed `round_wall_slo_burn` transitions step service through
    /// pinned routing → widened negative cache → reduced sampling →
    /// shed, walking back on recovery.  Off by default — the ladder
    /// couples serving behavior to the PROCESS-GLOBAL health engine,
    /// which a library embedder may share across queues.
    pub brownout: bool,
}

impl ServeConfig {
    pub fn new(cfg: SimConfig, shards: usize, n_records: usize) -> Self {
        Self {
            cfg,
            shards,
            objective: Objective::Edp,
            n_records,
            max_round: 32,
            cache_capacity: 1024,
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
            default_deadline: None,
            max_tenant_backlog: 0,
            retry_budget_ms: 50,
            breaker_threshold: 3,
            breaker_probe_after: 2,
            brownout: false,
        }
    }
}

/// Why admission control refused a program outright (fail fast, no
/// queueing — the tenant can retry elsewhere or back off immediately).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Load shedding: the tenant's backlog hit its hard bound, or the
    /// brownout ladder reached its shed step and the tenant is over its
    /// fair-share quota.
    Overloaded,
    /// A shard the program's placement needs is behind an open circuit
    /// breaker.
    ShardDown,
}

/// Serving failure modes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Program geometry differs from the serve table's.
    Geometry { expected: usize, got: usize },
    Plan(PlanError),
    Route(RouteError),
    /// An engine failed mid-round (formatted op + error).
    Engine(String),
    /// A durable-store operation (snapshot/restore) failed.
    Store(String),
    /// The program's deadline passed before it was scheduled; it never
    /// reached the array (activation counters are pinned).
    DeadlineExceeded,
    /// The tenant cancelled the program (via its [`CancelHandle`] or a
    /// tenant-wide cancel) before it produced a result.
    Cancelled,
    /// Admission control refused the program outright.
    Rejected(RejectReason),
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Geometry { expected, got } => {
                write!(f, "program has {got} records, serve table has {expected}")
            }
            ServeError::Plan(e) => write!(f, "planning: {e}"),
            ServeError::Route(e) => write!(f, "routing: {e}"),
            ServeError::Engine(s) => write!(f, "engine: {s}"),
            ServeError::Store(s) => write!(f, "store: {s}"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            ServeError::Cancelled => write!(f, "cancelled by tenant"),
            ServeError::Rejected(RejectReason::Overloaded) => {
                write!(f, "rejected: overloaded (load shed)")
            }
            ServeError::Rejected(RejectReason::ShardDown) => {
                write!(f, "rejected: shard down (circuit breaker open)")
            }
            ServeError::ShuttingDown => write!(f, "serve queue is shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a served program returns to its tenant.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Per-IR-step outputs, indexed like `Program::ops` — bit-identical
    /// to naive per-program execution.
    pub outputs: Vec<StepOutput>,
    /// Modeled cost of the ops actually executed for this program;
    /// cached steps and deduped writes contribute zero.
    pub measured: OpCost,
    /// Query steps answered from the cache.
    pub cached_steps: usize,
    /// Writes dropped by content dedup.
    pub skipped_writes: usize,
    /// Programs sharing this program's round.
    pub round_occupancy: usize,
    /// 1-based sequence number of the round that served this program —
    /// the starvation-freedom tests bound it.
    pub round: u64,
    /// Submission-to-reply wall seconds.
    pub wall: f64,
}

/// Per-submission knobs (deadline today; room to grow without another
/// `submit` signature change).
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Relative deadline: if the program has not STARTED executing this
    /// long after submission it is swept and answered
    /// `DeadlineExceeded` without ever touching the array.  `None`
    /// falls back to [`ServeConfig::default_deadline`].
    pub deadline: Option<Duration>,
}

/// Tenant-facing cancellation token returned at admission.  Cheap to
/// clone; `cancel()` is safe from any thread at any point in the
/// program's life: queued programs are swept before scheduling, and
/// in-flight single-program batches are abandoned at the next
/// cooperative check between fused batches.
#[derive(Clone)]
pub struct CancelHandle {
    flag: Arc<AtomicBool>,
}

impl CancelHandle {
    /// Request cancellation.  Idempotent; the program answers
    /// `Err(Cancelled)` unless it already completed.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Relaxed)
    }
}

/// Point-in-time overload-survival posture, for the REPL's `breaker` /
/// `degrade` commands and tests.
#[derive(Clone, Debug)]
pub struct LifecycleReport {
    /// Per-shard breaker state names ("closed" / "open" / "half-open").
    pub breaker: Vec<&'static str>,
    pub breaker_opens: u64,
    pub breaker_closes: u64,
    /// Current brownout-ladder step name.
    pub degrade: &'static str,
    /// Numeric ladder level (0 normal .. 4 shed).
    pub degrade_level: u64,
    /// Whether `ServeConfig::brownout` armed the ladder.
    pub brownout_armed: bool,
}

struct Admission {
    tenant: usize,
    program: Program,
    submitted: Instant,
    /// Absolute expiry; swept (never executed) once passed.
    deadline: Option<Instant>,
    /// Shared with the tenant's [`CancelHandle`].
    cancel: Arc<AtomicBool>,
    reply: Sender<Result<ServeReport, ServeError>>,
}

impl Admission {
    fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Terminal error if this admission must not execute.  Cancel wins
    /// over expiry: the tenant acted first, the clock merely ran.
    fn lifecycle_error(&self, now: Instant) -> Option<ServeError> {
        if self.cancelled() {
            Some(ServeError::Cancelled)
        } else if self.expired(now) {
            Some(ServeError::DeadlineExceeded)
        } else {
            None
        }
    }
}

/// Everything the scheduler thread receives: tenant admissions plus the
/// durability control plane (REPL `snapshot`/`restore`) and the
/// overload-survival control plane (tenant-wide cancel, lifecycle
/// introspection).  Control messages are handled between rounds, on the
/// scheduler thread, where the coordinator and table state are
/// exclusively owned.
enum QueueMsg {
    Admit(Admission),
    Snapshot { dir: PathBuf, reply: Sender<Result<(), String>> },
    Restore { dir: PathBuf, reply: Sender<Result<(), String>> },
    /// Cancel every queued program of one tenant; replies with how many
    /// were swept.  (Control messages drain between rounds, so nothing
    /// of the tenant's is mid-execution when this runs; programs already
    /// holding a [`CancelHandle`] can also cancel mid-round through it.)
    CancelTenant { tenant: usize, reply: Sender<usize> },
    Lifecycle { reply: Sender<LifecycleReport> },
}

/// Handle to an admitted program.
pub struct Ticket {
    rx: Receiver<Result<ServeReport, ServeError>>,
}

impl Ticket {
    /// Block until the program's round completes.
    pub fn wait(self) -> Result<ServeReport, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }
}

/// Monotone id source distinguishing queue instances in the registry
/// (the `queue` label): several queues can live in one process (tests,
/// the example's FIFO-vs-fair comparison) and their counters must not
/// collapse into one series.
static QUEUE_SEQ: AtomicU64 = AtomicU64::new(0);

/// The serving front door.  `Send + Sync`: submit from any thread.
pub struct ServeQueue {
    tx: Option<Sender<QueueMsg>>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<ServeMetrics>>,
    n_records: usize,
    default_deadline: Option<Duration>,
    id: u64,
}

impl ServeQueue {
    /// Spawn the scheduler thread and its coordinator pool.
    pub fn start(config: ServeConfig) -> Self {
        let (tx, rx) = channel::<QueueMsg>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let m2 = metrics.clone();
        let n_records = config.n_records;
        let default_deadline = config.default_deadline;
        let id = QUEUE_SEQ.fetch_add(1, Ordering::Relaxed);
        let handle = std::thread::Builder::new()
            .name("adra-serve".into())
            .spawn(move || scheduler(config, rx, m2, id))
            .expect("spawn serve scheduler");
        Self { tx: Some(tx), handle: Some(handle), metrics, n_records, default_deadline, id }
    }

    /// This queue's `queue` label value in the observe registry.
    pub fn instance(&self) -> u64 {
        self.id
    }

    /// Admit a tenant's program; returns a ticket to wait on.
    pub fn submit(&self, tenant: usize, program: Program) -> Result<Ticket, ServeError> {
        self.submit_with(tenant, program, SubmitOptions::default()).map(|(t, _)| t)
    }

    /// Admit with per-submission options; also returns the program's
    /// cancellation token.
    pub fn submit_with(
        &self,
        tenant: usize,
        program: Program,
        opts: SubmitOptions,
    ) -> Result<(Ticket, CancelHandle), ServeError> {
        if program.n_records != self.n_records {
            return Err(ServeError::Geometry {
                expected: self.n_records,
                got: program.n_records,
            });
        }
        let (reply, rx) = channel();
        let now = Instant::now();
        let deadline = opts.deadline.or(self.default_deadline).map(|d| now + d);
        let cancel = Arc::new(AtomicBool::new(false));
        let handle = CancelHandle { flag: cancel.clone() };
        let adm = Admission { tenant, program, submitted: now, deadline, cancel, reply };
        self.tx
            .as_ref()
            .ok_or(ServeError::ShuttingDown)?
            .send(QueueMsg::Admit(adm))
            .map_err(|_| ServeError::ShuttingDown)?;
        Ok((Ticket { rx }, handle))
    }

    /// Cancel every queued program of `tenant`; returns how many were
    /// swept (each answers `Err(Cancelled)` on its ticket).
    pub fn cancel_tenant(&self, tenant: usize) -> Result<usize, ServeError> {
        let (reply, rx) = channel();
        self.tx
            .as_ref()
            .ok_or(ServeError::ShuttingDown)?
            .send(QueueMsg::CancelTenant { tenant, reply })
            .map_err(|_| ServeError::ShuttingDown)?;
        rx.recv().map_err(|_| ServeError::ShuttingDown)
    }

    /// Current breaker / brownout posture (synchronous round-trip to the
    /// scheduler thread).
    pub fn lifecycle(&self) -> Result<LifecycleReport, ServeError> {
        let (reply, rx) = channel();
        self.tx
            .as_ref()
            .ok_or(ServeError::ShuttingDown)?
            .send(QueueMsg::Lifecycle { reply })
            .map_err(|_| ServeError::ShuttingDown)?;
        rx.recv().map_err(|_| ServeError::ShuttingDown)
    }

    /// Checkpoint the queue's durable state (table contents, wear
    /// counters, calibration store) into `dir`, synchronously.  Works
    /// with or without a configured `store_dir`; when `dir` IS the live
    /// store, the live WAL is truncated too.
    pub fn snapshot_to(&self, dir: impl Into<PathBuf>) -> Result<(), ServeError> {
        self.control(|reply| QueueMsg::Snapshot { dir: dir.into(), reply })
    }

    /// Replace the serving state with the checkpoint recovered from
    /// `dir` (snapshot + WAL replay): all workers respawn on fresh
    /// arrays and the restored contents are replayed into them.  Cached
    /// results stay correct across the swap — the table epoch continues
    /// from `max(live, restored)`, so post-restore writes can never
    /// alias a pre-restore fingerprint.
    pub fn restore_from(&self, dir: impl Into<PathBuf>) -> Result<(), ServeError> {
        self.control(|reply| QueueMsg::Restore { dir: dir.into(), reply })
    }

    fn control<F>(&self, make: F) -> Result<(), ServeError>
    where
        F: FnOnce(Sender<Result<(), String>>) -> QueueMsg,
    {
        let (reply, rx) = channel();
        self.tx
            .as_ref()
            .ok_or(ServeError::ShuttingDown)?
            .send(make(reply))
            .map_err(|_| ServeError::ShuttingDown)?;
        rx.recv().map_err(|_| ServeError::ShuttingDown)?.map_err(ServeError::Store)
    }

    /// Snapshot of the serve-layer metrics.
    pub fn metrics(&self) -> ServeMetrics {
        self.metrics.lock().expect("metrics lock").clone()
    }
}

impl Drop for ServeQueue {
    fn drop(&mut self) {
        drop(self.tx.take()); // scheduler drains and exits on disconnect
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Device endurance budget the serve-side wear trackers assume (HZO
/// mid-range, paper §II.B cites 1e5–1e11 cycles).
const WEAR_ENDURANCE: u64 = 100_000_000;

fn scheduler(
    config: ServeConfig,
    rx: Receiver<QueueMsg>,
    metrics: Arc<Mutex<ServeMetrics>>,
    queue_id: u64,
) {
    let ServeConfig {
        cfg,
        shards,
        objective,
        n_records,
        max_round,
        cache_capacity,
        admission,
        batch,
        sample_every,
        calibrate_every,
        calibration_path,
        calibration,
        store_dir,
        checkpoint_every,
        route_retries,
        retry_backoff_ms,
        wear_spare_rows,
        wear_migrate_threshold,
        default_deadline: _,
        max_tenant_backlog,
        retry_budget_ms,
        breaker_threshold,
        breaker_probe_after,
        brownout,
    } = config;
    let mut coord = planned_coordinator(&cfg, shards, objective);
    // the calibrated cost model: analytic tables wrapped by the runtime
    // correction store — seeded from the shared handle (a warm daemon)
    // when it has content, else from the persisted snapshot, else empty
    // (factors 1.0 == pure analytic behavior)
    let seed_store = calibration
        .as_ref()
        .map(|s| s.lock().expect("calibration lock").clone())
        .filter(|s| !s.is_empty())
        .or_else(|| calibration_path.as_deref().map(CalibrationStore::load))
        .unwrap_or_default();
    let cal_preseeded = !seed_store.is_empty();
    let mut cal =
        CalibratedCostModel::with_store(PlanCostModel::new(&cfg, objective), shards, seed_store);
    // restored routing pins must reach the workers before the first round
    cal.sync_routing(&coord);
    let mut service_window = ServiceWindow::new();
    let mut state = TableState::new(&cfg, n_records);
    let mut cache = ResultCache::new(cache_capacity);
    // per-shard wear accounting + the wear-steering row maps (logical →
    // physical; identity until a migration redirects a hot row onto one
    // of the reserved spare rows)
    let mut wear: Vec<WearTracker> =
        (0..shards).map(|_| WearTracker::new(cfg.rows, WEAR_ENDURANCE)).collect();
    let mut row_maps: Vec<Vec<usize>> = (0..shards).map(|_| (0..cfg.rows).collect()).collect();
    let spare_base = cfg.rows.saturating_sub(wear_spare_rows);
    // steering disarms per shard if a program ever addresses a reserved
    // row directly (the reserve was sized too small for the workload)
    let mut steer_ok: Vec<bool> =
        vec![wear_spare_rows > 0 && spare_base > 0; shards];

    // durable store: recover, seed state/wear/calibration, replay the
    // recovered logical contents into the fresh arrays, then arm the WAL
    // journal — everything before the first admission is drained
    let mut store: Option<DurableStore> = None;
    if let Some(dir) = &store_dir {
        if let Ok((s, rec)) = DurableStore::open(dir) {
            // a WAL with no snapshot (checkpoint_every = 0, or a crash
            // before the first checkpoint) still recovers: replay onto
            // the fresh table
            if rec.state.is_some() || !rec.wal.is_empty() {
                let mut recovered = match &rec.state {
                    Some(ds) => TableState::from_image(&ds.table),
                    None => TableState::new(&cfg, n_records),
                };
                for op in &rec.wal {
                    recovered.apply_wal(op);
                }
                if recovered.n_records() == n_records {
                    state = recovered;
                    if let Some(ds) = &rec.state {
                        for (t, counts) in wear.iter_mut().zip(&ds.wear) {
                            t.seed_counts(counts);
                        }
                        // the durable calibration snapshot is the weakest
                        // seed: an explicit handle or path wins
                        if !cal_preseeded {
                            if let Some(cs) = CalibrationStore::from_json(&ds.calibration_json) {
                                if !cs.is_empty() {
                                    cal = CalibratedCostModel::with_store(
                                        PlanCostModel::new(&cfg, objective),
                                        shards,
                                        cs,
                                    );
                                    cal.sync_routing(&coord);
                                }
                            }
                        }
                    }
                    for shard in 0..shards {
                        let ops = shard_replay_ops(&cfg, n_records, shards, shard, &state);
                        if !ops.is_empty() {
                            let _ = coord.call_batch(shard, &ops);
                        }
                    }
                    metrics.lock().expect("metrics lock").recoveries += 1;
                }
            }
            store = Some(s);
        }
        state.enable_journal();
    }
    let mut controller = match batch {
        BatchPolicy::Static => BatchController::fixed(max_round),
        BatchPolicy::Adaptive { target_p95 } => BatchController::adaptive(max_round, target_p95),
    };
    let mut backlog: FairScheduler<Admission> = FairScheduler::new(admission);
    let mut round_no: u64 = 0;
    let mut open = true;
    // overload-survival state: per-shard circuit breakers (fail fast
    // while a shard is down, heal through half-open probes) and the
    // health-driven brownout ladder (steps only when `brownout` arms the
    // `on_health` feed — the helpers are inert at level Normal)
    let mut breaker = CircuitBreaker::new(shards, breaker_threshold, breaker_probe_after);
    let mut degrade = DegradeController::new();

    // observability: every counter this scheduler maintains is mirrored
    // into the global registry under the queue label, and each pipeline
    // stage records a trace span (observation only — no control flow or
    // modeled cost reads anything published here)
    let qlabel = queue_id.to_string();
    let reg = observe::global();
    let rec = observe::recorder();
    let round_wall = reg.histogram(
        "adra.serve.round_wall_ns",
        "Observed wall time per coalescing round (ns).",
        &[("queue", &qlabel)],
    );
    // self-metering: what the observer itself costs per round (publish
    // + series sample + health evaluation), gated in CI by the
    // observe-overhead ratio in BENCH_hotpath.json
    let observe_overhead = reg.histogram(
        "adra.observe.overhead_ns",
        "Per-round cost of registry publish + series sampling + health evaluation (ns).",
        &[("queue", &qlabel)],
    );

    while open || !backlog.is_empty() {
        // batch window: block for work only when the backlog is dry,
        // then sweep in everything already queued.  Control messages
        // (snapshot/restore) run here, between rounds, where everything
        // is exclusively owned.
        if backlog.is_empty() {
            match rx.recv() {
                Ok(QueueMsg::Admit(a)) => {
                    let quota = (controller.max_round() / backlog.active_tenants().max(1)).max(1);
                    if let Err(a) = admit_or_shed(
                        &mut backlog, a, max_tenant_backlog, degrade.shedding(), quota,
                    ) {
                        let _ = a.reply.send(Err(ServeError::Rejected(RejectReason::Overloaded)));
                        metrics.lock().expect("metrics lock").shed += 1;
                        rec.record_alert("serve_shed", "admitted", "rejected", 1.0);
                    }
                }
                Ok(QueueMsg::Snapshot { dir, reply }) => {
                    let _ = reply.send(do_snapshot(&dir, &mut store, &state, &wear, &cal));
                    continue;
                }
                Ok(QueueMsg::Restore { dir, reply }) => {
                    let r = do_restore(
                        &dir, &cfg, n_records, shards, objective, &mut coord, &mut state,
                        &mut wear, &mut row_maps, &mut cal, &mut store,
                    );
                    if r.is_ok() {
                        metrics.lock().expect("metrics lock").recoveries += 1;
                    }
                    let _ = reply.send(r);
                    continue;
                }
                Ok(QueueMsg::CancelTenant { tenant, reply }) => {
                    let n = cancel_tenant_queued(&mut backlog, tenant, &metrics, rec);
                    let _ = reply.send(n);
                    continue;
                }
                Ok(QueueMsg::Lifecycle { reply }) => {
                    let _ = reply.send(lifecycle_report(&breaker, &degrade, brownout, shards));
                    continue;
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        while open {
            match rx.try_recv() {
                Ok(QueueMsg::Admit(a)) => {
                    let quota = (controller.max_round() / backlog.active_tenants().max(1)).max(1);
                    if let Err(a) = admit_or_shed(
                        &mut backlog, a, max_tenant_backlog, degrade.shedding(), quota,
                    ) {
                        let _ = a.reply.send(Err(ServeError::Rejected(RejectReason::Overloaded)));
                        metrics.lock().expect("metrics lock").shed += 1;
                        rec.record_alert("serve_shed", "admitted", "rejected", 1.0);
                    }
                }
                Ok(QueueMsg::Snapshot { dir, reply }) => {
                    let _ = reply.send(do_snapshot(&dir, &mut store, &state, &wear, &cal));
                }
                Ok(QueueMsg::Restore { dir, reply }) => {
                    let r = do_restore(
                        &dir, &cfg, n_records, shards, objective, &mut coord, &mut state,
                        &mut wear, &mut row_maps, &mut cal, &mut store,
                    );
                    if r.is_ok() {
                        metrics.lock().expect("metrics lock").recoveries += 1;
                    }
                    let _ = reply.send(r);
                }
                Ok(QueueMsg::CancelTenant { tenant, reply }) => {
                    let n = cancel_tenant_queued(&mut backlog, tenant, &metrics, rec);
                    let _ = reply.send(n);
                }
                Ok(QueueMsg::Lifecycle { reply }) => {
                    let _ = reply.send(lifecycle_report(&breaker, &degrade, brownout, shards));
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }

        // lifecycle sweep: doomed programs (cancelled, or deadline
        // passed) answer their terminal error BEFORE placement —
        // coalescing mutates the shared TableState, so exclusion must
        // happen before any state is touched.  A swept program never
        // reaches the array; its activation counters are pinned.
        let now = Instant::now();
        let doomed = backlog.sweep(|_, a: &Admission| a.cancelled() || a.expired(now));
        if !doomed.is_empty() {
            let (mut n_cancel, mut n_expire) = (0u64, 0u64);
            for (_, a) in doomed {
                let err = a.lifecycle_error(now).unwrap_or(ServeError::Cancelled);
                match err {
                    ServeError::Cancelled => n_cancel += 1,
                    _ => n_expire += 1,
                }
                let _ = a.reply.send(Err(err));
            }
            {
                let mut m = metrics.lock().expect("metrics lock");
                m.cancelled += n_cancel;
                m.deadline_expired += n_expire;
            }
            if n_cancel > 0 {
                rec.record_alert("serve_cancel", "queued", "cancelled", n_cancel as f64);
            }
            if n_expire > 0 {
                rec.record_alert("serve_deadline", "queued", "expired", n_expire as f64);
            }
        }

        // half-open probes: open breakers age once per SCHEDULING PASS
        // (not per round — with every admission rejected pre-round no
        // rounds run, and round-based cadence would never heal the
        // shard).  A due shard gets a respawn-and-replay probe; success
        // closes the breaker, failure re-opens it.
        for shard in breaker.due_probes() {
            rec.record_alert("shard_breaker", "open", "half-open", shard as f64);
            let mut probe_ok = coord.respawn(shard).is_ok();
            if probe_ok {
                let mut replay = shard_replay_ops(&cfg, n_records, shards, shard, &state);
                if steer_ok.get(shard).copied().unwrap_or(false) && !is_identity(&row_maps[shard])
                {
                    for op in &mut replay {
                        *op = remap_op(op, &row_maps[shard]);
                    }
                }
                probe_ok = replay.is_empty() || coord.call_batch(shard, &replay).is_ok();
            }
            let transition = if probe_ok {
                breaker.record_success(shard)
            } else {
                breaker.record_failure(shard)
            };
            if let Some((from, to)) = transition {
                rec.record_alert("shard_breaker", from.name(), to.name(), shard as f64);
            }
        }

        // round selection: WFQ (or FIFO) over the backlog, sized by the
        // adaptive controller, weighted by the latency histograms
        let schedule_start = Instant::now();
        let weights = {
            let m = metrics.lock().expect("metrics lock");
            service_weights(&mut service_window, &m.tenant_latency, &m.tenant_energy)
        };
        let selection = backlog
            .next_round(controller.max_round(), |t| weights.get(&t).copied().unwrap_or(1.0));
        let admitted = selection.admitted;
        if admitted.is_empty() {
            continue;
        }
        round_no += 1;
        rec.record_span(
            round_no,
            None,
            Stage::Schedule,
            schedule_start.elapsed().as_nanos() as u64,
            admitted.len() as u64,
        );
        let round_start = Instant::now();

        // place each program; planning failures answer immediately
        let mut round: Vec<(Admission, Placement)> = Vec::with_capacity(admitted.len());
        for a in admitted {
            // last-chance lifecycle check: cancel/expiry raced in
            // between the sweep and selection
            if let Some(err) = a.lifecycle_error(Instant::now()) {
                {
                    let mut m = metrics.lock().expect("metrics lock");
                    match err {
                        ServeError::Cancelled => m.cancelled += 1,
                        _ => m.deadline_expired += 1,
                    }
                }
                let _ = a.reply.send(Err(err));
                continue;
            }
            rec.record_span(
                round_no,
                Some(a.tenant as u64),
                Stage::Admit,
                a.submitted.elapsed().as_nanos() as u64,
                1,
            );
            match place_calibrated(&a.program, &cfg, shards, &cal) {
                Ok(p) => {
                    // fail fast when the placement needs a shard behind
                    // an open breaker — queueing it would only time out
                    if breaker.any_open()
                        && p.shards
                            .iter()
                            .any(|sp| !sp.lowered.ops.is_empty() && breaker.is_open(sp.shard))
                    {
                        let _ = a.reply.send(Err(ServeError::Rejected(RejectReason::ShardDown)));
                        metrics.lock().expect("metrics lock").breaker_rejected += 1;
                        continue;
                    }
                    round.push((a, p));
                }
                Err(e) => {
                    let _ = a.reply.send(Err(ServeError::Plan(e)));
                }
            }
        }
        if round.is_empty() {
            continue;
        }
        let occupancy = round.len();

        // the fused path forces dual ops onto the ADRA engine; honor the
        // CALIBRATED routing by fusing only when every shard's dual ops
        // route there anyway (the analytic model routes them to the
        // baseline under the energy objective on voltage scheme 1, and
        // calibration can flip the decision either way at runtime —
        // force-fusing against it would cost MORE energy).  Dedup and
        // caching stay on either way; they are objective-neutral.
        let fuse = cal.fuse_dual_on_adra();
        let placements: Vec<&Placement> = round.iter().map(|(_, p)| p).collect();
        let coalesce_start = Instant::now();
        let mut coalesced = coalesce_round(&placements, &mut state, &mut cache, fuse);
        rec.record_span(
            round_no,
            None,
            Stage::Coalesce,
            coalesce_start.elapsed().as_nanos() as u64,
            coalesced.stats.coalesced_ops,
        );
        // fusion is planned during coalescing and executed inside the
        // shard batches; its span is an annotation carrying the forecast
        // activation count
        rec.record_span(round_no, None, Stage::Fuse, 0, coalesced.stats.activations);

        // durability: this round's content-changing writes hit the WAL
        // BEFORE execution (write-ahead), so any crash from here on
        // replays them on restart
        if let Some(st) = store.as_mut() {
            let _ = st.append(&state.take_journal());
        }

        // wear steering: route each shard batch through its row map
        // (identity until a migration redirects a hot row onto a spare).
        // A program addressing a reserved row directly means the reserve
        // was sized too small — steering disarms for that shard.
        if wear_spare_rows > 0 {
            for b in &mut coalesced.shard_batches {
                let Some(ok) = steer_ok.get_mut(b.shard) else { continue };
                if !*ok {
                    continue;
                }
                if b.ops.iter().any(|op| op_touches_reserved(op, spare_base)) {
                    *ok = false;
                } else if !is_identity(&row_maps[b.shard]) {
                    for op in &mut b.ops {
                        *op = remap_op(op, &row_maps[b.shard]);
                    }
                }
            }
        }

        // cooperative cancellation: a shard batch whose ops all belong
        // to ONE program carries that program's cancel flag, checked by
        // the worker between queued groups — `Ok(None)` means abandoned.
        // Multi-program batches always run: one tenant's cancel must not
        // void a neighbor's coalesced work.
        let batch_flags: Vec<Option<Arc<AtomicBool>>> = coalesced
            .shard_batches
            .iter()
            .map(|b| {
                let mut owner: Option<usize> = None;
                for &(pi, _, _) in &b.origins {
                    match owner {
                        None => owner = Some(pi),
                        Some(o) if o == pi => {}
                        _ => return None,
                    }
                }
                owner.map(|pi| round[pi].0.cancel.clone())
            })
            .collect();

        // execute every shard batch in parallel, fused when routing allows
        let execute_start = Instant::now();
        let coord_ref = &coord;
        let shard_results: Vec<Result<Option<Vec<Result<CimResult, EngineError>>>, RouteError>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = coalesced
                    .shard_batches
                    .iter()
                    .zip(&batch_flags)
                    .map(|(b, flag)| {
                        s.spawn(move || match flag {
                            Some(f) => {
                                coord_ref.call_batch_abandonable(b.shard, &b.ops, fuse, f)
                            }
                            None => if fuse {
                                coord_ref.call_batch_fused(b.shard, &b.ops)
                            } else {
                                coord_ref.call_batch(b.shard, &b.ops)
                            }
                            .map(Some),
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("serve shard thread panicked"))
                    .collect()
            });
        rec.record_span(
            round_no,
            None,
            Stage::Execute,
            execute_start.elapsed().as_nanos() as u64,
            coalesced.shard_batches.iter().map(|b| b.ops.len() as u64).sum(),
        );

        // fault recovery: a failed shard means its worker died mid-round
        // (injected or real).  Respawn it with a fresh engine, replay the
        // durable logical contents — which already include this round's
        // writes, so re-execution is idempotent: writes rewrite the same
        // values and queries recompute against identical contents — and
        // re-issue the shard's batch, with bounded exponential backoff.
        let mut shard_results = shard_results;
        let mut retries_this_round = 0u64;
        let mut recovered_shards = 0u64;
        // total backoff sleep this round is capped: one dead shard must
        // not stall every co-scheduled tenant past the round-wall
        // target — on exhaustion the shard is handed to the breaker
        let retry_deadline = (retry_budget_ms > 0)
            .then(|| Instant::now() + Duration::from_millis(retry_budget_ms));
        for (i, r) in shard_results.iter_mut().enumerate() {
            if r.is_ok() {
                continue;
            }
            let b = &coalesced.shard_batches[i];
            for attempt in 0..route_retries {
                let backoff =
                    Duration::from_millis(retry_backoff_ms.saturating_mul(1 << attempt.min(16)));
                if retry_deadline.is_some_and(|d| Instant::now() + backoff > d) {
                    break;
                }
                std::thread::sleep(backoff);
                if coord.respawn(b.shard).is_err() {
                    break;
                }
                retries_this_round += 1;
                let mut replay = shard_replay_ops(&cfg, n_records, shards, b.shard, &state);
                if steer_ok.get(b.shard).copied().unwrap_or(false)
                    && !is_identity(&row_maps[b.shard])
                {
                    for op in &mut replay {
                        *op = remap_op(op, &row_maps[b.shard]);
                    }
                }
                if !replay.is_empty() && coord.call_batch(b.shard, &replay).is_err() {
                    continue;
                }
                let res = if fuse {
                    coord.call_batch_fused(b.shard, &b.ops)
                } else {
                    coord.call_batch(b.shard, &b.ops)
                };
                if let Ok(v) = res {
                    *r = Ok(Some(v));
                    recovered_shards += 1;
                    break;
                }
            }
        }

        // breaker accounting: an answering shard resets its failure
        // streak; an exhausted retry loop counts one failure toward
        // opening its breaker
        for (b, r) in coalesced.shard_batches.iter().zip(&shard_results) {
            let transition = match r {
                Ok(_) => breaker.record_success(b.shard),
                Err(_) => breaker.record_failure(b.shard),
            };
            if let Some((from, to)) = transition {
                rec.record_alert("shard_breaker", from.name(), to.name(), b.shard as f64);
            }
        }

        let mut results: Vec<Option<Vec<Result<CimResult, EngineError>>>> =
            Vec::with_capacity(shard_results.len());
        let mut route_err = None;
        for r in shard_results {
            match r {
                Ok(v) => results.push(v),
                Err(e) => {
                    route_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = route_err {
            {
                let mut m = metrics.lock().expect("metrics lock");
                m.route_retries = m.route_retries.saturating_add(retries_this_round);
                m.worker_respawns = coord.respawns();
            }
            for (a, _) in round {
                let _ = a.reply.send(Err(ServeError::Route(e.clone())));
            }
            continue;
        }

        // demultiplex worker replies back to (program, shard plan, op)
        let mut slots: Vec<Vec<Vec<Option<Result<CimResult, EngineError>>>>> = round
            .iter()
            .map(|(_, p)| {
                p.shards.iter().map(|sp| vec![None; sp.lowered.ops.len()]).collect()
            })
            .collect();
        // an abandoned batch (None) dooms its owner program; the shard's
        // physical array is now behind the logical TableState (this
        // round's writes were recorded during coalescing but never
        // executed), so replay the shard before anything else runs on it
        // — replay is idempotent and bit-identical, same as recovery
        let mut abandoned: Vec<bool> = vec![false; round.len()];
        for (b, res) in coalesced.shard_batches.iter().zip(&results) {
            match res {
                Some(res) => {
                    for (i, &(pi, spi, oi)) in b.origins.iter().enumerate() {
                        slots[pi][spi][oi] = Some(res[i].clone());
                    }
                }
                None => {
                    for &(pi, _, _) in &b.origins {
                        abandoned[pi] = true;
                    }
                    let mut replay = shard_replay_ops(&cfg, n_records, shards, b.shard, &state);
                    if steer_ok.get(b.shard).copied().unwrap_or(false)
                        && !is_identity(&row_maps[b.shard])
                    {
                        for op in &mut replay {
                            *op = remap_op(op, &row_maps[b.shard]);
                        }
                    }
                    if !replay.is_empty() {
                        let _ = coord.call_batch(b.shard, &replay);
                    }
                }
            }
        }

        // close the control loop on this round's observed wall time
        let round_wall_s = round_start.elapsed().as_secs_f64();
        controller.observe(round_wall_s, occupancy);
        round_wall.record(round_wall_s * 1e9);

        // endurance accounting: charge every executed write to its
        // physical row; the fault injector's endurance-drift hook
        // multiplies the charge to compress soak time
        let wf = crate::faults::wear_factor();
        for (b, res) in coalesced.shard_batches.iter().zip(&results) {
            if res.is_none() {
                continue; // abandoned batch: its ops never executed
            }
            if let Some(t) = wear.get_mut(b.shard) {
                for op in &b.ops {
                    if let CimOp::Write { addr, .. } = op {
                        if addr.row < t.rows() {
                            t.note_writes(addr.row, wf);
                        }
                    }
                }
            }
        }

        // wear steering: when a serving row runs hot, copy its contents
        // to the coldest spare and redirect the row map (one migration
        // per shard per round bounds the overhead)
        let mut migrations_this_round = 0u64;
        if wear_spare_rows > 0 {
            for s in 0..shards {
                if !steer_ok.get(s).copied().unwrap_or(false) {
                    continue;
                }
                if let Some((hot, cold)) =
                    plan_migration(&wear[s], &row_maps[s], spare_base, wear_migrate_threshold)
                {
                    let ops = row_copy_ops(&cfg, n_records, shards, s, hot, cold, &state);
                    if ops.is_empty() || coord.call_batch(s, &ops).is_ok() {
                        row_maps[s][hot] = cold;
                        wear[s].note_writes(cold, (ops.len() as u64).saturating_mul(wf));
                        migrations_this_round += 1;
                    }
                }
            }
        }

        let coord_metrics: RunMetrics = coord.metrics();
        {
            let mut m = metrics.lock().expect("metrics lock");
            m.observe_round(occupancy as u64, &coalesced.stats, selection.quota_hits, selection.deferred);
            m.invalidating_writes = state.invalidating_writes;
            m.observe_controller(
                controller.grows,
                controller.shrinks,
                controller.holds,
                controller.max_round() as u64,
            );
            // engine-level per-tier activation split (pool snapshot, not
            // a per-round delta)
            m.observe_array(&coord_metrics.array);
            m.route_retries = m.route_retries.saturating_add(retries_this_round);
            m.recovered_shards = m.recovered_shards.saturating_add(recovered_shards);
            m.wear_migrations = m.wear_migrations.saturating_add(migrations_this_round);
            m.worker_respawns = coord.respawns();
            m.spike_shrinks = controller.spikes;
            m.breaker_opens = breaker.opens;
            m.breaker_closes = breaker.closes;
            m.degrade_step_ups = degrade.step_ups;
            m.degrade_step_downs = degrade.step_downs;
            m.degrade_level = degrade.level().as_gauge();
        }

        // assemble per program, splice cached outputs, memoize fresh ones
        let cache_start = Instant::now();
        let mut round_samples: Vec<CalibrationSample> = Vec::new();
        for ((((a, placement), per_shard), pa), was_abandoned) in
            round.into_iter().zip(slots).zip(&coalesced.programs).zip(abandoned)
        {
            if was_abandoned {
                // its batch was abandoned at the cooperative check; the
                // program produced nothing (and its shard was replayed)
                let _ = a.reply.send(Err(ServeError::Cancelled));
                metrics.lock().expect("metrics lock").cancelled += 1;
                rec.record_alert("serve_cancel", "in-flight", "cancelled", 1.0);
                continue;
            }
            let reply = match placement.assemble(per_shard, coord_metrics.clone()) {
                Err(ExecError::Route(r)) => Err(ServeError::Route(r)),
                Err(other) => Err(ServeError::Engine(other.to_string())),
                Ok(mut rep) => {
                    round_samples.append(&mut rep.samples);
                    for (g, action) in pa.actions.iter().enumerate() {
                        match action {
                            StepAction::Cached(out) => rep.outputs[g] = out.clone(),
                            StepAction::RunAndCache(key) => {
                                cache.insert(*key, rep.outputs[g].clone(), &state);
                            }
                            _ => {}
                        }
                    }
                    let wall = a.submitted.elapsed().as_secs_f64();
                    metrics
                        .lock()
                        .expect("metrics lock")
                        .record_service(a.tenant, wall, rep.measured.energy.total());
                    Ok(ServeReport {
                        outputs: rep.outputs,
                        measured: rep.measured,
                        cached_steps: pa.cached_steps,
                        skipped_writes: pa.skipped_writes,
                        round_occupancy: occupancy,
                        round: round_no,
                        wall,
                    })
                }
            };
            let _ = a.reply.send(reply);
        }

        rec.record_span(
            round_no,
            None,
            Stage::Cache,
            cache_start.elapsed().as_nanos() as u64,
            coalesced.stats.cached_steps,
        );

        // close the calibration loop: fold this round's predicted-vs-
        // measured samples into the correction store, re-sync worker
        // routing on a committed flip, persist the snapshot, and mirror
        // the store into the shared handle the REPL reads.  With exact
        // tables this is a no-op (factors stay 1.0) — see the
        // `exact_tables` invariance tests.
        // brownout step 1 pins routing: under pressure the stable plan
        // beats a potentially-flapping recalibration
        if calibrate_every > 0
            && round_no % calibrate_every == 0
            && !round_samples.is_empty()
            && !degrade.pin_routing()
        {
            let flipped = cal.absorb(&round_samples);
            if flipped {
                cal.sync_routing(&coord);
            }
            cal.publish(reg);
            if let Some(p) = &calibration_path {
                let _ = cal.store().save(p);
            }
            let mirror = calibration.as_ref().unwrap_or_else(|| calibrate::shared());
            *mirror.lock().expect("calibration lock") = cal.store().clone();
        }

        // post-insert cache counters (inserts above may have evicted);
        // negative hits instead accumulate per round from RoundStats —
        // lookups only happen during coalescing; then mirror everything
        // into the registry so a scrape taken between rounds is current
        let observe_start = Instant::now();
        {
            let mut m = metrics.lock().expect("metrics lock");
            m.cache_evictions = cache.evictions;
            m.cache_swept = cache.swept;
            m.publish(reg, &qlabel);
        }
        for s in 0..shards {
            let shard_label = format!("{queue_id}.{s}");
            reg.gauge(
                "adra.serve.breaker_state",
                "Per-shard circuit-breaker state (0 closed, 1 open, 2 half-open).",
                &[("queue", &qlabel), ("shard", &shard_label)],
            )
            .set(breaker.state(s).as_gauge() as f64);
        }
        coord_metrics.publish(reg, &[("queue", &qlabel)]);
        // durable checkpoint cadence + store health counters (the
        // `adra.store.*` families the durability CI job asserts on)
        if let Some(st) = store.as_mut() {
            if checkpoint_every > 0 && round_no % checkpoint_every == 0 {
                let _ = st.checkpoint(&durable_state_of(&state, &wear, &cal));
            }
            st.publish(reg, &qlabel);
        }
        // time-series sampling + health evaluation at the configured
        // cadence (stretched by brownout step 3 — observation is load
        // too): the published state above becomes one point per series,
        // and rule transitions alert into the recorder
        let effective_sample = sample_every.saturating_mul(degrade.sample_stride());
        if effective_sample > 0 && round_no % effective_sample == 0 {
            // per-shard endurance state feeds the `array_wear_rate` rule
            for (s, t) in wear.iter().enumerate() {
                let shard_label = format!("{queue_id}.{s}");
                t.publish(reg, &shard_label);
            }
            let series = observe::series();
            series.sample(reg);
            let slo = {
                let mut h = observe::health().lock().expect("health lock");
                h.evaluate(series, reg, rec);
                h.state_of("round_wall_slo_burn")
            };
            // brownout ladder: committed SLO-burn transitions step
            // degraded service up one rung, recovery walks it back down.
            // Gated — the health engine is process-global, and an
            // embedder sharing it across queues must opt in.
            if brownout {
                if let Some((from, to)) = degrade.on_health(slo.unwrap_or(RuleState::Ok)) {
                    rec.record_alert("brownout", from.name(), to.name(), to.as_gauge() as f64);
                    cache.set_entry_cap_factor(degrade.cache_cap_factor());
                }
            }
        }
        observe_overhead.record(observe_start.elapsed().as_nanos() as f64);
    }
}

/// Admission control at the queue's front door: a hard per-tenant
/// backlog bound plus brownout-driven fair-share shedding (over-quota
/// tenants only — an idle tenant's first program is always admitted so
/// shedding cannot starve anyone outright).  `Err` hands the admission
/// back for an immediate `Rejected(Overloaded)` reply.
fn admit_or_shed(
    backlog: &mut FairScheduler<Admission>,
    a: Admission,
    max_tenant_backlog: usize,
    shedding: bool,
    quota: usize,
) -> Result<(), Admission> {
    let queued = backlog.tenant_backlog(a.tenant);
    let hard = max_tenant_backlog > 0 && queued >= max_tenant_backlog;
    let soft = shedding && queued >= quota;
    if hard || soft {
        return Err(a);
    }
    let t = a.tenant;
    backlog.push(t, a);
    Ok(())
}

/// Tenant-wide cancel: sweep the tenant's queued programs, answer each
/// `Err(Cancelled)`, and set their flags so cloned [`CancelHandle`]s
/// observe the cancellation too.
fn cancel_tenant_queued(
    backlog: &mut FairScheduler<Admission>,
    tenant: usize,
    metrics: &Arc<Mutex<ServeMetrics>>,
    rec: &crate::observe::FlightRecorder,
) -> usize {
    let swept = backlog.sweep(|t, _| t == tenant);
    let n = swept.len();
    for (_, a) in swept {
        a.cancel.store(true, Ordering::Relaxed);
        let _ = a.reply.send(Err(ServeError::Cancelled));
    }
    if n > 0 {
        metrics.lock().expect("metrics lock").cancelled += n as u64;
        rec.record_alert("serve_cancel", "queued", "cancelled", n as f64);
    }
    n
}

fn lifecycle_report(
    breaker: &CircuitBreaker,
    degrade: &DegradeController,
    brownout: bool,
    shards: usize,
) -> LifecycleReport {
    LifecycleReport {
        breaker: (0..shards).map(|s| breaker.state(s).name()).collect(),
        breaker_opens: breaker.opens,
        breaker_closes: breaker.closes,
        degrade: degrade.level().name(),
        degrade_level: degrade.level().as_gauge(),
        brownout_armed: brownout,
    }
}

/// Everything one durable checkpoint captures, assembled from the
/// scheduler's live state.
fn durable_state_of(
    state: &TableState,
    wear: &[WearTracker],
    cal: &CalibratedCostModel,
) -> DurableState {
    DurableState {
        table: state.image(),
        wear: wear.iter().map(|t| t.counts().to_vec()).collect(),
        calibration_json: cal.store().to_json(),
    }
}

/// Record-slot range one shard owns under the placement partition
/// (`planner::place_with`'s contiguous chunking — must stay in sync).
fn shard_slice(n_records: usize, shards: usize, shard: usize) -> (usize, usize) {
    let chunk = n_records.div_ceil(shards.max(1));
    let lo = (shard * chunk).min(n_records);
    let hi = ((shard + 1) * chunk).min(n_records);
    (lo, hi)
}

/// Writes that rebuild one shard's physical array from the logical table
/// state: every known record slot plus every known scratch-row broadcast
/// (replicated per shard, exactly as placement replicates them).
/// Unknown words are skipped — a fresh array already holds 0, and
/// `FefetArray::write_bit` is drift-free, so replay is bit-identical to
/// the original write history (see `FefetArray::state_digest` tests).
fn shard_replay_ops(
    cfg: &SimConfig,
    n_records: usize,
    shards: usize,
    shard: usize,
    state: &TableState,
) -> Vec<CimOp> {
    let (lo, hi) = shard_slice(n_records, shards, shard);
    if lo >= hi {
        return Vec::new();
    }
    let layout = Layout::of(cfg, hi - lo);
    let mut ops = Vec::new();
    for slot in lo..hi {
        if let Some(v) = state.record_value(slot) {
            ops.push(CimOp::Write { addr: layout.record_addr(slot - lo), value: v });
        }
    }
    for idx in 0..state.scratch_len() {
        if let Some(v) = state.scratch_value(idx) {
            let row = layout.scratch_row(ScratchRow(idx));
            for word in 0..layout.words_per_row {
                ops.push(CimOp::Write { addr: WordAddr { row, word }, value: v });
            }
        }
    }
    ops
}

fn is_identity(map: &[usize]) -> bool {
    map.iter().enumerate().all(|(i, &p)| i == p)
}

/// Does this op address a reserved spare row directly?
fn op_touches_reserved(op: &CimOp, spare_base: usize) -> bool {
    let (a, b) = op.rows();
    a >= spare_base || b.map_or(false, |r| r >= spare_base)
}

/// Rewrite an op's row references through a shard's logical→physical
/// wear-steering map.  Word indices and values are untouched, so
/// results are bit-identical — only WHERE the bits live changes.
fn remap_op(op: &CimOp, map: &[usize]) -> CimOp {
    let m = |r: usize| map.get(r).copied().unwrap_or(r);
    match *op {
        CimOp::Read(a) => CimOp::Read(WordAddr { row: m(a.row), word: a.word }),
        CimOp::Write { addr, value } => {
            CimOp::Write { addr: WordAddr { row: m(addr.row), word: addr.word }, value }
        }
        CimOp::Read2 { row_a, row_b, word } => {
            CimOp::Read2 { row_a: m(row_a), row_b: m(row_b), word }
        }
        CimOp::Bool { f, row_a, row_b, word } => {
            CimOp::Bool { f, row_a: m(row_a), row_b: m(row_b), word }
        }
        CimOp::Add { row_a, row_b, word } => CimOp::Add { row_a: m(row_a), row_b: m(row_b), word },
        CimOp::Sub { row_a, row_b, word } => CimOp::Sub { row_a: m(row_a), row_b: m(row_b), word },
        CimOp::Compare { row_a, row_b, word } => {
            CimOp::Compare { row_a: m(row_a), row_b: m(row_b), word }
        }
    }
}

/// Pick a wear migration for one shard: the hottest serving physical row
/// vs the coldest unmapped spare; `Some((logical_row, cold_physical))`
/// when the wear delta exceeds the threshold.
fn plan_migration(
    t: &WearTracker,
    map: &[usize],
    spare_base: usize,
    threshold: u64,
) -> Option<(usize, usize)> {
    let hot_logical = (0..spare_base.min(map.len())).max_by_key(|&r| t.writes(map[r]))?;
    let hot_writes = t.writes(map[hot_logical]);
    let serving = &map[..spare_base.min(map.len())];
    let cold = t.coldest_of((spare_base..t.rows()).filter(|r| !serving.contains(r)))?;
    (hot_writes >= t.writes(cold).saturating_add(threshold)).then_some((hot_logical, cold))
}

/// Writes that copy one logical row's known contents onto a new physical
/// row (a migration's data move).  Unknown words write 0: the source
/// cell was never written through the serving layer, so it still holds
/// the reset value — the copy must reproduce it on a possibly-dirty
/// spare.
fn row_copy_ops(
    cfg: &SimConfig,
    n_records: usize,
    shards: usize,
    shard: usize,
    logical_row: usize,
    to_phys: usize,
    state: &TableState,
) -> Vec<CimOp> {
    let (lo, hi) = shard_slice(n_records, shards, shard);
    if lo >= hi {
        return Vec::new();
    }
    let layout = Layout::of(cfg, hi - lo);
    let wpr = layout.words_per_row.max(1);
    let mut ops = Vec::with_capacity(wpr);
    if logical_row < layout.scratch_base {
        for word in 0..wpr {
            let local = logical_row * wpr + word;
            if local >= hi - lo {
                break;
            }
            let v = state.record_value(lo + local).unwrap_or(0);
            ops.push(CimOp::Write { addr: WordAddr { row: to_phys, word }, value: v });
        }
    } else {
        let v = state.scratch_value(logical_row - layout.scratch_base).unwrap_or(0);
        for word in 0..wpr {
            ops.push(CimOp::Write { addr: WordAddr { row: to_phys, word }, value: v });
        }
    }
    ops
}

/// Checkpoint the live state into `dir` — through the live store (WAL
/// truncates too) when `dir` IS its directory, through a transient store
/// otherwise.
fn do_snapshot(
    dir: &std::path::Path,
    live: &mut Option<DurableStore>,
    state: &TableState,
    wear: &[WearTracker],
    cal: &CalibratedCostModel,
) -> Result<(), String> {
    let ds = durable_state_of(state, wear, cal);
    match live.as_mut().filter(|s| s.dir() == dir) {
        Some(s) => s.checkpoint(&ds).map_err(|e| e.to_string()),
        None => {
            let (mut s, _) = DurableStore::open(dir).map_err(|e| e.to_string())?;
            s.checkpoint(&ds).map_err(|e| e.to_string())
        }
    }
}

/// Swap the serving state for the checkpoint recovered from `dir`:
/// respawn every worker onto a fresh array, replay the restored logical
/// contents, and re-checkpoint into the live store.  The table epoch
/// CONTINUES across the swap (`TableState::restore_into`), so cached
/// results from before the restore can never alias post-restore writes.
#[allow(clippy::too_many_arguments)]
fn do_restore(
    dir: &std::path::Path,
    cfg: &SimConfig,
    n_records: usize,
    shards: usize,
    objective: Objective,
    coord: &mut Coordinator,
    state: &mut TableState,
    wear: &mut [WearTracker],
    row_maps: &mut [Vec<usize>],
    cal: &mut CalibratedCostModel,
    live: &mut Option<DurableStore>,
) -> Result<(), String> {
    let (_probe, rec) = DurableStore::open(dir).map_err(|e| e.to_string())?;
    let ds = rec
        .state
        .ok_or_else(|| format!("no usable checkpoint in {}", dir.display()))?;
    let mut recovered = TableState::from_image(&ds.table);
    for op in &rec.wal {
        recovered.apply_wal(op);
    }
    if recovered.n_records() != n_records {
        return Err(format!(
            "checkpoint has {} records, serve table has {n_records}",
            recovered.n_records()
        ));
    }
    // fresh arrays: the restore must erase live contents the checkpoint
    // does not know about, or stale physical words would leak into
    // post-restore query results
    for shard in 0..shards {
        coord.respawn(shard).map_err(|e| format!("respawn shard {shard}: {e}"))?;
    }
    state.restore_into(&recovered.image());
    for (t, counts) in wear.iter_mut().zip(&ds.wear) {
        t.seed_counts(counts);
    }
    for m in row_maps.iter_mut() {
        for (i, p) in m.iter_mut().enumerate() {
            *p = i;
        }
    }
    if let Some(cs) = CalibrationStore::from_json(&ds.calibration_json) {
        if !cs.is_empty() {
            *cal = CalibratedCostModel::with_store(PlanCostModel::new(cfg, objective), shards, cs);
        }
    }
    cal.sync_routing(coord);
    for shard in 0..shards {
        let ops = shard_replay_ops(cfg, n_records, shards, shard, state);
        if !ops.is_empty() {
            coord
                .call_batch(shard, &ops)
                .map_err(|e| format!("replay shard {shard}: {e}"))?;
        }
    }
    // the restored contents were never journaled — make them durable now
    if let Some(st) = live.as_mut() {
        let _ = state.take_journal();
        st.checkpoint(&durable_state_of(state, wear, cal)).map_err(|e| e.to_string())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SensingScheme;
    use crate::planner::{place, StepOutput};
    use crate::workload::analytics_scenario;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c.max_batch = 16;
        c
    }

    fn queue(n_records: usize) -> ServeQueue {
        ServeQueue::start(ServeConfig::new(cfg(), 2, n_records))
    }

    #[test]
    fn served_outputs_match_naive_execution() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 48, 3);
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let pl = place(&s.program, &cfg, 2, &model).unwrap();
        let naive_coord = planned_coordinator(&cfg, 2, Objective::Edp);
        let naive = pl.execute(&naive_coord).unwrap();

        let q = queue(48);
        let rep = q.submit(0, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(rep.outputs, naive.outputs);
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches));
    }

    #[test]
    fn repeat_program_is_served_from_cache_and_dedup() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 48, 4);
        let q = queue(48);
        let first = q.submit(1, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(first.cached_steps, 0);
        assert!(first.measured.energy.total() > 0.0);

        // waiting for the first reply guarantees a separate round, so the
        // repeat hits the now-populated cache and the dedup shadow
        let second = q.submit(1, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(second.outputs, first.outputs, "bit-identical");
        assert_eq!(second.cached_steps, 3, "filter+compare+aggregate cached");
        assert!(second.skipped_writes >= 48, "loads deduped");
        assert_eq!(second.measured.energy.total(), 0.0, "nothing touched the array");

        let m = q.metrics();
        assert_eq!(m.programs, 2);
        assert!(m.cache_hit_rate() > 0.0);
        assert_eq!(m.invalidating_writes, 48, "only the first load changed contents");
    }

    #[test]
    fn overlapping_load_invalidates_cached_results() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 48, 5);
        let q = queue(48);
        let first = q.submit(0, s.program.clone()).unwrap().wait().unwrap();

        // rewrite every record with its complement, then re-query
        let mut changed = s.program.clone();
        let new_values: Vec<u64> = s.values.iter().map(|v| 127 - v).collect();
        changed.ops[0] = crate::planner::IrOp::Load { start: 0, values: new_values.clone() };
        let rep = q.submit(0, changed).unwrap().wait().unwrap();
        assert_eq!(rep.cached_steps, 0, "stale entries must not serve");
        let want: Vec<usize> = new_values
            .iter()
            .enumerate()
            .filter(|(_, &v)| v < s.threshold)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(want));
        assert_ne!(rep.outputs[s.filter_step], first.outputs[s.filter_step]);
    }

    /// Under the energy objective on voltage scheme 1 the cost model
    /// routes dual ops to the baseline executor; the serve layer must
    /// honor that instead of force-fusing everything onto ADRA (which
    /// would cost MORE energy than the naive routed path).
    #[test]
    fn baseline_routed_objectives_are_not_force_fused() {
        let mut cfg = cfg();
        cfg.scheme = SensingScheme::VoltagePrecharged;
        let s = analytics_scenario(&cfg, 48, 8);
        let model = PlanCostModel::new(&cfg, Objective::Energy);
        let pl = place(&s.program, &cfg, 2, &model).unwrap();
        let naive_coord = planned_coordinator(&cfg, 2, Objective::Energy);
        let naive = pl.execute(&naive_coord).unwrap();

        let q = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: 2,
            objective: Objective::Energy,
            n_records: 48,
            max_round: 8,
            cache_capacity: 64,
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
            store_dir: None,
            checkpoint_every: 32,
            route_retries: 2,
            retry_backoff_ms: 1,
            wear_spare_rows: 0,
            wear_migrate_threshold: 1024,
            default_deadline: None,
            max_tenant_backlog: 0,
            retry_budget_ms: 50,
            breaker_threshold: 3,
            breaker_probe_after: 2,
            brownout: false,
        });
        let rep = q.submit(0, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(rep.outputs, naive.outputs);
        // a first submission has nothing to dedupe or cache, so honoring
        // the routing objective means costs match the naive path exactly
        assert!(
            (rep.measured.energy.total() - naive.measured.energy.total()).abs()
                <= 1e-9 * naive.measured.energy.total(),
            "serve {:e} vs naive {:e}",
            rep.measured.energy.total(),
            naive.measured.energy.total()
        );
        let m = q.metrics();
        assert_eq!(m.activations, 0, "fusion must be disabled under baseline routing");
        assert_eq!(m.fused_followers, 0);
    }

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("adra_queue_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// A queue with a durable store journals its writes, checkpoints,
    /// and a RESTARTED queue over the same directory recovers contents
    /// and serves bit-identical results without re-loading the table.
    #[test]
    fn durable_queue_recovers_contents_across_restart() {
        let cfg = cfg();
        let dir = tmpdir("recover");
        let s = analytics_scenario(&cfg, 48, 11);
        let mut config = ServeConfig::new(cfg.clone(), 2, 48);
        config.store_dir = Some(dir.clone());
        config.checkpoint_every = 0; // WAL-only: recovery must replay it
        let first = {
            let q = ServeQueue::start(config.clone());
            q.submit(0, s.program.clone()).unwrap().wait().unwrap()
        }; // drop = clean shutdown; WAL holds the load's writes

        // restart over the same directory: recovery replays the WAL into
        // fresh arrays, so a query-only program (no Load step) sees the
        // table
        let q2 = ServeQueue::start(config);
        let mut query_only = s.program.clone();
        query_only.ops.remove(0); // drop the Load; broadcast + queries stay
        let rep = q2.submit(0, query_only).unwrap().wait().unwrap();
        assert_eq!(rep.outputs[s.filter_step - 1], first.outputs[s.filter_step]);
        let m = q2.metrics();
        assert_eq!(m.recoveries, 1, "startup recovery must be counted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `snapshot_to` + `restore_from` round-trips the serving state, and
    /// results served after the restore are bit-identical to before it.
    #[test]
    fn snapshot_restore_round_trips_serving_state() {
        let cfg = cfg();
        let dir = tmpdir("snaproll");
        let s = analytics_scenario(&cfg, 48, 12);
        let q = queue(48);
        let before = q.submit(0, s.program.clone()).unwrap().wait().unwrap();
        q.snapshot_to(&dir).unwrap();

        // clobber the table with different contents...
        let mut changed = s.program.clone();
        let new_values: Vec<u64> = s.values.iter().map(|v| 127 - v).collect();
        changed.ops[0] = crate::planner::IrOp::Load { start: 0, values: new_values };
        let clobbered = q.submit(0, changed).unwrap().wait().unwrap();
        assert_ne!(clobbered.outputs[s.filter_step], before.outputs[s.filter_step]);

        // ...then restore: the snapshot's contents come back exactly,
        // and NO stale cache entry leaks across the swap
        q.restore_from(&dir).unwrap();
        let mut query_only = s.program.clone();
        query_only.ops.remove(0);
        let after = q.submit(0, query_only).unwrap().wait().unwrap();
        assert_eq!(after.outputs[s.filter_step - 1], before.outputs[s.filter_step]);
        assert!(matches!(
            q.restore_from(tmpdir("snaproll_empty")),
            Err(ServeError::Store(_))
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn geometry_mismatch_is_rejected_at_submission() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 20, 6);
        let q = queue(48);
        assert_eq!(
            q.submit(0, s.program).unwrap_err(),
            ServeError::Geometry { expected: 48, got: 20 }
        );
    }

    #[test]
    fn malformed_program_answers_with_plan_error() {
        let q = queue(48);
        let mut p = Program::new(48);
        p.aggregate(crate::planner::RecordRange::new(40, 20), crate::planner::AggKind::Min);
        let res = q.submit(0, p).unwrap().wait();
        assert!(matches!(res, Err(ServeError::Plan(_))), "{res:?}");
    }

    #[test]
    fn concurrent_tenants_all_get_answers() {
        let cfg = cfg();
        let q = std::sync::Arc::new(queue(48));
        let mut handles = Vec::new();
        for t in 0..4usize {
            let q2 = q.clone();
            let cfg2 = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let s = analytics_scenario(&cfg2, 48, 7); // same table for all
                for _ in 0..3 {
                    let rep = q2.submit(t, s.program.clone()).unwrap().wait().unwrap();
                    assert_eq!(
                        rep.outputs[s.filter_step],
                        StepOutput::Matches(s.expected_matches.clone())
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = q.metrics();
        assert_eq!(m.programs, 12);
        assert_eq!(m.tenant_latency.len(), 4);
        assert!(m.rounds <= 12);
    }
}
