//! The multi-tenant serve queue: admission, round scheduling, fused
//! execution, and reply plumbing.
//!
//! One scheduler thread owns the coordinator, the cost model, the shared
//! `TableState`, and the `ResultCache`.  Clients (any number of OS
//! threads) `submit` planned programs and block on their [`Ticket`];
//! everything queued while a round executes is coalesced into the next
//! round, so batch occupancy rises exactly when the system is loaded —
//! the same backpressure-free design as `coordinator::pool`, one layer
//! up.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::cim::{CimResult, EngineError};
use crate::config::SimConfig;
use crate::coordinator::RouteError;
use crate::energy::OpCost;
use crate::metrics::RunMetrics;
use crate::observe::{self, Stage};
use crate::planner::{
    calibrate, place_calibrated, planned_coordinator, CalibratedCostModel, CalibrationSample,
    CalibrationStore, ExecError, Objective, PlanCostModel, PlanError, Placement, Program,
    SharedCalibration, StepOutput,
};

use super::cache::{ResultCache, TableState};
use super::coalesce::{coalesce_round, StepAction};
use super::control::{
    service_weights, AdmissionPolicy, BatchController, BatchPolicy, FairScheduler, ServiceWindow,
};
use super::metrics::ServeMetrics;

/// Serving deployment parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub cfg: SimConfig,
    /// Coordinator shards (worker threads / array shards).
    pub shards: usize,
    /// Routing objective for the planned workers and cost model.
    pub objective: Objective,
    /// Shared table geometry; every admitted program must match it so
    /// record slots, shard partitioning, and scratch rows line up across
    /// tenants (a mismatch is rejected at submission).
    pub n_records: usize,
    /// Max programs coalesced into one round.  Under
    /// [`BatchPolicy::Adaptive`] this is the ceiling and starting point
    /// of the EWMA controller; under [`BatchPolicy::Static`] it is the
    /// round size, as in PR 2.
    pub max_round: usize,
    /// Result-cache budget in slots (see `cache::ResultCache`).
    pub cache_capacity: usize,
    /// How rounds are selected from the backlog.
    pub admission: AdmissionPolicy,
    /// How `max_round` is governed.
    pub batch: BatchPolicy,
    /// Sample the registry into the global `observe::series()` store
    /// (and evaluate the health rules) every N rounds; `0` disables
    /// per-round sampling.  Observation only — results and modeled
    /// costs are bit-identical at any setting.
    pub sample_every: u64,
    /// Absorb each round's predicted-vs-measured samples into the
    /// calibrated cost model every N rounds; `0` disables calibration
    /// entirely (pure analytic tables, the pre-calibration behavior).
    pub calibrate_every: u64,
    /// Persist the calibration store to this path after every absorb
    /// (and seed it from there at startup), so a restarted queue keeps
    /// its learned corrections.
    pub calibration_path: Option<std::path::PathBuf>,
    /// Externally-owned store handle: seeded from at startup (when
    /// non-empty) and mirrored into after every absorb.  `None` mirrors
    /// into the process-global `planner::calibrate::shared()` cell
    /// instead (what the REPL's `calibration` commands read).
    pub calibration: Option<SharedCalibration>,
}

impl ServeConfig {
    pub fn new(cfg: SimConfig, shards: usize, n_records: usize) -> Self {
        Self {
            cfg,
            shards,
            objective: Objective::Edp,
            n_records,
            max_round: 32,
            cache_capacity: 1024,
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
        }
    }
}

/// Serving failure modes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Program geometry differs from the serve table's.
    Geometry { expected: usize, got: usize },
    Plan(PlanError),
    Route(RouteError),
    /// An engine failed mid-round (formatted op + error).
    Engine(String),
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Geometry { expected, got } => {
                write!(f, "program has {got} records, serve table has {expected}")
            }
            ServeError::Plan(e) => write!(f, "planning: {e}"),
            ServeError::Route(e) => write!(f, "routing: {e}"),
            ServeError::Engine(s) => write!(f, "engine: {s}"),
            ServeError::ShuttingDown => write!(f, "serve queue is shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a served program returns to its tenant.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Per-IR-step outputs, indexed like `Program::ops` — bit-identical
    /// to naive per-program execution.
    pub outputs: Vec<StepOutput>,
    /// Modeled cost of the ops actually executed for this program;
    /// cached steps and deduped writes contribute zero.
    pub measured: OpCost,
    /// Query steps answered from the cache.
    pub cached_steps: usize,
    /// Writes dropped by content dedup.
    pub skipped_writes: usize,
    /// Programs sharing this program's round.
    pub round_occupancy: usize,
    /// 1-based sequence number of the round that served this program —
    /// the starvation-freedom tests bound it.
    pub round: u64,
    /// Submission-to-reply wall seconds.
    pub wall: f64,
}

struct Admission {
    tenant: usize,
    program: Program,
    submitted: Instant,
    reply: Sender<Result<ServeReport, ServeError>>,
}

/// Handle to an admitted program.
pub struct Ticket {
    rx: Receiver<Result<ServeReport, ServeError>>,
}

impl Ticket {
    /// Block until the program's round completes.
    pub fn wait(self) -> Result<ServeReport, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::ShuttingDown))
    }
}

/// Monotone id source distinguishing queue instances in the registry
/// (the `queue` label): several queues can live in one process (tests,
/// the example's FIFO-vs-fair comparison) and their counters must not
/// collapse into one series.
static QUEUE_SEQ: AtomicU64 = AtomicU64::new(0);

/// The serving front door.  `Send + Sync`: submit from any thread.
pub struct ServeQueue {
    tx: Option<Sender<Admission>>,
    handle: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<ServeMetrics>>,
    n_records: usize,
    id: u64,
}

impl ServeQueue {
    /// Spawn the scheduler thread and its coordinator pool.
    pub fn start(config: ServeConfig) -> Self {
        let (tx, rx) = channel::<Admission>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let m2 = metrics.clone();
        let n_records = config.n_records;
        let id = QUEUE_SEQ.fetch_add(1, Ordering::Relaxed);
        let handle = std::thread::Builder::new()
            .name("adra-serve".into())
            .spawn(move || scheduler(config, rx, m2, id))
            .expect("spawn serve scheduler");
        Self { tx: Some(tx), handle: Some(handle), metrics, n_records, id }
    }

    /// This queue's `queue` label value in the observe registry.
    pub fn instance(&self) -> u64 {
        self.id
    }

    /// Admit a tenant's program; returns a ticket to wait on.
    pub fn submit(&self, tenant: usize, program: Program) -> Result<Ticket, ServeError> {
        if program.n_records != self.n_records {
            return Err(ServeError::Geometry {
                expected: self.n_records,
                got: program.n_records,
            });
        }
        let (reply, rx) = channel();
        let adm = Admission { tenant, program, submitted: Instant::now(), reply };
        self.tx
            .as_ref()
            .ok_or(ServeError::ShuttingDown)?
            .send(adm)
            .map_err(|_| ServeError::ShuttingDown)?;
        Ok(Ticket { rx })
    }

    /// Snapshot of the serve-layer metrics.
    pub fn metrics(&self) -> ServeMetrics {
        self.metrics.lock().expect("metrics lock").clone()
    }
}

impl Drop for ServeQueue {
    fn drop(&mut self) {
        drop(self.tx.take()); // scheduler drains and exits on disconnect
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn scheduler(
    config: ServeConfig,
    rx: Receiver<Admission>,
    metrics: Arc<Mutex<ServeMetrics>>,
    queue_id: u64,
) {
    let ServeConfig {
        cfg,
        shards,
        objective,
        n_records,
        max_round,
        cache_capacity,
        admission,
        batch,
        sample_every,
        calibrate_every,
        calibration_path,
        calibration,
    } = config;
    let coord = planned_coordinator(&cfg, shards, objective);
    // the calibrated cost model: analytic tables wrapped by the runtime
    // correction store — seeded from the shared handle (a warm daemon)
    // when it has content, else from the persisted snapshot, else empty
    // (factors 1.0 == pure analytic behavior)
    let seed_store = calibration
        .as_ref()
        .map(|s| s.lock().expect("calibration lock").clone())
        .filter(|s| !s.is_empty())
        .or_else(|| calibration_path.as_deref().map(CalibrationStore::load))
        .unwrap_or_default();
    let mut cal =
        CalibratedCostModel::with_store(PlanCostModel::new(&cfg, objective), shards, seed_store);
    // restored routing pins must reach the workers before the first round
    cal.sync_routing(&coord);
    let mut service_window = ServiceWindow::new();
    let mut state = TableState::new(&cfg, n_records);
    let mut cache = ResultCache::new(cache_capacity);
    let mut controller = match batch {
        BatchPolicy::Static => BatchController::fixed(max_round),
        BatchPolicy::Adaptive { target_p95 } => BatchController::adaptive(max_round, target_p95),
    };
    let mut backlog: FairScheduler<Admission> = FairScheduler::new(admission);
    let mut round_no: u64 = 0;
    let mut open = true;

    // observability: every counter this scheduler maintains is mirrored
    // into the global registry under the queue label, and each pipeline
    // stage records a trace span (observation only — no control flow or
    // modeled cost reads anything published here)
    let qlabel = queue_id.to_string();
    let reg = observe::global();
    let rec = observe::recorder();
    let round_wall = reg.histogram(
        "adra.serve.round_wall_ns",
        "Observed wall time per coalescing round (ns).",
        &[("queue", &qlabel)],
    );
    // self-metering: what the observer itself costs per round (publish
    // + series sample + health evaluation), gated in CI by the
    // observe-overhead ratio in BENCH_hotpath.json
    let observe_overhead = reg.histogram(
        "adra.observe.overhead_ns",
        "Per-round cost of registry publish + series sampling + health evaluation (ns).",
        &[("queue", &qlabel)],
    );

    while open || !backlog.is_empty() {
        // batch window: block for work only when the backlog is dry,
        // then sweep in everything already queued
        if backlog.is_empty() {
            match rx.recv() {
                Ok(a) => {
                    let t = a.tenant;
                    backlog.push(t, a);
                }
                Err(_) => {
                    open = false;
                    continue;
                }
            }
        }
        while open {
            match rx.try_recv() {
                Ok(a) => {
                    let t = a.tenant;
                    backlog.push(t, a);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }

        // round selection: WFQ (or FIFO) over the backlog, sized by the
        // adaptive controller, weighted by the latency histograms
        let schedule_start = Instant::now();
        let weights = {
            let m = metrics.lock().expect("metrics lock");
            service_weights(&mut service_window, &m.tenant_latency, &m.tenant_energy)
        };
        let selection = backlog
            .next_round(controller.max_round(), |t| weights.get(&t).copied().unwrap_or(1.0));
        let admitted = selection.admitted;
        if admitted.is_empty() {
            continue;
        }
        round_no += 1;
        rec.record_span(
            round_no,
            None,
            Stage::Schedule,
            schedule_start.elapsed().as_nanos() as u64,
            admitted.len() as u64,
        );
        let round_start = Instant::now();

        // place each program; planning failures answer immediately
        let mut round: Vec<(Admission, Placement)> = Vec::with_capacity(admitted.len());
        for a in admitted {
            rec.record_span(
                round_no,
                Some(a.tenant as u64),
                Stage::Admit,
                a.submitted.elapsed().as_nanos() as u64,
                1,
            );
            match place_calibrated(&a.program, &cfg, shards, &cal) {
                Ok(p) => round.push((a, p)),
                Err(e) => {
                    let _ = a.reply.send(Err(ServeError::Plan(e)));
                }
            }
        }
        if round.is_empty() {
            continue;
        }
        let occupancy = round.len();

        // the fused path forces dual ops onto the ADRA engine; honor the
        // CALIBRATED routing by fusing only when every shard's dual ops
        // route there anyway (the analytic model routes them to the
        // baseline under the energy objective on voltage scheme 1, and
        // calibration can flip the decision either way at runtime —
        // force-fusing against it would cost MORE energy).  Dedup and
        // caching stay on either way; they are objective-neutral.
        let fuse = cal.fuse_dual_on_adra();
        let placements: Vec<&Placement> = round.iter().map(|(_, p)| p).collect();
        let coalesce_start = Instant::now();
        let coalesced = coalesce_round(&placements, &mut state, &mut cache, fuse);
        rec.record_span(
            round_no,
            None,
            Stage::Coalesce,
            coalesce_start.elapsed().as_nanos() as u64,
            coalesced.stats.coalesced_ops,
        );
        // fusion is planned during coalescing and executed inside the
        // shard batches; its span is an annotation carrying the forecast
        // activation count
        rec.record_span(round_no, None, Stage::Fuse, 0, coalesced.stats.activations);

        // execute every shard batch in parallel, fused when routing allows
        let execute_start = Instant::now();
        let coord_ref = &coord;
        let shard_results: Vec<Result<Vec<Result<CimResult, EngineError>>, RouteError>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = coalesced
                    .shard_batches
                    .iter()
                    .map(|b| {
                        s.spawn(move || {
                            if fuse {
                                coord_ref.call_batch_fused(b.shard, &b.ops)
                            } else {
                                coord_ref.call_batch(b.shard, &b.ops)
                            }
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("serve shard thread panicked"))
                    .collect()
            });
        rec.record_span(
            round_no,
            None,
            Stage::Execute,
            execute_start.elapsed().as_nanos() as u64,
            coalesced.shard_batches.iter().map(|b| b.ops.len() as u64).sum(),
        );

        let mut results: Vec<Vec<Result<CimResult, EngineError>>> =
            Vec::with_capacity(shard_results.len());
        let mut route_err = None;
        for r in shard_results {
            match r {
                Ok(v) => results.push(v),
                Err(e) => {
                    route_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = route_err {
            for (a, _) in round {
                let _ = a.reply.send(Err(ServeError::Route(e.clone())));
            }
            continue;
        }

        // demultiplex worker replies back to (program, shard plan, op)
        let mut slots: Vec<Vec<Vec<Option<Result<CimResult, EngineError>>>>> = round
            .iter()
            .map(|(_, p)| {
                p.shards.iter().map(|sp| vec![None; sp.lowered.ops.len()]).collect()
            })
            .collect();
        for (b, res) in coalesced.shard_batches.iter().zip(&results) {
            for (i, &(pi, spi, oi)) in b.origins.iter().enumerate() {
                slots[pi][spi][oi] = Some(res[i].clone());
            }
        }

        // close the control loop on this round's observed wall time
        let round_wall_s = round_start.elapsed().as_secs_f64();
        controller.observe(round_wall_s, occupancy);
        round_wall.record(round_wall_s * 1e9);

        let coord_metrics: RunMetrics = coord.metrics();
        {
            let mut m = metrics.lock().expect("metrics lock");
            m.observe_round(occupancy as u64, &coalesced.stats, selection.quota_hits, selection.deferred);
            m.invalidating_writes = state.invalidating_writes;
            m.observe_controller(
                controller.grows,
                controller.shrinks,
                controller.holds,
                controller.max_round() as u64,
            );
            // engine-level per-tier activation split (pool snapshot, not
            // a per-round delta)
            m.observe_array(&coord_metrics.array);
        }

        // assemble per program, splice cached outputs, memoize fresh ones
        let cache_start = Instant::now();
        let mut round_samples: Vec<CalibrationSample> = Vec::new();
        for (((a, placement), per_shard), pa) in
            round.into_iter().zip(slots).zip(&coalesced.programs)
        {
            let reply = match placement.assemble(per_shard, coord_metrics.clone()) {
                Err(ExecError::Route(r)) => Err(ServeError::Route(r)),
                Err(other) => Err(ServeError::Engine(other.to_string())),
                Ok(mut rep) => {
                    round_samples.append(&mut rep.samples);
                    for (g, action) in pa.actions.iter().enumerate() {
                        match action {
                            StepAction::Cached(out) => rep.outputs[g] = out.clone(),
                            StepAction::RunAndCache(key) => {
                                cache.insert(*key, rep.outputs[g].clone(), &state);
                            }
                            _ => {}
                        }
                    }
                    let wall = a.submitted.elapsed().as_secs_f64();
                    metrics
                        .lock()
                        .expect("metrics lock")
                        .record_service(a.tenant, wall, rep.measured.energy.total());
                    Ok(ServeReport {
                        outputs: rep.outputs,
                        measured: rep.measured,
                        cached_steps: pa.cached_steps,
                        skipped_writes: pa.skipped_writes,
                        round_occupancy: occupancy,
                        round: round_no,
                        wall,
                    })
                }
            };
            let _ = a.reply.send(reply);
        }

        rec.record_span(
            round_no,
            None,
            Stage::Cache,
            cache_start.elapsed().as_nanos() as u64,
            coalesced.stats.cached_steps,
        );

        // close the calibration loop: fold this round's predicted-vs-
        // measured samples into the correction store, re-sync worker
        // routing on a committed flip, persist the snapshot, and mirror
        // the store into the shared handle the REPL reads.  With exact
        // tables this is a no-op (factors stay 1.0) — see the
        // `exact_tables` invariance tests.
        if calibrate_every > 0 && round_no % calibrate_every == 0 && !round_samples.is_empty() {
            let flipped = cal.absorb(&round_samples);
            if flipped {
                cal.sync_routing(&coord);
            }
            cal.publish(reg);
            if let Some(p) = &calibration_path {
                let _ = cal.store().save(p);
            }
            let mirror = calibration.as_ref().unwrap_or_else(|| calibrate::shared());
            *mirror.lock().expect("calibration lock") = cal.store().clone();
        }

        // post-insert cache counters (inserts above may have evicted);
        // negative hits instead accumulate per round from RoundStats —
        // lookups only happen during coalescing; then mirror everything
        // into the registry so a scrape taken between rounds is current
        let observe_start = Instant::now();
        {
            let mut m = metrics.lock().expect("metrics lock");
            m.cache_evictions = cache.evictions;
            m.cache_swept = cache.swept;
            m.publish(reg, &qlabel);
        }
        coord_metrics.publish(reg, &[("queue", &qlabel)]);
        // time-series sampling + health evaluation at the configured
        // cadence: the published state above becomes one point per
        // series, and rule transitions alert into the recorder
        if sample_every > 0 && round_no % sample_every == 0 {
            let store = observe::series();
            store.sample(reg);
            observe::health()
                .lock()
                .expect("health lock")
                .evaluate(store, reg, rec);
        }
        observe_overhead.record(observe_start.elapsed().as_nanos() as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SensingScheme;
    use crate::planner::{place, StepOutput};
    use crate::workload::analytics_scenario;

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c.max_batch = 16;
        c
    }

    fn queue(n_records: usize) -> ServeQueue {
        ServeQueue::start(ServeConfig::new(cfg(), 2, n_records))
    }

    #[test]
    fn served_outputs_match_naive_execution() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 48, 3);
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let pl = place(&s.program, &cfg, 2, &model).unwrap();
        let naive_coord = planned_coordinator(&cfg, 2, Objective::Edp);
        let naive = pl.execute(&naive_coord).unwrap();

        let q = queue(48);
        let rep = q.submit(0, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(rep.outputs, naive.outputs);
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(s.expected_matches));
    }

    #[test]
    fn repeat_program_is_served_from_cache_and_dedup() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 48, 4);
        let q = queue(48);
        let first = q.submit(1, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(first.cached_steps, 0);
        assert!(first.measured.energy.total() > 0.0);

        // waiting for the first reply guarantees a separate round, so the
        // repeat hits the now-populated cache and the dedup shadow
        let second = q.submit(1, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(second.outputs, first.outputs, "bit-identical");
        assert_eq!(second.cached_steps, 3, "filter+compare+aggregate cached");
        assert!(second.skipped_writes >= 48, "loads deduped");
        assert_eq!(second.measured.energy.total(), 0.0, "nothing touched the array");

        let m = q.metrics();
        assert_eq!(m.programs, 2);
        assert!(m.cache_hit_rate() > 0.0);
        assert_eq!(m.invalidating_writes, 48, "only the first load changed contents");
    }

    #[test]
    fn overlapping_load_invalidates_cached_results() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 48, 5);
        let q = queue(48);
        let first = q.submit(0, s.program.clone()).unwrap().wait().unwrap();

        // rewrite every record with its complement, then re-query
        let mut changed = s.program.clone();
        let new_values: Vec<u64> = s.values.iter().map(|v| 127 - v).collect();
        changed.ops[0] = crate::planner::IrOp::Load { start: 0, values: new_values.clone() };
        let rep = q.submit(0, changed).unwrap().wait().unwrap();
        assert_eq!(rep.cached_steps, 0, "stale entries must not serve");
        let want: Vec<usize> = new_values
            .iter()
            .enumerate()
            .filter(|(_, &v)| v < s.threshold)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(rep.outputs[s.filter_step], StepOutput::Matches(want));
        assert_ne!(rep.outputs[s.filter_step], first.outputs[s.filter_step]);
    }

    /// Under the energy objective on voltage scheme 1 the cost model
    /// routes dual ops to the baseline executor; the serve layer must
    /// honor that instead of force-fusing everything onto ADRA (which
    /// would cost MORE energy than the naive routed path).
    #[test]
    fn baseline_routed_objectives_are_not_force_fused() {
        let mut cfg = cfg();
        cfg.scheme = SensingScheme::VoltagePrecharged;
        let s = analytics_scenario(&cfg, 48, 8);
        let model = PlanCostModel::new(&cfg, Objective::Energy);
        let pl = place(&s.program, &cfg, 2, &model).unwrap();
        let naive_coord = planned_coordinator(&cfg, 2, Objective::Energy);
        let naive = pl.execute(&naive_coord).unwrap();

        let q = ServeQueue::start(ServeConfig {
            cfg: cfg.clone(),
            shards: 2,
            objective: Objective::Energy,
            n_records: 48,
            max_round: 8,
            cache_capacity: 64,
            admission: AdmissionPolicy::Fair,
            batch: BatchPolicy::Adaptive { target_p95: 2e-3 },
            sample_every: 1,
            calibrate_every: 1,
            calibration_path: None,
            calibration: None,
        });
        let rep = q.submit(0, s.program.clone()).unwrap().wait().unwrap();
        assert_eq!(rep.outputs, naive.outputs);
        // a first submission has nothing to dedupe or cache, so honoring
        // the routing objective means costs match the naive path exactly
        assert!(
            (rep.measured.energy.total() - naive.measured.energy.total()).abs()
                <= 1e-9 * naive.measured.energy.total(),
            "serve {:e} vs naive {:e}",
            rep.measured.energy.total(),
            naive.measured.energy.total()
        );
        let m = q.metrics();
        assert_eq!(m.activations, 0, "fusion must be disabled under baseline routing");
        assert_eq!(m.fused_followers, 0);
    }

    #[test]
    fn geometry_mismatch_is_rejected_at_submission() {
        let cfg = cfg();
        let s = analytics_scenario(&cfg, 20, 6);
        let q = queue(48);
        assert_eq!(
            q.submit(0, s.program).unwrap_err(),
            ServeError::Geometry { expected: 48, got: 20 }
        );
    }

    #[test]
    fn malformed_program_answers_with_plan_error() {
        let q = queue(48);
        let mut p = Program::new(48);
        p.aggregate(crate::planner::RecordRange::new(40, 20), crate::planner::AggKind::Min);
        let res = q.submit(0, p).unwrap().wait();
        assert!(matches!(res, Err(ServeError::Plan(_))), "{res:?}");
    }

    #[test]
    fn concurrent_tenants_all_get_answers() {
        let cfg = cfg();
        let q = std::sync::Arc::new(queue(48));
        let mut handles = Vec::new();
        for t in 0..4usize {
            let q2 = q.clone();
            let cfg2 = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let s = analytics_scenario(&cfg2, 48, 7); // same table for all
                for _ in 0..3 {
                    let rep = q2.submit(t, s.program.clone()).unwrap().wait().unwrap();
                    assert_eq!(
                        rep.outputs[s.filter_step],
                        StepOutput::Matches(s.expected_matches.clone())
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = q.metrics();
        assert_eq!(m.programs, 12);
        assert_eq!(m.tenant_latency.len(), 4);
        assert!(m.rounds <= 12);
    }
}
