//! The per-shard coalescer: merge one round of placed programs into one
//! batch per shard, drop provably redundant writes, answer query steps
//! from the result cache, and count the fusion the workers will realize.
//!
//! Correctness argument (property-tested in `tests/serve_equivalence`):
//! shard state is private to its worker, and per shard the coalesced
//! batch is exactly the concatenation, in admission order, of each
//! program's shard-local stream — i.e. the very op sequence sequential
//! per-program execution would issue.  On top of that sequence,
//! * fusion regroups dual ops without crossing a write to either operand
//!   row (`coordinator::fuse`), so derived values are unchanged;
//! * a deduped write rewrote known-equal masked contents, a state no-op;
//! * a cached step's key pins (kind, range fingerprint, rhs contents),
//!   which fully determine its output.

use crate::cim::CimOp;
use crate::coordinator::fuse::{fuse_batch, fused_followers, planned_activations, PlanStep};
use crate::planner::{IrOp, Placement, StepOutput};

use super::cache::{key_for, CacheKey, ResultCache, TableState};

/// What the coalescer decided for one global IR step of one program.
#[derive(Clone, Debug)]
pub enum StepAction {
    /// Execute every lowered op of the step.
    Run,
    /// Load step: per-value redundancy flags (`true` = drop that write).
    RunPartial(Vec<bool>),
    /// Broadcast step whose contents are already in place on every shard.
    Skip,
    /// Query step answered from the cache.
    Cached(StepOutput),
    /// Query step to execute and memoize under this key.
    RunAndCache(CacheKey),
}

/// Per-program coalescing decisions, indexed like `Program::ops`.
#[derive(Clone, Debug)]
pub struct ProgramActions {
    pub actions: Vec<StepAction>,
    pub skipped_writes: usize,
    pub cached_steps: usize,
}

/// One shard's merged multi-program batch.
#[derive(Clone, Debug, Default)]
pub struct ShardBatch {
    pub shard: usize,
    pub ops: Vec<CimOp>,
    /// For each op: (program index in the round, shard-plan index in that
    /// program's placement, op index in that shard plan's lowered
    /// stream).  The executor's reply is demultiplexed through this.
    pub origins: Vec<(usize, usize, usize)>,
}

/// Round-level coalescing/fusion statistics.  The fusion numbers are a
/// forecast of the plan the workers deterministically recompute.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundStats {
    /// Lowered ops across the round before dedup/caching.
    pub submitted_ops: u64,
    /// Ops actually shipped to workers.
    pub coalesced_ops: u64,
    pub skipped_writes: u64,
    pub cached_steps: u64,
    pub cache_misses: u64,
    /// Cached steps answered by zero-weight negative (empty-filter)
    /// entries — a subset of `cached_steps`.
    pub negative_hits: u64,
    pub dual_ops: u64,
    /// Activations the fused batches will issue.
    pub activations: u64,
    /// Dual ops served as followers of an already-latched activation.
    pub fused_followers: u64,
    /// Follower ops whose activation was opened by a DIFFERENT program.
    pub cross_program_fused_ops: u64,
}

/// A coalesced round ready for fused execution.
#[derive(Clone, Debug)]
pub struct CoalescedRound {
    pub shard_batches: Vec<ShardBatch>,
    pub programs: Vec<ProgramActions>,
    pub stats: RoundStats,
}

/// Coalesce one round of placed programs (admission order) against the
/// shared table state and result cache.  Mutates `state` with every
/// observed write and charges cache hit/miss counters; cache *inserts*
/// happen post-execution (`ResultCache::insert`) with the keys returned
/// in `StepAction::RunAndCache`.
///
/// `fuse` mirrors how the round will execute: the fused path forces
/// dual ops onto the ADRA engine, so the queue disables it whenever the
/// cost model routes dual ops to the baseline executor (energy
/// objective under voltage scheme 1) — dedup and caching still apply,
/// and the fusion forecast is skipped to match.
pub fn coalesce_round(
    placements: &[&Placement],
    state: &mut TableState,
    cache: &mut ResultCache,
    fuse: bool,
) -> CoalescedRound {
    let n_shards = placements
        .iter()
        .flat_map(|p| p.shards.iter().map(|sp| sp.shard + 1))
        .max()
        .unwrap_or(0);
    let mut batches: Vec<ShardBatch> = (0..n_shards)
        .map(|shard| ShardBatch { shard, ..Default::default() })
        .collect();
    let mut programs = Vec::with_capacity(placements.len());
    let mut stats = RoundStats::default();
    let negative_hits_before = cache.negative_hits;

    for (pi, placement) in placements.iter().enumerate() {
        // pass 1: walk the GLOBAL program in order, updating the shared
        // table view and deciding each step's action.  Later programs in
        // the round see earlier programs' (not-yet-executed but
        // guaranteed-to-succeed) writes, exactly as sequential execution
        // would.
        let mut actions = Vec::with_capacity(placement.program.ops.len());
        for op in &placement.program.ops {
            let action = match op {
                IrOp::Load { start, values } => StepAction::RunPartial(
                    values
                        .iter()
                        .enumerate()
                        .map(|(j, &v)| state.record_write(start + j, v))
                        .collect(),
                ),
                IrOp::Broadcast { scratch, value } => {
                    if state.scratch_write(scratch.0, *value) {
                        StepAction::Skip
                    } else {
                        StepAction::Run
                    }
                }
                query => match key_for(query, state) {
                    Some(key) => match cache.lookup(&key) {
                        Some(out) => StepAction::Cached(out),
                        None => StepAction::RunAndCache(key),
                    },
                    None => StepAction::Run,
                },
            };
            actions.push(action);
        }

        // pass 2: apply the decisions to every shard plan's lowered
        // stream, appending surviving ops to the shard batches
        let mut skipped_writes = 0usize;
        for (spi, sp) in placement.shards.iter().enumerate() {
            stats.submitted_ops += sp.lowered.ops.len() as u64;
            for span in &sp.lowered.spans {
                let g = sp.ir_map[span.ir_index];
                match &actions[g] {
                    StepAction::Skip => skipped_writes += span.len,
                    StepAction::Cached(_) => {}
                    StepAction::RunPartial(flags) => {
                        // the clipped load's k-th write covers global slot
                        // record_offset + local_start + k; flags are
                        // indexed from the global load's start
                        let local_start = match &sp.program.ops[span.ir_index] {
                            IrOp::Load { start, .. } => *start,
                            other => unreachable!("RunPartial on non-load {other:?}"),
                        };
                        let global_start = match &placement.program.ops[g] {
                            IrOp::Load { start, .. } => *start,
                            other => unreachable!("RunPartial on non-load {other:?}"),
                        };
                        for k in 0..span.len {
                            let slot = sp.record_offset + local_start + k;
                            if flags[slot - global_start] {
                                skipped_writes += 1;
                            } else {
                                batches[sp.shard].ops.push(sp.lowered.ops[span.start + k].op);
                                batches[sp.shard].origins.push((pi, spi, span.start + k));
                            }
                        }
                    }
                    StepAction::Run | StepAction::RunAndCache(_) => {
                        for k in 0..span.len {
                            batches[sp.shard].ops.push(sp.lowered.ops[span.start + k].op);
                            batches[sp.shard].origins.push((pi, spi, span.start + k));
                        }
                    }
                }
            }
        }

        let cached_steps =
            actions.iter().filter(|a| matches!(a, StepAction::Cached(_))).count();
        stats.cached_steps += cached_steps as u64;
        stats.cache_misses += actions
            .iter()
            .filter(|a| matches!(a, StepAction::RunAndCache(_)))
            .count() as u64;
        stats.skipped_writes += skipped_writes as u64;
        programs.push(ProgramActions { actions, skipped_writes, cached_steps });
    }

    stats.negative_hits = cache.negative_hits - negative_hits_before;

    // fusion forecast over the merged batches (the workers recompute the
    // same deterministic plan; this serial pass is O(ops) bookkeeping)
    for b in &batches {
        stats.coalesced_ops += b.ops.len() as u64;
        stats.dual_ops += b.ops.iter().filter(|o| o.is_dual()).count() as u64;
        if !fuse {
            continue;
        }
        let plan = fuse_batch(&b.ops);
        stats.activations += planned_activations(&plan) as u64;
        stats.fused_followers += fused_followers(&plan) as u64;
        for step in &plan {
            if let PlanStep::Fused { indices, .. } = step {
                let first_prog = b.origins[indices[0]].0;
                stats.cross_program_fused_ops += indices
                    .iter()
                    .filter(|&&i| b.origins[i].0 != first_prog)
                    .count() as u64;
            }
        }
    }

    CoalescedRound { shard_batches: batches, programs, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SensingScheme, SimConfig};
    use crate::planner::{place, Objective, PlanCostModel, Predicate, Program};
    use crate::workload::{analytics_scenario, diff_scenario};

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c.max_batch = 16;
        c
    }

    #[test]
    fn identical_programs_dedupe_and_cache() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let s = analytics_scenario(&cfg, 40, 5);
        let p1 = place(&s.program, &cfg, 2, &model).unwrap();
        let p2 = p1.clone();
        let mut state = TableState::new(&cfg, 40);
        let mut cache = ResultCache::new(64);

        let round = coalesce_round(&[&p1, &p2], &mut state, &mut cache, true);
        // program 0 runs everything (first sight of the table)
        assert_eq!(round.programs[0].skipped_writes, 0);
        assert_eq!(round.programs[0].cached_steps, 0);
        // program 1: all writes deduped, no queries executed twice IN THE
        // SAME round (cache inserts happen post-execution, so its queries
        // are misses here — but every one of its dual ops fuses onto
        // program 0's activations)
        let broadcast_writes = 2 * cfg.words_per_row(); // replicated on 2 shards
        assert_eq!(round.programs[1].skipped_writes, 40 + broadcast_writes);
        assert!(round.stats.cross_program_fused_ops > 0, "{:?}", round.stats);
        assert_eq!(
            round.stats.submitted_ops - round.stats.coalesced_ops,
            round.stats.skipped_writes,
            "no steps were cached, so only dedup may drop ops"
        );
    }

    #[test]
    fn second_round_hits_the_cache() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let s = analytics_scenario(&cfg, 40, 6);
        let pl = place(&s.program, &cfg, 2, &model).unwrap();
        let mut state = TableState::new(&cfg, 40);
        let mut cache = ResultCache::new(64);

        let r1 = coalesce_round(&[&pl], &mut state, &mut cache, true);
        // simulate post-execution inserts
        for (g, a) in r1.programs[0].actions.iter().enumerate() {
            if let StepAction::RunAndCache(key) = a {
                cache.insert(*key, StepOutput::Matches(vec![g]), &state);
            }
        }
        let r2 = coalesce_round(&[&pl], &mut state, &mut cache, true);
        // filter + compare + aggregate all hit; loads/broadcast deduped
        assert_eq!(r2.programs[0].cached_steps, 3);
        assert_eq!(r2.stats.coalesced_ops, 0, "repeat round touches no array");

        // an overlapping load with NEW contents invalidates
        let mut changed = s.program.clone();
        changed.ops[0] = IrOp::Load { start: 0, values: vec![255; 40] };
        let pl3 = place(&changed, &cfg, 2, &model).unwrap();
        let r3 = coalesce_round(&[&pl3], &mut state, &mut cache, true);
        assert_eq!(r3.programs[0].cached_steps, 0, "stale keys must miss");
        assert_eq!(r3.programs[0].skipped_writes, 2 * cfg.words_per_row());
    }

    #[test]
    fn negative_hits_are_counted_per_round() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        let mut p = Program::new(24);
        let t = p.scratch();
        let all = p.all();
        p.load(0, (0..24).map(|i| i as u64).collect());
        p.broadcast(t, 0);
        p.filter(all, t, Predicate::Lt); // v < 0: never matches
        let pl = place(&p, &cfg, 2, &model).unwrap();
        let mut state = TableState::new(&cfg, 24);
        let mut cache = ResultCache::new(64);

        let r1 = coalesce_round(&[&pl], &mut state, &mut cache, true);
        assert_eq!(r1.stats.negative_hits, 0, "first sight misses");
        for a in r1.programs[0].actions.iter() {
            if let StepAction::RunAndCache(key) = a {
                cache.insert(*key, StepOutput::Matches(Vec::new()), &state);
            }
        }
        let r2 = coalesce_round(&[&pl], &mut state, &mut cache, true);
        assert_eq!(r2.stats.negative_hits, 1, "the empty filter hit the negative cache");
        assert_eq!(r2.stats.cached_steps, 1);
        assert_eq!(r2.stats.coalesced_ops, 0, "repeat round touches no array");
    }

    #[test]
    fn mixed_query_kinds_fuse_across_programs() {
        let cfg = cfg();
        let model = PlanCostModel::new(&cfg, Objective::Edp);
        // same table + same broadcast contents, different query kinds:
        // the diff program's subs ride the analytics program's compares
        let a = analytics_scenario(&cfg, 32, 9);
        let d = diff_scenario(&cfg, 32, 9);
        let pa = place(&a.program, &cfg, 2, &model).unwrap();
        let pd = place(&d.program, &cfg, 2, &model).unwrap();
        let mut state = TableState::new(&cfg, 32);
        let mut cache = ResultCache::new(64);
        let round = coalesce_round(&[&pa, &pd], &mut state, &mut cache, true);
        assert!(
            round.stats.cross_program_fused_ops >= 32,
            "every sub must follow a compare's activation: {:?}",
            round.stats
        );
        assert_eq!(round.programs[1].skipped_writes, 32 + 2 * cfg.words_per_row());
    }
}
