//! Deterministic, seeded fault injection for the durability/chaos suite.
//!
//! A process-global injector threads four fault families through the
//! stack (ISSUE 9, ROADMAP item 5):
//!
//! * **worker death** — the coordinator's worker loop exits mid-batch
//!   after a configured number of ops on a shard, so the serve scheduler
//!   must detect the `RouteError`, respawn the worker, replay durable
//!   contents, and retry (`serve::queue`);
//! * **latency spikes** — a configured stall is injected before every
//!   Nth op, exercising the batch controller's multiplicative decrease;
//! * **endurance-drift acceleration** — wear accounting multiplies every
//!   observed write by `wear_factor`, compressing a months-long soak
//!   into one test run;
//! * **storage corruption** — WAL records and snapshots get seeded byte
//!   flips as they are written, which the store's checksums must detect
//!   and recover from (`store::DurableStore`).
//!
//! The happy path pays exactly ONE relaxed atomic load per hook
//! ([`active`] is `false` unless a spec is installed); everything else
//! lives behind that branch.  Injection points are deterministic given a
//! spec: per-shard op counters drive death/spike schedules, and byte
//! flips come from a `SplitMix64` stream seeded by `FaultSpec::seed`, so
//! a failing chaos run replays exactly.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::rng::SplitMix64;

/// Most shards any one process realistically runs; per-shard fault
/// counters index `shard % MAX_SHARDS`.
const MAX_SHARDS: usize = 64;

/// What faults to inject and when.  All schedules are deterministic
/// counters, not probabilities, so tests replay bit-identically.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    /// Seed for the corruption byte-flip stream.
    pub seed: u64,
    /// Kill a worker after every Nth op it executes (per shard).
    pub death_every: Option<u64>,
    /// Total worker deaths to inject before the death schedule disarms
    /// (bounds chaos so bounded retries can win).
    pub death_max: u64,
    /// Stall before every Nth op (per shard).
    pub spike_every: Option<u64>,
    /// Stall duration in nanoseconds.
    pub spike_ns: u64,
    /// Multiply wear accounting by this factor (endurance drift).
    pub wear_factor: u64,
    /// Flip a byte in every Nth WAL record as it is encoded.
    pub corrupt_wal_every: Option<u64>,
    /// Flip a byte in the next snapshot written, then disarm.
    pub corrupt_snapshot: bool,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self {
            seed: 0xFA_017,
            death_every: None,
            death_max: 1,
            spike_every: None,
            spike_ns: 1_000_000,
            wear_factor: 1,
            corrupt_wal_every: None,
            corrupt_snapshot: false,
        }
    }
}

impl FaultSpec {
    /// Parse a space-separated `key=value` spec string (the REPL `faults`
    /// command).  Keys: `seed=N`, `death=N` (every Nth op),
    /// `death-max=N`, `spike=N` (every Nth op), `spike-ns=N`, `wear=N`
    /// (factor), `corrupt-wal=N`, `corrupt-snapshot`.
    ///
    /// The parser is strict, and every error names the offending key: a
    /// typoed key (`spkie=16`), a stray value on a flag key
    /// (`corrupt-snapshot=5`), or a duplicated key all fail the whole
    /// spec rather than silently disarming part of the chaos plan.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut spec = Self::default();
        let mut seen: Vec<&str> = Vec::new();
        for tok in text.split_whitespace() {
            let (key, val) = match tok.split_once('=') {
                Some((k, v)) => (k, Some(v)),
                None => (tok, None),
            };
            let num = || -> Result<u64, String> {
                val.ok_or_else(|| format!("{key}: missing value"))?
                    .parse::<u64>()
                    .map_err(|e| format!("{key}: {e}"))
            };
            match key {
                "seed" => spec.seed = num()?,
                "death" => spec.death_every = Some(num()?.max(1)),
                "death-max" => spec.death_max = num()?,
                "spike" => spec.spike_every = Some(num()?.max(1)),
                "spike-ns" => spec.spike_ns = num()?,
                "wear" => spec.wear_factor = num()?.max(1),
                "corrupt-wal" => spec.corrupt_wal_every = Some(num()?.max(1)),
                "corrupt-snapshot" => {
                    if val.is_some() {
                        return Err(format!("{key}: takes no value"));
                    }
                    spec.corrupt_snapshot = true;
                }
                other => return Err(format!("unknown fault key {other:?}")),
            }
            if seen.contains(&key) {
                return Err(format!("duplicate fault key {key:?}"));
            }
            seen.push(key);
        }
        Ok(spec)
    }

    /// One-line human-readable rendering (REPL `faults` with no args).
    pub fn render(&self) -> String {
        let mut parts = vec![format!("seed={}", self.seed)];
        if let Some(n) = self.death_every {
            parts.push(format!("death={n} death-max={}", self.death_max));
        }
        if let Some(n) = self.spike_every {
            parts.push(format!("spike={n} spike-ns={}", self.spike_ns));
        }
        if self.wear_factor > 1 {
            parts.push(format!("wear={}", self.wear_factor));
        }
        if let Some(n) = self.corrupt_wal_every {
            parts.push(format!("corrupt-wal={n}"));
        }
        if self.corrupt_snapshot {
            parts.push("corrupt-snapshot".into());
        }
        parts.join(" ")
    }
}

/// Action a worker must take before executing its next op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerFault {
    None,
    /// Exit the worker loop without replying (callers see
    /// `RouteError::ShuttingDown`).
    Die,
    /// Stall for this many nanoseconds, then execute normally.
    Delay(u64),
}

struct Injector {
    spec: FaultSpec,
    rng: SplitMix64,
    deaths_injected: u64,
    wal_records_seen: u64,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static INJECTOR: Mutex<Option<Injector>> = Mutex::new(None);
// Per-shard op counters live outside the mutex: the worker hot path
// under an installed spec bumps its own cell without contending on the
// injector lock unless a schedule actually fires.
static SHARD_OPS: [AtomicU64; MAX_SHARDS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const Z: AtomicU64 = AtomicU64::new(0);
    [Z; MAX_SHARDS]
};

/// Whether any fault spec is installed.  The ONLY cost fault injection
/// adds to the happy path: one relaxed load, false by default
/// (bench-gated in `BENCH_hotpath.json`).
#[inline(always)]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Install a spec (replacing any previous one) and arm the hooks.
pub fn install(spec: FaultSpec) {
    let seed = spec.seed;
    *INJECTOR.lock().expect("faults lock") = Some(Injector {
        spec,
        rng: SplitMix64::new(seed ^ 0xC0_22_0F_AA),
        deaths_injected: 0,
        wal_records_seen: 0,
    });
    for c in &SHARD_OPS {
        c.store(0, Ordering::Relaxed);
    }
    ACTIVE.store(true, Ordering::Relaxed);
}

/// Disarm and forget the installed spec.
pub fn clear() {
    ACTIVE.store(false, Ordering::Relaxed);
    *INJECTOR.lock().expect("faults lock") = None;
}

/// The currently installed spec, if any.
pub fn spec() -> Option<FaultSpec> {
    INJECTOR.lock().expect("faults lock").as_ref().map(|i| i.spec.clone())
}

fn count_injection(kind: &str) {
    crate::observe::global()
        .counter("adra.faults.injected", "Faults injected by the chaos layer.", &[("kind", kind)])
        .inc();
}

/// Worker-loop hook: what (if anything) to inject before the next op on
/// `shard`.  Call only when [`active`] — the caller owns the fast-path
/// branch.
pub fn on_worker_op(shard: usize) -> WorkerFault {
    let n = SHARD_OPS[shard % MAX_SHARDS].fetch_add(1, Ordering::Relaxed) + 1;
    let mut guard = INJECTOR.lock().expect("faults lock");
    let Some(inj) = guard.as_mut() else { return WorkerFault::None };
    if let Some(every) = inj.spec.death_every {
        if n % every == 0 && inj.deaths_injected < inj.spec.death_max {
            inj.deaths_injected += 1;
            drop(guard);
            count_injection("worker_death");
            return WorkerFault::Die;
        }
    }
    if let Some(every) = inj.spec.spike_every {
        if n % every == 0 {
            let ns = inj.spec.spike_ns;
            drop(guard);
            count_injection("latency_spike");
            return WorkerFault::Delay(ns);
        }
    }
    WorkerFault::None
}

/// Endurance-drift hook: how many device cycles one observed write
/// charges.  1 when no spec is installed.
pub fn wear_factor() -> u64 {
    if !active() {
        return 1;
    }
    INJECTOR
        .lock()
        .expect("faults lock")
        .as_ref()
        .map(|i| i.spec.wear_factor)
        .unwrap_or(1)
}

/// Storage hook: maybe flip a byte in an encoded WAL record (AFTER its
/// checksum was computed, so the corruption is detectable).  Returns
/// `true` when a flip was injected.
pub fn corrupt_wal(buf: &mut [u8]) -> bool {
    if !active() || buf.is_empty() {
        return false;
    }
    let mut guard = INJECTOR.lock().expect("faults lock");
    let Some(inj) = guard.as_mut() else { return false };
    inj.wal_records_seen += 1;
    let Some(every) = inj.spec.corrupt_wal_every else { return false };
    if inj.wal_records_seen % every != 0 {
        return false;
    }
    let at = (inj.rng.next_u64() as usize) % buf.len();
    buf[at] ^= 0x5A;
    drop(guard);
    count_injection("wal_corruption");
    true
}

/// Storage hook: maybe flip a byte in an encoded snapshot, then disarm
/// (one torn snapshot per spec).  Returns `true` when a flip was
/// injected.
pub fn corrupt_snapshot(buf: &mut [u8]) -> bool {
    if !active() || buf.is_empty() {
        return false;
    }
    let mut guard = INJECTOR.lock().expect("faults lock");
    let Some(inj) = guard.as_mut() else { return false };
    if !inj.spec.corrupt_snapshot {
        return false;
    }
    inj.spec.corrupt_snapshot = false;
    let at = (inj.rng.next_u64() as usize) % buf.len();
    buf[at] ^= 0x5A;
    drop(guard);
    count_injection("snapshot_corruption");
    true
}

/// Serializes tests that install process-global fault specs — the
/// injector is shared state, so chaos tests across modules (pool, store,
/// serve queue, this one) must not overlap.  Test infrastructure, not
/// serving API.
#[doc(hidden)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_by_default_and_hooks_are_noops() {
        let _g = test_lock();
        clear();
        assert!(!active());
        assert_eq!(wear_factor(), 1);
        let mut buf = vec![7u8; 16];
        assert!(!corrupt_wal(&mut buf));
        assert!(!corrupt_snapshot(&mut buf));
        assert!(buf.iter().all(|&b| b == 7));
    }

    #[test]
    fn parse_round_trips_and_rejects() {
        let s = FaultSpec::parse("seed=9 death=64 death-max=2 spike=16 spike-ns=500 wear=8 corrupt-wal=3 corrupt-snapshot").unwrap();
        assert_eq!(s.seed, 9);
        assert_eq!(s.death_every, Some(64));
        assert_eq!(s.death_max, 2);
        assert_eq!(s.spike_every, Some(16));
        assert_eq!(s.spike_ns, 500);
        assert_eq!(s.wear_factor, 8);
        assert_eq!(s.corrupt_wal_every, Some(3));
        assert!(s.corrupt_snapshot);
        let rendered = s.render();
        assert!(rendered.contains("death=64"), "{rendered}");
        assert!(FaultSpec::parse("frob=1").is_err());
        assert!(FaultSpec::parse("death").is_err());
    }

    /// The regression the overload PR hardens: a typoed key must fail the
    /// whole spec (naming the bad key), never silently disarm the chaos
    /// plan — `spkie=16` quietly parsing as "no spikes" is how a soak run
    /// ends up testing nothing.
    #[test]
    fn parse_errors_name_the_offending_key() {
        let err = FaultSpec::parse("seed=9 spkie=16").unwrap_err();
        assert!(err.contains("spkie"), "typo must be named: {err}");

        let err = FaultSpec::parse("corrupt-snapshot=5").unwrap_err();
        assert!(err.contains("corrupt-snapshot"), "{err}");
        assert!(err.contains("takes no value"), "{err}");

        let err = FaultSpec::parse("death=4 spike=2 death=8").unwrap_err();
        assert!(err.contains("duplicate") && err.contains("death"), "{err}");

        let err = FaultSpec::parse("spike-ns=fast").unwrap_err();
        assert!(err.contains("spike-ns"), "{err}");
    }

    // Schedule/corruption behavior under an INSTALLED spec is covered by
    // `tests/durability.rs`: the injector is process-global, so arming
    // it here would perturb unrelated lib tests running in parallel.
}
