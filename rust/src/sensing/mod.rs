//! Sensing periphery: reference generation from the device model, the
//! three-sense-amplifier ADRA bank (OR / B / AND), voltage-mode sensing
//! for schemes 1 and 2, and margin analysis.

pub mod current;
pub mod margin;
pub mod refs;
pub mod voltage;

pub use current::{CurrentSenseBank, SenseOut};
pub use margin::{DvtBudget, MarginReport};
pub use refs::{CurrentRefs, VoltageRefs};
pub use voltage::VoltageSenseBank;
