//! Sense-amplifier reference generation.
//!
//! References sit at the midpoints between adjacent I_SL levels (Fig. 3(b)):
//!   * I_REF-OR  between I(0,0) and I(1,0)   -> output = A + B
//!   * I_REF-B   between I(1,0) and I(0,1)   -> output = B
//!   * I_REF-AND between I(0,1) and I(1,1)   -> output = A . B
//! and similarly (reversed polarity) for the voltage-discharge levels.
//! They are *derived from the device model*, not hard-coded, so a bias
//! change that collapses the margin breaks sensing here exactly as it
//! would in SPICE.

use crate::config::DeviceParams;
use crate::device;

/// Current-sensing references (amperes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CurrentRefs {
    pub i_ref_or: f64,
    pub i_ref_b: f64,
    pub i_ref_and: f64,
    /// Single-row read reference (between I_HRS and I_LRS at V_GREAD).
    pub i_ref_read: f64,
}

impl CurrentRefs {
    /// Derive from the DC I_SL levels at the given biases.
    pub fn derive(p: &DeviceParams, vg1: f64, vg2: f64) -> Self {
        let l = device::isl_levels(p, vg1, vg2);
        let i_lrs = device::cell_current(p, p.v_gread2, p.v_read, p.pol_of_bit(true), 0.0);
        let i_hrs = device::cell_current(p, p.v_gread2, p.v_read, p.pol_of_bit(false), 0.0);
        Self {
            // level order with vg1 < vg2: I00 < I10 < I01 < I11
            i_ref_or: 0.5 * (l[0b00] + l[0b10]),
            i_ref_b: 0.5 * (l[0b10] + l[0b01]),
            i_ref_and: 0.5 * (l[0b01] + l[0b11]),
            i_ref_read: 0.5 * (i_hrs + i_lrs),
        }
    }
}

/// Voltage-sensing references (volts, on the discharged RBL).  Note the
/// polarity flip: larger I_SL discharges *deeper*, so V references are
/// ordered V11 < V01 < V10 < V00 and comparisons are `v < ref`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VoltageRefs {
    pub v_ref_or: f64,
    pub v_ref_b: f64,
    pub v_ref_and: f64,
    pub v_ref_read: f64,
}

impl VoltageRefs {
    /// Derive from full discharge transients of the four input vectors.
    pub fn derive(p: &DeviceParams, vg1: f64, vg2: f64, c_rbl: f64) -> Self {
        let vf = |a: bool, b: bool| -> f64 {
            device::rbl_transient(
                p,
                p.pol_of_bit(a),
                p.pol_of_bit(b),
                vg1,
                vg2,
                p.v_read,
                c_rbl,
                0.0,
                0.0,
            )
            .v_final
        };
        let v00 = vf(false, false);
        let v10 = vf(true, false);
        let v01 = vf(false, true);
        let v11 = vf(true, true);
        // single-row read discharge levels (one cell on the stronger WL)
        let single = |bit: bool| -> f64 {
            let mut v = p.v_read;
            for _ in 0..p.n_steps {
                let i = device::cell_current(p, p.v_gread2, v, p.pol_of_bit(bit), 0.0);
                v = (v - i * p.t_step / c_rbl).max(0.0);
            }
            v
        };
        Self {
            v_ref_or: 0.5 * (v00 + v10),
            v_ref_b: 0.5 * (v10 + v01),
            v_ref_and: 0.5 * (v01 + v11),
            v_ref_read: 0.5 * (single(true) + single(false)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn current_refs_strictly_ordered() {
        let p = DeviceParams::default();
        let r = CurrentRefs::derive(&p, p.v_gread1, p.v_gread2);
        assert!(r.i_ref_or < r.i_ref_b);
        assert!(r.i_ref_b < r.i_ref_and);
        assert!(r.i_ref_or > 0.0);
    }

    #[test]
    fn current_refs_separate_levels() {
        let p = DeviceParams::default();
        let r = CurrentRefs::derive(&p, p.v_gread1, p.v_gread2);
        let l = device::isl_levels(&p, p.v_gread1, p.v_gread2);
        assert!(l[0b00] < r.i_ref_or && r.i_ref_or < l[0b10]);
        assert!(l[0b10] < r.i_ref_b && r.i_ref_b < l[0b01]);
        assert!(l[0b01] < r.i_ref_and && r.i_ref_and < l[0b11]);
    }

    #[test]
    fn voltage_refs_reverse_ordered() {
        let p = DeviceParams::default();
        let c = 1024.0 * p.c_rbl_cell;
        let r = VoltageRefs::derive(&p, p.v_gread1, p.v_gread2, c);
        assert!(r.v_ref_and < r.v_ref_b);
        assert!(r.v_ref_b < r.v_ref_or);
        assert!(r.v_ref_or < p.v_read);
    }

    #[test]
    fn read_ref_between_states() {
        let p = DeviceParams::default();
        let r = CurrentRefs::derive(&p, p.v_gread1, p.v_gread2);
        let i_lrs = device::cell_current(&p, p.v_gread2, p.v_read, p.pol_of_bit(true), 0.0);
        let i_hrs = device::cell_current(&p, p.v_gread2, p.v_read, p.pol_of_bit(false), 0.0);
        assert!(i_hrs < r.i_ref_read && r.i_ref_read < i_lrs);
    }
}
