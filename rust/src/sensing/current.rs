//! Current-mode sense-amplifier bank (Section IV.A).
//!
//! Three SAs per column compare I_SL against the OR / B / AND references;
//! their outputs (plus complements, free in a differential SA) feed the
//! compute module.  The OAI21 recovery of A (paper §III.A) happens here.

use super::refs::CurrentRefs;

/// Per-column sense outputs of one ADRA activation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SenseOut {
    /// A + B  (OR sense amp)
    pub or: bool,
    /// B      (the additional ADRA sense amp)
    pub b: bool,
    /// A . B  (AND sense amp)
    pub and: bool,
}

impl SenseOut {
    /// Recover A via the OAI21 gate: A = NOT[(B + NOR(A,B)) . NAND(A,B)].
    #[inline]
    pub fn a(&self) -> bool {
        let nand = !self.and;
        let nor = !self.or;
        !((self.b || nor) && nand)
    }

    /// XOR comes free from OR and AND (used by Boolean CiM ops).
    #[inline]
    pub fn xor(&self) -> bool {
        self.or && !self.and
    }
}

/// The three-SA bank for current sensing.
#[derive(Clone, Copy, Debug)]
pub struct CurrentSenseBank {
    pub refs: CurrentRefs,
}

impl CurrentSenseBank {
    pub fn new(refs: CurrentRefs) -> Self {
        Self { refs }
    }

    /// Sense one column's senseline current.
    #[inline]
    pub fn sense(&self, i_sl: f64) -> SenseOut {
        SenseOut {
            or: i_sl > self.refs.i_ref_or,
            b: i_sl > self.refs.i_ref_b,
            and: i_sl > self.refs.i_ref_and,
        }
    }

    /// Sense a slice of columns.
    pub fn sense_all(&self, i_sl: &[f64]) -> Vec<SenseOut> {
        i_sl.iter().map(|&i| self.sense(i)).collect()
    }

    /// `sense_all` into a caller-owned buffer (cleared first) — the
    /// zero-allocation engine hot path reuses scratch here.
    pub fn sense_into(&self, i_sl: &[f64], out: &mut Vec<SenseOut>) {
        out.clear();
        out.extend(i_sl.iter().map(|&i| self.sense(i)));
    }

    /// Single-row read decision (standard memory read).
    #[inline]
    pub fn sense_read(&self, i_cell: f64) -> bool {
        i_cell > self.refs.i_ref_read
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceParams;
    use crate::device;

    fn bank() -> CurrentSenseBank {
        let p = DeviceParams::default();
        CurrentSenseBank::new(CurrentRefs::derive(&p, p.v_gread1, p.v_gread2))
    }

    #[test]
    fn sense_decodes_all_four_vectors() {
        let p = DeviceParams::default();
        let bank = bank();
        let levels = device::isl_levels(&p, p.v_gread1, p.v_gread2);
        for a in [false, true] {
            for b in [false, true] {
                let idx = ((a as usize) << 1) | b as usize;
                let out = bank.sense(levels[idx]);
                assert_eq!(out.or, a || b, "OR at ({a},{b})");
                assert_eq!(out.and, a && b, "AND at ({a},{b})");
                assert_eq!(out.b, b, "B at ({a},{b})");
                assert_eq!(out.a(), a, "recovered A at ({a},{b})");
                assert_eq!(out.xor(), a ^ b, "XOR at ({a},{b})");
            }
        }
    }

    #[test]
    fn single_read_decodes_both_states() {
        let p = DeviceParams::default();
        let bank = bank();
        let i_lrs = device::cell_current(&p, p.v_gread2, p.v_read, p.pol_of_bit(true), 0.0);
        let i_hrs = device::cell_current(&p, p.v_gread2, p.v_read, p.pol_of_bit(false), 0.0);
        assert!(bank.sense_read(i_lrs));
        assert!(!bank.sense_read(i_hrs));
    }

    #[test]
    fn sense_all_matches_pointwise() {
        let p = DeviceParams::default();
        let bank = bank();
        let levels = device::isl_levels(&p, p.v_gread1, p.v_gread2);
        let outs = bank.sense_all(&levels);
        assert_eq!(outs.len(), 4);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(*o, bank.sense(levels[i]));
        }
        // slice-based variant is pointwise-identical and reuses capacity
        let mut buf = vec![SenseOut::default(); 99];
        bank.sense_into(&levels, &mut buf);
        assert_eq!(buf, outs);
    }

    #[test]
    fn oai_truth_table_standalone() {
        for a in [false, true] {
            for b in [false, true] {
                let s = SenseOut { or: a || b, b, and: a && b };
                assert_eq!(s.a(), a);
            }
        }
    }
}
