//! Sense-margin analysis: the worst-case separation between adjacent
//! levels and the sensing failure point as the wordline asymmetry shrinks
//! (the ablation behind the V_GREAD1/V_GREAD2 design choice), plus the
//! per-cell deterministic-dVt budget behind the variation-aware margin
//! masks of the masked digital tier (DESIGN.md §10).

use super::refs::{CurrentRefs, VoltageRefs};
use crate::config::{DeviceParams, SensingScheme, SimConfig};
use crate::device;

/// Margin summary for one operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MarginReport {
    /// Worst-case current margin between adjacent I_SL levels (A).
    pub current_margin: f64,
    /// Worst-case voltage margin between adjacent discharge levels (V).
    pub voltage_margin: f64,
    /// Whether all four levels are strictly ordered the ADRA way
    /// (I00 < I10 < I01 < I11).
    pub one_to_one: bool,
}

impl MarginReport {
    /// Evaluate margins at the given bias pair and RBL capacitance.
    pub fn evaluate(p: &DeviceParams, vg1: f64, vg2: f64, c_rbl: f64) -> Self {
        let l = device::isl_levels(p, vg1, vg2);
        let one_to_one = l[0b00] < l[0b10] && l[0b10] < l[0b01] && l[0b01] < l[0b11];
        let mut li = l.to_vec();
        li.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let current_margin = li.windows(2).map(|w| w[1] - w[0]).fold(f64::MAX, f64::min);

        let mut vf: Vec<f64> = [(false, false), (true, false), (false, true), (true, true)]
            .iter()
            .map(|&(a, b)| {
                device::rbl_transient(
                    p,
                    p.pol_of_bit(a),
                    p.pol_of_bit(b),
                    vg1,
                    vg2,
                    p.v_read,
                    c_rbl,
                    0.0,
                    0.0,
                )
                .v_final
            })
            .collect();
        vf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let voltage_margin = vf.windows(2).map(|w| w[1] - w[0]).fold(f64::MAX, f64::min);

        Self { current_margin, voltage_margin, one_to_one }
    }

    /// Does this operating point satisfy the paper's Section IV targets?
    pub fn meets_paper_targets(&self) -> bool {
        self.one_to_one && self.current_margin > 1e-6 && self.voltage_margin > 0.050
    }
}

/// Sweep the asymmetry (vg1 from vg2 downward) and find the minimum
/// wordline separation that still meets the paper's margin targets.
pub fn min_viable_asymmetry(p: &DeviceParams, c_rbl: f64, steps: usize) -> Option<f64> {
    let vg2 = p.v_gread2;
    for i in 1..=steps {
        let dv = i as f64 * (vg2 - 0.5) / steps as f64;
        let vg1 = vg2 - dv;
        if MarginReport::evaluate(p, vg1, vg2, c_rbl).meets_paper_targets() {
            return Some(dv);
        }
    }
    None
}

/// Per-cell deterministic-dVt budget: the largest |dVt| a cell may carry
/// and still be GUARANTEED to decode identically to the nominal digital
/// decision, for every dual-row corner it can participate in and for the
/// single-row read — the classification behind the packed margin masks.
///
/// Soundness rests on monotonicity: cell current falls (and the RBL final
/// voltage rises) monotonically in dVt, and every sense decision is a
/// threshold test, so checking the two extremes `±t` of both cells of a
/// column covers the whole `[-t, +t]^2` square.  A guard band (0.1% of
/// the reference scale) absorbs the LUT-vs-exact backend gap so the same
/// mask is safe for either analog backend.
///
/// `t0`/`t1` are per-stored-bit budgets (write-time classification,
/// `MaskPolicy::Write`); `sym()` is the bit-independent worst case
/// (construction-time classification).  At the paper bias the corner that
/// binds involves both bits, so `t0 == t1 == sym()` — the refinement pays
/// off only at skewed operating points.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DvtBudget {
    /// Budget for a cell currently storing '0' (HRS).
    pub t0: f64,
    /// Budget for a cell currently storing '1' (LRS).
    pub t1: f64,
}

/// Relative guard band applied on every reference comparison (fraction of
/// the reference scale): decisions inside the band count as marginal even
/// if nominally correct, covering the `CellLut` approximation error
/// (< 1e-5 relative) with two orders of magnitude to spare.
const DECODE_GUARD_REL: f64 = 1e-3;

/// Bisection search cap: no realistic budget exceeds this (volts).
const BUDGET_CAP: f64 = 0.6;

/// One operating point's guarded decode checker, references derived once.
struct DecodeCheck {
    p: DeviceParams,
    scheme: SensingScheme,
    c_rbl: f64,
    cur: CurrentRefs,
    volt: VoltageRefs,
    i_guard: f64,
    v_guard: f64,
}

impl DecodeCheck {
    fn new(cfg: &SimConfig) -> Self {
        let p = cfg.device.clone();
        let c_rbl = cfg.c_rbl();
        let cur = CurrentRefs::derive(&p, p.v_gread1, p.v_gread2);
        let volt = VoltageRefs::derive(&p, p.v_gread1, p.v_gread2, c_rbl);
        let i_guard = DECODE_GUARD_REL * cur.i_ref_and;
        let v_guard = DECODE_GUARD_REL * p.v_read;
        Self { p, scheme: cfg.scheme, c_rbl, cur, volt, i_guard, v_guard }
    }

    /// `q` must sit on the `want_above` side of `r`, clear of the guard.
    fn side(q: f64, r: f64, want_above: bool, guard: f64) -> bool {
        if want_above {
            q > r + guard
        } else {
            q < r - guard
        }
    }

    /// Do all four (A,B) corners and both single-read states decode
    /// correctly with the A-role cell at `±t(a)` and the B-role cell at
    /// `±t(b)` (t per stored bit)?
    fn ok(&self, t0: f64, t1: f64) -> bool {
        let p = &self.p;
        let t_of = |bit: bool| if bit { t1 } else { t0 };
        for a in [false, true] {
            for b in [false, true] {
                for sa in [-t_of(a), t_of(a)] {
                    for sb in [-t_of(b), t_of(b)] {
                        let ok = match self.scheme {
                            SensingScheme::Current => {
                                let i = device::senseline_current(
                                    p,
                                    p.pol_of_bit(a),
                                    p.pol_of_bit(b),
                                    p.v_gread1,
                                    p.v_gread2,
                                    p.v_read,
                                    sa,
                                    sb,
                                );
                                Self::side(i, self.cur.i_ref_or, a || b, self.i_guard)
                                    && Self::side(i, self.cur.i_ref_b, b, self.i_guard)
                                    && Self::side(i, self.cur.i_ref_and, a && b, self.i_guard)
                            }
                            SensingScheme::VoltagePrecharged
                            | SensingScheme::VoltageDischarged => {
                                // voltage polarity flips: decision is v < ref
                                let v = device::rbl_transient(
                                    p,
                                    p.pol_of_bit(a),
                                    p.pol_of_bit(b),
                                    p.v_gread1,
                                    p.v_gread2,
                                    p.v_read,
                                    self.c_rbl,
                                    sa,
                                    sb,
                                )
                                .v_final;
                                Self::side(v, self.volt.v_ref_or, !(a || b), self.v_guard)
                                    && Self::side(v, self.volt.v_ref_b, !b, self.v_guard)
                                    && Self::side(v, self.volt.v_ref_and, !(a && b), self.v_guard)
                            }
                        };
                        if !ok {
                            return false;
                        }
                    }
                }
            }
        }
        // the single-row read decodes through the current reference on
        // every scheme (AdraEngine::read_word_sensed)
        for bit in [false, true] {
            for s in [-t_of(bit), t_of(bit)] {
                let i = device::cell_current(p, p.v_gread2, p.v_read, p.pol_of_bit(bit), s);
                if !Self::side(i, self.cur.i_ref_read, bit, self.i_guard) {
                    return false;
                }
            }
        }
        true
    }
}

/// Largest `t >= lo` passing `f`, by bisection on `[lo, BUDGET_CAP]`.
/// Returns the passing (lower) end of the final bracket — the safe side.
fn bisect_budget(lo: f64, f: impl Fn(f64) -> bool) -> f64 {
    if !f(lo) {
        return 0.0;
    }
    if f(BUDGET_CAP) {
        return BUDGET_CAP;
    }
    let (mut lo, mut hi) = (lo, BUDGET_CAP);
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        if f(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

impl DvtBudget {
    /// Budget of a cell storing `bit`.
    pub fn of(&self, bit: bool) -> f64 {
        if bit {
            self.t1
        } else {
            self.t0
        }
    }

    /// Bit-independent (construction-time) budget.
    pub fn sym(&self) -> f64 {
        self.t0.min(self.t1)
    }

    /// Is a cell with variation offset `dvt`, storing `bit`,
    /// deterministically resolvable?
    pub fn classify(&self, dvt: f64, bit: bool) -> bool {
        dvt.abs() <= self.of(bit)
    }

    /// Derive the budgets for an operating point.  Starts from the
    /// symmetric bisection, then two rounds of coordinate ascent grow the
    /// per-bit budgets (each step re-checks every corner with the current
    /// pair, so the pair stays jointly sound throughout).
    pub fn derive(cfg: &SimConfig) -> Self {
        let chk = DecodeCheck::new(cfg);
        let sym = bisect_budget(0.0, |t| chk.ok(t, t));
        let mut t0 = sym;
        let mut t1 = sym;
        for _ in 0..2 {
            t0 = bisect_budget(t0, |t| chk.ok(t, t1));
            t1 = bisect_budget(t1, |t| chk.ok(t0, t));
        }
        Self { t0, t1 }
    }

    /// Fraction of cells the construction-time classification marks
    /// deterministic for this config — replays (a capped prefix of) the
    /// array's variation RNG stream without allocating the planes.  The
    /// number is advisory (it feeds the planner's host-cost blend), so a
    /// 64k-cell sample is plenty and keeps `PlanCostModel` construction
    /// from re-walking a megacell array the engine already classified.
    /// 1.0 when `vt_sigma == 0`.
    pub fn deterministic_cell_fraction(cfg: &SimConfig) -> f64 {
        if cfg.vt_sigma <= 0.0 {
            return 1.0;
        }
        let t = Self::derive(cfg).sym();
        let mut rng = crate::util::rng::Rng::new(cfg.seed ^ crate::config::VT_SEED_SALT);
        let n = (cfg.rows * cfg.cols).min(1 << 16);
        let mut det = 0usize;
        for _ in 0..n {
            if (rng.normal() * cfg.vt_sigma).abs() <= t {
                det += 1;
            }
        }
        det as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bias_meets_targets() {
        let p = DeviceParams::default();
        let r = MarginReport::evaluate(&p, p.v_gread1, p.v_gread2, 1024.0 * p.c_rbl_cell);
        assert!(r.meets_paper_targets(), "{r:?}");
        assert!(r.current_margin > 1e-6);
        assert!(r.voltage_margin > 0.050);
    }

    #[test]
    fn symmetric_bias_fails_one_to_one() {
        let p = DeviceParams::default();
        let r = MarginReport::evaluate(&p, p.v_gread2, p.v_gread2, 1024.0 * p.c_rbl_cell);
        assert!(!r.one_to_one);
        assert!(!r.meets_paper_targets());
    }

    #[test]
    fn tiny_asymmetry_fails_margins() {
        let p = DeviceParams::default();
        let r = MarginReport::evaluate(&p, p.v_gread2 - 0.005, p.v_gread2, 1024.0 * p.c_rbl_cell);
        assert!(!r.meets_paper_targets(), "{r:?}");
    }

    #[test]
    fn viable_asymmetry_exists_and_paper_choice_exceeds_it() {
        let p = DeviceParams::default();
        let dv = min_viable_asymmetry(&p, 1024.0 * p.c_rbl_cell, 50)
            .expect("some asymmetry should work");
        assert!(dv <= (p.v_gread2 - p.v_gread1) + 1e-9,
                "paper separation {} below minimum viable {dv}",
                p.v_gread2 - p.v_gread1);
    }

    #[test]
    fn current_budget_is_tens_of_millivolts() {
        let cfg = SimConfig::square(256, SensingScheme::Current);
        let b = DvtBudget::derive(&cfg);
        assert!(b.sym() > 0.03 && b.sym() < 0.09, "{b:?}");
        // per-bit budgets can only extend the symmetric one
        assert!(b.t0 >= b.sym() && b.t1 >= b.sym());
    }

    #[test]
    fn budget_extremes_still_decode_every_corner() {
        // the certificate the classifier hands out: BOTH cells at their
        // budget extremes must decode every corner through the real refs
        let cfg = SimConfig::square(256, SensingScheme::Current);
        let b = DvtBudget::derive(&cfg);
        let chk = DecodeCheck::new(&cfg);
        assert!(chk.ok(b.t0, b.t1), "{b:?} must be jointly sound");
        // and a budget 10% past the boundary must NOT certify
        assert!(!chk.ok(b.t0 * 1.5, b.t1 * 1.5), "{b:?} must be tight-ish");
    }

    #[test]
    fn classify_respects_budget_and_sign() {
        let cfg = SimConfig::square(256, SensingScheme::Current);
        let b = DvtBudget::derive(&cfg);
        assert!(b.classify(0.0, false) && b.classify(0.0, true));
        assert!(b.classify(-0.9 * b.t0, false));
        assert!(!b.classify(1.1 * b.t1, true));
        assert!(!b.classify(-0.59, false), "past the cap is never deterministic");
    }

    #[test]
    fn collapsed_margins_give_zero_budget() {
        // 64-row voltage sensing discharges so deep the dual-row levels
        // compress to nanovolts — nothing can be deterministic there, and
        // the classifier must say so rather than certify garbage
        let mut cfg = SimConfig::square(64, SensingScheme::VoltagePrecharged);
        cfg.word_bits = 8;
        let b = DvtBudget::derive(&cfg);
        assert!(b.sym() < 1e-6, "{b:?}");
    }

    #[test]
    fn large_array_voltage_budget_recovers() {
        let cfg = SimConfig::square(1024, SensingScheme::VoltageDischarged);
        let b = DvtBudget::derive(&cfg);
        assert!(b.sym() > 0.02, "{b:?}: 1024-row voltage margins are real");
    }

    #[test]
    fn cell_fraction_tracks_sigma() {
        let mut cfg = SimConfig::square(256, SensingScheme::Current);
        assert_eq!(DvtBudget::deterministic_cell_fraction(&cfg), 1.0);
        cfg.vt_sigma = 0.02;
        let f20 = DvtBudget::deterministic_cell_fraction(&cfg);
        assert!(f20 > 0.95, "sigma=20mV: {f20}");
        cfg.vt_sigma = 0.05;
        let f50 = DvtBudget::deterministic_cell_fraction(&cfg);
        assert!(f50 < f20, "more variation, fewer deterministic cells");
        assert!(f50 > 0.3, "{f50}");
    }
}
