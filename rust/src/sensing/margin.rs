//! Sense-margin analysis: the worst-case separation between adjacent
//! levels and the sensing failure point as the wordline asymmetry shrinks
//! (the ablation behind the V_GREAD1/V_GREAD2 design choice).

use crate::config::DeviceParams;
use crate::device;

/// Margin summary for one operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MarginReport {
    /// Worst-case current margin between adjacent I_SL levels (A).
    pub current_margin: f64,
    /// Worst-case voltage margin between adjacent discharge levels (V).
    pub voltage_margin: f64,
    /// Whether all four levels are strictly ordered the ADRA way
    /// (I00 < I10 < I01 < I11).
    pub one_to_one: bool,
}

impl MarginReport {
    /// Evaluate margins at the given bias pair and RBL capacitance.
    pub fn evaluate(p: &DeviceParams, vg1: f64, vg2: f64, c_rbl: f64) -> Self {
        let l = device::isl_levels(p, vg1, vg2);
        let one_to_one = l[0b00] < l[0b10] && l[0b10] < l[0b01] && l[0b01] < l[0b11];
        let mut li = l.to_vec();
        li.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let current_margin = li.windows(2).map(|w| w[1] - w[0]).fold(f64::MAX, f64::min);

        let mut vf: Vec<f64> = [(false, false), (true, false), (false, true), (true, true)]
            .iter()
            .map(|&(a, b)| {
                device::rbl_transient(
                    p,
                    p.pol_of_bit(a),
                    p.pol_of_bit(b),
                    vg1,
                    vg2,
                    p.v_read,
                    c_rbl,
                    0.0,
                    0.0,
                )
                .v_final
            })
            .collect();
        vf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let voltage_margin = vf.windows(2).map(|w| w[1] - w[0]).fold(f64::MAX, f64::min);

        Self { current_margin, voltage_margin, one_to_one }
    }

    /// Does this operating point satisfy the paper's Section IV targets?
    pub fn meets_paper_targets(&self) -> bool {
        self.one_to_one && self.current_margin > 1e-6 && self.voltage_margin > 0.050
    }
}

/// Sweep the asymmetry (vg1 from vg2 downward) and find the minimum
/// wordline separation that still meets the paper's margin targets.
pub fn min_viable_asymmetry(p: &DeviceParams, c_rbl: f64, steps: usize) -> Option<f64> {
    let vg2 = p.v_gread2;
    for i in 1..=steps {
        let dv = i as f64 * (vg2 - 0.5) / steps as f64;
        let vg1 = vg2 - dv;
        if MarginReport::evaluate(p, vg1, vg2, c_rbl).meets_paper_targets() {
            return Some(dv);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bias_meets_targets() {
        let p = DeviceParams::default();
        let r = MarginReport::evaluate(&p, p.v_gread1, p.v_gread2, 1024.0 * p.c_rbl_cell);
        assert!(r.meets_paper_targets(), "{r:?}");
        assert!(r.current_margin > 1e-6);
        assert!(r.voltage_margin > 0.050);
    }

    #[test]
    fn symmetric_bias_fails_one_to_one() {
        let p = DeviceParams::default();
        let r = MarginReport::evaluate(&p, p.v_gread2, p.v_gread2, 1024.0 * p.c_rbl_cell);
        assert!(!r.one_to_one);
        assert!(!r.meets_paper_targets());
    }

    #[test]
    fn tiny_asymmetry_fails_margins() {
        let p = DeviceParams::default();
        let r = MarginReport::evaluate(&p, p.v_gread2 - 0.005, p.v_gread2, 1024.0 * p.c_rbl_cell);
        assert!(!r.meets_paper_targets(), "{r:?}");
    }

    #[test]
    fn viable_asymmetry_exists_and_paper_choice_exceeds_it() {
        let p = DeviceParams::default();
        let dv = min_viable_asymmetry(&p, 1024.0 * p.c_rbl_cell, 50)
            .expect("some asymmetry should work");
        assert!(dv <= (p.v_gread2 - p.v_gread1) + 1e-9,
                "paper separation {} below minimum viable {dv}",
                p.v_gread2 - p.v_gread1);
    }
}
