//! Voltage-mode sensing (Section IV.B): compare the discharged RBL voltage
//! against three references.  Polarity is flipped relative to current
//! sensing — larger I_SL means a *lower* final voltage — so the OR/B/AND
//! decisions are `v < ref`.
//!
//! Scheme 1 (precharged) and scheme 2 (discharged-at-hold) share the same
//! comparator bank; they differ in hold-state policy, which is an energy
//! question handled by `energy::model`, not a sensing one.

use super::current::SenseOut;
use super::refs::VoltageRefs;

/// Three-comparator voltage sense bank.
#[derive(Clone, Copy, Debug)]
pub struct VoltageSenseBank {
    pub refs: VoltageRefs,
}

impl VoltageSenseBank {
    pub fn new(refs: VoltageRefs) -> Self {
        Self { refs }
    }

    /// Sense one column's final RBL voltage after the discharge window.
    #[inline]
    pub fn sense(&self, v_final: f64) -> SenseOut {
        SenseOut {
            or: v_final < self.refs.v_ref_or,
            b: v_final < self.refs.v_ref_b,
            and: v_final < self.refs.v_ref_and,
        }
    }

    pub fn sense_all(&self, v_final: &[f64]) -> Vec<SenseOut> {
        v_final.iter().map(|&v| self.sense(v)).collect()
    }

    /// `sense_all` into a caller-owned buffer (cleared first) — the
    /// zero-allocation engine hot path reuses scratch here.
    pub fn sense_into(&self, v_final: &[f64], out: &mut Vec<SenseOut>) {
        out.clear();
        out.extend(v_final.iter().map(|&v| self.sense(v)));
    }

    /// Single-row read decision: '1' discharges below the read reference.
    #[inline]
    pub fn sense_read(&self, v_final: f64) -> bool {
        v_final < self.refs.v_ref_read
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceParams;
    use crate::device;

    #[test]
    fn voltage_sense_decodes_all_four_vectors() {
        let p = DeviceParams::default();
        let c = 1024.0 * p.c_rbl_cell;
        let bank = VoltageSenseBank::new(VoltageRefs::derive(&p, p.v_gread1, p.v_gread2, c));
        for a in [false, true] {
            for b in [false, true] {
                let t = device::rbl_transient(
                    &p,
                    p.pol_of_bit(a),
                    p.pol_of_bit(b),
                    p.v_gread1,
                    p.v_gread2,
                    p.v_read,
                    c,
                    0.0,
                    0.0,
                );
                let out = bank.sense(t.v_final);
                assert_eq!(out.or, a || b, "OR at ({a},{b})");
                assert_eq!(out.and, a && b, "AND at ({a},{b})");
                assert_eq!(out.b, b, "B at ({a},{b})");
                assert_eq!(out.a(), a, "A at ({a},{b})");
            }
        }
    }

    #[test]
    fn sense_all_matches_pointwise() {
        let p = DeviceParams::default();
        let c = 1024.0 * p.c_rbl_cell;
        let bank = VoltageSenseBank::new(VoltageRefs::derive(&p, p.v_gread1, p.v_gread2, c));
        let vf: Vec<f64> = (0..16).map(|i| 0.05 * i as f64).collect();
        let outs = bank.sense_all(&vf);
        let mut buf = Vec::new();
        bank.sense_into(&vf, &mut buf);
        assert_eq!(outs.len(), 16);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(*o, bank.sense(vf[i]));
        }
        assert_eq!(buf, outs, "sense_into must be pointwise-identical");
    }

    #[test]
    fn works_across_array_sizes() {
        let p = DeviceParams::default();
        for rows in [256usize, 512, 1024] {
            let c = rows as f64 * p.c_rbl_cell;
            let bank =
                VoltageSenseBank::new(VoltageRefs::derive(&p, p.v_gread1, p.v_gread2, c));
            let t = device::rbl_transient(
                &p,
                p.pol_of_bit(true),
                p.pol_of_bit(false),
                p.v_gread1,
                p.v_gread2,
                p.v_read,
                c,
                0.0,
                0.0,
            );
            let out = bank.sense(t.v_final);
            assert!(out.a() && !out.b, "rows={rows}");
        }
    }
}
