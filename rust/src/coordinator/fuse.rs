//! Activation fusion: the batch optimizer.
//!
//! The paper's alternate Fig. 3(d) compute module (duplicated XOR/AOI21,
//! +4 transistors) produces addition AND subtraction in the *same cycle*.
//! More generally, every dual-row op over the same (row_a, row_b, word)
//! consumes the same three sense-amp outputs — so a batch containing
//! {Sub, Add, Compare, Bool, Read2} of one operand pair needs ONE
//! asymmetric activation, not five.
//!
//! `fuse_batch` groups a batch by activation key while preserving
//! per-shard program order across writes (a write to a row invalidates
//! fusion across it).  `execute_fused` replays the plan on an engine,
//! charging one `cim_cost` per activation group and deriving every result
//! from the shared sense vector.  Equivalence with unfused execution is
//! property-tested.

use crate::cim::adra::AdraEngine;
use crate::cim::ops::{CimOp, CimResult, Engine, EngineError};
use crate::energy::OpCost;
use crate::sensing::SenseOut;

/// One step of a fused execution plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanStep {
    /// Ops that cannot fuse (writes, single reads, errors pass through).
    Passthrough(usize),
    /// One activation serving ops at the given batch indices.
    Fused { row_a: usize, row_b: usize, word: usize, indices: Vec<usize> },
}

/// Build a fusion plan for a batch.  Fusion groups never cross a write
/// to either row of the group (program order is preserved per shard).
pub fn fuse_batch(ops: &[CimOp]) -> Vec<PlanStep> {
    let mut plan: Vec<PlanStep> = Vec::new();
    // open groups: key -> plan index
    let mut open: Vec<((usize, usize, usize), usize)> = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        match *op {
            CimOp::Read2 { row_a, row_b, word }
            | CimOp::Bool { row_a, row_b, word, .. }
            | CimOp::Add { row_a, row_b, word }
            | CimOp::Sub { row_a, row_b, word }
            | CimOp::Compare { row_a, row_b, word } => {
                let key = (row_a, row_b, word);
                if let Some(&(_, pi)) = open.iter().find(|(k, _)| *k == key) {
                    if let PlanStep::Fused { indices, .. } = &mut plan[pi] {
                        indices.push(i);
                        continue;
                    }
                }
                let pi = plan.len();
                plan.push(PlanStep::Fused { row_a, row_b, word, indices: vec![i] });
                open.push((key, pi));
            }
            CimOp::Write { addr, .. } => {
                // a write invalidates any open group touching that row
                open.retain(|((ra, rb, _), _)| *ra != addr.row && *rb != addr.row);
                plan.push(PlanStep::Passthrough(i));
            }
            CimOp::Read(_) => plan.push(PlanStep::Passthrough(i)),
        }
    }
    plan
}

/// Count the activations a plan will issue (fused groups count once).
pub fn planned_activations(plan: &[PlanStep]) -> usize {
    plan.iter()
        .filter(|s| matches!(s, PlanStep::Fused { .. }))
        .count()
}

/// Count the follower ops a plan serves from an already-latched
/// activation (every fused-group member after the first).
pub fn fused_followers(plan: &[PlanStep]) -> usize {
    plan.iter()
        .map(|s| match s {
            PlanStep::Fused { indices, .. } => indices.len() - 1,
            PlanStep::Passthrough(_) => 0,
        })
        .sum()
}

/// Derive one op's result from a shared sense vector (the analog tiers'
/// path; semantics centralized in `AdraEngine::analog_value`).
fn derive(op: &CimOp, outs: &[SenseOut], cost: OpCost) -> CimResult {
    CimResult { value: AdraEngine::analog_value(op, outs), cost }
}

/// Cost of a fused-group follower given the group's full activation cost:
/// compute-module + latch only, no array access (the paper's +4T
/// duplicated datapath makes add+sub literally same-cycle; further
/// followers model extra module evaluations off the latched sense
/// outputs).  Shared by `execute_fused` and the planner's fusion-aware
/// cost prediction so both price followers identically.
pub fn follower_cost(full: &OpCost) -> OpCost {
    OpCost {
        energy: crate::energy::EnergyBreakdown {
            peripheral: 0.1 * full.energy.peripheral,
            ..Default::default()
        },
        latency: 0.05e-9,
    }
}

/// One unit of fused execution: a passthrough op, or a PAIR BATCH — the
/// run of fusion groups sharing one row pair with no intervening write
/// to either row.  On the packed tiers the whole batch is served from
/// ONE fill of the pair's row planes (`prefill_pair_planes`) instead of
/// re-extracting packed windows word by word; every group still records
/// its own activation, so modeled stats and charged costs are identical
/// to unbatched execution — the batching is purely host-side.
enum ExecStep {
    Pass(usize),
    Batch {
        row_a: usize,
        row_b: usize,
        /// (word, batch indices of the ops fused on that word)
        groups: Vec<(usize, Vec<usize>)>,
    },
}

/// Coalesce a fusion plan into pair batches.  A write to either row of a
/// pair closes its open batch exactly like it closes fusion groups, so a
/// batch's planes are always coherent with every group it serves.
fn pair_batches(plan: Vec<PlanStep>, ops: &[CimOp]) -> Vec<ExecStep> {
    let mut steps: Vec<ExecStep> = Vec::new();
    let mut open: Vec<((usize, usize), usize)> = Vec::new();
    for step in plan {
        match step {
            PlanStep::Fused { row_a, row_b, word, indices } => {
                if let Some(&(_, si)) = open.iter().find(|(k, _)| *k == (row_a, row_b)) {
                    if let ExecStep::Batch { groups, .. } = &mut steps[si] {
                        groups.push((word, indices));
                        continue;
                    }
                }
                let si = steps.len();
                steps.push(ExecStep::Batch { row_a, row_b, groups: vec![(word, indices)] });
                open.push(((row_a, row_b), si));
            }
            PlanStep::Passthrough(i) => {
                if let CimOp::Write { addr, .. } = &ops[i] {
                    open.retain(|((ra, rb), _)| *ra != addr.row && *rb != addr.row);
                }
                steps.push(ExecStep::Pass(i));
            }
        }
    }
    steps
}

/// Execute a batch with fusion on an `AdraEngine`.  Returns results in
/// the original batch order.  The first op of a fused group is charged
/// the full activation `cim_cost`; followers are charged only the
/// `follower_cost` compute-module increment.
pub fn execute_fused(
    engine: &mut AdraEngine,
    ops: &[CimOp],
) -> Vec<Result<CimResult, EngineError>> {
    let steps = pair_batches(fuse_batch(ops), ops);
    let mut results: Vec<Option<Result<CimResult, EngineError>>> = vec![None; ops.len()];
    let full = engine.energy_model().cim_cost();
    let follower = follower_cost(&full);
    let wb = engine.cfg().word_bits;
    for step in steps {
        match step {
            ExecStep::Pass(i) => {
                results[i] = Some(engine.execute(&ops[i]));
            }
            ExecStep::Batch { row_a, row_b, groups } => {
                // out-of-range words take the per-group path so a bad op
                // errors alone instead of poisoning the batch's span
                let words_per_row = engine.cfg().words_per_row();
                let (groups, bad): (Vec<_>, Vec<_>) =
                    groups.into_iter().partition(|(w, _)| *w < words_per_row);
                for (word, indices) in &bad {
                    let outcome = engine.activate_packed(row_a, row_b, *word);
                    serve_group(engine, ops, &mut results, indices, outcome, &full, &follower, wb);
                }
                if groups.is_empty() {
                    continue;
                }
                let lo = groups.iter().map(|(w, _)| w * wb).min().expect("non-empty batch");
                let hi = groups.iter().map(|(w, _)| (w + 1) * wb).max().expect("non-empty");
                // a sparse hull (served words cover < half the span) would
                // fill — and in masked mode analog-sense — columns no
                // group consumes; serve those batches per group instead
                let sparse = hi - lo > 2 * groups.len() * wb;
                let prefilled = if sparse {
                    Ok(false)
                } else {
                    engine.prefill_pair_planes(row_a, row_b, lo, hi)
                };
                match prefilled {
                    Err(e) => {
                        for (_, indices) in &groups {
                            for &i in indices {
                                results[i] = Some(Err(e.clone()));
                            }
                        }
                    }
                    Ok(true) => {
                        // packed tiers: every group serves from the one
                        // plane fill; followers derive by word arithmetic
                        for (word, indices) in &groups {
                            let outcome = engine.serve_group_from_planes(row_a, row_b, *word);
                            serve_group(engine, ops, &mut results, indices, outcome, &full, &follower, wb);
                        }
                    }
                    Ok(false) => {
                        // analog tiers and sparse batches: one activation
                        // per group, exactly the unbatched datapath
                        for (word, indices) in &groups {
                            let outcome = engine.activate_packed(row_a, row_b, *word);
                            serve_group(engine, ops, &mut results, indices, outcome, &full, &follower, wb);
                        }
                    }
                }
            }
        }
    }
    results.into_iter().map(|r| r.expect("plan covers batch")).collect()
}

/// Derive one fused group's results from its activation outcome.
#[allow(clippy::too_many_arguments)]
fn serve_group(
    engine: &AdraEngine,
    ops: &[CimOp],
    results: &mut [Option<Result<CimResult, EngineError>>],
    indices: &[usize],
    outcome: Result<Option<(u64, u64)>, EngineError>,
    full: &OpCost,
    follower: &OpCost,
    wb: usize,
) {
    match outcome {
        Err(e) => {
            for &i in indices {
                results[i] = Some(Err(e.clone()));
            }
        }
        Ok(Some((a, b))) => {
            for (k, &i) in indices.iter().enumerate() {
                let cost = if k == 0 { *full } else { *follower };
                let value = AdraEngine::digital_value(&ops[i], a, b, wb)
                    .expect("only dual-row ops are fused");
                results[i] = Some(Ok(CimResult { value, cost }));
            }
        }
        Ok(None) => {
            for (k, &i) in indices.iter().enumerate() {
                let cost = if k == 0 { *full } else { *follower };
                results[i] = Some(Ok(derive(&ops[i], engine.last_sense(), cost)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::{CimOp, WordAddr};
    use crate::config::{SensingScheme, SimConfig};
    use crate::util::quick::{Arbitrary, Quick};
    use crate::util::rng::Rng;
    use crate::workload::{OpMix, WorkloadGen};

    fn cfg() -> SimConfig {
        let mut c = SimConfig::square(64, SensingScheme::Current);
        c.word_bits = 8;
        c
    }

    #[test]
    fn same_pair_ops_fuse_to_one_activation() {
        let ops = vec![
            CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
            CimOp::Add { row_a: 0, row_b: 1, word: 0 },
            CimOp::Compare { row_a: 0, row_b: 1, word: 0 },
            CimOp::Read2 { row_a: 0, row_b: 1, word: 0 },
        ];
        let plan = fuse_batch(&ops);
        assert_eq!(planned_activations(&plan), 1);
    }

    #[test]
    fn write_breaks_fusion() {
        let ops = vec![
            CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
            CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 9 },
            CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
        ];
        let plan = fuse_batch(&ops);
        assert_eq!(planned_activations(&plan), 2, "write must split the group");
    }

    #[test]
    fn unrelated_write_does_not_break_fusion() {
        let ops = vec![
            CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
            CimOp::Write { addr: WordAddr { row: 5, word: 0 }, value: 9 },
            CimOp::Add { row_a: 0, row_b: 1, word: 0 },
        ];
        assert_eq!(planned_activations(&fuse_batch(&ops)), 1);
    }

    #[test]
    fn fused_execution_matches_unfused() {
        let cfg = cfg();
        let mut fused_engine = AdraEngine::new(&cfg);
        let mut plain_engine = AdraEngine::new(&cfg);
        let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), 42);
        let ops = gen.batch(400);
        let fused = execute_fused(&mut fused_engine, &ops);
        for (op, got) in ops.iter().zip(&fused) {
            let want = plain_engine.execute(op);
            match (got, want) {
                (Ok(g), Ok(w)) => assert_eq!(g.value, w.value, "op {op:?}"),
                (Err(_), Err(_)) => {}
                (g, w) => panic!("fusion divergence on {op:?}: {g:?} vs {w:?}"),
            }
        }
    }

    #[test]
    fn fusion_saves_activations_and_energy() {
        let cfg = cfg();
        let mut e1 = AdraEngine::new(&cfg);
        let mut e2 = AdraEngine::new(&cfg);
        // a hot operand pair queried many ways (the database-filter inner
        // loop does exactly this)
        let mut ops = vec![
            CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 99 },
            CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 45 },
        ];
        for _ in 0..8 {
            ops.push(CimOp::Sub { row_a: 0, row_b: 1, word: 0 });
            ops.push(CimOp::Compare { row_a: 0, row_b: 1, word: 0 });
        }
        e1.array_mut().reset_stats();
        let fused = execute_fused(&mut e1, &ops);
        let fused_activations = e1.array().stats().dual_activations;
        let fused_energy: f64 = fused
            .iter()
            .map(|r| r.as_ref().unwrap().cost.energy.total())
            .sum();

        e2.array_mut().reset_stats();
        let mut plain_energy = 0.0;
        for op in &ops {
            plain_energy += e2.execute(op).unwrap().cost.energy.total();
        }
        let plain_activations = e2.array().stats().dual_activations;

        assert_eq!(fused_activations, 1, "16 dual ops, one activation");
        assert_eq!(plain_activations, 16);
        assert!(
            fused_energy < 0.25 * plain_energy,
            "fused {fused_energy:e} vs plain {plain_energy:e}"
        );
    }

    /// The pair-batch planes reuse must be host-side only: a multi-word
    /// run on one row pair produces the same values, charged costs, AND
    /// array stats as the per-group analog datapath.
    #[test]
    fn pair_batched_words_match_per_group_execution() {
        let cfg = cfg(); // 64 cols x 8-bit words
        let mut lut_cfg = cfg.clone();
        lut_cfg.tier = crate::config::FidelityTier::Lut;
        let mut ops = Vec::new();
        for w in 0..4 {
            ops.push(CimOp::Write { addr: WordAddr { row: 0, word: w }, value: 40 + w as u64 });
            ops.push(CimOp::Write { addr: WordAddr { row: 1, word: w }, value: 90 + w as u64 });
        }
        for w in 0..4 {
            ops.push(CimOp::Sub { row_a: 0, row_b: 1, word: w });
            ops.push(CimOp::Compare { row_a: 0, row_b: 1, word: w });
            ops.push(CimOp::Add { row_a: 0, row_b: 1, word: w });
        }
        let mut digital = AdraEngine::new(&cfg);
        let mut lut = AdraEngine::new(&lut_cfg);
        assert!(digital.digital_active() && !lut.digital_active());
        let rd = execute_fused(&mut digital, &ops);
        let rl = execute_fused(&mut lut, &ops);
        for (i, (d, l)) in rd.iter().zip(&rl).enumerate() {
            let (d, l) = (d.as_ref().unwrap(), l.as_ref().unwrap());
            assert_eq!(d.value, l.value, "op {i}");
            assert_eq!(d.cost, l.cost, "op {i}: batching must not change charges");
        }
        let sd = digital.array().stats();
        let sl = lut.array().stats();
        assert_eq!(sd.dual_activations, 4, "one activation per word group");
        assert_eq!(sd.dual_activations, sl.dual_activations);
        assert_eq!(sd.half_selected_cols, sl.half_selected_cols);
        assert_eq!(sd.digital_activations, 4, "all groups served packed");
    }

    /// Planes cached for a pair batch must be refilled once a write to a
    /// batch row lands — the second group sees the new contents.
    #[test]
    fn write_between_groups_refills_planes() {
        let cfg = cfg();
        let mut e = AdraEngine::new(&cfg);
        let ops = vec![
            CimOp::Write { addr: WordAddr { row: 0, word: 0 }, value: 9 },
            CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 4 },
            CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
            CimOp::Write { addr: WordAddr { row: 1, word: 0 }, value: 7 },
            CimOp::Sub { row_a: 0, row_b: 1, word: 0 },
        ];
        let rs = execute_fused(&mut e, &ops);
        assert_eq!(rs[2].as_ref().unwrap().value, crate::cim::CimValue::Diff(5));
        assert_eq!(rs[4].as_ref().unwrap().value, crate::cim::CimValue::Diff(2));
        assert_eq!(e.array().stats().dual_activations, 2);
    }

    /// Property: random batches — fused == unfused values, and fused
    /// activations <= unfused activations.
    #[derive(Clone, Debug)]
    struct Seed(u64);

    impl Arbitrary for Seed {
        fn generate(rng: &mut Rng) -> Self {
            Seed(rng.next_u64())
        }
    }

    #[test]
    fn prop_fusion_equivalence() {
        let cfg = cfg();
        Quick::with_cases(30).check::<Seed, _>("fused == unfused", |s| {
            let mut gen = WorkloadGen::new(&cfg, OpMix::balanced(), s.0);
            let ops = gen.batch(80);
            let mut ef = AdraEngine::new(&cfg);
            let mut ep = AdraEngine::new(&cfg);
            let fused = execute_fused(&mut ef, &ops);
            for (op, got) in ops.iter().zip(&fused) {
                let want = ep.execute(op);
                let agree = match (got, &want) {
                    (Ok(g), Ok(w)) => g.value == w.value,
                    (Err(_), Err(_)) => true,
                    _ => false,
                };
                if !agree {
                    return false;
                }
            }
            ef.array().stats().dual_activations <= ep.array().stats().dual_activations
        });
    }
}
